"""Heterogeneous pools, spot preemption, and multi-region serving.

The tentpole claims: a single-pool fleet is indistinguishable from the
flat cluster it replaces, hardware-aware routers favor cheap/fast
pools, spot pools bill spot rates and their kills never lose requests
(the concrete twin of
``test_simulator_invariants.test_drain_to_zero_under_spot_kills``),
cross-region hops pay RTT and are accounted, and the planner can
recommend a mixed/spot-backed fleet on ``cost_per_goodput``.
"""
import dataclasses

import pytest

from repro import hw
from repro.calibrate.planner import plan_capacity, simulate_candidate
from repro.configs import get_config
from repro.serving.batching import make_policy
from repro.serving.cluster import (ClusterSpec, PoolSpec, make_router,
                                   simulate_cluster)
from repro.serving.latency_model import (LatencyModel,
                                         oracle_for_hardware)
from repro.serving.workload import WorkloadSpec

from invariant_checks import (check_busy_bound, check_drain_under_kills,
                              check_duration_covers_window,
                              check_event_budget, run_fleet_sim)


@pytest.fixture(scope="module")
def lat():
    return LatencyModel(get_config("gemma2-2b"), chips=4)


def _wl(**kw):
    base = dict(rate=120, duration_s=2, prompt_tokens=128,
                output_tokens=4, output_tokens_max=16, seed=3)
    base.update(kw)
    return WorkloadSpec(**base)


def _continuous():
    return make_policy("continuous", max_batch=16, max_prefill=8)


class TestPoolSpecValidation:
    def test_rejects_zero_replicas(self):
        with pytest.raises(ValueError):
            PoolSpec(replicas=0)

    def test_rejects_unknown_hardware(self):
        with pytest.raises(ValueError):
            PoolSpec(hardware="quantum-annealer")

    def test_rejects_unknown_pricing(self):
        with pytest.raises(ValueError):
            PoolSpec(pricing="preemptible")

    def test_rejects_preemption_on_reserved_pool(self):
        with pytest.raises(ValueError):
            PoolSpec(pricing="reserved", preempt_mtbf_s=30.0)

    def test_rejects_bounds_excluding_replicas(self):
        with pytest.raises(ValueError):
            PoolSpec(replicas=4, min_replicas=1, max_replicas=2)

    def test_bounds_default_to_static(self):
        assert PoolSpec(replicas=3).bounds() == (3, 3)
        assert PoolSpec(replicas=3, min_replicas=1,
                        max_replicas=5).bounds() == (1, 5)

    def test_cluster_rejects_pools_plus_disagg_or_autoscale(self):
        pools = (PoolSpec(replicas=1),)
        with pytest.raises(ValueError):
            ClusterSpec(pools=pools, autoscale=True)
        with pytest.raises(ValueError):
            ClusterSpec(pools=())

    def test_cluster_coerces_pool_dicts(self):
        c = ClusterSpec(pools=({"name": "a", "replicas": 2},))
        assert isinstance(c.pools[0], PoolSpec)
        assert c.pools[0].replicas == 2


class TestSinglePoolEquivalence:
    def test_one_pool_fleet_matches_flat_cluster(self, lat):
        """A fleet of one reserved base-hardware pool must serve exactly
        what the flat cluster serves — same traces, same summary."""
        wl = _wl()
        flat = simulate_cluster(wl, _continuous(), lat,
                                cluster=ClusterSpec(replicas=2,
                                                    router="least-loaded"))
        fleet = simulate_cluster(
            wl, _continuous(), lat,
            cluster=ClusterSpec(pools=(PoolSpec(name="serve", replicas=2),),
                                router="least-loaded"))
        flat_tr = sorted(dataclasses.astuple(t) for t in flat.traces)
        fleet_tr = sorted(dataclasses.astuple(t) for t in fleet.traces)
        assert flat_tr == fleet_tr
        fs, ss = flat.summary(), fleet.summary()
        for k in fs:
            assert fs[k] == pytest.approx(ss[k]), f"summary[{k}] diverged"

    def test_one_pool_fleet_reports_fleet_block(self, lat):
        res = simulate_cluster(
            _wl(), _continuous(), lat,
            cluster=ClusterSpec(pools=(PoolSpec(replicas=2),)))
        assert res.fleet is not None
        assert len(res.fleet["pools"]) == 1
        assert res.fleet["spot_preemptions"] == 0
        assert res.fleet["cross_region_fraction"] == 0.0


class TestHardwareAwareRouting:
    def test_cost_weighted_prefers_cheap_pool(self, lat):
        """At low load the cost-weighted router should send most traffic
        to the cheaper t4 pool."""
        res = simulate_cluster(
            _wl(rate=60), _continuous(), lat,
            cluster=ClusterSpec(pools=(
                PoolSpec(name="v5e", replicas=2),
                PoolSpec(name="t4", hardware="t4", replicas=2)),
                router="cost-weighted"))
        by_pool = {p["name"]: p for p in res.fleet["pools"]}
        assert by_pool["t4"]["busy_s"] > by_pool["v5e"]["busy_s"]

    def test_fastest_ttft_prefers_fast_pool(self, lat):
        """The fastest-TTFT router should keep traffic on the v5e pool
        even though the t4 pool is cheaper."""
        res = simulate_cluster(
            _wl(rate=60), _continuous(), lat,
            cluster=ClusterSpec(pools=(
                PoolSpec(name="v5e", replicas=2),
                PoolSpec(name="t4", hardware="t4", replicas=2)),
                router="fastest-ttft"))
        by_pool = {p["name"]: p for p in res.fleet["pools"]}
        assert by_pool["v5e"]["busy_s"] > by_pool["t4"]["busy_s"]

    def test_router_aliases(self):
        for alias in ("cost-weighted", "cost_weighted", "cost"):
            assert make_router(alias).name == "cost-weighted"
        for alias in ("fastest-ttft", "fastest_ttft", "ttft"):
            assert make_router(alias).name == "fastest-ttft"

    def test_oracle_retarget(self, lat):
        t4 = oracle_for_hardware(lat, "t4")
        assert t4.hw.name == "t4"
        assert oracle_for_hardware(lat) is lat
        # t4 is slower than v5e at equal batch/seq
        assert t4.prefill_latency(1, 256) > lat.prefill_latency(1, 256)


class TestSpotPreemption:
    def test_drain_under_kills_concrete(self):
        """Concrete twin of the hypothesis drain-to-zero property."""
        for seed in (0, 7):
            wl = _wl(duration_s=1.0, seed=seed)
            res = run_fleet_sim(wl, mtbf_s=0.3, seed=seed)
            check_drain_under_kills(wl, res)
            check_busy_bound(res)
            check_duration_covers_window(wl, res)
            check_event_budget(res)

    def test_kills_actually_fire_and_are_counted(self):
        res = run_fleet_sim(_wl(duration_s=1.0), mtbf_s=0.2, seed=0)
        assert res.fleet["spot_preemptions"] > 0
        assert res.fleet["spot_killed_requests"] > 0
        assert any(t.spot_evictions > 0 for t in res.traces)

    def test_spot_bills_below_reserved(self, lat):
        wl = _wl()
        def run(pricing, mtbf):
            return simulate_cluster(
                wl, _continuous(), lat,
                cluster=ClusterSpec(pools=(
                    PoolSpec(name="p", replicas=2, pricing=pricing,
                             preempt_mtbf_s=mtbf),),
                    router="least-loaded"))
        reserved = run("reserved", 0.0)
        spot = run("spot", 1e9)  # spot rates, no kills in the window
        assert spot.cost_usd() < reserved.cost_usd()
        ratio = spot.cost_usd() / reserved.cost_usd()
        expect = (hw.cloud_rate_usd_per_hour("tpu-v5e", pricing="spot")
                  / hw.cloud_rate_usd_per_hour("tpu-v5e"))
        assert ratio == pytest.approx(expect, rel=1e-6)

    def test_spot_requires_continuous_batching(self, lat):
        with pytest.raises(ValueError):
            simulate_cluster(
                _wl(), make_policy("tfs", max_batch=8, timeout_s=0.004),
                lat,
                cluster=ClusterSpec(pools=(
                    PoolSpec(replicas=1, pricing="spot",
                             preempt_mtbf_s=1.0),)))

    def test_goodput_loss_bounded_by_goodput(self):
        res = run_fleet_sim(_wl(duration_s=1.0), mtbf_s=0.3, seed=1)
        loss = res.preemption_goodput_loss(e2e_slo_s=0.05)
        gp = res.goodput(e2e_slo_s=0.05)
        assert 0.0 <= loss
        assert loss <= gp + res.fleet["spot_killed_requests"] / res.duration_s


class TestMultiRegion:
    def _two_region(self, lat, wl, router="cost-weighted"):
        return simulate_cluster(
            wl, _continuous(), lat,
            cluster=ClusterSpec(pools=(
                PoolSpec(name="us", replicas=1, region="us-east"),
                PoolSpec(name="eu", hardware="t4", replicas=2,
                         region="eu-west")),
                router=router))

    def test_cross_region_fraction_accounted(self, lat):
        res = self._two_region(lat, _wl(rate=60))
        frac = res.fleet["cross_region_fraction"]
        assert 0.0 < frac <= 1.0
        # the cheap pool is overseas, so cost-weighted routing crosses
        assert frac > 0.5

    def test_cross_region_hops_pay_rtt(self, lat):
        """Requests served overseas carry strictly more transmit time
        than the same workload served single-region."""
        wl = _wl(rate=60)
        two = self._two_region(lat, wl)
        one = simulate_cluster(
            wl, _continuous(), lat,
            cluster=ClusterSpec(pools=(
                PoolSpec(name="us", replicas=1, region="us-east"),
                PoolSpec(name="us2", hardware="t4", replicas=2,
                         region="us-east")),
                router="cost-weighted"))
        t_two = sum(t.t_transmit for t in two.traces)
        t_one = sum(t.t_transmit for t in one.traces)
        assert t_two > t_one
        assert one.fleet["cross_region_fraction"] == 0.0

    def test_regionless_pools_are_colocated(self, lat):
        res = simulate_cluster(
            _wl(rate=60), _continuous(), lat,
            cluster=ClusterSpec(pools=(
                PoolSpec(name="a", replicas=1),
                PoolSpec(name="b", hardware="t4", replicas=1)),
                router="round-robin"))
        assert res.fleet["cross_region_fraction"] == 0.0


class TestPerPoolAutoscale:
    def test_spot_pool_scales_within_bounds(self, lat):
        res = simulate_cluster(
            _wl(kind="burst", rate=300, burst_factor=8.0, duration_s=2),
            _continuous(), lat,
            cluster=ClusterSpec(pools=(
                PoolSpec(name="base", replicas=1),
                PoolSpec(name="flex", replicas=1, min_replicas=1,
                         max_replicas=3)),
                router="least-loaded"))
        flex = next(p for p in res.fleet["pools"] if p["name"] == "flex")
        base = next(p for p in res.fleet["pools"] if p["name"] == "base")
        assert base["replicas"] == 1
        assert 1 <= flex["replicas"] <= 3


class TestFleetPlanning:
    def test_planner_recommends_spot_fleet(self, lat):
        wl = _wl(duration_s=3, seed=21, output_tokens=8,
                 output_tokens_max=32)
        mixed = ({"name": "v5e", "replicas": 2},
                 {"name": "t4", "hardware": "t4", "replicas": 2})
        spot = ({"name": "v5e", "replicas": 2},
                {"name": "t4", "hardware": "t4", "replicas": 2,
                 "pricing": "spot", "preempt_mtbf_s": 2.0})
        plan = plan_capacity(
            lat, wl, slo_latency_s=0.4, slo_target=0.9,
            replicas=(3,), policies=("continuous",),
            routers=("cost-weighted",), objective="cost_per_goodput",
            fleets=(mixed, spot))
        best = plan.best
        assert best is not None and best.fleet is not None
        assert any(p["pricing"] == "spot" for p in best.fleet)
        flat = [c for c in plan.candidates if c.fleet is None]
        assert all(best.objective <= c.objective for c in flat
                   if c.meets_slo)
        # winner survives independent re-simulation
        res = simulate_candidate(lat, wl, best)
        assert res.slo_attainment(0.4) >= 0.9
        assert res.fleet is not None

    def test_plan_candidate_fleet_round_trips(self, lat):
        """PlanCandidate.fleet is plain dicts (JSON-able) and rebuilds
        the same ClusterSpec."""
        spot = ({"name": "v5e", "replicas": 1},
                {"name": "t4", "hardware": "t4", "replicas": 1,
                 "pricing": "spot", "preempt_mtbf_s": 5.0})
        plan = plan_capacity(
            lat, _wl(duration_s=1), slo_latency_s=0.5, slo_target=0.5,
            replicas=(), policies=("continuous",),
            routers=("cost-weighted",), fleets=(spot,))
        (cand,) = plan.candidates
        assert all(isinstance(p, dict) for p in cand.fleet)
        c = ClusterSpec(pools=cand.fleet, router=cand.router)
        assert all(isinstance(p, PoolSpec) for p in c.pools)
