"""Observability layer (repro.obs): span timelines, the time-series
recorder, and the HTML run report.

The load-bearing invariants:
  * observability is opt-in and never moves a simulated number — the
    obs-on summary is identical to the obs-off summary (which the golden
    tests in test_fastpath guard byte-for-byte);
  * the counters reconcile exactly with SimResult aggregates
    (completions == requests_served; live-replica step integral ==
    replica_seconds for fixed clusters);
  * the Chrome-trace export reconciles with the RequestTraces it was
    built from (span durations re-derive the per-stage accounting);
  * the report is a dependency-free artifact a browser can open.
"""
import dataclasses
import json

import pytest

from repro.configs import get_config
from repro.core import BenchmarkJobSpec, ModelRef, PerfDB, run_stages
from repro.core.spec import ClusterSpec as CoreClusterSpec
from repro.obs import (MetricsRecorder, ObsSpec, Timeseries, build_trace,
                       render_report, request_stage_spans, write_report,
                       write_trace)
from repro.obs.report import load_records, main as report_main
from repro.obs.timeline import US
from repro.serving.batching import make_policy
from repro.serving.cluster import ClusterSpec, simulate_cluster
from repro.serving.latency_model import LatencyModel
from repro.serving.workload import WorkloadSpec


@pytest.fixture(scope="module")
def lat():
    return LatencyModel(get_config("gemma2-2b"), chips=4)


FLASH = WorkloadSpec(kind="flash-crowd", rate=150.0, duration_s=4.0,
                     burst_factor=10.0, output_tokens=16, seed=7)
CLUSTER = ClusterSpec(replicas=2, router="least-loaded")


def _policy():
    return make_policy("continuous", max_batch=8, max_prefill=4)


@pytest.fixture(scope="module")
def flash_obs(lat):
    return simulate_cluster(FLASH, _policy(), lat,
                            cluster=dataclasses.replace(
                                CLUSTER, obs=ObsSpec()))


# ---- ObsSpec ----------------------------------------------------------------
class TestObsSpec:
    def test_defaults_and_roundtrip(self):
        spec = ObsSpec()
        assert spec.enabled and spec.timeseries and spec.timeline
        back = ObsSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
        assert back == spec

    def test_disabled_when_both_layers_off(self):
        assert not ObsSpec(timeseries=False, timeline=False).enabled

    def test_negative_interval_rejected(self):
        with pytest.raises(ValueError):
            ObsSpec(sample_interval_s=-0.1)

    def test_resolve_interval(self):
        assert ObsSpec(sample_interval_s=0.25).resolve_interval(10.0) \
            == 0.25
        # auto: window / AUTO_TICKS
        assert ObsSpec().resolve_interval(10.0) == pytest.approx(0.05)
        # no window (trace replay): the fixed default
        assert ObsSpec().resolve_interval(0.0) > 0

    def test_job_spec_merge_is_idempotent(self):
        spec = BenchmarkJobSpec(
            job_id="o", model=ModelRef(name="gemma2-2b"), chips=4,
            workload=WorkloadSpec(rate=50, duration_s=1, seed=0),
            cluster=CoreClusterSpec(replicas=2),
            obs=ObsSpec(timeline=False))
        assert spec.cluster.obs == ObsSpec(timeline=False)
        back = BenchmarkJobSpec.from_dict(spec.to_dict())
        assert back == spec


# ---- the recorder never moves a simulated number ---------------------------
class TestNoBehaviorChange:
    def test_summary_identical_obs_on_vs_off(self, lat, flash_obs):
        res_off = simulate_cluster(FLASH, _policy(), lat, cluster=CLUSTER)
        assert flash_obs.summary() == res_off.summary()
        assert res_off.timeseries is None
        assert res_off.engine_spans is None

    def test_default_path_has_no_recorder(self, lat):
        wl = WorkloadSpec(rate=40, duration_s=1, seed=3)
        res = simulate_cluster(wl, _policy(), lat, cluster=CLUSTER)
        assert res.timeseries is None and res.engine_spans is None


# ---- counter / gauge reconciliation ----------------------------------------
class TestTimeseries:
    def test_completions_counter_matches_served(self, flash_obs):
        ts = flash_obs.timeseries
        served = flash_obs.requests_served or len(flash_obs.traces)
        assert ts.counter_total("completions") == served
        assert ts.counter_total("arrivals") == served

    def test_counters_monotone(self, flash_obs):
        for name in ("arrivals", "completions", "preemptions"):
            c = flash_obs.timeseries.counter(name)
            assert all(a <= b for a, b in zip(c, c[1:])), name

    def test_live_replica_integral_matches_replica_seconds(self, flash_obs):
        ts = flash_obs.timeseries
        assert ts.live_replica_integral() \
            == pytest.approx(flash_obs.replica_seconds, rel=1e-6)

    def test_queue_depth_spikes_and_drains(self, flash_obs):
        """The flash crowd must be visible in the queue-depth series:
        a spike well above the pre-spike baseline, drained by the end."""
        q = flash_obs.timeseries.total("queue_depth")
        t = flash_obs.timeseries.times
        pre = [v for v, tt in zip(q, t) if tt < FLASH.duration_s / 3]
        assert max(q) >= max(pre) + 4, "no visible queue spike"
        assert q[-1] == 0.0, "queue did not drain by the end"

    def test_column_alignment_and_grid(self, flash_obs):
        ts = flash_obs.timeseries
        n = len(ts.times)
        assert n > 50
        assert len(ts.live_replicas) == n
        for series in ts.gauges.values():
            for col in series.values():
                assert len(col) == n
        for c in ts.counters.values():
            assert len(c) == n
        assert ts.times == sorted(ts.times)
        assert ts.times[-1] == pytest.approx(flash_obs.duration_s)

    def test_roundtrip(self, flash_obs):
        ts = flash_obs.timeseries
        back = Timeseries.from_dict(json.loads(json.dumps(ts.to_dict())))
        assert back.times == ts.times
        assert back.gauges == ts.gauges
        assert back.counters == ts.counters
        assert back.counter_total("completions") \
            == ts.counter_total("completions")

    def test_tenant_counter_slicing(self, lat):
        wl = WorkloadSpec(rate=80, duration_s=2, seed=1, tenants=(
            {"name": "a", "share": 0.5}, {"name": "b", "share": 0.5}))
        res = simulate_cluster(wl, _policy(), lat,
                               cluster=dataclasses.replace(
                                   CLUSTER, obs=ObsSpec(timeline=False)))
        ts = res.timeseries
        assert set(ts.tenants()) == {"a", "b"}
        total = sum(ts.counter_total("completions", tenant=t)
                    for t in ts.tenants())
        assert total == res.requests_served or total == len(res.traces)

    def test_rate_is_per_second(self):
        ts = Timeseries(interval_s=1.0, times=[1.0, 2.0, 3.0],
                        live_replicas=[1, 1, 1], gauges={},
                        counters={"arrivals": [2, 6, 6]},
                        tenant_counters={}, replica_pool={})
        assert ts.rate("arrivals") == [2.0, 4.0, 0.0]


# ---- Chrome-trace timeline --------------------------------------------------
class TestTimeline:
    def test_trace_schema(self, flash_obs):
        trace = build_trace(flash_obs)
        events = trace["traceEvents"]
        assert events, "empty trace"
        dur_us = flash_obs.duration_s * US
        for ev in events:
            assert ev["ph"] in ("X", "C", "M")
            if ev["ph"] == "X":
                assert ev["dur"] >= 0
                assert 0 <= ev["ts"] <= dur_us + 1
                assert ev["ts"] + ev["dur"] <= dur_us + 1
                assert isinstance(ev["pid"], int) and ev["pid"] >= 1
                assert isinstance(ev["tid"], int) and ev["tid"] >= 0

    def test_engine_lanes_present(self, flash_obs):
        trace = build_trace(flash_obs)
        engine = [e for e in trace["traceEvents"]
                  if e["ph"] == "X" and e["tid"] == 0]
        assert engine, "no engine activity spans"
        assert {e["pid"] for e in engine} <= {1, 2}

    def test_spans_reconcile_with_request_traces(self, flash_obs):
        """prefill + decode re-derive t_inference exactly for requests
        that were never preempted or migrated."""
        for tr in flash_obs.traces:
            if tr.preemptions or tr.t_kv_transfer:
                continue
            spans = dict((n, e - s)
                         for n, s, e in request_stage_spans(tr))
            if "prefill" in spans and "decode" in spans:
                assert spans["prefill"] + spans["decode"] \
                    == pytest.approx(tr.t_inference, abs=1e-9)
            for name, s, e in request_stage_spans(tr):
                assert e >= s, (name, s, e)

    def test_write_trace_is_perfetto_loadable_json(self, flash_obs,
                                                   tmp_path):
        p = tmp_path / "trace.json"
        write_trace(flash_obs, p)
        loaded = json.loads(p.read_text())
        assert "traceEvents" in loaded
        assert loaded["metadata"]["requests_served"] \
            == (flash_obs.requests_served or len(flash_obs.traces))

    def test_sampling_rate_counter_track(self, lat):
        res = simulate_cluster(FLASH, _policy(), lat,
                               cluster=dataclasses.replace(
                                   CLUSTER, obs=ObsSpec()),
                               trace_sample=0.25)
        trace = build_trace(res)
        counters = [e for e in trace["traceEvents"] if e["ph"] == "C"
                    and e["name"] == "sampling_rate"]
        assert counters, "no sampling_rate counter track"
        # the hash-sample keeps *about* the requested fraction; the
        # metadata reports the realized rate
        assert 0.1 < trace["metadata"]["sampling_rate"] < 0.5
        # request lanes only exist for the kept sample
        req_lanes = {e["tid"] for e in trace["traceEvents"]
                     if e["ph"] == "X" and e["tid"] > 0}
        assert len(req_lanes) < res.requests_served


# ---- session plumbing -------------------------------------------------------
class TestSessionPlumbing:
    SPEC = BenchmarkJobSpec(
        job_id="obs-e2e", model=ModelRef(name="gemma2-2b"), chips=4,
        workload=WorkloadSpec(kind="flash-crowd", rate=60, duration_s=3,
                              burst_factor=5.0, output_tokens=8, seed=7),
        cluster=CoreClusterSpec(replicas=2),
        obs=ObsSpec())

    def test_provenance_metrics_always_recorded(self):
        plain = dataclasses.replace(self.SPEC, obs=None,
                                    cluster=CoreClusterSpec(replicas=2))
        res = run_stages(plain)
        assert res.metrics["events"] > 0
        assert res.metrics["requests_served"] > 0
        assert res.metrics["sim_events_per_sec"] > 0
        assert res.timeseries is None

    def test_timeseries_survives_perfdb_roundtrip(self, tmp_path):
        res = run_stages(self.SPEC)
        assert res.timeseries is not None
        db = PerfDB(tmp_path / "perf.jsonl")
        db.append(res.to_record())
        rec = db.all()[-1]
        ts = Timeseries.from_dict(rec["timeseries"])
        assert ts.counter_total("completions") \
            == rec["result"]["requests_served"]


# ---- HTML report ------------------------------------------------------------
class TestReport:
    def _records(self):
        return [run_stages(TestSessionPlumbing.SPEC).to_record()]

    def test_render_report_standalone_html(self):
        html = render_report(self._records(), title="flash crowd")
        assert html.startswith("<!DOCTYPE html>")
        assert "<svg" in html and "Queue depth" in html
        assert "flash crowd" in html
        # dependency-free: no external fetches of any kind
        assert "http://" not in html and "https://" not in html
        assert "<script" not in html

    def test_report_warns_on_sampled_traces(self):
        rec = self._records()[0]
        rec["result"]["sampling_rate"] = 0.1
        html = render_report([rec])
        assert "sampl" in html.lower()

    def test_cli(self, tmp_path):
        db = tmp_path / "perf.jsonl"
        with db.open("w") as f:
            f.write(json.dumps(self._records()[0]) + "\n")
        out = tmp_path / "report.html"
        rc = report_main([str(db), "-o", str(out),
                          "--baseline",
                          "benchmarks/baselines/ci_baseline.json"])
        assert rc == 0
        html = out.read_text()
        assert "<svg" in html and "Baseline deltas" in html
        assert load_records(db)


# ---- recorder unit behavior -------------------------------------------------
class TestRecorderUnit:
    class _Engine:
        def __init__(self, rid):
            self.replica_id = rid
            self.queue = [1, 2]
            self.active = {}
            self.kv = None
            self.retired = False
            self.continuous = True
            self.server_free_at = 0.0

    def test_midrun_replica_zero_padded(self):
        rec = MetricsRecorder(ObsSpec(timeline=False), interval_s=0.1)
        e0 = self._Engine(0)
        rec.register_engine(0, "serve")
        rec.sample_ticks(0.35, [e0])            # ticks 0.0/0.1/0.2/0.3
        e1 = self._Engine(1)                    # spawned mid-run
        rec.register_engine(1, "serve")
        rec.finish(0.5, [e0, e1])
        ts = rec.build()
        col = ts.replica("queue_depth", 1)
        assert len(col) == len(ts.times)
        assert col[0] == 0.0 and col[-1] == 2.0

    def test_engine_span_noop_when_timeline_off(self):
        rec = MetricsRecorder(ObsSpec(timeline=False), interval_s=0.1)
        rec.engine_span(0, 0.0, 1.0, "iteration", 4)
        assert rec.spans == []
