"""Determinism + conservation guarantees of the workload generator and
the cluster simulator (no optional dependencies; always collected)."""
import dataclasses
from pathlib import Path

import pytest

from repro.configs import get_config
from repro.serving.batching import make_policy
from repro.serving.cluster import ClusterSpec, simulate_cluster
from repro.serving.latency_model import LatencyModel
from repro.serving.workload import KINDS, WorkloadSpec, generate

SAMPLE_TRACE = str(Path(__file__).resolve().parent.parent
                   / "configs" / "traces" / "sample.jsonl")


def _spec(kind: str, seed: int = 7) -> WorkloadSpec:
    return WorkloadSpec(
        kind=kind, rate=80, duration_s=2, output_tokens=2,
        output_tokens_max=6, concurrency=4, session_count=3,
        ramp_min_rate=20, ramp_max_rate=120, ramp_steps=3,
        trace_path=SAMPLE_TRACE if kind == "trace" else None, seed=seed)


class TestWorkloadDeterminism:
    @pytest.mark.parametrize("kind", KINDS)
    def test_identical_seed_identical_trace(self, kind):
        a, b = generate(_spec(kind)), generate(_spec(kind))
        assert a == b                       # Request is frozen: full equality
        # byte-identical serialized form
        assert ([dataclasses.astuple(r) for r in a]
                == [dataclasses.astuple(r) for r in b])

    @pytest.mark.parametrize("kind", ["poisson", "burst", "ramp"])
    def test_different_seed_different_trace(self, kind):
        assert generate(_spec(kind, seed=1)) != generate(_spec(kind, seed=2))

    def test_ramp_rates_increase(self):
        reqs = generate(_spec("ramp"))
        third = len(reqs) // 3
        first = [r for r in reqs if r.arrival_s < 2 / 3]
        last = [r for r in reqs if r.arrival_s >= 2 * 2 / 3]
        assert len(last) > len(first)       # stepped-up arrival rate
        assert third > 0

    def test_trace_replay_reads_columns(self):
        reqs = generate(_spec("trace"))
        assert len(reqs) == 16
        assert reqs[0].arrival_s == 0.0 and reqs[0].output_tokens == 16
        assert {r.session_id for r in reqs} == {0, 1, 2, 3}
        arrivals = [r.arrival_s for r in reqs]
        assert arrivals == sorted(arrivals)

    def test_trace_requires_path(self):
        with pytest.raises(ValueError):
            generate(WorkloadSpec(kind="trace"))


class TestSimulatorDeterminism:
    def setup_method(self):
        self.lat = LatencyModel(get_config("gemma2-2b"), chips=4)

    def _run(self, kind, policy_name, **cluster_kw):
        return simulate_cluster(_spec(kind), make_policy(policy_name),
                                self.lat,
                                cluster=ClusterSpec(**cluster_kw))

    @pytest.mark.parametrize("kind", ["poisson", "ramp", "trace", "closed"])
    def test_repeat_runs_byte_identical(self, kind):
        a = self._run(kind, "continuous", replicas=2, router="least-loaded")
        b = self._run(kind, "continuous", replicas=2, router="least-loaded")
        assert [dataclasses.astuple(t) for t in a.traces] \
            == [dataclasses.astuple(t) for t in b.traces]
        assert a.busy_s == b.busy_s and a.duration_s == b.duration_s
        assert a.summary() == b.summary()

    def test_cross_policy_conservation(self):
        """The same workload through all four policies serves the same
        request set (paper-grade harness validation)."""
        wl = _spec("poisson")
        expected = {r.req_id for r in generate(wl)}
        for name in ("none", "tfs", "tris", "continuous"):
            res = simulate_cluster(wl, make_policy(name), self.lat)
            served = sorted(t.request.req_id for t in res.traces)
            assert len(served) == len(expected)
            assert set(served) == expected, f"policy {name} lost requests"


class TestSpotPreemptionDeterminism:
    """Same preempt_seed ⇒ identical kill schedule, byte-identical
    results; different seeds ⇒ different schedules."""

    def setup_method(self):
        self.lat = LatencyModel(get_config("gemma2-2b"), chips=4)

    def _fleet(self, seed: int):
        from repro.serving.cluster import PoolSpec
        return ClusterSpec(
            router="least-loaded",
            pools=[
                PoolSpec(name="base", hardware="tpu-v5e", replicas=2),
                PoolSpec(name="spot", hardware="t4", replicas=2,
                         pricing="spot", preempt_mtbf_s=0.5),
            ],
            preempt_seed=seed)

    def _run(self, seed: int):
        return simulate_cluster(_spec("poisson"), make_policy("continuous"),
                                self.lat, cluster=self._fleet(seed))

    def test_same_seed_byte_identical(self):
        a, b = self._run(11), self._run(11)
        assert a.fleet["spot_preemptions"] > 0, \
            "mtbf=0.5s over a 2s window must land kills"
        assert a.fleet == b.fleet           # identical kill accounting
        assert [dataclasses.astuple(t) for t in a.traces] \
            == [dataclasses.astuple(t) for t in b.traces]
        assert a.summary() == b.summary()

    def test_kill_gap_stream_is_pure(self):
        from repro.serving.cluster import _kill_gap
        draws = [_kill_gap(11, s, d, 30.0)
                 for s in range(4) for d in range(4)]
        assert draws == [_kill_gap(11, s, d, 30.0)
                         for s in range(4) for d in range(4)]
        assert all(g > 0 for g in draws)
        # distinct (slot, draw) keys decorrelate
        assert len(set(draws)) == len(draws)

    def test_different_seed_different_schedule(self):
        from repro.serving.cluster import _kill_gap
        a = [_kill_gap(1, 0, d, 30.0) for d in range(8)]
        b = [_kill_gap(2, 0, d, 30.0) for d in range(8)]
        assert a != b

    def test_every_request_still_served_under_kills(self):
        wl = _spec("poisson")
        expected = {r.req_id for r in generate(wl)}
        res = simulate_cluster(wl, make_policy("continuous"), self.lat,
                               cluster=self._fleet(seed=3))
        assert {t.request.req_id for t in res.traces} == expected
        assert all(t.done_s > 0 for t in res.traces)
