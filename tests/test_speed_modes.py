"""Speed modes (int8 / speculative): mode algebra, oracle scaling,
memory-budget interaction, kernel-calibration plumbing, and the planner's
speed-mode axis (the quantized config must win KV-bound and lose
compute-bound)."""
import math

import pytest

from repro import hw as hw_lib
from repro.calibrate import (attach_kernel_calibration, derive_speed_modes,
                             fit_kernel_records, kernel_records,
                             kernel_registry, plan_capacity,
                             run_calibration_job, simulate_candidate)
from repro.calibrate.profile import CalibrationProfile
from repro.configs import get_config
from repro.core.spec import CalibrationSpec, ModelRef, PlanSpec
from repro.serving.cluster import ClusterSpec, simulate_cluster
from repro.serving.latency_model import (SPEED_MODES, FittedLatencyModel,
                                         LatencyModel, SpeedMode,
                                         apply_speed_mode,
                                         resolve_speed_mode)
from repro.serving.memory import MemorySpec, resolve_memory, scaled_memory_spec
from repro.serving.batching import ContinuousBatcher
from repro.serving.workload import WorkloadSpec

HW = hw_lib.HARDWARE["tpu-v5e"]


def roofline(chips=1, **kw):
    return LatencyModel(get_config("gemma2-2b"), hw=HW, chips=chips, **kw)


def fitted(**kw):
    return FittedLatencyModel(prefill_coef=(2e-3, 5e-6, 1.5e-8),
                              decode_coef=(1e-3, 2e-4, 3e-7), chips=1, **kw)


# ---- mode algebra -----------------------------------------------------------
def test_presets_and_resolution():
    assert set(SPEED_MODES) == {"fp16", "int8", "speculative"}
    assert resolve_speed_mode(None).is_identity
    assert resolve_speed_mode("fp16").is_identity
    int8 = resolve_speed_mode("int8")
    assert int8.kv_bytes_scale == 0.5 and int8.weight_bytes_scale == 0.5
    # dict / SpeedMode / override resolution
    custom = SpeedMode("int8", kv_bytes_scale=0.25)
    assert resolve_speed_mode(custom) is custom
    assert resolve_speed_mode({"name": "x", "compute_scale": 2.0}
                              ).compute_scale == 2.0
    got = resolve_speed_mode("int8", {"int8": custom.to_dict()})
    assert got.kv_bytes_scale == 0.25
    with pytest.raises(KeyError):
        resolve_speed_mode("fp4")
    with pytest.raises(TypeError):
        resolve_speed_mode(3.14)


def test_mode_round_trip_and_validation():
    mode = SpeedMode("spec", draft_len=4, acceptance_rate=0.7,
                     draft_cost_frac=0.3)
    assert SpeedMode.from_dict(mode.to_dict()) == mode
    with pytest.raises(ValueError):
        SpeedMode("bad", acceptance_rate=1.5)
    with pytest.raises(ValueError):
        SpeedMode("bad", kv_bytes_scale=0.0)
    with pytest.raises(ValueError):
        SpeedMode("bad", draft_len=-1)


def test_expected_tokens_and_cost_factor():
    vanilla = SpeedMode("fp16")
    assert vanilla.decode_cost_factor() == 1.0
    spec = SpeedMode("s", draft_len=4, acceptance_rate=1.0,
                     draft_cost_frac=1.0)
    # perfect acceptance at full draft cost: k+1 tokens for (1 + k) cost
    assert spec.expected_tokens_per_cycle() == pytest.approx(5.0)
    assert spec.decode_cost_factor() == pytest.approx(1.0)
    free = SpeedMode("s", draft_len=4, acceptance_rate=1.0,
                     draft_cost_frac=0.0)
    assert free.decode_cost_factor() == pytest.approx(1.0 / 5.0)
    # factor is strictly decreasing in acceptance rate
    factors = [SpeedMode("s", draft_len=4, acceptance_rate=a,
                         draft_cost_frac=0.3).decode_cost_factor()
               for a in (0.0, 0.25, 0.5, 0.75, 1.0)]
    assert all(a > b for a, b in zip(factors, factors[1:]))


# ---- oracle scaling ---------------------------------------------------------
def test_fp16_is_identity_passthrough():
    base = roofline()
    assert apply_speed_mode(base, "fp16") is base
    assert apply_speed_mode(base, None) is base


def test_speculative_unit_acceptance_reduces_to_vanilla_tpot():
    """acceptance=1.0 at draft_cost_frac=1.0 must reproduce vanilla decode
    *exactly* — bit-for-bit, not approximately."""
    unit = SpeedMode("spec1", draft_len=4, acceptance_rate=1.0,
                     draft_cost_frac=1.0)
    for base in (roofline(chips=4), fitted()):
        spec = apply_speed_mode(base, unit)
        for b, c in ((1, 128), (8, 1024), (32, 4096)):
            assert spec.decode_latency(b, c) == base.decode_latency(b, c)
            assert spec.prefill_latency(b, c) == base.prefill_latency(b, c)


def test_draft_len_zero_is_identity():
    mode = SpeedMode("noop", draft_len=0, acceptance_rate=0.9)
    assert mode.is_identity
    base = roofline()
    assert apply_speed_mode(base, mode) is base


def test_int8_halves_memory_footprint_and_speeds_memory_bound_decode():
    base = roofline(chips=4)
    int8 = apply_speed_mode(base, "int8")
    assert int8.kv_bytes_per_token() == pytest.approx(
        base.kv_bytes_per_token() / 2)
    assert int8.weight_bytes() == pytest.approx(base.weight_bytes() / 2)
    # decode at small batch is weight-read bound: halving bytes must help
    assert int8.decode_latency(1, 1024) < base.decode_latency(1, 1024)


def test_fitted_mode_mapping_scales_the_right_coefficients():
    base = fitted()
    int8 = base.with_speed_mode(resolve_speed_mode("int8"))
    p0, p1, p2 = base.prefill_coef
    d0, a, bta = base.decode_coef
    cs = 1.05
    assert int8.prefill_coef == pytest.approx((p0, p1 * cs, p2 * cs))
    assert int8.decode_coef == pytest.approx((d0 * 0.5, a * cs, bta * 0.5))
    assert int8.name.endswith("+int8")


def test_generic_wrapper_hides_absent_memory_hooks():
    """Oracles without kv_bytes_per_token must stay hook-less after
    wrapping, so memory resolution keeps treating them as profile-like.
    Without a roofline split the wrapper is conservative: int8 decode
    scales by compute_scale (never optimistically by the byte scale),
    while speculative decoding still pays off through the cost factor."""
    class Plain:
        def prefill_latency(self, b, s):
            return 1e-3 * b

        def decode_latency(self, b, c):
            return 1e-4 * b

    wrapped = apply_speed_mode(Plain(), "int8")
    assert getattr(wrapped, "kv_bytes_per_token", None) is None
    assert wrapped.prefill_latency(2, 64) == pytest.approx(2e-3 * 1.05)
    assert wrapped.decode_latency(2, 64) == pytest.approx(2e-4 * 1.05)
    free = SpeedMode("s", draft_len=4, acceptance_rate=1.0,
                     draft_cost_frac=0.0)
    spec = apply_speed_mode(Plain(), free)
    assert spec.decode_latency(2, 64) == pytest.approx(2e-4 / 5.0)


# ---- memory invariant -------------------------------------------------------
def test_int8_strictly_increases_max_feasible_batch():
    """Under a fixed HBM budget, int8's half-size KV entries must admit a
    strictly larger max feasible batch at every context length."""
    base = roofline()
    spec = MemorySpec(hbm_gb=2.0)
    fp16_mem = resolve_memory(spec, base)
    int8_mode = resolve_speed_mode("int8")
    int8_mem = resolve_memory(scaled_memory_spec(spec, int8_mode) or spec,
                              apply_speed_mode(base, int8_mode))
    assert int8_mem.total_blocks > fp16_mem.total_blocks
    for ctx in (512, 2048, 8192):
        tokens_per_req = ctx
        fp16_batch = fp16_mem.total_blocks * spec.block_tokens \
            // tokens_per_req
        int8_batch = int8_mem.total_blocks * spec.block_tokens \
            // tokens_per_req
        assert int8_batch > fp16_batch


def test_scaled_memory_spec_only_rescales_explicit_bytes():
    int8 = resolve_speed_mode("int8")
    assert scaled_memory_spec(None, int8) is None
    derived = MemorySpec(hbm_gb=2.0)      # kv bytes derived from oracle
    assert scaled_memory_spec(derived, int8) is derived
    explicit = MemorySpec(hbm_gb=2.0, kv_bytes_per_token=4096.0)
    scaled = scaled_memory_spec(explicit, int8)
    assert scaled.kv_bytes_per_token == pytest.approx(2048.0)


# ---- goodput monotonicity ---------------------------------------------------
def test_acceptance_rate_sweep_is_monotone_in_goodput():
    """Higher draft acceptance → cheaper effective decode → goodput under
    a TPOT SLO must be non-decreasing, and strictly better end-to-end."""
    base = roofline(chips=4)
    wl = WorkloadSpec(rate=6.0, duration_s=12.0, prompt_tokens=256,
                      output_tokens=128)
    rates = (0.2, 0.6, 1.0)
    # SLO pinned between the slowest and fastest mode's decode cost so
    # the sweep actually separates: mid-acceptance TPOT at a busy batch
    mid = apply_speed_mode(base, SpeedMode("s", draft_len=4,
                                           acceptance_rate=rates[1],
                                           draft_cost_frac=0.3))
    tpot_slo = mid.decode_latency(8, 384) * 1.05
    goodputs = []
    for a in rates:
        mode = SpeedMode(f"spec{a}", draft_len=4, acceptance_rate=a,
                         draft_cost_frac=0.3)
        oracle = apply_speed_mode(base, mode)
        res = simulate_cluster(wl, ContinuousBatcher(max_batch=8), oracle,
                               cluster=ClusterSpec(replicas=1))
        goodputs.append(res.goodput(tpot_slo_s=tpot_slo))
    assert all(g1 <= g2 + 1e-9 for g1, g2 in zip(goodputs, goodputs[1:]))
    assert goodputs[-1] > goodputs[0]


# ---- planner axis -----------------------------------------------------------
KV_BOUND = WorkloadSpec(rate=4.0, duration_s=15.0, prompt_tokens=2048,
                        output_tokens=256)


def test_planner_int8_wins_kv_bound():
    """Long contexts + tight HBM: fp16 can't fit the big batch, int8 can —
    the quantized config must win on cost-per-goodput, and its claimed
    attainment must survive an independent re-simulation."""
    base = roofline()
    mem = MemorySpec(hbm_gb=2.0)
    plan = plan_capacity(base, KV_BOUND, slo_latency_s=20.0, slo_target=0.9,
                         replicas=(1,), policies=("continuous",),
                         max_batches=(8, 16),
                         speed_modes=["fp16", "int8", "speculative"],
                         memory=mem, objective="cost_per_goodput")
    modes = {c.speed_mode for c in plan.candidates}
    assert modes == {"fp16", "int8", "speculative"}
    best = plan.best
    assert best is not None and best.speed_mode == "int8"
    # fp16 is memory-rejected exactly where int8 fits
    rejected = {(c.speed_mode, c.max_batch)
                for c in plan.candidates if c.infeasible_reason}
    assert ("fp16", 16) in rejected
    assert ("int8", 16) not in rejected
    # verify half of plan → verify: replay the winner independently
    res = simulate_candidate(base, KV_BOUND, best, memory=mem)
    assert res.slo_attainment(20.0) >= 0.9


def test_planner_fp16_wins_compute_bound():
    """Prefill is compute-bound, so int8's 5% compute tax makes every
    TTFT strictly worse.  Pin the TTFT SLO between the two modes'
    observed worst cases (same seeded workload the planner replays):
    fp16 keeps full goodput, int8 drops requests — the vanilla config
    must win on cost-per-goodput."""
    base = roofline(chips=4)
    # sparse single-token requests: no decode phase and no queueing, so
    # TTFT is pure network + prefill and the 5% compute tax separates
    # the modes cleanly
    wl = WorkloadSpec(rate=0.5, duration_s=20.0, prompt_tokens=512,
                      output_tokens=1)
    cluster = ClusterSpec(replicas=1)
    maxima = []
    for name in ("fp16", "int8"):
        oracle = apply_speed_mode(base, name)
        res = simulate_cluster(wl, ContinuousBatcher(max_batch=4), oracle,
                               cluster=cluster)
        maxima.append(res.ttft(100.0))
    assert maxima[1] > maxima[0]      # int8 prefill is strictly slower
    ttft_slo = (maxima[0] + maxima[1]) / 2
    plan = plan_capacity(base, wl, ttft_slo_s=ttft_slo, slo_target=0.9,
                         replicas=(1,), policies=("continuous",),
                         max_batches=(4,), speed_modes=["fp16", "int8"],
                         objective="cost_per_goodput")
    best = plan.best
    assert best is not None and best.speed_mode == "fp16"
    by_mode = {c.speed_mode: c for c in plan.candidates}
    assert by_mode["fp16"].objective < by_mode["int8"].objective


def test_simulate_candidate_honors_speed_mode():
    base = roofline()
    mem = MemorySpec(hbm_gb=2.0)
    plan = plan_capacity(base, KV_BOUND, slo_latency_s=20.0, slo_target=0.9,
                         replicas=(1,), policies=("continuous",),
                         max_batches=(8,), speed_modes=["fp16", "int8"],
                         memory=mem, objective="cost_per_goodput")
    by_mode = {c.speed_mode: c for c in plan.candidates
               if not c.infeasible_reason}
    res_fp16 = simulate_candidate(base, KV_BOUND, by_mode["fp16"],
                                  memory=mem)
    res_int8 = simulate_candidate(base, KV_BOUND, by_mode["int8"],
                                  memory=mem)
    assert res_int8.percentile(99) < res_fp16.percentile(99)


def test_plan_spec_round_trips_speed_modes():
    spec = PlanSpec(job_id="p", user="t", profile="gemma2-2b@tpu-v5e",
                    speed_modes=("fp16", "int8"))
    spec2 = PlanSpec.from_dict(spec.to_dict())
    assert tuple(spec2.speed_modes) == ("fp16", "int8")


# ---- kernel calibration backend ---------------------------------------------
def test_kernel_registry_names():
    assert set(kernel_registry()) == {"flash_attention", "decode_attention",
                                      "int8_matmul", "wkv6", "rglru_scan"}


def test_kernel_records_provenance_and_fit():
    recs = kernel_records(["wkv6"], batches=(1, 2), seqs=(64, 128),
                          dtypes=("float32",), repeats=1,
                          meta={"job_id": "k"})
    assert len(recs) == 4
    for r in recs:
        assert r["kind"] == "calibration"
        assert r["backend"] == "pallas-kernel"
        assert r["kernel"] == "wkv6"
        assert r["result"]["latency_s"] > 0
        assert r["result"]["max_err_vs_ref"] is not None
    fits = fit_kernel_records(recs)
    assert set(fits) == {"wkv6/float32"}
    fit = fits["wkv6/float32"]
    assert fit["backend"] == "pallas-kernel"
    assert fit["n_points"] == 4


def test_attach_kernel_calibration_and_profile_round_trip():
    prof = roofline().to_profile()
    recs = kernel_records(["rglru_scan"], batches=(1,), seqs=(64,),
                          dtypes=("float32",), repeats=1)
    prof = attach_kernel_calibration(prof, recs)
    assert prof.kernels and "rglru_scan/float32" in prof.kernels
    assert set(prof.speed_modes) == {"fp16", "int8", "speculative"}
    prof2 = CalibrationProfile.from_dict(prof.to_dict())
    assert prof2.kernels == prof.kernels
    assert prof2.speed_modes == prof.speed_modes
    # profile-carried speed modes override the built-in presets
    custom = dict(prof2.speed_modes)
    custom["int8"] = dict(custom["int8"], kv_bytes_scale=0.25)
    assert resolve_speed_mode("int8", custom).kv_bytes_scale == 0.25


def test_run_calibration_job_with_kernels(tmp_path):
    spec = CalibrationSpec(
        job_id="k", user="t",
        model=ModelRef(kind="registered", name="gemma2-2b"),
        hardware="tpu-v5e", chips=1, batches=(1,), seqs=(64,), repeats=1,
        kernels=("int8_matmul",), profile_dir=str(tmp_path))
    res = run_calibration_job(spec)
    assert res.metrics["kernels"] == ["int8_matmul"]
    assert res.metrics["n_kernel_records"] >= 1
    krecs = [r for r in res.extra_records
             if r.get("backend") == "pallas-kernel"]
    assert krecs and all(r["kind"] == "calibration" for r in krecs)
    prof = CalibrationProfile.from_dict(res.metrics["profile"])
    assert prof.kernels and prof.speed_modes


def test_derive_speed_modes_shape():
    modes = derive_speed_modes()
    assert set(modes) == {"fp16", "int8", "speculative"}
    for d in modes.values():
        SpeedMode.from_dict(d)   # every derived mode must round-trip
