"""Cloud-rate catalog coverage: every hardware key must price, with and
without a named instance, under both pricing classes.

Regression test for the gap where ``CLOUD_RATES_USD_PER_HOUR`` had no
entries for ``2080ti``/``cpu-xeon`` and ``cloud_cost_usd(...,
instance=...)`` KeyError'd on catalog hardware.
"""
import pytest

from repro import hw


class TestCloudRateCoverage:
    def test_every_hardware_key_has_listed_rates(self):
        for name in hw.HARDWARE:
            assert hw.CLOUD_RATES_USD_PER_HOUR.get(name), \
                f"{name} missing from CLOUD_RATES_USD_PER_HOUR"

    def test_every_key_resolves_without_instance(self):
        for name in hw.HARDWARE:
            cost = hw.cloud_cost_usd(name, 3600.0)
            assert cost > 0.0, f"{name} priced at zero"
            # default instance is the cheapest listed one
            rates = hw.CLOUD_RATES_USD_PER_HOUR[name]
            assert cost == pytest.approx(min(rates.values()))

    def test_every_key_resolves_with_every_listed_instance(self):
        for name in hw.HARDWARE:
            for inst, rate in hw.CLOUD_RATES_USD_PER_HOUR[name].items():
                cost = hw.cloud_cost_usd(name, 3600.0, instance=inst)
                assert cost == pytest.approx(rate)

    def test_unknown_instance_on_known_hardware_raises(self):
        with pytest.raises(KeyError):
            hw.cloud_cost_usd("tpu-v5e", 3600.0, instance="nope/I9")

    def test_unknown_hardware_is_self_hosted_zero(self):
        assert hw.cloud_cost_usd("my-basement-rig", 3600.0) == 0.0


class TestSpotPricing:
    def test_every_key_has_a_spot_rate_below_reserved(self):
        for name in hw.HARDWARE:
            spot = hw.cloud_rate_usd_per_hour(name, pricing="spot")
            reserved = hw.cloud_rate_usd_per_hour(name)
            assert 0.0 < spot < reserved, \
                f"{name}: spot {spot} not below reserved {reserved}"

    def test_spot_cost_scales_with_seconds(self):
        one_hr = hw.cloud_cost_usd("t4", 3600.0, pricing="spot")
        half_hr = hw.cloud_cost_usd("t4", 1800.0, pricing="spot")
        assert one_hr == pytest.approx(2 * half_hr)
        assert one_hr == pytest.approx(hw.SPOT_RATES_USD_PER_HOUR["t4"])

    def test_unknown_pricing_class_raises(self):
        with pytest.raises(ValueError):
            hw.cloud_rate_usd_per_hour("t4", pricing="preemptible")

    def test_pricing_classes_constant(self):
        assert hw.PRICING_CLASSES == ("reserved", "spot")
