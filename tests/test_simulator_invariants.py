"""Property-based simulator invariants (hypothesis; gated in conftest.py).

The invariants that make the simulator citable (FlexBench's argument:
benchmark numbers are only as good as the harness they come from):

  * every admitted request completes exactly once,
  * per-request stage sanity: t_queue >= 0, t_batch_wait within t_queue,
    batch sizes never exceed the policy cap,
  * total busy_s <= duration_s × replicas (utilization <= 1),
  * closed-loop in-flight never exceeds spec.concurrency,
  * memory layer: block allocations never exceed the HBM budget,
    prefix-cache hits never change token-level results, preempted
    requests always eventually complete, occupancy is 0 on drain.

Each property runs through the full cluster event loop across workload
kinds, batching policies, replica counts and routers.
"""
from hypothesis import given, settings, strategies as st

from repro.serving.memory import MemorySpec
from repro.serving.workload import WorkloadSpec

from invariant_checks import (check_all_complete_exactly_once,
                              check_busy_bound, check_closed_concurrency,
                              check_drain_under_kills,
                              check_duration_covers_window,
                              check_event_budget, check_memory_invariants,
                              check_stage_sanity,
                              check_token_results_match, policy_cap,
                              run_fleet_sim, run_sim)

SETTINGS = dict(max_examples=20, deadline=None)

open_kinds = st.sampled_from(["poisson", "uniform", "burst", "ramp"])
policies = st.sampled_from(["none", "tfs", "tris", "continuous"])
routers = st.sampled_from(["round-robin", "least-loaded", "affinity"])


def _policy_kw(policy, max_batch):
    if policy == "tfs":
        return {"max_batch": max_batch, "timeout_s": 0.004}
    if policy == "tris":
        return {"preferred": tuple(sorted({max_batch, 2, 1}, reverse=True))}
    if policy == "continuous":
        return {"max_batch": max_batch, "max_prefill": max(max_batch // 2, 1)}
    return {}


@st.composite
def open_workloads(draw):
    return WorkloadSpec(
        kind=draw(open_kinds),
        rate=draw(st.floats(20, 250)),
        duration_s=draw(st.floats(0.3, 1.5)),
        prompt_tokens=draw(st.integers(16, 256)),
        output_tokens=draw(st.integers(1, 4)),
        output_tokens_max=draw(st.sampled_from([0, 8])),
        payload_bytes=4096,
        ramp_min_rate=draw(st.floats(10, 50)),
        ramp_max_rate=draw(st.floats(60, 300)),
        ramp_steps=draw(st.integers(2, 5)),
        session_count=draw(st.integers(1, 6)),
        seed=draw(st.integers(0, 2**16)),
    )


@given(wl=open_workloads(), policy=policies,
       max_batch=st.integers(1, 16), replicas=st.integers(1, 4),
       router=routers)
@settings(**SETTINGS)
def test_conservation_and_stages(wl, policy, max_batch, replicas, router):
    kw = _policy_kw(policy, max_batch)
    res = run_sim(wl, policy, replicas=replicas, router=router, **kw)
    check_all_complete_exactly_once(wl, res)
    check_stage_sanity(res, policy_cap(policy, **kw))
    check_busy_bound(res)
    check_duration_covers_window(wl, res)
    check_event_budget(res)


@given(wl=open_workloads(), max_batch=st.integers(1, 16),
       autoscale=st.booleans())
@settings(**SETTINGS)
def test_autoscaled_cluster_invariants(wl, max_batch, autoscale):
    kw = _policy_kw("continuous", max_batch)
    res = run_sim(wl, "continuous", replicas=1, autoscale=autoscale, **kw)
    check_all_complete_exactly_once(wl, res)
    check_stage_sanity(res, policy_cap("continuous", **kw))
    check_busy_bound(res)


@given(concurrency=st.integers(1, 8), policy=policies,
       max_batch=st.integers(1, 8), replicas=st.integers(1, 3),
       router=routers, duration=st.floats(0.2, 0.8),
       out_tokens=st.integers(1, 4), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_closed_loop_concurrency_cap(concurrency, policy, max_batch,
                                     replicas, router, duration,
                                     out_tokens, seed):
    wl = WorkloadSpec(kind="closed", concurrency=concurrency,
                      duration_s=duration, output_tokens=out_tokens,
                      payload_bytes=4096, seed=seed)
    kw = _policy_kw(policy, max_batch)
    res = run_sim(wl, policy, replicas=replicas, router=router, **kw)
    check_all_complete_exactly_once(wl, res)
    check_closed_concurrency(wl, res)
    check_busy_bound(res)


# ---- memory layer ----------------------------------------------------------
@st.composite
def memory_workloads(draw):
    """Generation workloads with session-shared prefixes (the regime the
    KV layer exists for)."""
    prompt = draw(st.integers(16, 256))
    return WorkloadSpec(
        kind=draw(st.sampled_from(["poisson", "uniform", "burst"])),
        rate=draw(st.floats(20, 150)),
        duration_s=draw(st.floats(0.3, 1.2)),
        prompt_tokens=prompt,
        prefix_tokens=draw(st.integers(0, prompt)),
        output_tokens=draw(st.integers(1, 16)),
        output_tokens_max=draw(st.sampled_from([0, 32])),
        payload_bytes=4096,
        session_count=draw(st.integers(1, 6)),
        seed=draw(st.integers(0, 2**16)),
    )


def _memory_spec(draw_blocks, wl, block_tokens, **kw):
    """A budget that always fits the largest single request (anything
    smaller is rejected by simulate_cluster up front) but is often tight
    enough to force eviction and preemption."""
    worst = wl.prompt_tokens + max(wl.output_tokens,
                                   wl.output_tokens_max or 0, 1)
    floor = -(-worst // block_tokens)
    return MemorySpec(block_tokens=block_tokens,
                      num_blocks=floor + draw_blocks, **kw)


@given(wl=memory_workloads(), policy=policies,
       max_batch=st.integers(1, 16), replicas=st.integers(1, 3),
       router=routers, block_tokens=st.sampled_from([8, 16, 32]),
       extra_blocks=st.integers(0, 48),
       victim=st.sampled_from(["youngest", "largest"]))
@settings(**SETTINGS)
def test_memory_budget_and_completion(wl, policy, max_batch, replicas,
                                      router, block_tokens, extra_blocks,
                                      victim):
    """Blocks never exceed the budget, preempted requests still complete,
    and every replica drains to zero referenced blocks."""
    mem = _memory_spec(extra_blocks, wl, block_tokens, preemption=victim)
    kw = _policy_kw(policy, max_batch)
    res = run_sim(wl, policy, replicas=replicas, router=router,
                  memory=mem, **kw)
    check_all_complete_exactly_once(wl, res)
    check_memory_invariants(res)
    check_busy_bound(res)


@given(wl=memory_workloads(), max_batch=st.integers(1, 16),
       replicas=st.integers(1, 3),
       block_tokens=st.sampled_from([8, 16, 32]),
       extra_blocks=st.integers(0, 2))
@settings(**SETTINGS)
def test_kv_blocking_clock_always_advances(wl, max_batch, replicas,
                                           block_tokens, extra_blocks):
    """Under the tightest feasible KV budget — barely above one request,
    so admission is KV-blocked almost continuously — the loop still
    terminates within a linear event budget: the clock strictly advances
    (a KV-blocked engine re-armed at ``now`` with nothing admissible
    would spin, inflating ``SimResult.events`` far past the bound)."""
    mem = _memory_spec(extra_blocks, wl, block_tokens,
                       prefix_caching=False)
    kw = _policy_kw("continuous", max_batch)
    res = run_sim(wl, "continuous", replicas=replicas, memory=mem, **kw)
    check_all_complete_exactly_once(wl, res)
    check_event_budget(res)
    check_memory_invariants(res)


@given(wl=memory_workloads(), max_batch=st.integers(1, 16),
       block_tokens=st.sampled_from([8, 16, 32]),
       extra_blocks=st.integers(8, 64))
@settings(**SETTINGS)
def test_prefix_cache_transparent_to_results(wl, max_batch, block_tokens,
                                             extra_blocks):
    """Prefix-cache hits skip compute but never change which requests
    complete or how many tokens they produce."""
    kw = _policy_kw("continuous", max_batch)
    runs = [run_sim(wl, "continuous",
                    memory=_memory_spec(extra_blocks, wl, block_tokens,
                                        prefix_caching=pc), **kw)
            for pc in (True, False)]
    check_token_results_match(runs[0], runs[1])
    for res in runs:
        check_memory_invariants(res)


# ---- heterogeneous fleet / spot preemption ---------------------------------
@given(wl=open_workloads(), mtbf=st.floats(0.05, 5.0),
       seed=st.integers(0, 2**16), max_batch=st.integers(1, 8),
       router=st.sampled_from(["round-robin", "least-loaded",
                               "cost-weighted", "fastest-ttft"]))
@settings(**SETTINGS)
def test_drain_to_zero_under_spot_kills(wl, mtbf, seed, max_batch, router):
    """Seeded spot kills mid-decode never lose requests: everything the
    workload admits drains to completion through requeue/recompute, and
    the fleet's eviction/billing accounting stays self-consistent.
    Concrete twin: test_fleet.py::TestDrainUnderKills."""
    res = run_fleet_sim(wl, mtbf_s=mtbf, seed=seed, router=router,
                        max_batch=max_batch)
    check_drain_under_kills(wl, res)
    check_busy_bound(res)
    check_duration_covers_window(wl, res)
    check_event_budget(res)
