"""Memory-aware serving: MemorySpec plumbing, KVCacheManager unit
behavior, prefix caching, preemption/recompute, planner HBM rejection,
and the unbounded-output clamp (the hypothesis-free twin of the memory
properties in test_simulator_invariants)."""
import pytest

from repro.analysis.memory_model import (kv_bytes_per_token,
                                         serving_hbm_headroom)
from repro.calibrate.planner import plan_capacity
from repro.configs import get_config
from repro.core import BenchmarkJobSpec, JobResult, MemorySpec, run_stages
from repro.core.analysis import memory_table, plan_table
from repro.core.perfdb import PerfDB
from repro.serving.batching import make_policy
from repro.serving.cluster import ClusterSpec, simulate_cluster
from repro.serving.latency_model import LatencyModel
from repro.serving.memory import (KVCacheManager, ResolvedMemory,
                                  resolve_memory)
from repro.serving.workload import (UNBOUNDED_OUTPUT_TOKENS, WorkloadSpec,
                                    generate)


@pytest.fixture(scope="module")
def lat():
    return LatencyModel(get_config("gemma2-2b"), chips=4)


def _manager(blocks=64, block_tokens=16, **kw):
    spec = MemorySpec(block_tokens=block_tokens, num_blocks=blocks, **kw)
    resolved = ResolvedMemory(total_blocks=blocks,
                              kv_bytes_per_token=1024.0,
                              max_model_len=4096,
                              budget_bytes=blocks * block_tokens * 1024.0)
    return KVCacheManager(spec, resolved)


class TestMemorySpec:
    def test_validation(self):
        with pytest.raises(ValueError):
            MemorySpec(block_tokens=0)
        with pytest.raises(ValueError):
            MemorySpec(preemption="lifo")
        with pytest.raises(ValueError):
            MemorySpec(util_fraction=0.0)

    def test_resolve_from_model_config(self, lat):
        r = resolve_memory(MemorySpec(), lat)
        assert r.kv_bytes_per_token == kv_bytes_per_token(lat.cfg)
        assert r.max_model_len == lat.cfg.max_seq_len
        headroom = serving_hbm_headroom(lat.hw, lat.chips,
                                        lat.weight_bytes())
        assert r.budget_bytes == pytest.approx(headroom)
        assert r.total_blocks == int(
            headroom // (16 * r.kv_bytes_per_token))

    def test_profile_oracle_needs_explicit_bytes(self):
        from repro.serving.latency_model import FittedLatencyModel
        fitted = FittedLatencyModel(prefill_coef=(1e-3, 1e-6, 0.0),
                                    decode_coef=(1e-3, 1e-5, 0.0))
        with pytest.raises(ValueError, match="kv_bytes_per_token"):
            resolve_memory(MemorySpec(), fitted)
        with pytest.raises(ValueError, match="hbm_gb"):
            resolve_memory(MemorySpec(kv_bytes_per_token=1024.0), fitted)
        r = resolve_memory(MemorySpec(kv_bytes_per_token=1024.0,
                                      hbm_gb=0.001), fitted)
        assert r.total_blocks >= 1

    def test_round_trip_through_job_spec(self):
        spec = BenchmarkJobSpec(
            job_id="m0",
            cluster={"replicas": 2,
                     "memory": {"block_tokens": 32, "hbm_gb": 2.0,
                                "preemption": "largest"}})
        again = BenchmarkJobSpec.from_dict(spec.to_dict())
        assert again == spec
        assert again.cluster.memory.block_tokens == 32
        assert again.cluster.memory.preemption == "largest"


class TestKVCacheManager:
    def test_allocate_extend_free_accounting(self):
        kv = _manager(blocks=10, block_tokens=16)
        assert kv.allocate(1, 33, 0.0) == 0          # 3 blocks
        assert kv.resident_blocks == 3
        assert kv.extend(1, 48, 0.1)                 # same 3 blocks
        assert kv.resident_blocks == 3
        assert kv.extend(1, 49, 0.2)                 # crosses → 4 blocks
        assert kv.resident_blocks == 4
        kv.free(1, 0.3)
        assert kv.resident_blocks == 0
        assert kv.referenced_blocks() == 0
        assert kv.peak_blocks == 4

    def test_allocation_fails_beyond_budget(self):
        kv = _manager(blocks=4, block_tokens=16)
        assert kv.allocate(1, 48, 0.0) == 0          # 3 of 4 blocks
        assert kv.allocate(2, 32, 0.0) is None       # needs 2, 1 free
        assert kv.resident_blocks == 3               # failed alloc is clean
        assert kv.extend(1, 64, 0.1)                 # 4th block: fits
        assert not kv.extend(1, 65, 0.2)             # 5th: over budget
        assert kv.resident_blocks == 4

    def test_prefix_cache_hit_and_refcount(self):
        kv = _manager(blocks=32, block_tokens=16)
        # 64-token shared prefix = 4 blocks; 96-token prompt = 6 blocks
        assert kv.allocate(1, 96, 0.0, session_id=7, prefix_tokens=64) == 0
        assert kv.resident_blocks == 6
        # second request of the session hits all 4 prefix blocks
        assert kv.allocate(2, 96, 0.1, session_id=7, prefix_tokens=64) == 64
        assert kv.resident_blocks == 8               # only 2 new private
        kv.free(1, 0.2)
        kv.free(2, 0.3)
        # prefix blocks stay cached (resident but unreferenced)
        assert kv.resident_blocks == 4
        assert kv.referenced_blocks() == 0
        assert kv.allocate(3, 96, 0.4, session_id=7, prefix_tokens=64) == 64
        assert kv.stats(1.0)["prefix_hit_rate"] == pytest.approx(
            128 / (96 * 3))

    def test_idle_prefix_evicted_under_pressure(self):
        kv = _manager(blocks=8, block_tokens=16)
        kv.allocate(1, 64, 0.0, session_id=1, prefix_tokens=64)  # 4 blocks
        kv.free(1, 0.1)                              # cached, refs=0
        assert kv.resident_blocks == 4
        assert kv.allocate(2, 96, 0.2) == 0          # 6 blocks: must evict
        assert kv.resident_blocks == 6
        assert kv.evictions == 1

    def test_different_sessions_do_not_share(self):
        kv = _manager(blocks=32, block_tokens=16)
        kv.allocate(1, 64, 0.0, session_id=1, prefix_tokens=64)
        assert kv.allocate(2, 64, 0.1, session_id=2, prefix_tokens=64) == 0

    def test_prefix_caching_disabled(self):
        kv = _manager(blocks=32, prefix_caching=False)
        kv.allocate(1, 64, 0.0, session_id=1, prefix_tokens=64)
        assert kv.allocate(2, 64, 0.1, session_id=1,
                           prefix_tokens=64) == 0
        assert kv.stats(1.0)["prefix_hit_rate"] == 0.0

    def test_own_idle_prefix_sacrificed_not_deadlocked(self):
        """A session whose cached prefix starves its own next allocation
        must evict that prefix and allocate cold, not fail forever
        (head-of-line hang on an otherwise empty replica)."""
        kv = _manager(blocks=10, block_tokens=16)
        # cache a 6-block prefix, then free it (idle, refs=0)
        kv.allocate(1, 96, 0.0, session_id=5, prefix_tokens=96)
        kv.free(1, 0.1)
        assert kv.resident_blocks == 6
        # same session, shorter shareable prefix but a 10-block prompt:
        # hits 2 blocks but needs 8 fresh with only 4 free — must drop
        # its own idle prefix and succeed cold
        assert kv.allocate(2, 160, 0.2, session_id=5,
                           prefix_tokens=32) == 0
        assert kv.resident_blocks == 10
        assert kv.evictions >= 1
        kv.free(2, 0.3)
        assert kv.referenced_blocks() == 0

    def test_num_blocks_bypasses_byte_math_for_profiles(self):
        from repro.serving.latency_model import FittedLatencyModel
        fitted = FittedLatencyModel(prefill_coef=(1e-3, 1e-6, 0.0),
                                    decode_coef=(1e-3, 1e-5, 0.0))
        r = resolve_memory(MemorySpec(num_blocks=512), fitted)
        assert r.total_blocks == 512


WL_SHARED = WorkloadSpec(rate=120, duration_s=1.5, prompt_tokens=256,
                         prefix_tokens=192, output_tokens=2,
                         output_tokens_max=6, session_count=4, seed=7)


class TestMemoryAwareSimulation:
    def test_budget_never_exceeded_and_drains(self, lat):
        res = simulate_cluster(
            WL_SHARED, make_policy("continuous", max_batch=8), lat,
            cluster=ClusterSpec(memory=MemorySpec(num_blocks=64)))
        m = res.memory
        assert m["peak_blocks"] <= m["total_blocks_per_replica"]
        assert 0.0 <= m["peak_occupancy"] <= 1.0
        for p in m["per_replica"]:
            assert p["referenced_blocks_end"] == 0
        assert len(res.traces) == len(generate(WL_SHARED))

    def test_prefix_cache_does_not_change_token_results(self, lat):
        results = {}
        for pc in (True, False):
            res = simulate_cluster(
                WL_SHARED, make_policy("continuous", max_batch=8), lat,
                cluster=ClusterSpec(
                    memory=MemorySpec(prefix_caching=pc)))
            results[pc] = sorted(
                (t.request.req_id, t.request.output_tokens)
                for t in res.traces)
        assert results[True] == results[False]

    def test_tight_budget_preempts_and_completes(self, lat):
        wl = WorkloadSpec(rate=40, duration_s=1.5, prompt_tokens=64,
                          output_tokens=96, output_tokens_max=192,
                          session_count=2, seed=3)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=8), lat,
            cluster=ClusterSpec(
                memory=MemorySpec(num_blocks=48, prefix_caching=False)))
        m = res.memory
        assert m["preemptions"] > 0
        assert m["peak_blocks"] <= m["total_blocks_per_replica"]
        assert len(res.traces) == len(generate(wl))
        assert any(t.preemptions > 0 for t in res.traces)
        # preemption moves time between stages but never loses any
        for t in res.traces:
            assert t.e2e == pytest.approx(t.done_s - t.request.arrival_s)

    def test_largest_victim_policy_runs(self, lat):
        wl = WorkloadSpec(rate=40, duration_s=1.5, prompt_tokens=64,
                          output_tokens=96, output_tokens_max=192,
                          session_count=2, seed=3)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=8), lat,
            cluster=ClusterSpec(
                memory=MemorySpec(num_blocks=48, prefix_caching=False,
                                  preemption="largest")))
        assert len(res.traces) == len(generate(wl))
        assert res.memory["preemptions"] > 0

    def test_budget_below_one_request_rejected(self, lat):
        with pytest.raises(ValueError, match="cannot hold"):
            simulate_cluster(
                WL_SHARED, make_policy("continuous", max_batch=8), lat,
                cluster=ClusterSpec(memory=MemorySpec(num_blocks=4)))

    def test_request_level_policy_bounds_batch_working_set(self, lat):
        # each sequence needs 5 blocks (68 tokens); 16 blocks hold 3
        wl = WorkloadSpec(rate=400, duration_s=0.5, prompt_tokens=64,
                          output_tokens=4, seed=9)
        res = simulate_cluster(
            wl, make_policy("tfs", max_batch=8, timeout_s=0.002), lat,
            cluster=ClusterSpec(memory=MemorySpec(num_blocks=16)))
        assert len(res.traces) == len(generate(wl))
        assert max(t.batch_size for t in res.traces) <= 3
        assert res.memory["peak_blocks"] <= 16

    def test_unbounded_output_clamped_by_max_seq_len(self, lat):
        wl = WorkloadSpec(rate=8, duration_s=0.5, prompt_tokens=32,
                          output_tokens=4, output_tokens_max=None, seed=1)
        reqs = generate(wl)
        assert all(r.output_tokens == UNBOUNDED_OUTPUT_TOKENS
                   for r in reqs)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=4), lat,
            cluster=ClusterSpec(memory=MemorySpec(
                num_blocks=64, max_model_len=128)))
        # decode stops at max_model_len - prompt, not the sentinel
        assert len(res.traces) == len(reqs)
        m = res.memory
        assert m["peak_blocks"] <= m["total_blocks_per_replica"]

    def test_unbounded_output_clamped_without_memory_too(self):
        """Even with memory unmodeled, decode is bounded by the model's
        max_seq_len — the 32k sentinel must not run past the context
        window (or blow up simulated time)."""
        import dataclasses as dc
        cfg = dc.replace(get_config("gemma2-2b"), max_seq_len=64)
        small = LatencyModel(cfg, chips=4)
        wl = WorkloadSpec(rate=8, duration_s=0.5, prompt_tokens=32,
                          output_tokens=4, output_tokens_max=None, seed=1)
        res = simulate_cluster(wl, make_policy("continuous", max_batch=4),
                               small, cluster=ClusterSpec())
        assert len(res.traces) == len(generate(wl))
        # 32 decode steps each, not 32768: inference stays sub-second
        assert max(t.t_inference for t in res.traces) < 1.0

    def test_autoscaled_replicas_get_managers(self, lat):
        wl = WorkloadSpec(rate=600, duration_s=2, prompt_tokens=128,
                          output_tokens=8, seed=4)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=8), lat,
            cluster=ClusterSpec(replicas=1, autoscale=True, max_replicas=3,
                                scale_interval_s=0.2, spawn_delay_s=0.1,
                                memory=MemorySpec()))
        assert res.replicas > 1
        assert len(res.memory["per_replica"]) == res.replicas


class TestPlumbingAndAnalysis:
    def test_run_stages_records_memory(self):
        spec = BenchmarkJobSpec(
            job_id="mem1", chips=4,
            software={"policy": "continuous", "max_batch": 8},
            cluster={"replicas": 1, "memory": {"block_tokens": 16}},
            workload={"rate": 60, "duration_s": 1, "prompt_tokens": 256,
                      "prefix_tokens": 128, "output_tokens": 2,
                      "session_count": 2, "seed": 0})
        result = run_stages(spec)
        assert result.memory is not None
        assert 0.0 <= result.metrics["prefix_hit_rate"] <= 1.0
        assert "kv_peak_occupancy" in result.metrics
        rec = result.to_record()
        assert rec["memory"]["total_blocks_per_replica"] > 0
        assert JobResult.from_record(rec).memory == result.memory

    def test_memory_table(self):
        db = PerfDB()
        db.append({"job_id": "job-mem", "arch": "gemma2-2b",
                   "policy": "cont",
                   "memory": {"total_blocks_per_replica": 100,
                              "peak_occupancy": 0.5, "mean_occupancy": 0.25,
                              "prefix_hit_rate": 0.8, "preemptions": 3,
                              "evictions": 1}})
        db.append({"job_id": "job-nomem", "arch": "x", "policy": "tfs"})
        table = memory_table(db)
        assert "job-mem" in table and "50.00%" in table
        assert "job-nomem" not in table
        assert "(no records" in memory_table(db, job_id="absent")

    def test_plan_rejects_oom_config_with_reason(self, lat):
        wl = WorkloadSpec(rate=100, duration_s=1, prompt_tokens=512,
                          output_tokens=16, output_tokens_max=64, seed=0)
        plan = plan_capacity(
            lat, wl, slo_latency_s=2.0, slo_target=0.5,
            replicas=(1,), policies=("continuous",),
            max_batches=(4, 512), memory=MemorySpec(hbm_gb=0.5))
        by_mb = {c.max_batch: c for c in plan.candidates}
        assert by_mb[4].infeasible_reason is None
        assert by_mb[512].infeasible_reason is not None
        assert "exceeds" in by_mb[512].infeasible_reason
        assert not by_mb[512].meets_slo
        assert by_mb[512].objective == float("inf")
        table = plan_table(plan)
        assert "REJECTED" in table

    def test_plan_sizes_unbounded_output_at_max_model_len(self, lat):
        """output_tokens_max=None must be costed at max_model_len per
        slot, so the candidate is rejected up front instead of the
        simulator crashing on a budget that cannot hold one sequence."""
        wl = WorkloadSpec(rate=20, duration_s=0.5, prompt_tokens=32,
                          output_tokens=4, output_tokens_max=None, seed=0)
        plan = plan_capacity(
            lat, wl, slo_latency_s=2.0, slo_target=0.5,
            replicas=(1,), policies=("continuous",), max_batches=(4,),
            memory=MemorySpec(num_blocks=64, max_model_len=4096))
        (cand,) = plan.candidates
        assert cand.infeasible_reason is not None
        assert "4096" in cand.infeasible_reason

    def test_plan_with_memory_still_raises_on_config_typos(self, lat):
        """The per-candidate KVBudgetError catch must not swallow
        genuine configuration mistakes."""
        wl = WorkloadSpec(rate=20, duration_s=0.5, output_tokens=2, seed=0)
        with pytest.raises(ValueError, match="unknown router"):
            plan_capacity(lat, wl, slo_latency_s=1.0, replicas=(1,),
                          policies=("continuous",),
                          routers=("least-loded",),
                          memory=MemorySpec(num_blocks=512))

    def test_plan_without_memory_unchanged(self, lat):
        wl = WorkloadSpec(rate=60, duration_s=1, output_tokens=2, seed=0)
        plan = plan_capacity(lat, wl, slo_latency_s=1.0, replicas=(1,),
                             policies=("continuous",))
        assert all(c.infeasible_reason is None for c in plan.candidates)
        assert plan.best is not None
