"""BenchmarkSession API: spec/config round-trips, typed results ↔ PerfDB
JSONL, executor equivalence (inline vs concurrent followers), closed-loop
workloads, and the Leader deprecation shim."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import (BenchmarkJobSpec, BenchmarkSession,
                        ConcurrentFollowerExecutor, InlineExecutor, JobResult,
                        Leader, ModelRef, PerfDB, ScheduleInfo, SoftwareSpec,
                        StageBreakdown, SweepSpec, load_jobs, run_stages)
from repro.serving.workload import WorkloadSpec

BASE = BenchmarkJobSpec(
    job_id="t", model=ModelRef(name="gemma2-2b"), chips=8,
    software=SoftwareSpec(policy="tris", preferred=(8, 4, 2, 1)),
    workload=WorkloadSpec(rate=100, duration_s=1, seed=0))


# ---- spec & config round-trips ---------------------------------------------
def test_spec_dict_roundtrip_identity():
    d1 = BASE.to_dict()
    spec = BenchmarkJobSpec.from_dict(d1)
    assert spec == BASE
    # nested sequences normalize to tuples and survive a second trip
    assert isinstance(spec.software.preferred, tuple)
    assert isinstance(spec.metrics, tuple)
    assert spec.to_dict() == d1
    assert BenchmarkJobSpec.from_dict(spec.to_dict()) == spec


def test_sweep_dict_roundtrip_and_dotted_axes():
    sweep = SweepSpec(BASE, axes={"software.policy": ["none", "tfs"],
                                  "workload.rate": [10, 20, 30]})
    back = SweepSpec.from_dict(json.loads(json.dumps(sweep.to_dict())))
    jobs = list(back.expand())
    assert len(jobs) == 6
    assert {j.software.policy for j in jobs} == {"none", "tfs"}
    assert {j.workload.rate for j in jobs} == {10, 20, 30}
    assert len({j.job_id for j in jobs}) == 6


def test_spec_from_json_file(tmp_path):
    p = tmp_path / "job.json"
    p.write_text(BASE.to_json(indent=2))
    assert BenchmarkJobSpec.from_file(p).job_id == "t"


def _has_toml() -> bool:
    try:
        import tomllib  # noqa: F401
        return True
    except ImportError:
        try:
            import tomli  # noqa: F401
            return True
        except ImportError:
            return False


@pytest.mark.skipif(not _has_toml(), reason="neither tomllib nor tomli")
def test_spec_from_toml_file(tmp_path):
    p = tmp_path / "job.toml"
    p.write_text('job_id = "toml-job"\nchips = 4\n'
                 '[model]\nname = "gemma2-2b"\n'
                 '[workload]\nrate = 50\nduration_s = 1\n')
    spec = BenchmarkJobSpec.from_file(p)
    assert spec.job_id == "toml-job" and spec.chips == 4
    assert spec.workload.rate == 50


def test_load_jobs_shapes(tmp_path):
    single = tmp_path / "one.json"
    single.write_text(BASE.to_json())
    assert [s.job_id for s in load_jobs(single)] == ["t"]
    sweep = tmp_path / "sweep.json"
    sweep.write_text(json.dumps({"base": BASE.to_dict(),
                                 "axes": {"chips": [4, 8]}}))
    assert len(load_jobs(sweep)) == 2
    joblist = tmp_path / "list.json"
    joblist.write_text(json.dumps({"jobs": [BASE.to_dict(),
                                            dict(BASE.to_dict(),
                                                 job_id="t2")]}))
    assert [s.job_id for s in load_jobs(joblist)] == ["t", "t2"]
    with pytest.raises(ValueError):
        BenchmarkJobSpec.from_file(sweep)


# ---- typed results ↔ PerfDB JSONL ------------------------------------------
def test_jobresult_record_roundtrip(tmp_path):
    result = run_stages(BASE).with_schedule(
        ScheduleInfo(worker=1, start_s=0.0, finish_s=2.5, jct_s=2.5))
    db = PerfDB(str(tmp_path / "perf.jsonl"))
    db.insert(result.to_record())
    reloaded = PerfDB(str(tmp_path / "perf.jsonl"))
    back = JobResult.from_record(reloaded.all()[0])
    assert back.spec == BASE
    assert back.schedule == result.schedule
    assert back.stages == result.stages
    assert back.metrics == result.metrics
    assert back.mode == "roofline-model"
    # full record identity modulo the ts PerfDB stamps on insert
    rec = back.to_record()
    rec.pop("ts")
    assert rec == result.to_record()
    # and the typed view re-serializes to valid JSONL
    json.loads(json.dumps(back.to_record()))


def test_stage_breakdown_total():
    sb = StageBreakdown(preprocess=1, transmit=2, queue=3, inference=4,
                        postprocess=5)
    assert sb.total() == 15
    assert StageBreakdown.from_dict(sb.to_dict()) == sb


# ---- session submission styles ---------------------------------------------
def test_session_three_submission_styles(tmp_path):
    config = tmp_path / "sweep.json"
    config.write_text(json.dumps({
        "base": dict(BASE.to_dict(), job_id="cfg"),
        "axes": {"software.policy": ["none", "tris"]}}))
    session = BenchmarkSession(n_workers=2)
    h1 = session.submit(BASE)                                     # object
    h2 = session.submit(dict(BASE.to_dict(), job_id="t-dict"))    # dict
    hs = session.submit_file(config)                              # file
    assert session.pending == 4
    assert not h1.done()
    with pytest.raises(TimeoutError):
        h2.result(timeout=0.01)
    results = session.run()
    assert len(results) == 4 and session.pending == 0
    assert len(session.db) == 4 and len(session.results()) == 4
    assert h1.result().job_id == "t"
    assert {h.result().job_id for h in hs} == {"cfg-0", "cfg-1"}
    for r in results:
        assert r.metric("throughput_rps") > 0
        assert r.schedule is not None and r.schedule.jct_s > 0


def test_session_rejects_duplicates_and_junk():
    session = BenchmarkSession(n_workers=1)
    session.submit(BASE)
    with pytest.raises(ValueError):
        session.submit(BASE)
    with pytest.raises(TypeError):
        session.submit(42)


def test_session_context_manager_runs_pending():
    with BenchmarkSession(n_workers=2) as session:
        handle = session.submit(BASE)
    assert handle.done()
    assert len(session.results()) == 1


# ---- executor equivalence & follower bookkeeping ---------------------------
SWEEP = SweepSpec(BASE, axes={"software.policy": ["none", "tfs", "tris"],
                              "chips": [4, 8]})


def _run(executor):
    session = BenchmarkSession(n_workers=3, executor=executor)
    session.submit_sweep(SWEEP)
    return session, session.run()


def test_executors_produce_identical_records():
    _, inline_res = _run(InlineExecutor())
    _, conc_res = _run(ConcurrentFollowerExecutor())

    def strip(r):
        rec = r.to_record()
        rec.pop("benchmark_wall_s")        # wall-clock; all else deterministic
        rec["result"].pop("sim_events_per_sec", None)   # also wall-clocked
        return rec

    a = {r.job_id: strip(r) for r in inline_res}
    b = {r.job_id: strip(r) for r in conc_res}
    assert a == b and len(a) == 6


@pytest.mark.parametrize("executor_cls",
                         [InlineExecutor, ConcurrentFollowerExecutor])
def test_follower_busy_until_matches_schedule(executor_cls):
    session, results = _run(executor_cls())
    per_worker = {}
    for r in results:
        w = r.schedule.worker
        per_worker.setdefault(w, []).append(r.schedule)
    for f in session.followers:
        scheds = per_worker.get(f.worker_id, [])
        assert f.executed == len(scheds)
        expect = max((s.finish_s for s in scheds), default=0.0)
        assert abs(f.busy_until - expect) < 1e-9
    # two-tier schedule honored: per-worker intervals never overlap
    for scheds in per_worker.values():
        scheds.sort(key=lambda s: s.start_s)
        for x, y in zip(scheds, scheds[1:]):
            assert y.start_s >= x.finish_s - 1e-9


@pytest.mark.parametrize("executor_cls",
                         [InlineExecutor, ConcurrentFollowerExecutor])
def test_failed_job_fails_every_unexecuted_handle(executor_cls):
    session = BenchmarkSession(n_workers=1, executor=executor_cls())
    bad = session.submit(dataclasses.replace(BASE, job_id="bad",
                                             hardware="no-such-hw"))
    other = session.submit(dataclasses.replace(BASE, job_id="other"))
    with pytest.raises(KeyError):
        session.run()
    for h in (bad, other):
        assert h.done()
        with pytest.raises((KeyError, RuntimeError)):
            h.result(timeout=1)


# ---- closed-loop workload ---------------------------------------------------
def test_closed_loop_reissues_until_duration():
    spec = dataclasses.replace(
        BASE, job_id="closed",
        software=SoftwareSpec(policy="tris", preferred=(4, 2, 1)),
        workload=WorkloadSpec(kind="closed", concurrency=4, duration_s=1.0))
    res = run_stages(spec)
    # far more completions than the 4 seed requests
    assert res.metric("requests") > 4 * 10
    assert res.metric("throughput_rps") > 0


def test_closed_loop_steady_concurrency():
    from repro.configs import get_config
    from repro.serving.batching import make_policy
    from repro.serving.latency_model import LatencyModel
    from repro.serving.simulator import simulate
    wl = WorkloadSpec(kind="closed", concurrency=4, duration_s=1.0)
    res = simulate(wl, make_policy("tris", preferred=(4, 2, 1)),
                   LatencyModel(get_config("gemma2-2b"), chips=8))
    for t in np.linspace(0.1, 0.9, 9):
        inflight = sum(1 for tr in res.traces
                       if tr.request.arrival_s <= t < tr.done_s)
        assert inflight == wl.concurrency, (t, inflight)


# ---- deprecation shim -------------------------------------------------------
def test_leader_shim_still_works(tmp_path):
    db = PerfDB(str(tmp_path / "perf.jsonl"))
    with pytest.deprecated_call():
        leader = Leader(n_workers=2, db=db)
    for s in SweepSpec(BASE, axes={"chips": [4, 8]}).expand():
        leader.submit(s)
    recs = leader.run_all()
    assert len(recs) == 2 and len(db) == 2
    for rec in recs:
        assert rec["sched"]["jct_s"] > 0
        assert rec["result"]["throughput_rps"] > 0
