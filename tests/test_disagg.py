"""Disaggregated prefill/decode serving, phase (TTFT/TPOT/goodput)
metrics, the session-affinity remapping fix, and replica-second cost
accounting."""
import dataclasses

import pytest

from repro import hw as hw_lib
from repro.configs import get_config
from repro.core import BenchmarkJobSpec, run_stages
from repro.core.spec import PlanSpec
from repro.calibrate.planner import plan_capacity
from repro.serving.batching import make_policy
from repro.serving.cluster import (ClusterSpec, DisaggSpec,
                                   SessionAffinityRouter, simulate_cluster)
from repro.serving.latency_model import LatencyModel
from repro.serving.simulator import RequestTrace, SimResult, simulate
from repro.serving.workload import Request, WorkloadSpec, generate

from invariant_checks import (check_all_complete_exactly_once,
                              check_busy_bound,
                              check_duration_covers_window,
                              check_memory_invariants, check_stage_sanity,
                              run_sim)


@pytest.fixture(scope="module")
def lat():
    return LatencyModel(get_config("gemma2-2b"), chips=4)


def _mixed_workload(rate, duration_s=2.0, seed=6):
    """Mixed long-prefill/short-decode load (disaggregation's home turf)."""
    return WorkloadSpec(rate=rate, duration_s=duration_s, prompt_tokens=64,
                        prompt_tokens_max=4096, output_tokens=2,
                        output_tokens_max=8, seed=seed)


def _disagg(prefill=3, decode=1, **kw):
    return ClusterSpec(disaggregation=DisaggSpec(
        prefill_replicas=prefill, decode_replicas=decode,
        prefill_chunk_tokens=512, prefill_max_batch=8, **kw))


# ---- TTFT / TPOT / goodput on hand-computable traces -----------------------
def _trace(req_id, arrival, first, done, tokens, post=0.0):
    r = Request(req_id=req_id, arrival_s=arrival, prompt_tokens=8,
                output_tokens=tokens, payload_bytes=0)
    return RequestTrace(request=r, t_postprocess=post, done_s=done,
                        first_token_s=first, tokens_out=tokens)


class TestPhaseMetrics:
    def _result(self):
        # A: ttft 0.5, 6 tokens over [0.5, 1.0] → tpot 0.1
        # B: ttft 0.2, single token → no defined tpot
        traces = [_trace(0, arrival=0.0, first=0.5, done=1.0, tokens=6),
                  _trace(1, arrival=1.0, first=1.2, done=1.2, tokens=1)]
        return SimResult(traces=traces, busy_s=0.0, duration_s=10.0,
                         hw=hw_lib.TPU_V5E, chips=1)

    def test_ttft_tpot_values(self):
        res = self._result()
        assert sorted(res.ttfts()) == pytest.approx([0.2, 0.5])
        assert list(res.tpots()) == pytest.approx([0.1])
        assert res.ttft(50) == pytest.approx(0.35)
        assert res.ttft(99) == pytest.approx(0.497)
        assert res.tpot(50) == pytest.approx(0.1)

    def test_postprocess_excluded_from_tpot(self):
        tr = _trace(0, arrival=0.0, first=0.5, done=1.1, tokens=6,
                    post=0.1)
        assert tr.tpot == pytest.approx(0.1)    # (1.1-0.1-0.5)/5

    def test_goodput_requires_both_slos(self):
        res = self._result()
        # both meet ttft<=0.6 and tpot<=0.15 (B trivially: no decode)
        assert res.goodput(0.6, 0.15) == pytest.approx(0.2)
        # A misses ttft<=0.3 → only B counts
        assert res.goodput(0.3, 0.15) == pytest.approx(0.1)
        # A misses tpot<=0.05 → only B counts
        assert res.goodput(0.6, 0.05) == pytest.approx(0.1)

    def test_phase_slo_attainment(self):
        res = self._result()
        assert res.phase_slo_attainment(ttft_slo_s=0.6,
                                        tpot_slo_s=0.15) == 1.0
        assert res.phase_slo_attainment(ttft_slo_s=0.3) == 0.5
        assert res.phase_slo_attainment(ttft_slo_s=0.6,
                                        tpot_slo_s=0.05) == 0.5

    def test_empty_result(self):
        res = SimResult(traces=[], busy_s=0, duration_s=0,
                        hw=hw_lib.TPU_V5E, chips=1)
        assert res.ttft(99) == 0.0 and res.tpot(99) == 0.0
        assert res.goodput(0.1, 0.1) == 0.0
        assert res.phase_slo_attainment(ttft_slo_s=0.1) == 0.0

    def test_simulated_traces_populate_phases(self, lat):
        wl = WorkloadSpec(rate=60, duration_s=1, output_tokens=8, seed=0)
        for policy in ("tfs", "continuous"):
            res = simulate(wl, make_policy(policy, max_batch=8), lat)
            assert len(res.ttfts()) == len(res.traces)
            assert all(t.t_first_token > 0 for t in res.traces)
            assert all(t.tpot > 0 for t in res.traces)
            # first token cannot come after completion
            assert all(t.first_token_s
                       <= t.done_s - t.t_postprocess + 1e-9
                       for t in res.traces)


# ---- disaggregated cluster simulation --------------------------------------
class TestDisaggregatedServing:
    def test_invariants_hold(self, lat):
        wl = _mixed_workload(rate=150)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=16), lat,
            cluster=_disagg(prefill=2, decode=2))
        check_all_complete_exactly_once(wl, res)
        check_stage_sanity(res, 16)     # e2e == done-arrival incl. handoff
        check_busy_bound(res)
        check_duration_covers_window(wl, res)
        assert res.replicas == 4
        assert res.router == "disaggregated"
        assert res.pools["migrated_requests"] > 0

    def test_kv_transfer_clocked_for_migrated_requests(self, lat):
        wl = _mixed_workload(rate=100)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=16), lat,
            cluster=_disagg(prefill=2, decode=2))
        multi = [t for t in res.traces if t.request.output_tokens > 1]
        assert multi
        assert all(t.t_kv_transfer > 0 for t in multi)
        # transfer scales with the prompt (bytes = kv/token × prompt)
        big = max(multi, key=lambda t: t.request.prompt_tokens)
        small = min(multi, key=lambda t: t.request.prompt_tokens)
        assert big.t_kv_transfer > small.t_kv_transfer

    def test_single_token_requests_never_migrate(self, lat):
        from repro.serving.simulator import POST_PROCESS_S
        wl = WorkloadSpec(rate=80, duration_s=1, output_tokens=1, seed=3)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=16), lat,
            cluster=_disagg(prefill=1, decode=1))
        assert len(res.traces) == len(generate(wl))
        assert all(t.t_kv_transfer == 0.0 for t in res.traces)
        assert res.pools["migrated_requests"] == 0
        # the decode pool never ran
        assert res.pools["decode_busy_s"] == 0.0
        # requests completing on the prefill pool still pay postprocess
        # (no colocated-vs-disaggregated accounting asymmetry)
        assert all(t.t_postprocess == POST_PROCESS_S for t in res.traces)

    def test_memory_accounting_drains(self, lat):
        wl = WorkloadSpec(rate=80, duration_s=1.5, prompt_tokens=96,
                          output_tokens=8, output_tokens_max=32, seed=4)
        res = run_sim(wl, "continuous", max_batch=16,
                      disaggregation={"prefill_replicas": 2,
                                      "decode_replicas": 2},
                      memory={"hbm_gb": 0.5, "prefix_caching": False})
        check_all_complete_exactly_once(wl, res)
        check_memory_invariants(res)

    def test_beats_colocated_ttft_on_mixed_workload(self, lat):
        """Acceptance: at matched chip count, a prefill/decode split wins
        p99 TTFT (and TPOT) on a mixed long-prefill/short-decode load."""
        wl = _mixed_workload(rate=260)
        coloc = simulate_cluster(
            wl, make_policy("continuous", max_batch=16, max_prefill=8),
            lat, cluster=ClusterSpec(replicas=4, router="least-loaded"))
        dis = simulate_cluster(
            wl, make_policy("continuous", max_batch=16, max_prefill=8),
            lat, cluster=_disagg(prefill=3, decode=1))
        assert len(dis.traces) == len(coloc.traces) == len(generate(wl))
        assert dis.ttft(99) < coloc.ttft(99)
        assert dis.tpot(99) < coloc.tpot(99)

    def test_requires_continuous_policy(self, lat):
        wl = _mixed_workload(rate=50, duration_s=0.5)
        with pytest.raises(ValueError, match="continuous"):
            simulate_cluster(wl, make_policy("tfs"), lat,
                             cluster=_disagg())

    def test_rejects_autoscale(self):
        with pytest.raises(ValueError, match="autoscale"):
            ClusterSpec(autoscale=True,
                        disaggregation=DisaggSpec())

    def test_spec_validation_and_round_trip(self):
        with pytest.raises(ValueError):
            DisaggSpec(prefill_replicas=0)
        with pytest.raises(ValueError):
            DisaggSpec(kv_network="nope")
        with pytest.raises(ValueError):
            DisaggSpec(prefill_chunk_tokens=-512)
        spec = BenchmarkJobSpec(
            job_id="d0",
            software={"policy": "continuous", "max_batch": 16},
            cluster={"disaggregation": {"prefill_replicas": 2,
                                        "decode_replicas": 2,
                                        "kv_network": "nvlink"}})
        again = BenchmarkJobSpec.from_dict(spec.to_dict())
        assert again == spec
        assert again.cluster.disaggregation.total_replicas == 4

    def test_run_stages_reports_phase_metrics(self):
        spec = BenchmarkJobSpec(
            job_id="d1", chips=4, slo_ttft_s=2.0, slo_tpot_s=0.5,
            software={"policy": "continuous", "max_batch": 16},
            cluster={"disaggregation": {"prefill_replicas": 1,
                                        "decode_replicas": 1}},
            workload=WorkloadSpec(rate=40, duration_s=1, output_tokens=4,
                                  seed=0))
        result = run_stages(BenchmarkJobSpec.from_dict(spec.to_dict()))
        m = result.metrics
        assert m["ttft_p99_s"] > 0 and m["tpot_p99_s"] > 0
        assert 0.0 <= m["phase_slo_attainment"] <= 1.0
        assert m["goodput_rps"] <= m["throughput_rps"] + 1e-9
        assert result.cluster["pools"]["prefill_replicas"] == 1
        assert result.stages.kv_transfer > 0
        rec = result.to_record()
        assert rec["stages"]["kv_transfer"] > 0


# ---- phase-SLO capacity planning (colocated vs disaggregated) --------------
class TestPlannerPhaseSlos:
    def test_tight_ttft_slo_prefers_disaggregated(self, lat):
        wl = _mixed_workload(rate=240)
        plan = plan_capacity(
            lat, wl, ttft_slo_s=0.35, tpot_slo_s=0.03, slo_target=0.9,
            replicas=(4,), policies=("continuous",),
            routers=("least-loaded",), prefill_decode_splits=((3, 1),))
        assert plan.best is not None
        assert plan.best.split == (3, 1)
        coloc = [c for c in plan.candidates if c.split is None]
        assert coloc and not coloc[0].meets_slo
        assert all("goodput_rps" in c.metrics for c in plan.candidates)

    def test_colocated_wins_when_transfer_dominates(self, lat):
        wl = _mixed_workload(rate=140)
        plan = plan_capacity(
            lat, wl, ttft_slo_s=0.2, tpot_slo_s=0.05, slo_target=0.9,
            replicas=(4,), policies=("continuous",),
            routers=("least-loaded",), prefill_decode_splits=((3, 1),),
            kv_network="4g")     # KV handoff over a slow link
        assert plan.best is not None
        assert plan.best.split is None
        dis = [c for c in plan.candidates if c.split is not None]
        assert dis and not dis[0].meets_slo

    def test_requires_some_slo(self, lat):
        with pytest.raises(ValueError, match="SLO"):
            plan_capacity(lat, WorkloadSpec(rate=10, duration_s=0.5))

    def test_memory_check_sizes_at_longest_prompt(self, lat):
        """The static KV admission check must use prompt_tokens_max, not
        the minimum prompt, for mixed-prompt workloads."""
        from repro.serving.memory import MemorySpec
        wl = WorkloadSpec(rate=10, duration_s=0.5, prompt_tokens=64,
                          prompt_tokens_max=4096, output_tokens=2)
        plan = plan_capacity(
            lat, wl, slo_latency_s=0.25, replicas=(1,),
            policies=("continuous",), max_batch=64,
            memory=MemorySpec(hbm_gb=1.0))
        c = plan.candidates[0]
        # 64 slots × ~4100 tokens × ~104 KB/token ≫ 1 GiB: must be
        # rejected up front (sizing at prompt_tokens=64 would pass)
        assert c.infeasible_reason is not None
        assert "4098 tok" in c.infeasible_reason

    def test_plan_spec_round_trip(self):
        spec = PlanSpec(job_id="p0", profile="x@y", ttft_slo_s=0.2,
                        tpot_slo_s=0.05, slo_latency_s=None,
                        prefill_decode_splits=[[3, 1], [2, 2]])
        assert spec.prefill_decode_splits == ((3, 1), (2, 2))
        again = PlanSpec.from_dict(spec.to_dict())
        assert again.prefill_decode_splits == ((3, 1), (2, 2))
        assert again.ttft_slo_s == 0.2 and again.slo_latency_s is None


# ---- satellite: session-affinity remapping fix -----------------------------
class _FakeEngine:
    def __init__(self, replica_id):
        self.replica_id = replica_id


class TestSessionAffinityRemapping:
    def _homes(self, router, engines, sessions):
        return {s: engines[router.route(
            Request(req_id=0, arrival_s=0.0, prompt_tokens=1,
                    output_tokens=1, payload_bytes=0, session_id=s),
            engines, 0.0)].replica_id for s in sessions}

    def test_only_retired_replicas_sessions_move(self):
        router = SessionAffinityRouter()
        engines = [_FakeEngine(i) for i in range(4)]
        sessions = range(64)
        before = self._homes(router, engines, sessions)
        # every replica should host some sessions (rendezvous balance)
        assert {before[s] for s in sessions} == {0, 1, 2, 3}
        # retire replica 2: only its sessions remap
        live = [e for e in engines if e.replica_id != 2]
        after = self._homes(router, live, sessions)
        for s in sessions:
            if before[s] == 2:
                assert after[s] != 2
            else:
                assert after[s] == before[s]

    def test_scale_up_keeps_existing_sessions(self):
        router = SessionAffinityRouter()
        engines = [_FakeEngine(i) for i in range(3)]
        sessions = range(64)
        before = self._homes(router, engines, sessions)
        grown = engines + [_FakeEngine(3)]
        after = self._homes(router, grown, sessions)
        assert after == before          # 100% stickiness under scale-up
        # new sessions do land on the new replica
        fresh = self._homes(router, grown, range(64, 256))
        assert 3 in set(fresh.values())

    def test_stickiness_under_autoscaler_churn(self, lat):
        """Regression: autoscaler adds/cold-starts replicas mid-run; every
        session must stay on one replica (the old modulo-over-filtered-
        list router remapped all sessions on every churn event)."""
        wl = WorkloadSpec(rate=900, duration_s=2, output_tokens=8,
                          session_count=12, seed=9)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=8), lat,
            cluster=ClusterSpec(replicas=1, autoscale=True,
                                max_replicas=5, scale_interval_s=0.2,
                                spawn_delay_s=0.1, router="affinity"))
        assert res.replicas > 1         # churn actually happened
        by_session = {}
        for t in res.traces:
            by_session.setdefault(t.request.session_id,
                                  set()).add(t.replica)
        assert all(len(reps) == 1 for reps in by_session.values()), \
            f"sessions split across replicas: {by_session}"


# ---- satellite: replica-second cost accounting -----------------------------
class TestReplicaSecondAccounting:
    def test_static_cluster_bills_replicas_times_duration(self, lat):
        wl = WorkloadSpec(rate=100, duration_s=1, output_tokens=2, seed=0)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=8), lat,
            cluster=ClusterSpec(replicas=3, router="least-loaded"))
        assert res.replica_seconds == pytest.approx(3 * res.duration_s)
        assert res.cost_usd() == pytest.approx(
            hw_lib.cloud_cost_usd(res.hw.name, res.duration_s)
            * res.chips * 3)
        assert res.energy_joules() == pytest.approx(
            hw_lib.energy_joules(res.hw, res.duration_s,
                                 res.utilization()) * res.chips * 3)

    def test_autoscaled_cluster_bills_strictly_below_peak(self, lat):
        """Regression: energy/cost used to multiply peak replicas by the
        full duration, overcharging autoscaled clusters for spans where
        scaled-up replicas did not exist yet (or were already retired)."""
        wl = WorkloadSpec(kind="burst", rate=300, duration_s=2,
                          burst_factor=8, output_tokens=4, seed=3)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=8), lat,
            cluster=ClusterSpec(replicas=1, autoscale=True,
                                max_replicas=6, scale_interval_s=0.25,
                                spawn_delay_s=0.2))
        assert res.replicas > 1
        peak_span = res.duration_s * res.replicas
        assert 0 < res.replica_seconds < peak_span
        peak_cost = hw_lib.cloud_cost_usd(res.hw.name, res.duration_s) \
            * res.chips * res.replicas
        assert res.cost_usd() < peak_cost
        assert res.cost_usd() == pytest.approx(
            hw_lib.cloud_cost_usd(res.hw.name, res.replica_seconds)
            * res.chips)
        # utilization keeps the peak-count denominator (per the spec)
        assert res.utilization() == pytest.approx(
            res.busy_s / (res.duration_s * res.replicas))
        assert res.summary()["replica_seconds"] == pytest.approx(
            res.replica_seconds)

    def test_retired_replica_stops_billing(self, lat):
        """A replica retired mid-run bills its spawn→retire span only."""
        wl = WorkloadSpec(kind="burst", rate=400, duration_s=3,
                          burst_factor=10, burst_fraction=0.05,
                          output_tokens=2, seed=5)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=8), lat,
            cluster=ClusterSpec(replicas=1, autoscale=True,
                                max_replicas=4, scale_interval_s=0.1,
                                spawn_delay_s=0.05, scale_down_load=0.3))
        assert res.replica_seconds <= res.duration_s * res.replicas
