"""Checkpointing, restart recovery, straggler monitor, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import reduced
from repro.training import checkpoint as ckpt
from repro.training.compress import compress_grads_with_feedback, quantize
from repro.training.data import DataConfig, PrefetchingLoader, host_batch
from repro.training.ft import RunnerConfig, StragglerMonitor, TrainingRunner


def _tree(key=0):
    k = jax.random.key(key)
    return {"a": jax.random.normal(k, (16, 8)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jax.random.normal(jax.random.fold_in(k, 1), (3,))}}


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        t = _tree()
        ckpt.save(str(tmp_path), 7, t)
        step, back = ckpt.restore(str(tmp_path), target=t)
        assert step == 7
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, back)

    def test_latest_and_cleanup(self, tmp_path):
        t = _tree()
        for s in (1, 2, 3, 4):
            ckpt.save(str(tmp_path), s, t)
        assert ckpt.latest_step(str(tmp_path)) == 4
        ckpt.cleanup(str(tmp_path), keep=2)
        assert ckpt.latest_step(str(tmp_path)) == 4
        step, _ = ckpt.restore(str(tmp_path), target=t)
        assert step == 4

    def test_async(self, tmp_path):
        t = _tree()
        ac = ckpt.AsyncCheckpointer(str(tmp_path))
        ac.save(5, t)
        ac.wait()
        step, back = ckpt.restore(str(tmp_path), target=t)
        assert step == 5
        np.testing.assert_array_equal(back["a"], t["a"])

    def test_elastic_resharding(self, tmp_path):
        """Restore onto explicit shardings (different layout than saved)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        t = _tree()
        ckpt.save(str(tmp_path), 1, t)
        mesh = jax.make_mesh((1,), ("data",))
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
        step, back = ckpt.restore(str(tmp_path), target=t, shardings=sh)
        np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(t["a"]))


class TestRunner:
    def test_restart_recovers(self, tmp_path):
        calls = []

        def step_fn(state, step):
            calls.append(step)
            return {"x": state["x"] + 1}, {"loss": float(state["x"])}

        runner = TrainingRunner(
            RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=5, max_steps=20,
                         fail_at_step=12, async_ckpt=False),
            step_fn, lambda: {"x": jnp.zeros(())})
        out = runner.run()
        assert out["restarts"] == 1
        assert out["final_step"] == 20
        # state is consistent: x == number of *effective* steps
        step, state = ckpt.restore(str(tmp_path), target={"x": jnp.zeros(())})
        assert int(state["x"]) == 20
        # steps 10..12 re-executed after recovery from step-10 checkpoint
        assert 10 in calls and calls.count(11) >= 2


class TestStraggler:
    def test_flags_slow_host(self):
        mon = StragglerMonitor(n_hosts=4, threshold=1.5)
        flagged = []
        for _ in range(10):
            flagged = mon.record([1.0, 1.0, 1.0, 3.0])
        assert flagged == [3]

    def test_uniform_no_flags(self):
        mon = StragglerMonitor(n_hosts=4)
        for _ in range(5):
            assert mon.record([1.0, 1.0, 1.0, 1.0]) == []


class TestData:
    def test_deterministic_and_learnable_shapes(self):
        cfg = DataConfig(global_batch=4, seq_len=32)
        mc = reduced(ARCHS["granite-3-2b"])
        b1 = host_batch(cfg, mc, step=3)
        b2 = host_batch(cfg, mc, step=3)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert b1["tokens"].shape == (4, 32)
        assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()
        assert (b1["tokens"] < mc.vocab_size).all()

    def test_host_sharding_disjoint_sizes(self):
        mc = reduced(ARCHS["granite-3-2b"])
        full = host_batch(DataConfig(global_batch=8, seq_len=16), mc, 0)
        h0 = host_batch(DataConfig(global_batch=8, seq_len=16, n_hosts=2,
                                   host_id=0), mc, 0)
        h1 = host_batch(DataConfig(global_batch=8, seq_len=16, n_hosts=2,
                                   host_id=1), mc, 0)
        assert h0["tokens"].shape == (4, 16) == h1["tokens"].shape
        assert not (h0["tokens"] == h1["tokens"]).all()

    def test_prefetch_loader(self):
        mc = reduced(ARCHS["granite-3-2b"])
        loader = PrefetchingLoader(DataConfig(global_batch=2, seq_len=16),
                                   mc, start_step=5)
        step, batch = next(loader)
        assert step == 5 and batch["tokens"].shape == (2, 16)
        step2, _ = next(loader)
        assert step2 == 6
        loader.close()


class TestCompression:
    def test_quantize_bound(self):
        g = jax.random.normal(jax.random.key(0), (256,))
        q, s = quantize(g)
        err = jnp.max(jnp.abs(q.astype(jnp.float32) * s - g))
        assert float(err) <= float(s) / 2 + 1e-6

    def test_error_feedback_unbiased_over_time(self):
        g = jax.random.normal(jax.random.key(1), (128,)) * 1e-3
        grads = {"w": g}
        err = None
        acc = jnp.zeros_like(g)
        for _ in range(50):
            deq, err = compress_grads_with_feedback(grads, err)
            acc = acc + deq["w"]
        # with feedback, the accumulated dequantized sum tracks 50·g
        rel = jnp.linalg.norm(acc - 50 * g) / jnp.linalg.norm(50 * g)
        assert float(rel) < 0.05
