"""Scenario library: named workload profiles, production arrival
processes (diurnal / flash-crowd / sweep), multi-tenant traffic splits
with fairness metrics, and the synthetic trace scaler."""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.configs import get_config
from repro.calibrate.planner import plan_capacity, simulate_candidate
from repro.core.analysis import jain_index
from repro.core.results import JobResult
from repro.core.perfdb import PerfDB
from repro.core.session import BenchmarkSession, resolve_policy
from repro.core.spec import BenchmarkJobSpec, SoftwareSpec, spec_from_dict
from repro.scenarios import (ScenarioProfile, TenantSpec, catalog_table,
                             get_profile, list_profiles, register_profile,
                             scale_trace, tenant_report, trace_stats,
                             write_trace_rows)
from repro.scenarios import arrivals
from repro.scenarios.tenants import resolve_tenant_slos, tenant_table
from repro.serving.cluster import ClusterSpec, simulate_cluster
from repro.serving.latency_model import NETWORKS, LatencyModel
from repro.serving.workload import WorkloadSpec, generate

SEED_TRACE = str(Path(__file__).resolve().parent.parent
                 / "configs" / "traces" / "seed_chat.jsonl")

TENANTS = ({"name": "chatbot", "share": 3.0, "scenario": "chat"},
           {"name": "classifier", "share": 1.0,
            "scenario": "classification"})


@pytest.fixture(scope="module")
def lat():
    return LatencyModel(get_config("gemma2-2b"), chips=4)


def _sim(wl, lat, replicas=2, policy="continuous", max_batch=16):
    pol = resolve_policy(SoftwareSpec(policy=policy, max_batch=max_batch))
    return simulate_cluster(wl, pol, lat,
                            cluster=ClusterSpec(replicas=replicas),
                            network=NETWORKS["lan"])


# ---- WorkloadSpec validation (satellite a) ---------------------------------
class TestWorkloadValidation:
    def test_unknown_kind(self):
        with pytest.raises(ValueError, match="unknown workload kind"):
            WorkloadSpec(kind="sinusoid")

    def test_nonpositive_rate(self):
        for kind in ("poisson", "uniform", "burst", "diurnal",
                     "flash-crowd"):
            with pytest.raises(ValueError, match="rate must be > 0"):
                WorkloadSpec(kind=kind, rate=0.0)

    def test_nonpositive_duration(self):
        with pytest.raises(ValueError, match="duration_s must be > 0"):
            WorkloadSpec(duration_s=-1.0)

    def test_burst_fraction_bounds(self):
        for bad in (-0.1, 1.5):
            with pytest.raises(ValueError, match="burst_fraction"):
                WorkloadSpec(kind="burst", burst_fraction=bad)

    def test_ramp_steps_floor(self):
        with pytest.raises(ValueError, match="ramp_steps"):
            WorkloadSpec(kind="ramp", ramp_steps=0)

    def test_sweep_needs_positive_min_rate(self):
        with pytest.raises(ValueError, match="ramp_min_rate"):
            WorkloadSpec(kind="sweep", ramp_min_rate=0.0)

    def test_diurnal_amplitude_bounds(self):
        with pytest.raises(ValueError, match="diurnal_amplitude"):
            WorkloadSpec(kind="diurnal", diurnal_amplitude=1.5)

    def test_trace_kind_skips_rate_and_duration_checks(self):
        # trace replay owns its own timeline; rate/duration don't apply
        WorkloadSpec(kind="trace", rate=0.0, duration_s=60.0,
                     trace_path="x.jsonl")


# ---- burst/ramp degenerate cases (satellite b) -----------------------------
class TestDegenerateKinds:
    def test_burst_fraction_zero_is_poisson(self):
        burst = generate(WorkloadSpec(kind="burst", rate=40.0,
                                      duration_s=5.0, burst_fraction=0.0,
                                      seed=9))
        plain = generate(WorkloadSpec(kind="poisson", rate=40.0,
                                      duration_s=5.0, seed=9))
        assert burst == plain

    def test_burst_fraction_one_is_poisson_at_burst_rate(self):
        burst = generate(WorkloadSpec(kind="burst", rate=10.0,
                                      duration_s=5.0, burst_fraction=1.0,
                                      burst_factor=4.0, seed=9))
        plain = generate(WorkloadSpec(kind="poisson", rate=40.0,
                                      duration_s=5.0, seed=9))
        assert burst == plain

    def test_single_step_ramp_is_uniform_window_at_min_rate(self):
        ramp = generate(WorkloadSpec(kind="ramp", duration_s=5.0,
                                     ramp_min_rate=25.0,
                                     ramp_max_rate=400.0, ramp_steps=1,
                                     seed=9))
        plain = generate(WorkloadSpec(kind="poisson", rate=25.0,
                                      duration_s=5.0, seed=9))
        assert ramp == plain

    def test_single_step_sweep_matches_single_step_ramp(self):
        kw = dict(duration_s=5.0, ramp_min_rate=25.0, ramp_max_rate=400.0,
                  ramp_steps=1, seed=9)
        assert generate(WorkloadSpec(kind="sweep", **kw)) \
            == generate(WorkloadSpec(kind="ramp", **kw))


# ---- scenario profiles (tentpole 1 + satellite c) --------------------------
class TestProfiles:
    def test_required_catalog(self):
        names = list_profiles()
        for required in ("chat", "code-generation", "summarization",
                        "classification", "rag-long-context"):
            assert required in names
        table = catalog_table()
        for name in names:
            assert name in table

    def test_unknown_profile_lists_known(self):
        with pytest.raises(ValueError, match="chat"):
            get_profile("no-such-scenario")

    def test_register_rejects_duplicates(self):
        prof = ScenarioProfile(name="chat", description="dup",
                               prompt_tokens=1)
        with pytest.raises(ValueError, match="already registered"):
            register_profile(prof)

    @pytest.mark.parametrize("name", ["chat", "code-generation",
                                      "summarization", "classification",
                                      "rag-long-context"])
    def test_one_config_line_resolves(self, name):
        spec = spec_from_dict({
            "job_id": f"s-{name}", "model": {"name": "gemma2-2b"},
            "scenario": name,
            "workload": {"rate": 5.0, "duration_s": 3.0, "seed": 4}})
        prof = get_profile(name)
        assert spec.workload.prompt_tokens == prof.prompt_tokens
        assert spec.workload.output_tokens == prof.output_tokens
        assert spec.workload.session_count == prof.session_count
        assert spec.workload.prefix_tokens == prof.prefix_tokens
        for field, slo in prof.slos().items():
            assert getattr(spec, field) == slo
        # at least one SLO so the profile is benchmarkable out of the box
        assert any(v is not None for v in prof.slos().values())
        # explicit rate survived the profile
        assert spec.workload.rate == 5.0

    def test_round_trip_is_stable_and_deterministic(self):
        spec = spec_from_dict({
            "job_id": "rt", "model": {"name": "gemma2-2b"},
            "scenario": "chat",
            "workload": {"rate": 5.0, "duration_s": 3.0, "seed": 4}})
        d = spec.to_dict()
        again = BenchmarkJobSpec.from_dict(json.loads(json.dumps(d)))
        assert again == spec and again.to_dict() == d
        reqs = generate(spec.workload)
        assert reqs == generate(again.workload)
        assert len(reqs) > 0

    def test_explicit_fields_beat_profile(self):
        spec = spec_from_dict({
            "job_id": "win", "model": {"name": "gemma2-2b"},
            "scenario": "chat", "slo_ttft_s": 9.0,
            "workload": {"rate": 5.0, "duration_s": 3.0,
                         "prompt_tokens": 333, "prompt_tokens_max": 2000}})
        assert spec.workload.prompt_tokens == 333
        assert spec.workload.prompt_tokens_max == 2000
        assert spec.slo_ttft_s == 9.0
        # untouched fields still come from the profile
        assert spec.slo_tpot_s == get_profile("chat").slo_tpot_s

    def test_profile_fits_model_context(self):
        max_len = get_config("gemma2-2b").max_seq_len
        for name in list_profiles():
            prof = get_profile(name)
            assert max(prof.prompt_tokens, prof.prompt_tokens_max) \
                + 1 <= max_len, name

    def test_session_runs_scenario_and_records_it(self, tmp_path):
        session = BenchmarkSession(n_workers=1,
                                   db=PerfDB(tmp_path / "perf.jsonl"))
        session.submit({"job_id": "e2e-chat",
                        "model": {"name": "gemma2-2b"}, "chips": 4,
                        "scenario": "chat",
                        "workload": {"rate": 4.0, "duration_s": 3.0,
                                     "seed": 2}})
        (result,) = session.run()
        assert result.metric("throughput_rps") > 0
        rec = result.to_record()
        assert rec["scenario"] == "chat"
        back = JobResult.from_record(rec)
        assert back.spec == result.spec
        assert back.spec.scenario == "chat"
        # and the PerfDB row on disk carries it too
        (row,) = [json.loads(l) for l in
                  (tmp_path / "perf.jsonl").read_text().splitlines()]
        assert row["scenario"] == "chat"


# ---- arrival processes (tentpole 2) ----------------------------------------
class TestArrivals:
    def test_diurnal_peak_beats_trough(self):
        wl = WorkloadSpec(kind="diurnal", rate=60.0, duration_s=40.0,
                          diurnal_period_s=40.0, diurnal_amplitude=0.9,
                          seed=1)
        reqs = generate(wl)
        # sin peak at t=10 (quarter period), trough at t=30
        peak = sum(5.0 <= r.arrival_s < 15.0 for r in reqs)
        trough = sum(25.0 <= r.arrival_s < 35.0 for r in reqs)
        assert peak > 2 * trough

    def test_diurnal_mean_rate_matches_empirical(self):
        wl = WorkloadSpec(kind="diurnal", rate=50.0, duration_s=60.0,
                          diurnal_period_s=15.0, seed=3)
        reqs = generate(wl)
        empirical = len(reqs) / wl.duration_s
        assert arrivals.mean_rate(wl) == pytest.approx(empirical, rel=0.1)

    def test_flash_crowd_spikes_then_decays(self):
        wl = WorkloadSpec(kind="flash-crowd", rate=20.0, duration_s=30.0,
                          burst_factor=8.0, flash_start_s=10.0,
                          flash_decay_s=3.0, seed=5)
        reqs = generate(wl)
        before = sum(r.arrival_s < 10.0 for r in reqs) / 10.0
        spike = sum(10.0 <= r.arrival_s < 13.0 for r in reqs) / 3.0
        tail = sum(25.0 <= r.arrival_s for r in reqs) / 5.0
        assert spike > 3 * before          # the spike is a real spike
        assert tail < 2 * before           # and it decays back to baseline
        assert arrivals.mean_rate(wl) == pytest.approx(
            len(reqs) / wl.duration_s, rel=0.15)

    def test_flash_sentinels_resolve_to_window_fractions(self):
        wl = WorkloadSpec(kind="flash-crowd", rate=5.0, duration_s=30.0)
        assert arrivals.flash_params(wl) == (10.0, 3.0)

    def test_sweep_ladder_is_geometric(self):
        wl = WorkloadSpec(kind="sweep", duration_s=8.0, ramp_min_rate=10.0,
                          ramp_max_rate=160.0, ramp_steps=5)
        rates = arrivals.sweep_step_rates(wl)
        assert rates[0] == pytest.approx(10.0)
        assert rates[-1] == pytest.approx(160.0)
        ratios = [b / a for a, b in zip(rates, rates[1:])]
        assert all(r == pytest.approx(2.0) for r in ratios)

    def test_sweep_load_increases_per_step(self):
        wl = WorkloadSpec(kind="sweep", duration_s=8.0, ramp_min_rate=20.0,
                          ramp_max_rate=320.0, ramp_steps=4, seed=7)
        reqs = generate(wl)
        step = wl.duration_s / wl.ramp_steps
        counts = [sum(k * step <= r.arrival_s < (k + 1) * step
                      for r in reqs) for k in range(wl.ramp_steps)]
        assert counts == sorted(counts) and counts[-1] > 4 * counts[0]

    def test_mean_rate_steady_kinds(self):
        assert arrivals.mean_rate(WorkloadSpec(rate=12.0)) == 12.0
        burst = WorkloadSpec(kind="burst", rate=10.0, burst_factor=5.0,
                             burst_fraction=0.5)
        assert arrivals.mean_rate(burst) == pytest.approx(30.0)

    def test_deterministic_per_seed(self):
        for kind in ("diurnal", "flash-crowd", "sweep"):
            wl = WorkloadSpec(kind=kind, rate=30.0, duration_s=6.0, seed=11)
            assert generate(wl) == generate(wl)
            bumped = generate(WorkloadSpec(kind=kind, rate=30.0,
                                           duration_s=6.0, seed=12))
            assert bumped != generate(wl)


# ---- multi-tenant traffic (tentpole 3) -------------------------------------
class TestTenants:
    def test_validation(self):
        with pytest.raises(ValueError, match="non-empty name"):
            TenantSpec(name="")
        with pytest.raises(ValueError, match="share > 0"):
            TenantSpec(name="t", share=0.0)
        with pytest.raises(ValueError, match="unknown"):
            TenantSpec(name="t", scenario="no-such-profile")
        with pytest.raises(ValueError, match="duplicate"):
            WorkloadSpec(rate=5.0, tenants=({"name": "a"}, {"name": "a"}))
        for kind in ("closed", "trace"):
            wl = WorkloadSpec(kind=kind, rate=5.0, trace_path="x.jsonl",
                              tenants=TENANTS)
            with pytest.raises(ValueError, match="multi-tenant"):
                generate(wl)

    def test_generate_tags_and_splits_shares(self):
        wl = WorkloadSpec(rate=40.0, duration_s=10.0, seed=7,
                          tenants=TENANTS)
        reqs = generate(wl)
        assert [r.req_id for r in reqs] == list(range(len(reqs)))
        assert all(a.arrival_s <= b.arrival_s
                   for a, b in zip(reqs, reqs[1:]))
        counts = {t: sum(r.tenant == t for r in reqs)
                  for t in ("chatbot", "classifier")}
        assert set(counts) == {"chatbot", "classifier"}
        assert counts["chatbot"] + counts["classifier"] == len(reqs)
        # 3:1 share split, generous statistical tolerance
        assert 1.8 < counts["chatbot"] / counts["classifier"] < 4.5
        # per-tenant profiles shaped the slices
        chat_prompts = {r.prompt_tokens for r in reqs
                        if r.tenant == "chatbot"}
        cls_out = {r.output_tokens for r in reqs
                   if r.tenant == "classifier"}
        assert min(chat_prompts) >= 256 and cls_out == {1}
        # disjoint session-id ranges: affinity/prefix never alias
        chat_sids = {r.session_id for r in reqs if r.tenant == "chatbot"}
        cls_sids = {r.session_id for r in reqs if r.tenant == "classifier"}
        assert not (chat_sids & cls_sids)

    def test_absolute_rate_overrides_share(self):
        wl = WorkloadSpec(rate=10.0, duration_s=10.0, seed=3,
                          tenants=({"name": "fixed", "rate": 30.0},
                                   {"name": "rest", "share": 1.0}))
        reqs = generate(wl)
        fixed = sum(r.tenant == "fixed" for r in reqs) / wl.duration_s
        assert fixed == pytest.approx(30.0, rel=0.2)

    def test_resolved_slos_fall_back_to_profile(self):
        own = TenantSpec(name="a", scenario="chat", slo_ttft_s=2.0)
        slos = resolve_tenant_slos(own)
        assert slos["slo_ttft_s"] == 2.0                   # own field wins
        assert slos["slo_tpot_s"] == get_profile("chat").slo_tpot_s

    def test_jain_index(self):
        assert jain_index([5.0, 5.0, 5.0]) == pytest.approx(1.0)
        assert jain_index([1.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)
        assert jain_index([]) == 0.0
        assert jain_index([0.0, 0.0]) == 0.0

    def test_sim_slices_and_report(self, lat):
        wl = WorkloadSpec(rate=20.0, duration_s=8.0, seed=7,
                          tenants=TENANTS)
        res = _sim(wl, lat)
        assert res.tenants() == ["chatbot", "classifier"]
        sub = res.tenant_result("chatbot")
        assert sub.traces and all(t.request.tenant == "chatbot"
                                  for t in sub.traces)
        report = tenant_report(res, wl.tenants)
        per = report["per_tenant"]
        assert set(per) == {"chatbot", "classifier"}
        total = sum(p["requests"] for p in per.values())
        assert total == len(res.traces)
        assert 0.0 < report["fairness_index"] <= 1.0
        assert report["worst_tenant"] in per
        assert report["worst_tenant_attainment"] == \
            min(p["slo_attainment"] for p in per.values())
        assert "fairness=" in tenant_table(report)

    def test_session_reports_tenants(self, tmp_path):
        session = BenchmarkSession(n_workers=1,
                                   db=PerfDB(tmp_path / "perf.jsonl"))
        session.submit({"job_id": "mt", "model": {"name": "gemma2-2b"},
                        "chips": 4, "slo_latency_s": 2.0,
                        "workload": {"rate": 10.0, "duration_s": 5.0,
                                     "seed": 3,
                                     "tenants": list(TENANTS)}})
        (result,) = session.run()
        rep = result.metrics["tenants"]
        assert set(rep["per_tenant"]) == {"chatbot", "classifier"}
        assert 0.0 < rep["fairness_index"] <= 1.0
        # the workload (tenants included) round-trips through the record
        back = JobResult.from_record(result.to_record())
        assert back.spec.workload == result.spec.workload


# ---- tenant-aware capacity planning ----------------------------------------
class TestPlannerTenants:
    def test_plan_and_reverify_best(self, lat):
        base = WorkloadSpec(rate=16.0, duration_s=6.0, seed=11)
        plan = plan_capacity(lat, base, tenants=TENANTS, slo_target=0.9,
                             replicas=(1, 2), policies=("continuous",),
                             max_batch=16)
        best = plan.best
        assert best is not None
        feasible = [c for c in plan.candidates if c.meets_slo]
        assert best.objective == min(c.objective for c in feasible)
        assert 0.0 < best.metrics["fairness_index"] <= 1.0
        assert set(best.metrics["tenants"]) == {"chatbot", "classifier"}
        # independently re-simulate the winning config: every tenant must
        # hit its own SLOs at the target (plan → verify)
        res = simulate_candidate(lat, base, best, tenants=TENANTS)
        rep = tenant_report(res, TENANTS)
        assert rep["worst_tenant_attainment"] == \
            pytest.approx(best.metrics["slo_attainment"])
        for name, per in rep["per_tenant"].items():
            assert per["slo_attainment"] >= 0.9, name

    def test_tenant_without_any_slo_rejected(self, lat):
        with pytest.raises(ValueError, match="resolves no SLO"):
            plan_capacity(lat, WorkloadSpec(rate=4.0, duration_s=3.0),
                          tenants=({"name": "bare"},))

    def test_plain_plan_still_requires_slo(self, lat):
        with pytest.raises(ValueError, match="at least one SLO"):
            plan_capacity(lat, WorkloadSpec(rate=4.0, duration_s=3.0))


# ---- synthetic trace scaling (tentpole 4) ----------------------------------
class TestSynth:
    def test_errors(self):
        with pytest.raises(ValueError, match="at least 2"):
            scale_trace([{"arrival_s": 0.0}], 10.0)
        with pytest.raises(ValueError, match="factor"):
            scale_trace(SEED_TRACE, 0.0)

    def test_100x_preserves_shape(self):
        # the acceptance bar: 100× volume, interarrival CV within 20%,
        # session-length p50/p95 within 15%
        s0 = trace_stats(SEED_TRACE)
        assert s0["interarrival_cv"] > 1.1   # the seed is genuinely bursty
        big = scale_trace(SEED_TRACE, 100.0, seed_rng=1)
        s1 = trace_stats(big)
        assert s1["requests"] == pytest.approx(100 * s0["requests"])
        assert abs(s1["interarrival_cv"] - s0["interarrival_cv"]) \
            <= 0.20 * s0["interarrival_cv"]
        for q in ("session_len_p50", "session_len_p95"):
            assert abs(s1[q] - s0[q]) <= 0.15 * s0[q]
        # same wall window (rate went up 100×, duration did not)
        assert s1["duration_s"] == pytest.approx(s0["duration_s"], rel=0.3)
        assert s1["mean_prompt_tokens"] == pytest.approx(
            s0["mean_prompt_tokens"], rel=0.1)

    def test_deterministic_and_sorted(self):
        a = scale_trace(SEED_TRACE, 5.0, seed_rng=3)
        assert a == scale_trace(SEED_TRACE, 5.0, seed_rng=3)
        assert a != scale_trace(SEED_TRACE, 5.0, seed_rng=4)
        times = [r["arrival_s"] for r in a]
        assert times == sorted(times)

    def test_sessions_keep_prefix_structure(self):
        out = scale_trace(SEED_TRACE, 3.0, seed_rng=2)
        by_sid = {}
        for r in out:
            by_sid.setdefault(r["session_id"], []).append(r)
        for sid, rows in by_sid.items():
            # a cloned session keeps one shared prefix, like its template
            assert len({r["prefix_tokens"] for r in rows}) == 1
        seed_stats = trace_stats(SEED_TRACE)
        assert np.mean([r["prefix_tokens"] for r in out]) == pytest.approx(
            seed_stats["mean_prefix_tokens"], rel=0.25)

    def test_scaled_trace_replays(self, tmp_path, lat):
        out = scale_trace(SEED_TRACE, 2.0, seed_rng=5)
        path = write_trace_rows(out, tmp_path / "scaled.jsonl",
                                header="scaled 2x for replay test")
        wl = WorkloadSpec(kind="trace", trace_path=str(path))
        reqs = generate(wl)
        assert len(reqs) == len(out)
        res = _sim(wl, lat)
        assert len(res.traces) == len(reqs)

    def test_downscale(self):
        small = scale_trace(SEED_TRACE, 0.25, seed_rng=6)
        s0 = trace_stats(SEED_TRACE)
        assert len(small) == pytest.approx(0.25 * s0["requests"], abs=1)
