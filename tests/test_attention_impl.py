"""The Pallas flash-attention path must agree with the XLA path at the
model level (full forward of a dense and a local-window arch)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model, reduced
from repro.models import layers as L


@pytest.mark.parametrize("name", ["granite-3-2b", "gemma2-2b"])
def test_flash_path_matches_xla(name):
    cfg = reduced(ARCHS[name])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab_size)
    try:
        L.set_attention_impl("xla")
        ref, _ = model.forward(params, tokens)
        L.set_attention_impl("pallas")
        out, _ = model.forward(params, tokens)
    finally:
        L.set_attention_impl("xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-3, rtol=5e-3)


def test_ragged_masks_fall_back_to_xla():
    """prefill (k_valid mask) must not take the kernel path."""
    try:
        L.set_attention_impl("pallas")
        assert not L._flash_ok(None, 0, 0.0, jnp.ones((2, 8), bool))
        assert L._flash_ok(None, 0, 0.0, None)
        # traced per-layer window scalars are not static ints -> fallback
        assert not L._flash_ok(None, jnp.int32(4), 0.0, None)
    finally:
        L.set_attention_impl("xla")
    assert not L._flash_ok(None, 0, 0.0, None)   # toggle off -> xla
