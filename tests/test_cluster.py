"""Cluster simulator: continuous batching, routers, autoscaler, the
duration/t_batch_wait fixes, and concrete runs of the shared invariant
checks (the hypothesis-free twin of test_simulator_invariants)."""
from pathlib import Path

import pytest

from repro.configs import get_config
from repro.core import BenchmarkJobSpec, ClusterSpec as CoreClusterSpec, \
    run_stages
from repro.core.analysis import saturation_knee, slo_attainment
from repro.serving.batching import ContinuousBatcher, make_policy
from repro.serving.cluster import (Autoscaler, ClusterSpec,
                                   LeastLoadedRouter, make_router,
                                   simulate_cluster)
from repro.serving.latency_model import LatencyModel
from repro.serving.simulator import simulate
from repro.serving.workload import WorkloadSpec, generate

from invariant_checks import (check_all_complete_exactly_once,
                              check_busy_bound, check_closed_concurrency,
                              check_duration_covers_window,
                              check_stage_sanity, policy_cap, run_sim)

SAMPLE_TRACE = str(Path(__file__).resolve().parent.parent
                   / "configs" / "traces" / "sample.jsonl")


@pytest.fixture(scope="module")
def lat():
    return LatencyModel(get_config("gemma2-2b"), chips=4)


class TestContinuousBatcher:
    def test_all_served_with_generation(self, lat):
        wl = WorkloadSpec(rate=100, duration_s=2, output_tokens=4,
                          output_tokens_max=16, seed=0)
        res = simulate(wl, make_policy("continuous", max_batch=8), lat)
        assert len(res.traces) == len(generate(wl))
        assert all(1 <= t.batch_size <= 8 for t in res.traces)

    def test_mid_batch_join(self, lat):
        """A request arriving while a long batch decodes joins mid-batch
        instead of waiting for the whole batch to finish."""
        wl = WorkloadSpec(kind="uniform", rate=40, duration_s=1,
                          output_tokens=64, seed=0)
        res = simulate(wl, make_policy("continuous", max_batch=16), lat)
        joined = [t for t in res.traces if t.batch_size > 1]
        assert joined, "no request ever shared the running batch"
        # queueing stays far below one full-request latency
        solo = lat.request_latency(1, wl.prompt_tokens, wl.output_tokens)
        late = [t for t in res.traces if t.request.arrival_s > 0.1]
        assert late and min(t.t_queue for t in late) < solo

    def test_continuous_beats_window_on_ramp(self, lat):
        """Acceptance: ≥ window-batcher throughput at equal-or-better p99
        on the ramp scenario."""
        wl = WorkloadSpec(kind="ramp", duration_s=3, ramp_min_rate=50,
                          ramp_max_rate=400, ramp_steps=3,
                          output_tokens=8, output_tokens_max=32, seed=0)
        win = simulate(wl, make_policy("tfs", max_batch=16,
                                       timeout_s=0.01), lat)
        cont = simulate(wl, make_policy("continuous", max_batch=16), lat)
        assert cont.throughput() >= win.throughput()
        assert cont.percentile(99) <= win.percentile(99)

    def test_config_validation(self):
        with pytest.raises(ValueError):
            ContinuousBatcher(max_batch=0)
        with pytest.raises(TypeError):
            ContinuousBatcher().next_batch([], 0.0, 0.0)


class TestClusterSpecValidation:
    def test_rejects_zero_replicas(self):
        with pytest.raises(ValueError):
            ClusterSpec(replicas=0)

    def test_rejects_scale_to_zero(self):
        """min_replicas=0 would let the autoscaler retire the last
        replica; the cluster can never scale back up from zero (backlog
        is only observed on live replicas), so reject it up front."""
        with pytest.raises(ValueError):
            ClusterSpec(autoscale=True, min_replicas=0)
        with pytest.raises(ValueError):
            ClusterSpec(min_replicas=2, max_replicas=1)


class TestRouters:
    def test_make_router_aliases(self):
        assert make_router("jsq").name == "least-loaded"
        assert make_router("rr").name == "round-robin"
        assert make_router("session").name == "affinity"
        with pytest.raises(ValueError):
            make_router("nope")

    def test_affinity_is_sticky(self, lat):
        wl = WorkloadSpec(rate=150, duration_s=2, session_count=6,
                          output_tokens=2, seed=1)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=8), lat,
            cluster=ClusterSpec(replicas=3, router="affinity"))
        by_session = {}
        for t in res.traces:
            by_session.setdefault(t.request.session_id, set()).add(t.replica)
        assert all(len(reps) == 1 for reps in by_session.values())

    def test_least_loaded_spreads(self, lat):
        wl = WorkloadSpec(rate=400, duration_s=2, output_tokens=4, seed=2)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=8), lat,
            cluster=ClusterSpec(replicas=4, router="least-loaded"))
        used = {t.replica for t in res.traces}
        assert used == {0, 1, 2, 3}

    def test_four_replicas_sustain_3x_single_rate(self, lat):
        """Acceptance: a 4-replica least-loaded cluster sustains ≥ 3× the
        single-replica saturation rate."""
        def saturation(replicas):
            last = None
            for rate in (100, 200, 300, 400, 600, 800, 1200, 1600):
                wl = WorkloadSpec(rate=rate, duration_s=2, output_tokens=8,
                                  output_tokens_max=32, seed=3)
                res = simulate_cluster(
                    wl, make_policy("continuous", max_batch=16), lat,
                    cluster=ClusterSpec(replicas=replicas,
                                        router="least-loaded"))
                if res.duration_s > 1.1 * wl.duration_s \
                        or res.percentile(99) > 0.25:
                    break
                last = rate
            return last

        single, quad = saturation(1), saturation(4)
        assert single and quad and quad >= 3 * single


class TestAutoscaler:
    def test_scales_up_under_backlog(self, lat):
        wl = WorkloadSpec(rate=600, duration_s=2, output_tokens=8, seed=4)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=8), lat,
            cluster=ClusterSpec(replicas=1, autoscale=True, max_replicas=4,
                                scale_interval_s=0.2, spawn_delay_s=0.1))
        assert 1 < res.replicas <= 4
        fixed = simulate_cluster(
            wl, make_policy("continuous", max_batch=8), lat,
            cluster=ClusterSpec(replicas=1))
        assert res.percentile(99) < fixed.percentile(99)

    def test_respects_max_replicas(self, lat):
        wl = WorkloadSpec(rate=800, duration_s=1.5, output_tokens=8, seed=5)
        res = simulate_cluster(
            wl, make_policy("continuous", max_batch=4), lat,
            cluster=ClusterSpec(replicas=1, autoscale=True, max_replicas=2,
                                scale_interval_s=0.2, spawn_delay_s=0.1))
        assert res.replicas <= 2
        check_busy_bound(res)


class TestSatelliteFixes:
    def test_sparse_open_loop_duration_not_inflated(self, lat):
        """Regression: duration_s = max(workload window, last completion),
        so a sparse workload no longer inflates throughput/utilization."""
        wl = WorkloadSpec(rate=1, duration_s=10, seed=0)
        res = simulate(wl, make_policy("none"), lat)
        n = len(generate(wl))
        assert res.duration_s == pytest.approx(10.0)
        assert res.throughput() == pytest.approx(n / 10.0)

    def test_overload_extends_duration(self, lat):
        wl = WorkloadSpec(rate=4000, duration_s=1, output_tokens=8, seed=1)
        res = simulate(wl, make_policy("tfs", max_batch=8,
                                       timeout_s=0.002), lat)
        last_done = max(t.done_s for t in res.traces)
        assert res.duration_s == pytest.approx(last_done)
        assert res.duration_s > 1.0

    def test_batch_wait_populated_and_in_stage_means(self, lat):
        """A lone request under a window batcher waits out the timeout:
        that wait is batching-attributable, hence t_batch_wait ≈ t_queue."""
        wl = WorkloadSpec(rate=2, duration_s=1, seed=2)
        res = simulate(wl, make_policy("tfs", max_batch=8,
                                       timeout_s=0.05), lat)
        means = res.stage_means()
        assert "batch_wait" in means and means["batch_wait"] > 0.04
        for t in res.traces:
            assert t.t_batch_wait == pytest.approx(t.t_queue)
            assert t.t_batch_wait >= 0.05 - 1e-9

    def test_batch_wait_zero_when_server_is_bottleneck(self, lat):
        """NoBatching never holds requests: all queueing is server-busy
        wait, none batching-attributable."""
        wl = WorkloadSpec(rate=2000, duration_s=0.5, seed=3)
        res = simulate(wl, make_policy("none"), lat)
        assert max(t.t_batch_wait for t in res.traces) < 1e-9
        assert max(t.t_queue for t in res.traces) > 0


class TestConcreteInvariants:
    """The hypothesis-gated invariants on fixed examples (always run)."""

    CASES = [
        ("poisson", "tfs", {"max_batch": 8, "timeout_s": 0.004}, 1),
        ("burst", "tris", {"preferred": (8, 4, 2, 1)}, 2),
        ("ramp", "continuous", {"max_batch": 8, "max_prefill": 4}, 3),
        ("uniform", "none", {}, 2),
    ]

    @pytest.mark.parametrize("kind,policy,kw,replicas", CASES)
    def test_invariants(self, kind, policy, kw, replicas):
        wl = WorkloadSpec(kind=kind, rate=120, duration_s=1.5,
                          output_tokens=2, output_tokens_max=6,
                          ramp_min_rate=30, ramp_max_rate=150,
                          ramp_steps=3, seed=11)
        res = run_sim(wl, policy, replicas=replicas,
                      router="least-loaded", **kw)
        check_all_complete_exactly_once(wl, res)
        check_stage_sanity(res, policy_cap(policy, **kw))
        check_busy_bound(res)
        check_duration_covers_window(wl, res)

    def test_closed_loop_concurrency(self):
        wl = WorkloadSpec(kind="closed", concurrency=5, duration_s=1,
                          output_tokens=2, seed=12)
        res = run_sim(wl, "continuous", replicas=2, router="affinity",
                      max_batch=4)
        check_all_complete_exactly_once(wl, res)
        check_closed_concurrency(wl, res)
        check_busy_bound(res)


class TestEndToEndPlumbing:
    def test_spec_round_trip_with_cluster(self):
        spec = BenchmarkJobSpec(
            job_id="c0", cluster=CoreClusterSpec(replicas=4,
                                                 router="least-loaded"),
            workload=WorkloadSpec(kind="trace", trace_path=SAMPLE_TRACE))
        again = BenchmarkJobSpec.from_dict(spec.to_dict())
        assert again == spec
        assert again.cluster.replicas == 4

    def test_run_stages_cluster_metrics(self):
        spec = BenchmarkJobSpec(
            job_id="c1", chips=4, slo_latency_s=0.5,
            software={"policy": "continuous", "max_batch": 8},
            cluster=CoreClusterSpec(replicas=2, router="least-loaded"),
            workload=WorkloadSpec(rate=100, duration_s=1, output_tokens=2,
                                  seed=0))
        spec = BenchmarkJobSpec.from_dict(spec.to_dict())
        result = run_stages(spec)
        assert result.metrics["replicas"] == 2
        assert 0.0 <= result.metrics["slo_attainment"] <= 1.0
        assert result.cluster["router"] == "least-loaded"
        assert len(result.cluster["per_replica_busy_s"]) == 2
        rec = result.to_record()
        assert rec["cluster"]["replicas"] == 2
        from repro.core import JobResult
        assert JobResult.from_record(rec).cluster == result.cluster
        assert rec["stages"]["batch_wait"] >= 0.0

    def test_analysis_helpers(self):
        assert slo_attainment([0.1, 0.2, 0.4], 0.25) == pytest.approx(2 / 3)
        assert slo_attainment([], 0.25) == 0.0
        assert saturation_knee([10, 20, 40], [0.1, 0.2, 0.9], 0.25) == 20
        assert saturation_knee([10, 20], [0.9, 1.0], 0.25) is None
