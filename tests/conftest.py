"""Gate tests whose optional dependencies are absent in this image."""
collect_ignore = []

try:
    import hypothesis  # noqa: F401
except ImportError:
    collect_ignore.append("test_property.py")
    collect_ignore.append("test_simulator_invariants.py")
