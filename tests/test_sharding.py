"""Logical-axis sharding rules: divisibility fallback, duplicate-axis
avoidance, per-device byte accounting.  Uses AbstractMesh so no devices
are needed."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd

MESH1 = shd.abstract_mesh((16, 16), ("data", "model"))
MESH2 = shd.abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def spec(shape, axes, rules=None, mesh=MESH1):
    return shd.partition_spec(shape, axes, rules or shd.TRAIN_RULES, mesh)


def test_basic_tp():
    assert spec((4096, 14336), ("embed", "ffn")) == P("data", "model")


def test_divisibility_fallback_drops_axis():
    # 4 kv heads can't shard over 16-way model → replicated
    assert spec((2048, 4, 128), ("embed", "kv_heads", "head_dim")) \
        == P("data",)
    # whisper vocab 51865 % 16 != 0 → vocab dropped, embed keeps data
    assert spec((51865, 384), ("vocab", "embed")) == P(None, "data")


def test_duplicate_axis_avoided():
    # both dims want "model": only the first gets it
    s = spec((1024, 1024), ("ffn", "embed_out"))
    flat = [a for a in s if a is not None]
    assert flat.count("model") <= 1


def test_multi_axis_batch():
    assert spec((256, 4096), ("batch", "seq"), mesh=MESH2) \
        == P(("pod", "data"),)
    # missing pod axis on single-pod mesh → just data
    assert spec((256, 4096), ("batch", "seq"), mesh=MESH1) == P("data",)
    # batch=1 long-context decode → fully replicated
    assert spec((1,), ("batch",), mesh=MESH1) == P()


def test_kv_cache_seq_sharding():
    s = spec((26, 128, 32768, 4, 256),
             ("layers", "batch", "kv", "kv_heads", "head_dim"))
    assert s == P(None, "data", "model")


def test_tree_specs_and_bytes():
    shapes = {"w": jax.ShapeDtypeStruct((4096, 14336), jax.numpy.float32),
              "b": jax.ShapeDtypeStruct((14336,), jax.numpy.float32)}
    axes = {"w": ("embed", "ffn"), "b": ("ffn",)}
    specs = shd.tree_partition_specs(shapes, axes, shd.TRAIN_RULES, MESH1)
    assert specs["w"] == P("data", "model")
    per_dev = shd.bytes_per_device(shapes, specs, MESH1)
    # w: 4096·14336·4/256, b: 14336·4/16
    assert per_dev == (4096 * 14336 * 4) // 256 + (14336 * 4) // 16


def test_rules_variants():
    assert shd.SERVE_TP_RULES["embed"] == []
    assert shd.MOE_EP_RULES["expert"] == ["model"]
    s = shd.partition_spec((16, 6144, 10752), ("expert", "embed", "ffn"),
                           shd.MOE_EP_RULES, MESH1)
    assert s == P("model", "data")


def test_rank_mismatch_raises():
    with pytest.raises(ValueError):
        spec((4, 4), ("embed",))
