"""Two-tier scheduler: invariants + the paper's Fig. 15 claim."""
import numpy as np
import pytest

from repro.core.scheduler import (ClusterScheduler, Job, average_jct,
                                  evaluate_schedulers, make_job_trace)


def _check_no_overlap(schedule):
    by_worker = {}
    for s in schedule:
        by_worker.setdefault(s.worker, []).append(s)
    for jobs in by_worker.values():
        jobs.sort(key=lambda s: s.start_s)
        for a, b in zip(jobs, jobs[1:]):
            assert b.start_s >= a.finish_s - 1e-9


@pytest.mark.parametrize("lb", ["rr", "qa"])
@pytest.mark.parametrize("order", ["fcfs", "sjf"])
def test_schedule_validity(lb, order):
    jobs = make_job_trace(n_jobs=100, seed=3)
    sched = ClusterScheduler(4, lb=lb, order=order).run(jobs)
    assert len(sched) == len(jobs)                       # all jobs run once
    assert len({s.job.job_id for s in sched}) == len(jobs)
    for s in sched:
        assert s.start_s >= s.job.submit_s - 1e-9        # no time travel
        assert abs((s.finish_s - s.start_s) - s.job.processing_s) < 1e-9
    _check_no_overlap(sched)


def test_sjf_beats_fcfs_single_worker_batch():
    """All jobs at t=0 on one worker: SJF minimises mean JCT (theorem)."""
    jobs = [Job(f"j{i}", 0.0, p) for i, p in enumerate([9, 1, 5, 3, 7])]
    fcfs = average_jct(ClusterScheduler(1, lb="rr", order="fcfs").run(jobs))
    sjf = average_jct(ClusterScheduler(1, lb="rr", order="sjf").run(jobs))
    assert sjf <= fcfs
    # exact optimum for this instance: sorted prefix sums
    ps = np.cumsum(sorted([9, 1, 5, 3, 7]))
    assert abs(sjf - ps.mean()) < 1e-9


def test_qa_beats_rr_under_skew():
    """Queue-aware placement wins when jobs are heavy-tailed."""
    jobs = make_job_trace(n_jobs=300, n_heavy_frac=0.3, seed=7)
    rr = average_jct(ClusterScheduler(4, lb="rr", order="fcfs").run(jobs))
    qa = average_jct(ClusterScheduler(4, lb="qa", order="fcfs").run(jobs))
    assert qa <= rr * 1.02


def test_paper_claim_speedup():
    """Paper: QA-LB + SJF improves average JCT ≥1.43× vs RR + FCFS.
    Across seeds our heavy-tailed trace reproduces at least that much."""
    speedups = [evaluate_schedulers(seed=s)["speedup_qa_sjf_vs_rr_fcfs"]
                for s in range(5)]
    assert min(speedups) >= 1.43
