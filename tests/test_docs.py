"""Docs stay honest: every ``repro.*`` dotted symbol referenced by
docs/*.md must resolve to a real module/attribute, the public spec
dataclasses must document every field in their docstrings, and the
architecture page's mermaid diagram must at least parse structurally."""
import dataclasses
import importlib
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"
PAGES = ("architecture.md", "metrics.md", "calibration.md")

# repro.foo.bar but not repro.calibration-profile.v1 (schema strings)
SYMBOL = re.compile(r"\brepro(?:\.[A-Za-z_]\w*)+(?![-\w])")


def resolve(dotted: str):
    """Longest importable module prefix, then getattr the rest."""
    parts = dotted.split(".")
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
        except ImportError:
            continue
        for name in parts[i:]:
            obj = getattr(obj, name)       # AttributeError = broken doc
        return obj
    raise ImportError(dotted)


def test_doc_pages_exist_and_are_substantial():
    for page in PAGES:
        text = (DOCS / page).read_text()
        assert len(text) > 2000, f"{page} looks like a stub"


@pytest.mark.parametrize("page", PAGES)
def test_every_repro_symbol_resolves(page):
    text = (DOCS / page).read_text()
    symbols = sorted(set(SYMBOL.findall(text)))
    assert symbols, f"{page} references no repro.* entry points"
    broken = []
    for sym in symbols:
        try:
            resolve(sym)
        except (ImportError, AttributeError):
            broken.append(sym)
    assert not broken, f"{page} references unresolvable symbols: {broken}"


def test_readme_links_to_docs():
    readme = (REPO / "README.md").read_text()
    for page in PAGES:
        assert f"docs/{page}" in readme, f"README does not link {page}"
        assert (DOCS / page).exists()


def test_architecture_mermaid_block_parses_structurally():
    text = (DOCS / "architecture.md").read_text()
    blocks = re.findall(r"```mermaid\n(.*?)```", text, flags=re.S)
    assert blocks, "architecture.md has no mermaid diagram"
    diagram = blocks[0]
    first = diagram.strip().splitlines()[0]
    assert first.split()[0] in ("flowchart", "graph", "sequenceDiagram")
    # a dataflow diagram needs edges, and the fences must be balanced
    assert diagram.count("-->") >= 5
    assert text.count("```") % 2 == 0
    # the measure → model → plan loop must actually appear as stages
    for stage in ("measure", "model", "plan"):
        assert stage in diagram


# ---- docstring field coverage ----------------------------------------------
def spec_classes():
    from repro.core.spec import (BenchmarkJobSpec, CalibrationSpec,
                                 PlanSpec, SoftwareSpec)
    from repro.obs.spec import ObsSpec
    from repro.serving.latency_model import SpeedMode
    return [BenchmarkJobSpec, SoftwareSpec, CalibrationSpec, PlanSpec,
            ObsSpec, SpeedMode]


@pytest.mark.parametrize("cls", spec_classes(),
                         ids=lambda c: c.__name__)
def test_public_spec_fields_are_documented(cls):
    doc = cls.__doc__ or ""
    assert len(doc.strip()) > 80, f"{cls.__name__} docstring is empty/thin"
    missing = [f.name for f in dataclasses.fields(cls)
               if not f.name.startswith("_") and f.name not in doc]
    assert not missing, \
        f"{cls.__name__} fields missing from its docstring: {missing}"


def test_job_spec_docstrings_mention_units():
    """Latency/size fields must say their units somewhere in the doc."""
    from repro.core.spec import BenchmarkJobSpec
    doc = BenchmarkJobSpec.__doc__
    assert "seconds" in doc
