"""Regression tests for the event-loop accounting fixes that rode along
with the indexed-scheduler refactor:

  * t_batch_wait no longer absorbs KV-pressure admission stalls — when a
    request sits queued because the replica's block budget is exhausted,
    the wait belongs to memory pressure (visible in t_queue and the
    preemption/occupancy stats), not to the batching policy;
  * prompts at or beyond the context limit are rejected up front instead
    of being admitted with a 1-token sentinel and decoded past
    ``max_model_len``;
  * round-robin routing is skip-based over stable replica ids, so
    autoscaler churn (or a replica finishing its cold start) no longer
    shifts the rotation for every later arrival.

Each test failed against the pre-refactor engine.
"""
import json

import pytest

from invariant_checks import check_event_budget
from repro.configs import get_config
from repro.serving.batching import make_policy
from repro.serving.cluster import ClusterSpec, RoundRobinRouter, \
    simulate_cluster
from repro.serving.latency_model import LatencyModel
from repro.serving.memory import KVBudgetError, MemorySpec
from repro.serving.workload import WorkloadSpec


@pytest.fixture(scope="module")
def lat():
    return LatencyModel(get_config("gemma2-2b"), chips=4)


def _trace_workload(tmp_path, rows):
    path = tmp_path / "trace.jsonl"
    path.write_text("\n".join(json.dumps(r) for r in rows))
    return WorkloadSpec(kind="trace", trace_path=str(path))


# ---- t_batch_wait under KV pressure ---------------------------------------
def test_kv_blocked_wait_not_charged_to_batching(lat, tmp_path):
    """Two requests against a budget that holds only one: the second is
    KV-blocked until the first completes and frees its blocks.  That
    wait used to land in t_batch_wait (the policy-attributable slice of
    queueing) because ``_slot_free_s`` only advanced when a *batch slot*
    freed; the batch was never full here, so the stale mark attributed
    the whole memory stall to the batcher."""
    # 9 blocks × 16 tokens = 144-token budget; r0 grows to exactly
    # 128 + 16 = 144 tokens, so r1 (32 + 4 tokens, 3 blocks) cannot be
    # admitted until r0 frees
    wl = _trace_workload(tmp_path, [
        {"arrival_s": 0.0, "prompt_tokens": 128, "output_tokens": 16,
         "payload_bytes": 4096},
        {"arrival_s": 0.001, "prompt_tokens": 32, "output_tokens": 4,
         "payload_bytes": 4096},
    ])
    res = simulate_cluster(
        wl, make_policy("continuous", max_batch=8, max_prefill=4), lat,
        cluster=ClusterSpec(memory=MemorySpec(
            num_blocks=9, block_tokens=16, prefix_caching=False)))
    assert len(res.traces) == 2
    blocked = next(t for t in res.traces if t.request.req_id == 1)
    r0 = next(t for t in res.traces if t.request.req_id == 0)
    # it really was memory-blocked: queued until roughly r0's completion
    assert blocked.t_queue > 0.5 * (r0.done_s - r0.request.arrival_s)
    # ... but none of that stall is the batching policy's fault: the
    # engine admits it at the iteration boundary where the blocks freed
    assert blocked.t_batch_wait <= 1e-9, (
        f"KV-pressure stall misattributed to batching: t_batch_wait="
        f"{blocked.t_batch_wait:.4f}s of t_queue={blocked.t_queue:.4f}s")


# ---- over-length prompt rejection -----------------------------------------
def test_overlong_prompt_rejected_with_memory(lat, tmp_path):
    """A prompt at/over max_model_len used to be admitted with the
    1-token output sentinel and decoded past the context limit."""
    wl = _trace_workload(tmp_path, [
        {"arrival_s": 0.0, "prompt_tokens": 8192, "output_tokens": 4,
         "payload_bytes": 4096},
    ])
    with pytest.raises(KVBudgetError, match="no room to decode"):
        simulate_cluster(wl, make_policy("continuous", max_batch=8), lat,
                         cluster=ClusterSpec(memory=MemorySpec()))


def test_overlong_prompt_rejected_without_memory(lat, tmp_path):
    """Same rejection on the memory-less path (context cap comes straight
    from the model config)."""
    wl = _trace_workload(tmp_path, [
        {"arrival_s": 0.0, "prompt_tokens": lat.cfg.max_seq_len,
         "output_tokens": 4, "payload_bytes": 4096},
    ])
    with pytest.raises(ValueError, match="max_model_len|context"):
        simulate_cluster(wl, make_policy("continuous", max_batch=8), lat)


def test_prompt_below_limit_still_served(lat, tmp_path):
    wl = _trace_workload(tmp_path, [
        {"arrival_s": 0.0, "prompt_tokens": lat.cfg.max_seq_len - 1,
         "output_tokens": 8, "payload_bytes": 4096},
    ])
    res = simulate_cluster(wl, make_policy("continuous", max_batch=8), lat)
    assert len(res.traces) == 1
    # the clamp still caps decode at the context limit: 1 token fits
    assert res.traces[0].tokens_out == 1


# ---- skip-based round-robin under churn -----------------------------------
class _Stub:
    def __init__(self, replica_id):
        self.replica_id = replica_id


def test_round_robin_rotation_static():
    r = RoundRobinRouter()
    engines = [_Stub(0), _Stub(1), _Stub(2)]
    picks = [engines[r.route(None, engines, 0.0)].replica_id
             for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]


def test_round_robin_stable_under_churn():
    """Retiring or adding a replica only affects that replica's slot in
    the rotation; the old counter-mod-len scheme shifted every later
    assignment (and double-hit neighbours) on any membership change."""
    r = RoundRobinRouter()
    e0, e1, e2 = _Stub(0), _Stub(1), _Stub(2)
    full = [e0, e1, e2]
    assert [full[r.route(None, full, 0.0)].replica_id
            for _ in range(4)] == [0, 1, 2, 0]
    # replica 1 retires (mid-rotation: last pick was id 0)
    remaining = [e0, e2]
    assert [remaining[r.route(None, remaining, 0.0)].replica_id
            for _ in range(4)] == [2, 0, 2, 0]
    # the autoscaler spawns replica 3: it slots in after id 2, and the
    # survivors keep their cadence
    grown = [e0, e2, _Stub(3)]
    assert [grown[r.route(None, grown, 0.0)].replica_id
            for _ in range(5)] == [2, 3, 0, 2, 3]


def test_kv_blocked_loop_stays_within_event_budget(lat):
    """Concrete twin of the hypothesis clock-advance property (gated on
    the hypothesis package): a bursty workload against a budget barely
    above one request keeps admission KV-blocked almost continuously,
    and the loop must still terminate within a linear event budget
    instead of re-arming blocked engines at ``now``."""
    wl = WorkloadSpec(kind="burst", rate=120, duration_s=1.0,
                      prompt_tokens=96, output_tokens=16,
                      payload_bytes=4096, seed=3)
    res = simulate_cluster(
        wl, make_policy("continuous", max_batch=8, max_prefill=4), lat,
        cluster=ClusterSpec(replicas=2, router="least-loaded",
                            memory=MemorySpec(num_blocks=8,
                                              block_tokens=16,
                                              prefix_caching=False)))
    assert res.traces, "no request completed under KV pressure"
    check_event_budget(res)


def test_round_robin_churn_runs_are_deterministic(lat):
    """Same seed + same autoscaled cluster (spawns *and* scale-downs
    mid-run) → identical assignment, regardless of router-internal
    state layout."""
    wl = WorkloadSpec(kind="burst", rate=200, duration_s=1.5,
                      output_tokens=2, payload_bytes=4096, seed=9)
    spec = ClusterSpec(replicas=1, router="round-robin", autoscale=True,
                       max_replicas=4, scale_interval_s=0.2,
                       spawn_delay_s=0.05)
    runs = [simulate_cluster(wl, make_policy("continuous", max_batch=8),
                             lat, cluster=spec) for _ in range(2)]
    assert runs[0].summary() == runs[1].summary()
    assert [t.done_s for t in runs[0].traces] \
        == [t.done_s for t in runs[1].traces]
