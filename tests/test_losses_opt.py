"""Chunked CE exactness + AdamW behaviour + costing helpers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.launch.costing import depth_variants, extrapolate
from repro.training.losses import chunked_cross_entropy
from repro.training.optimizer import (OptimizerConfig, adamw_update,
                                      init_opt_state, schedule)


def test_chunked_ce_matches_full():
    key = jax.random.key(0)
    B, S, D, V = 2, 64, 16, 50
    hidden = jax.random.normal(key, (B, S, D))
    embed = jax.random.normal(jax.random.fold_in(key, 1), (V, D))
    labels = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, V)
    mask = (jax.random.uniform(jax.random.fold_in(key, 3), (B, S)) > 0.2
            ).astype(jnp.float32)
    full = chunked_cross_entropy(hidden, embed, labels, mask, chunk=S)
    chunked = chunked_cross_entropy(hidden, embed, labels, mask, chunk=16)
    unrolled = chunked_cross_entropy(hidden, embed, labels, mask, chunk=16,
                                     unroll=True)
    np.testing.assert_allclose(full, chunked, rtol=1e-6)
    np.testing.assert_allclose(full, unrolled, rtol=1e-6)


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    cfg = OptimizerConfig(learning_rate=0.3, warmup_steps=1, total_steps=200,
                          weight_decay=0.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, m = adamw_update(cfg, grads, opt, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1
    assert m["grad_norm"] >= 0


def test_grad_clip():
    params = {"w": jnp.zeros(4)}
    opt = init_opt_state(params)
    cfg = OptimizerConfig(grad_clip_norm=1.0, warmup_steps=1, total_steps=10)
    _, _, m = adamw_update(cfg, {"w": jnp.full(4, 100.0)}, opt, params)
    assert float(m["grad_norm"]) > 1.0  # raw norm reported pre-clip


def test_schedule_shape():
    cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10,
                          total_steps=100, min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[1] == 1.0                      # end of warmup
    assert lrs[-1] <= 0.11                    # cosine floor
    assert all(a >= b - 1e-6 for a, b in zip(lrs[1:], lrs[2:]))


def test_depth_variants_and_extrapolation():
    cfg = ARCHS["recurrentgemma-9b"]          # 38 = 12·3 + 2
    d1, d2, n1, n_full = depth_variants(cfg)
    assert d1.num_layers == 5 and d2.num_layers == 8
    assert n1 == 1 and n_full == 12
    assert d1.cost_unroll and d2.cost_unroll
    c1 = {"flops": 10.0, "bytes": 100.0, "transcendentals": 0.0,
          "collectives": {"all-reduce": {"bytes": 4, "count": 1}}}
    c2 = {"flops": 13.0, "bytes": 130.0, "transcendentals": 0.0,
          "collectives": {"all-reduce": {"bytes": 6, "count": 2}}}
    total = extrapolate(c1, c2, n1, n_full)
    assert total["flops"] == 10.0 + 11 * 3.0
    assert total["collectives"]["all-reduce"]["bytes"] == 4 + 11 * 2


def test_depth_variants_encdec():
    cfg = ARCHS["whisper-tiny"]
    d1, d2, n1, n_full = depth_variants(cfg)
    assert d1.num_layers == d1.encoder_layers == 1
    assert d2.num_layers == 2 and n_full == 4
