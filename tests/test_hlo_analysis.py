"""HLO collective parser + roofline term computation."""
import numpy as np

from repro.analysis import hlo
from repro.analysis.roofline import analyze

SAMPLE = """
HloModule test
%x = bf16[256,1024]{1,0} all-gather(bf16[16,1024]{1,0} %p0), dimensions={0}
%y = f32[512,512]{1,0} all-reduce(f32[512,512]{1,0} %p1), to_apply=%sum
%z = f32[32,64]{1,0} reduce-scatter(f32[512,64]{1,0} %p2), dimensions={0}
%w = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)
%cp = u32[128]{0} collective-permute(u32[128]{0} %src), source_target_pairs={{0,1}}
%notacoll = f32[4,4]{1,0} add(f32[4,4]{1,0} %i, f32[4,4]{1,0} %j)
"""


def test_parse_collectives():
    out = hlo.parse_collectives(SAMPLE)
    assert out["all-gather"]["count"] == 1
    # effective traffic: all-gather = result, all-reduce = 2×result,
    # reduce-scatter = result × group (default 2), rest = result
    assert out["all-gather"]["bytes"] == 256 * 1024 * 2
    assert out["all-reduce"]["bytes"] == 2 * 512 * 512 * 4
    assert out["reduce-scatter"]["bytes"] == 2 * 32 * 64 * 4
    assert out["all-to-all"]["bytes"] == 2 * 8 * 8 * 4
    assert out["collective-permute"]["bytes"] == 128 * 4
    assert hlo.collective_bytes(SAMPLE) == sum(
        v["bytes"] for v in out.values())


def test_roofline_dominance():
    rep = analyze(flops_per_device=1.97e14,          # exactly 1s of compute
                  bytes_per_device=819e9 * 0.5,      # 0.5s of HBM
                  collectives={"all-reduce": {"bytes": 50e9 * 0.25,
                                              "count": 1}},  # 0.25s
                  chips=256, model_flops=1.97e14 * 256)
    assert rep.dominant == "compute"
    assert abs(rep.compute_s - 1.0) < 1e-9
    assert abs(rep.step_time_s - 1.0) < 1e-9
    assert abs(rep.model_flops_util - 1.0) < 1e-9
    assert abs(rep.useful_ratio - 1.0) < 1e-9


def test_roofline_memory_model_override():
    rep = analyze(flops_per_device=1.0, bytes_per_device=819e9,
                  bytes_model_per_device=819e9 / 2,
                  collectives={}, chips=1, model_flops=1.0)
    assert abs(rep.memory_s_hlo - 1.0) < 1e-9
    assert abs(rep.memory_s - 0.5) < 1e-9
