"""Shared simulator invariant checks.

Used both by the hypothesis property suite (``test_simulator_invariants``,
gated on the hypothesis package) and by the deterministic cluster tests,
so every invariant also runs on concrete examples in images without
hypothesis installed.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.serving.batching import ContinuousBatcher, make_policy
from repro.serving.cluster import ClusterSpec, PoolSpec, simulate_cluster
from repro.serving.latency_model import LatencyModel
from repro.serving.simulator import SimResult
from repro.serving.workload import CLOSED, WorkloadSpec, generate

_LM = None


def latency_model() -> LatencyModel:
    """One shared (expensive to build) latency oracle for all checks."""
    global _LM
    if _LM is None:
        _LM = LatencyModel(get_config("gemma2-2b"), chips=4)
    return _LM


def run_sim(workload: WorkloadSpec, policy_name: str, *,
            replicas: int = 1, router: str = "round-robin",
            autoscale: bool = False, memory=None, disaggregation=None,
            **policy_kw) -> SimResult:
    policy = make_policy(policy_name, **policy_kw)
    return simulate_cluster(
        workload, policy, latency_model(),
        cluster=ClusterSpec(replicas=replicas, router=router,
                            autoscale=autoscale, memory=memory,
                            disaggregation=disaggregation))


def run_fleet_sim(workload: WorkloadSpec, *, mtbf_s: float, seed: int = 0,
                  router: str = "least-loaded", base_replicas: int = 1,
                  spot_replicas: int = 1, spot_hardware: str = "t4",
                  max_batch: int = 8, memory=None) -> SimResult:
    """A reserved pool plus a spot pool under seeded preemption kills."""
    policy = make_policy("continuous", max_batch=max_batch,
                         max_prefill=max(max_batch // 2, 1))
    pools = (
        PoolSpec(name="base", replicas=base_replicas),
        PoolSpec(name="spot", hardware=spot_hardware,
                 replicas=spot_replicas, pricing="spot",
                 preempt_mtbf_s=mtbf_s),
    )
    return simulate_cluster(
        workload, policy, latency_model(),
        cluster=ClusterSpec(pools=pools, router=router, memory=memory,
                            preempt_seed=seed))


def policy_cap(policy_name: str, **policy_kw) -> int:
    policy = make_policy(policy_name, **policy_kw)
    if isinstance(policy, ContinuousBatcher):
        return policy.max_batch
    if hasattr(policy, "max_batch"):
        return policy.max_batch
    if hasattr(policy, "preferred"):
        return max(policy.preferred)
    return 1


def check_all_complete_exactly_once(workload: WorkloadSpec,
                                    res: SimResult) -> None:
    """Every admitted request completes exactly once."""
    served = [t.request.req_id for t in res.traces]
    assert len(served) == len(set(served)), "a request completed twice"
    if workload.kind != CLOSED:
        expected = {r.req_id for r in generate(workload)}
        assert set(served) == expected, (
            f"served {len(served)} != admitted {len(expected)}")
    else:
        # closed loop admits dynamically; at least the seeds must finish
        assert len(served) >= workload.concurrency
    for t in res.traces:
        assert t.done_s > 0


def check_stage_sanity(res: SimResult, cap: int) -> None:
    """t_queue >= 0, batch_wait within t_queue, batch sizes <= policy cap,
    and the stage breakdown sums to completion − arrival (preemption must
    move time between stages, never create or lose any)."""
    for t in res.traces:
        assert t.t_queue >= -1e-9, f"negative queue time {t.t_queue}"
        assert -1e-9 <= t.t_batch_wait <= t.t_queue + 1e-9, (
            f"batch_wait {t.t_batch_wait} outside [0, t_queue={t.t_queue}]")
        assert t.t_inference > 0
        assert 1 <= t.batch_size <= cap, (
            f"batch size {t.batch_size} exceeds cap {cap}")
        assert abs(t.e2e - (t.done_s - t.request.arrival_s)) < 1e-6, (
            f"stage breakdown {t.e2e} != done - arrival "
            f"{t.done_s - t.request.arrival_s}")


def check_busy_bound(res: SimResult) -> None:
    """Total server busy time fits inside duration × replicas."""
    assert res.busy_s <= res.duration_s * res.replicas + 1e-6, (
        f"busy {res.busy_s} > duration {res.duration_s} × "
        f"{res.replicas} replicas")
    assert 0.0 <= res.utilization() <= 1.0 + 1e-9
    if res.per_replica_busy_s is not None:
        assert sum(res.per_replica_busy_s) == res.busy_s


def check_closed_concurrency(workload: WorkloadSpec, res: SimResult) -> None:
    """Closed-loop in-flight never exceeds spec.concurrency."""
    events = []
    for t in res.traces:
        events.append((t.request.arrival_s, 1))
        events.append((t.done_s, -1))
    # at equal times, process completions before the reissued arrivals
    events.sort(key=lambda e: (e[0], e[1]))
    inflight = peak = 0
    for _, delta in events:
        inflight += delta
        peak = max(peak, inflight)
    assert peak <= workload.concurrency, (
        f"{peak} in flight > concurrency {workload.concurrency}")


def check_duration_covers_window(workload: WorkloadSpec,
                                 res: SimResult) -> None:
    """Open-loop duration is max(workload window, last completion)."""
    last_done = max((t.done_s for t in res.traces), default=0.0)
    assert abs(res.duration_s - max(workload.duration_s, last_done)) < 1e-9


def check_memory_invariants(res: SimResult) -> None:
    """KV accounting: blocks never exceed the budget, occupancy is sane,
    and every replica fully drains (no leaked/live blocks at the end)."""
    m = res.memory
    assert m is not None, "memory-enabled run produced no accounting"
    assert m["peak_blocks"] <= m["total_blocks_per_replica"], (
        f"allocated {m['peak_blocks']} of "
        f"{m['total_blocks_per_replica']} budget blocks")
    assert 0.0 <= m["peak_occupancy"] <= 1.0
    assert 0.0 <= m["mean_occupancy"] <= 1.0 + 1e-9
    assert 0.0 <= m["prefix_hit_rate"] <= 1.0
    for p in m["per_replica"]:
        assert p["peak_blocks"] <= p["total_blocks"]
        assert p["referenced_blocks_end"] == 0, (
            f"{p['referenced_blocks_end']} blocks still referenced after "
            "the cluster drained")


def check_event_budget(res: SimResult) -> None:
    """The event loop terminated within a linear event budget.

    ``SimResult.events`` counts arrival/migration pops plus engine acts.
    Each engine act either starts an iteration or retires one, and every
    iteration makes real progress (a prefill join or at least one decoded
    token), so the total is linear in requests + tokens + preemption
    recompute — a spinning scheduler (an engine re-armed at ``now`` with
    nothing to do, e.g. KV-blocked admission rescheduling itself) blows
    this bound long before it would hang the suite."""
    n = len(res.traces)
    tokens = sum(t.tokens_out for t in res.traces)
    pre = sum(t.preemptions for t in res.traces)
    worst = max((t.request.prompt_tokens + t.tokens_out
                 for t in res.traces), default=0)
    bound = 64 + 8 * res.replicas + 4 * (n + tokens + pre * worst)
    assert 0 < res.events <= bound, (
        f"{res.events} loop events for {n} requests / {tokens} tokens / "
        f"{pre} preemptions (budget {bound}) — the scheduler is spinning")


def check_drain_under_kills(workload: WorkloadSpec, res: SimResult) -> None:
    """Spot kills drain to zero: every admitted request still completes
    exactly once, eviction accounting is self-consistent, and the fleet
    breakdown covers every replica-second that was billed."""
    check_all_complete_exactly_once(workload, res)
    fleet = res.fleet
    assert fleet is not None, "fleet run produced no fleet accounting"
    killed = sum(1 for t in res.traces if t.spot_evictions > 0)
    assert fleet["spot_killed_requests"] == killed
    assert fleet["spot_preemptions"] >= 0
    if fleet["spot_preemptions"] == 0:
        assert killed == 0, "evicted traces but zero recorded kills"
    for t in res.traces:
        assert t.spot_evictions <= t.preemptions, (
            "spot evictions must be a subset of total preemptions")
        assert t.done_s > 0
    for p in fleet["pools"]:
        assert p["replica_seconds"] >= -1e-9
        assert p["busy_s"] <= p["replica_seconds"] + 1e-6, (
            f"pool {p['name']} busy {p['busy_s']} exceeds its "
            f"replica-seconds {p['replica_seconds']}")
        assert p["cost_usd"] >= 0.0
    assert abs(sum(p["busy_s"] for p in fleet["pools"]) - res.busy_s) < 1e-6


def check_token_results_match(res_a: SimResult, res_b: SimResult) -> None:
    """Two runs served the same requests to the same token counts (the
    prefix cache must only skip compute, never change results)."""
    key = lambda res: sorted((t.request.req_id, t.request.prompt_tokens,
                              t.request.output_tokens)
                             for t in res.traces)
    assert key(res_a) == key(res_b), \
        "token-level results diverged between runs"
