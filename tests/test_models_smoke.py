"""Per-architecture smoke tests: reduced same-family config, one forward
and one train step on CPU, asserting shapes + finiteness; plus the core
serving invariant (prefill+decode ≡ teacher-forced forward)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models import build_model, count_params, reduced
from repro.training.optimizer import OptimizerConfig
from repro.training.step import init_train_state, make_train_step

ALL_ARCHS = sorted(ARCHS)


def _reduced(name):
    cfg = reduced(ARCHS[name])
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    return cfg


def _example(cfg, B=2, S=64, key=None):
    key = key or jax.random.key(1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encdec:
        kw["frames"] = jax.random.normal(jax.random.fold_in(key, 1),
                                         (B, 16, cfg.d_model))
    if cfg.frontend == "vision_patches":
        kw["prefix_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (B, cfg.num_frontend_tokens, cfg.d_model))
    return tokens, kw


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_finite(name):
    cfg = _reduced(name)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    assert count_params(params) > 0
    B, S = 2, 64
    tokens, kw = _example(cfg, B, S)
    logits, aux = model.forward(params, tokens, **kw)
    S_total = S + (cfg.num_frontend_tokens
                   if cfg.frontend == "vision_patches" else 0)
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_no_nan(name):
    cfg = _reduced(name)
    model = build_model(cfg)
    params, opt = init_train_state(model, jax.random.key(0))
    B, S = 2, 64
    tokens, kw = _example(cfg, B, S)
    batch = {"tokens": tokens,
             "labels": jnp.roll(tokens, -1, axis=1),
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    if "frames" in kw:
        batch["frames"] = kw["frames"]
    if "prefix_embeds" in kw:
        batch["patches"] = kw["prefix_embeds"]
    step = jax.jit(make_train_step(model, OptimizerConfig(warmup_steps=1,
                                                          total_steps=10)))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["loss"]) > 0
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_matches_forward(name):
    cfg = _reduced(name)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 64
    tokens, kw = _example(cfg, B, S)
    n_front = cfg.num_frontend_tokens if cfg.frontend == "vision_patches" else 0
    # lengths are in *concatenated* position space (patches + text)
    lengths = jnp.array([S + n_front - 1, S + n_front - 9], jnp.int32)
    logits_full, _ = model.forward(params, tokens, **kw)
    if cfg.is_encdec:
        cache = model.init_cache(B, S + 8, enc_len=16)
    else:
        cache = model.init_cache(B, S + n_front + 8)
    cache, pre_logits = model.prefill(params, cache, tokens, lengths, **kw)
    b = jnp.arange(B)
    want_pre = logits_full[b, lengths - 1]
    assert float(jnp.max(jnp.abs(pre_logits - want_pre))) < 1e-3
    next_tok = tokens[b, lengths - n_front]
    cache, dec_logits = model.decode_step(params, cache, next_tok)
    want_dec = logits_full[b, lengths]
    assert float(jnp.max(jnp.abs(dec_logits - want_dec))) < 1e-3


def test_long_context_flags():
    assert ARCHS["rwkv6-7b"].sub_quadratic
    assert ARCHS["recurrentgemma-9b"].sub_quadratic
    for name in ("gemma2-2b", "yi-9b", "whisper-tiny", "llava-next-34b"):
        assert not ARCHS[name].sub_quadratic


def test_vlm_prefill_uses_prefix():
    """VLM decode position accounting includes the image-token prefix."""
    cfg = _reduced("llava-next-34b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    tokens, kw = _example(cfg, B, S)
    n_front = cfg.num_frontend_tokens
    cache = model.init_cache(B, S + n_front + 4)
    lengths = jnp.full((B,), S + n_front, jnp.int32)  # all positions valid
    cache, logits = model.prefill(params, cache, tokens, lengths, **kw)
    full, _ = model.forward(params, tokens, prefix_embeds=kw["prefix_embeds"])
    assert float(jnp.max(jnp.abs(logits - full[:, -1]))) < 1e-3
