"""Property-based tests (hypothesis) over the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import ClusterScheduler, Job, average_jct
from repro.dist import sharding as shd
from repro.kernels import ref
from repro.serving.batching import PreferredBatcher, QueuedRequest, WindowBatcher
from repro.serving.workload import Request
from repro.training.compress import dequantize, quantize

MESH = shd.abstract_mesh((4, 8), ("data", "model"))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 64), min_size=1, max_size=5),
       st.sampled_from(["embed", "ffn", "heads", "kv", "batch", "vocab"]))
def test_partition_spec_always_divides(dims, ax):
    """Whatever the tensor shape, the resolved spec divides every dim."""
    axes = tuple([ax] + [None] * (len(dims) - 1))
    spec = shd.partition_spec(tuple(dims), axes, shd.TRAIN_RULES, MESH)
    sizes = dict(MESH.shape)
    for dim, entry in zip(dims, list(spec) + [None] * len(dims)):
        if entry is None:
            continue
        shards = np.prod([sizes[a] for a in
                          ((entry,) if isinstance(entry, str) else entry)])
        assert dim % shards == 0


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.01, 100.0), min_size=1, max_size=40),
       st.integers(1, 6))
def test_sjf_never_worse_than_fcfs_at_t0(procs, workers):
    """All jobs submitted together: SJF mean JCT ≤ FCFS mean JCT."""
    jobs = [Job(f"j{i}", 0.0, p) for i, p in enumerate(procs)]
    fcfs = average_jct(ClusterScheduler(workers, lb="qa", order="fcfs").run(jobs))
    sjf = average_jct(ClusterScheduler(workers, lb="qa", order="sjf").run(jobs))
    assert sjf <= fcfs + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_quantize_roundtrip_bound(seed):
    g = jax.random.normal(jax.random.key(seed), (64,)) * \
        (10.0 ** ((seed % 7) - 3))
    q, s = quantize(g)
    err = jnp.max(jnp.abs(dequantize(q, s) - g))
    assert float(err) <= float(s) * 0.5 + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(1, 8))
def test_wkv_chunk_invariance(b, nheads):
    """Chunked WKV must not depend on the chunk size (exactness)."""
    from repro.models.rwkv6 import wkv_chunked
    key = jax.random.key(b * 100 + nheads)
    S, N = 64, 16
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (b, S, nheads, N)) * 0.5
    k = jax.random.normal(ks[1], (b, S, nheads, N)) * 0.5
    v = jax.random.normal(ks[2], (b, S, nheads, N))
    lw = -jnp.exp(jax.random.normal(ks[3], (b, S, nheads, N)) * 0.3)
    u = jax.random.normal(ks[4], (nheads, N)) * 0.1
    s0 = jax.random.normal(ks[5], (b, nheads, N, N)) * 0.1
    o16, f16 = wkv_chunked(r, k, v, lw, u, s0, chunk=16)
    o32, f32_ = wkv_chunked(r, k, v, lw, u, s0, chunk=32)
    np.testing.assert_allclose(o16, o32, atol=3e-4, rtol=1e-3)
    np.testing.assert_allclose(f16, f32_, atol=3e-4, rtol=1e-3)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 16), st.integers(1, 16))
def test_batchers_never_exceed_limits(n_queued, max_batch):
    q = [QueuedRequest(Request(i, 0.0, 8, 1, 10), 0.0)
         for i in range(n_queued)]
    w = WindowBatcher(max_batch=max_batch, timeout_s=0.0)
    out = w.next_batch(q, now=1.0, server_free_at=0.0)
    assert out is not None
    assert 1 <= len(out[0]) <= max(max_batch, n_queued)
    p = PreferredBatcher(preferred=(max_batch,), max_queue_delay_s=0.0)
    out2 = p.next_batch(q, now=1.0, server_free_at=0.0)
    assert out2 is not None and len(out2[0]) <= max(max_batch, 1)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(2, 4), st.integers(8, 32))
def test_attention_reference_causality(b, h, s):
    """Changing future keys never changes past outputs."""
    key = jax.random.key(s)
    q = jax.random.normal(key, (b, h, s, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s, 8))
    out1 = ref.mha_reference(q, k, v, causal=True)
    k2 = k.at[:, :, -1].set(999.0)
    v2 = v.at[:, :, -1].set(-999.0)
    out2 = ref.mha_reference(q, k2, v2, causal=True)
    np.testing.assert_allclose(out1[:, :, :-1], out2[:, :, :-1],
                               atol=1e-5, rtol=1e-5)
