"""End-to-end behaviour tests for the benchmark system (paper workflow):
submit a config → leader schedules → followers execute the 4 stages →
PerfDB → analysis; plus real-execution serving and training smoke."""
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.core import (BenchmarkJobSpec, BenchmarkSession,
                        ConcurrentFollowerExecutor, ModelRef, PerfDB,
                        SoftwareSpec, SweepSpec)
from repro.core.analysis import recommend
from repro.models import build_model, reduced
from repro.serving.batching import make_policy
from repro.serving.workload import WorkloadSpec


def test_full_benchmark_workflow(tmp_path):
    """The paper's end-to-end path: config file → report."""
    db = PerfDB(str(tmp_path / "db.jsonl"))
    session = BenchmarkSession(n_workers=2, db=db, lb="qa", order="sjf",
                               executor=ConcurrentFollowerExecutor())
    base = BenchmarkJobSpec(
        job_id="workflow", model=ModelRef(name="gemma2-2b"), chips=8,
        slo_latency_s=0.05,
        workload=WorkloadSpec(rate=100, duration_s=2, seed=0))
    sweep = SweepSpec(base, axes={
        "software.policy": ["none", "tfs", "tris"],
        "chips": [4, 8],
    })
    handles = session.submit_sweep(sweep)
    results = session.run()
    assert len(results) == 6
    # every typed result has the full metric set + scheduling metadata
    for h in handles:
        r = h.result()
        assert r.metric("throughput_rps") > 0
        assert r.schedule is not None and r.schedule.jct_s > 0
    # stage 4: recommendation under the SLO
    top = recommend(db, slo_latency_s=0.05)
    assert top, "no configuration met the SLO"
    assert top[0]["result"]["p99_s"] <= 0.05


def test_real_execution_serving_small_model():
    """Actual jitted prefill+decode behind the batcher (CPU-scale)."""
    from repro.launch.serve import run_server
    cfg = reduced(get_config("granite-3-2b"))
    out = run_server(cfg, make_policy("tris", preferred=(4, 2, 1)),
                     WorkloadSpec(rate=50, duration_s=1.0, prompt_tokens=16,
                                  seed=0),
                     max_len=64, decode_steps=4)
    assert out["requests"] > 10
    assert out["p99_s"] > 0 and out["mean_infer_s"] > 0


def test_generate_fn_greedy_decode():
    """prefill → N greedy decode steps returns N+1 tokens per sequence."""
    from repro.serving.engine import make_generate_fn
    cfg = reduced(get_config("rwkv6-7b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    gen = jax.jit(make_generate_fn(model, steps=4))
    tokens = jnp.ones((2, 32), jnp.int32)
    lengths = jnp.full((2,), 32, jnp.int32)
    out = gen(params, tokens, lengths)
    assert out.shape == (2, 5)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())


def test_training_runner_end_to_end(tmp_path):
    """A few real optimizer steps with checkpoint + restart recovery."""
    from repro.training.data import DataConfig, host_batch
    from repro.training.ft import RunnerConfig, TrainingRunner
    from repro.training.optimizer import OptimizerConfig
    from repro.training.step import init_train_state, make_train_step

    cfg = reduced(get_config("granite-3-2b"))
    model = build_model(cfg)
    step_raw = jax.jit(make_train_step(
        model, OptimizerConfig(warmup_steps=1, total_steps=10)))
    data_cfg = DataConfig(global_batch=2, seq_len=32)

    def init_state():
        p, o = init_train_state(model, jax.random.key(0))
        return {"p": p, "o": o}

    def step_fn(state, step):
        batch = host_batch(data_cfg, cfg, step)
        p, o, m = step_raw(state["p"], state["o"], batch)
        return {"p": p, "o": o}, {k: float(v) for k, v in m.items()}

    runner = TrainingRunner(
        RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=3, max_steps=8,
                     fail_at_step=5, async_ckpt=False),
        step_fn, init_state)
    out = runner.run()
    assert out["final_step"] == 8
    assert out["restarts"] == 1
    assert all(m["loss"] > 0 for m in out["metrics"])
