"""Benchmark-system behaviour: spec round-trip, sweep expansion,
leader/follower execution, PerfDB, analysis models, generator."""
import json

import jax
import numpy as np
import pytest

from repro.core import (BenchmarkJobSpec, BenchmarkSession, ModelRef, PerfDB,
                        SoftwareSpec, SweepSpec, execute_job)
from repro.core import generator as gen
from repro.core.analysis import (cdf, heatmap, leaderboard, recommend,
                                 render_heatmap, roofline_point)
from repro.serving.workload import WorkloadSpec


def test_spec_roundtrip():
    spec = BenchmarkJobSpec(job_id="j1", model=ModelRef(name="yi-9b"),
                            software=SoftwareSpec(policy="tfs", int8=True),
                            workload=WorkloadSpec(rate=10, duration_s=1))
    back = BenchmarkJobSpec.from_json(json.dumps(spec.to_dict()))
    assert back == spec


def test_sweep_expansion():
    base = BenchmarkJobSpec(job_id="s", workload=WorkloadSpec(duration_s=1))
    sweep = SweepSpec(base, axes={"software.policy": ["none", "tfs"],
                                  "chips": [1, 2, 4]})
    jobs = list(sweep.expand())
    assert len(jobs) == 6
    assert {j.software.policy for j in jobs} == {"none", "tfs"}
    assert len({j.job_id for j in jobs}) == 6


def test_execute_registered_job():
    spec = BenchmarkJobSpec(job_id="r1", model=ModelRef(name="gemma2-2b"),
                            chips=8,
                            workload=WorkloadSpec(rate=50, duration_s=2))
    rec = execute_job(spec)
    r = rec["result"]
    assert r["requests"] > 0 and r["p99_s"] >= r["p50_s"] > 0
    assert rec["cold_start_s"] > 0
    assert set(rec["stages"]) == {"preprocess", "transmit", "queue",
                                  "batch_wait", "kv_transfer", "inference",
                                  "postprocess"}


def test_session_end_to_end(tmp_path):
    db = PerfDB(str(tmp_path / "perf.jsonl"))
    session = BenchmarkSession(n_workers=2, db=db)
    base = BenchmarkJobSpec(job_id="sw", model=ModelRef(name="granite-8b"),
                            chips=8, slo_latency_s=0.1,
                            workload=WorkloadSpec(rate=100, duration_s=2))
    session.submit_sweep(
        SweepSpec(base, axes={"software.policy": ["none", "tris"]}))
    recs = session.run()
    assert len(recs) == 2 and len(db) == 2
    # persistence round-trip
    db2 = PerfDB(str(tmp_path / "perf.jsonl"))
    assert len(db2) == 2
    top = recommend(db2, slo_latency_s=1.0)
    assert 1 <= len(top) <= 3
    board = leaderboard(db2)
    assert "throughput_rps" in board


@pytest.mark.parametrize("family", gen.FAMILIES)
def test_generated_models_run(family):
    spec = gen.GeneratedSpec(family=family, layers=2, width=64, batch=2,
                             seq=16)
    params, apply_fn, inputs = gen.build(spec)
    out = jax.jit(apply_fn)(params, *inputs)
    assert out.shape == (2, spec.num_classes)
    assert bool(jax.numpy.isfinite(out).all())
    assert gen.flops_estimate(spec) > 0
    assert gen.param_bytes(params) > 0


def test_cdf_monotone():
    xs, qs = cdf([5, 1, 4, 2, 3], points=10)
    assert xs == sorted(xs) and qs == sorted(qs)
    assert xs[0] == 1 and xs[-1] == 5


def test_heatmap_pivot():
    db = PerfDB()
    for L in (2, 4):
        for w in (64, 128):
            db.insert({"generated": {"layers": L, "width": w},
                       "result": {"latency_s": L * w * 1e-6}})
    hm = heatmap(db, row_key="generated.layers", col_key="generated.width",
                 value_key="result.latency_s")
    assert hm["rows"] == [2, 4] and hm["cols"] == [64, 128]
    m = np.array(hm["matrix"])
    assert m[1, 1] > m[0, 0]
    assert "heatmap" in render_heatmap(hm)


def test_roofline_point():
    pt = roofline_point(flops=1e12, bytes_moved=1e9, runtime_s=0.01)
    assert pt["intensity"] == 1000.0
    assert pt["attained_flops"] == 1e14


def test_recommender_respects_slo():
    db = PerfDB()
    for i, p99 in enumerate([0.01, 0.05, 0.2]):
        db.insert({"job_id": f"j{i}",
                   "result": {"p99_s": p99, "cost_per_1k_req": 1.0 - i * 0.1}})
    top = recommend(db, slo_latency_s=0.06)
    ids = [r["job_id"] for r in top]
    assert "j2" not in ids and len(ids) == 2
    # cheaper config first
    assert top[0]["result"]["cost_per_1k_req"] <= top[1]["result"]["cost_per_1k_req"]
