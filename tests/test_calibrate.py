"""repro.calibrate: fit round-trips, profile persistence, the SLO-aware
planner, session integration of CalibrationSpec/PlanSpec, and the PerfDB
dotted-path/append satellites."""
import json
import threading

import numpy as np
import pytest

from repro.calibrate import (CalibrationProfile, fit_records, load_profile,
                             oracle_records, plan_capacity, profile_path,
                             run_calibration_job, run_plan_job,
                             sweep_calibration)
from repro.configs import get_config
from repro.core import (BenchmarkJobSpec, BenchmarkSession, CalibrationSpec,
                        JobResult, ModelRef, PerfDB, PlanSpec, SoftwareSpec,
                        resolve_policy, run_stages, spec_from_dict)
from repro.core.analysis import fit_report, heatmap, plan_table
from repro.serving.cluster import ClusterSpec, simulate_cluster
from repro.serving.latency_model import FittedLatencyModel, LatencyModel
from repro.serving.workload import WorkloadSpec

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# a full-rank grid: batch and seq both vary so every design column is live
BATCHES = (1, 2, 4, 8)
SEQS = (16, 32, 64, 128)

KNOWN = FittedLatencyModel(prefill_coef=(2e-3, 5e-6, 1.5e-8),
                           decode_coef=(1e-3, 2e-4, 3e-7), chips=4)


def known_records(**kw):
    return oracle_records(KNOWN, batches=BATCHES, seqs=SEQS, **kw)


def fit_known(**kw):
    return fit_records(known_records(), model="known", hardware="tpu-v5e",
                       chips=4, source="oracle", **kw)


# ---- fitter ----------------------------------------------------------------
def test_fit_recovers_known_model_within_5pct():
    prof = fit_known()
    for got, want in zip(prof.prefill.coef + prof.decode.coef,
                         KNOWN.prefill_coef + KNOWN.decode_coef):
        assert got == pytest.approx(want, rel=0.05)
    # and the fit is essentially exact on its own grid
    assert prof.prefill.mean_rel_err < 1e-6
    assert prof.decode.mean_rel_err < 1e-6
    assert prof.prefill.r2 > 0.999999


def test_holdout_generalizes_within_15pct():
    prof = fit_known(holdout_fraction=0.25)
    assert prof.holdout is not None
    assert prof.holdout["mean_rel_err"] <= 0.15
    assert prof.holdout["prefill_points"] > 0


def test_fit_rejects_empty_and_derives_missing_decode():
    with pytest.raises(ValueError):
        fit_records([], model="m", hardware="tpu-v5e")
    prefill_only = [r for r in known_records() if r["phase"] == "prefill"]
    prof = fit_records(prefill_only, model="m", hardware="tpu-v5e")
    assert prof.decode.derived_from == "prefill"
    p0, p1, p2 = prof.prefill.coef
    assert prof.decode.coef == pytest.approx((p0, p1 + p2, 0.0))


def test_fit_pins_degenerate_columns_to_zero():
    # prompt never varies (fc-style grid): the quadratic column duplicates
    # the linear one and must be dropped, not poison the solve
    recs = [{"phase": "prefill", "batch": b, "tokens": 1,
             "result": {"latency_s": 1e-3 + 2e-5 * b}}
            for b in (1, 2, 4, 8, 16)]
    prof = fit_records(recs, model="fc", hardware="cpu-xeon")
    assert prof.prefill.coef[2] == 0.0
    assert prof.prefill.coef[0] == pytest.approx(1e-3, rel=1e-6)
    assert prof.prefill.mean_rel_err < 1e-9


def test_fitted_model_floors_degenerate_latency():
    lm = FittedLatencyModel(prefill_coef=(0.0, 0.0, 0.0),
                            decode_coef=(0.0, 0.0, 0.0))
    assert lm.prefill_latency(4, 128) > 0
    assert lm.decode_latency(4, 128) > 0
    assert lm.request_latency(4, 128, 8) > 0


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(scale=st.floats(min_value=1e-2, max_value=1e3))
    def test_fit_is_scale_invariant(scale):
        """Scaling every measured latency by k scales every fitted
        coefficient by k (fitting hardware-independent shape)."""
        base = fit_known()
        scaled_records = known_records()
        for rec in scaled_records:
            rec["result"]["latency_s"] *= scale
        scaled = fit_records(scaled_records, model="known",
                             hardware="tpu-v5e", chips=4, source="oracle")
        for got, want in zip(scaled.prefill.coef + scaled.decode.coef,
                             base.prefill.coef + base.decode.coef):
            assert got == pytest.approx(want * scale, rel=1e-4, abs=1e-15)


# ---- profiles --------------------------------------------------------------
def test_profile_json_roundtrip_and_key_loading(tmp_path):
    prof = fit_known(holdout_fraction=0.25)
    path = prof.save(tmp_path)
    assert path == profile_path(tmp_path, "known", "tpu-v5e")
    back = CalibrationProfile.from_json(path.read_text())
    assert back.prefill == prof.prefill and back.decode == prof.decode
    assert back.key == "known@tpu-v5e"
    # by path and by model@hardware key
    assert load_profile(path).prefill == prof.prefill
    assert load_profile("known@tpu-v5e", tmp_path).prefill == prof.prefill
    with pytest.raises(FileNotFoundError):
        load_profile("missing@tpu-v5e", tmp_path)
    # schema versioning is enforced
    bad = dict(prof.to_dict(), schema="repro.calibration-profile.v999")
    with pytest.raises(ValueError):
        CalibrationProfile.from_dict(bad)


def test_from_profile_reproduces_predictions(tmp_path):
    prof = fit_known()
    lm = FittedLatencyModel.from_profile(prof)
    assert lm.chips == 4 and lm.hw.name == "tpu-v5e"
    for b in BATCHES:
        for s in SEQS:
            assert lm.prefill_latency(b, s) == \
                pytest.approx(KNOWN.prefill_latency(b, s), rel=0.05)
            assert lm.decode_latency(b, s) == \
                pytest.approx(KNOWN.decode_latency(b, s), rel=0.05)
    # dict and path forms build the same oracle
    via_dict = FittedLatencyModel.from_profile(prof.to_dict())
    via_path = FittedLatencyModel.from_profile(str(prof.save(tmp_path)))
    assert via_dict.prefill_coef == via_path.prefill_coef == lm.prefill_coef
    # unknown hardware must fail loudly, not silently cost as tpu-v5e
    with pytest.raises(ValueError, match="unknown hardware"):
        FittedLatencyModel.from_profile(
            dict(prof.to_dict(), hardware="tpu-v9x"))


def test_latency_model_to_profile_roundtrip():
    analytic = LatencyModel(get_config("gemma2-2b"), chips=4)
    prof = analytic.to_profile(holdout_fraction=0.25)
    assert prof.key == "gemma2-2b@tpu-v5e"
    assert prof.cold_start_s == pytest.approx(analytic.cold_start())
    fitted = prof.to_latency_model()
    # decode is exactly linear in the roofline model → near-exact fit
    assert prof.decode.mean_rel_err < 0.01
    for b, c in ((1, 64), (4, 256), (16, 512)):
        assert fitted.decode_latency(b, c) == \
            pytest.approx(analytic.decode_latency(b, c), rel=0.05)
    assert fit_report(prof)        # renders


# ---- microbench ------------------------------------------------------------
def test_measured_fc_sweep_and_fit():
    spec = CalibrationSpec(
        job_id="cal-fc-test",
        model=ModelRef(kind="generated", family="fc", layers=1, width=32),
        batches=(1, 2, 4), repeats=2, holdout_fraction=0.0)
    records = sweep_calibration(spec)
    assert len(records) == 3           # fc has no seq axis → one per batch
    for rec in records:
        assert rec["kind"] == "calibration"
        assert rec["phase"] == "prefill" and rec["tokens"] == 1
        assert rec["result"]["latency_s"] > 0
        assert rec["result"]["mode"] == "measured-cpu"
    result = run_calibration_job(spec)
    prof = CalibrationProfile.from_dict(result.metrics["profile"])
    assert prof.source == "measured-cpu"
    assert all(np.isfinite(prof.prefill.coef))
    assert result.extra_records == records or len(result.extra_records) == 3
    # grid metadata reflects what was measured, not the spec defaults:
    # fc has no seq axis, so the prompt grid collapsed to length 1
    assert prof.grid == {"batches": [1, 2, 4], "seqs": [1], "contexts": []}


def test_oracle_sweep_matches_latency_model():
    spec = CalibrationSpec(job_id="cal-oracle",
                           model=ModelRef(name="gemma2-2b"),
                           hardware="tpu-v5e", chips=4,
                           batches=(1, 4), seqs=(32, 128))
    records = sweep_calibration(spec)
    assert len(records) == 8           # 4 prefill + 4 decode points
    analytic = LatencyModel(get_config("gemma2-2b"), chips=4)
    for rec in records:
        fn = (analytic.prefill_latency if rec["phase"] == "prefill"
              else analytic.decode_latency)
        assert rec["result"]["latency_s"] == \
            pytest.approx(fn(rec["batch"], rec["tokens"]))


# ---- planner (acceptance: verified SLO at minimum modeled cost) ------------
def _plan_workload():
    return WorkloadSpec(kind="poisson", rate=600, duration_s=2,
                        prompt_tokens=128, output_tokens=4,
                        output_tokens_max=16, seed=0)


def test_planner_best_is_slo_verified_and_cheapest():
    prof = LatencyModel(get_config("gemma2-2b"), chips=4).to_profile()
    plan = plan_capacity(prof, _plan_workload(), slo_latency_s=0.25,
                         slo_target=0.99, replicas=(1, 2),
                         policies=("tfs", "continuous"))
    best = plan.best
    assert best is not None
    # the load is sized so one replica misses the SLO — the planner must
    # actually discriminate
    assert any(not c.meets_slo for c in plan.candidates)
    # minimum modeled cost among every feasible candidate
    feasible = [c for c in plan.candidates if c.meets_slo]
    assert best.objective == min(c.objective for c in feasible)
    # independent re-verification: simulate_cluster at the chosen config
    res = simulate_cluster(
        _plan_workload(),
        resolve_policy(SoftwareSpec(policy=best.policy, max_batch=16,
                                    max_prefill=8)),
        prof.to_latency_model(),
        cluster=ClusterSpec(replicas=best.replicas, router=best.router))
    assert res.slo_attainment(0.25) >= 0.99
    assert plan_table(plan)            # renders, feasible-first
    assert plan.candidates[0].meets_slo


def test_planner_rejects_unknown_objective():
    prof = fit_known()
    with pytest.raises(ValueError, match="objective"):
        plan_capacity(prof, _plan_workload(), slo_latency_s=0.25,
                      replicas=(1,), policies=("tfs",),
                      objective="cost_per_1k_requests")  # typo'd key


# ---- session integration ---------------------------------------------------
def test_session_runs_calibration_and_plan_specs(tmp_path):
    db = PerfDB(str(tmp_path / "perf.jsonl"))
    session = BenchmarkSession(n_workers=2, db=db)
    cal = session.submit(CalibrationSpec(
        job_id="cal", model=ModelRef(name="gemma2-2b"), hardware="tpu-v5e",
        chips=4, batches=(1, 2, 4, 8), seqs=(32, 64, 128),
        profile_dir=str(tmp_path)))
    session.run()
    cal_result = cal.result()
    assert cal_result.metrics["profile_path"] is not None

    # dict submission with kind dispatch, consuming the saved profile
    plan = session.submit({
        "kind": "plan", "job_id": "plan",
        "profile": "gemma2-2b@tpu-v5e", "profile_dir": str(tmp_path),
        "workload": {"kind": "poisson", "rate": 600, "duration_s": 2,
                     "prompt_tokens": 128, "output_tokens": 4,
                     "output_tokens_max": 16, "seed": 0},
        "slo_latency_s": 0.25, "slo_target": 0.99,
        "replicas": [1, 2], "policies": ["tfs", "continuous"]})
    session.run()
    best = plan.result().metrics["best"]
    assert best is not None and best["replicas"] >= 1

    # per-grid-point records landed in PerfDB under the calibration kind,
    # alongside the two job records
    grid = db.query(kind="calibration", phase="prefill")
    assert len(grid) == 12
    assert db.query(kind="calibration", job_id="cal",
                    **{"result.mode": "oracle"})
    assert len(db.query(job_id="cal")) == 1 + 12 + 12  # job + decode + prefill

    # write-through: a fresh PerfDB sees every line intact
    reloaded = PerfDB(str(tmp_path / "perf.jsonl"))
    assert len(reloaded) == len(db)

    # typed record round-trip for both new kinds
    for rec in (cal_result.to_record(), plan.result().to_record()):
        back = JobResult.from_record(json.loads(json.dumps(rec)))
        assert back.spec == (cal_result.spec if rec["kind"] == "calibration"
                             else plan.result().spec)
        assert back.metrics.keys() == rec["result"].keys()


def test_benchmark_job_clocked_by_profile(tmp_path):
    prof = LatencyModel(get_config("gemma2-2b"), chips=4).to_profile()
    path = prof.save(tmp_path)
    spec = BenchmarkJobSpec(
        job_id="prof-job", model=ModelRef(name="gemma2-2b"),
        profile=str(path), slo_latency_s=0.25,
        software={"policy": "continuous", "max_batch": 16},
        workload=WorkloadSpec(rate=100, duration_s=1, output_tokens=4,
                              seed=0))
    result = run_stages(spec)
    assert result.metrics["throughput_rps"] > 0
    assert result.mode == "fitted-profile"     # provenance, not roofline
    assert result.cold_start_s == pytest.approx(prof.cold_start_s)
    # spec round-trips with the new field
    assert BenchmarkJobSpec.from_dict(spec.to_dict()) == spec


def test_spec_kind_dispatch_roundtrips():
    cal = CalibrationSpec(job_id="c", model=ModelRef(name="gemma2-2b"))
    plan = PlanSpec(job_id="p", profile="x@y")
    for spec in (cal, plan):
        d = json.loads(json.dumps(spec.to_dict()))
        assert spec_from_dict(d) == spec
    assert spec_from_dict({"job_id": "b"}) == BenchmarkJobSpec(job_id="b")
    with pytest.raises(ValueError):
        spec_from_dict({"kind": "nope", "job_id": "x"})


# ---- PerfDB satellites -----------------------------------------------------
def test_perfdb_get_path_and_dotted_query():
    db = PerfDB()
    db.append({"a": {"b": {"c": 1}}, "flat": 2})
    db.append({"a": {"b": {"c": 2}}, "flat": 2})
    assert PerfDB.get_path(db.all()[0], "a.b.c") == 1
    assert PerfDB.get_path(db.all()[0], "a.missing.c") is None
    assert PerfDB.get_path(db.all()[0], "flat.too.deep") is None
    assert len(db.query(**{"a.b.c": 2})) == 1
    assert len(db.query(flat=2)) == 2


def test_perfdb_append_write_through_and_concurrent(tmp_path):
    path = tmp_path / "db.jsonl"
    db = PerfDB(str(path))
    db.append({"i": -1})
    # write-through: visible on disk immediately, before any close/exit
    assert len(path.read_text().splitlines()) == 1

    def writer(k):
        for i in range(50):
            db.append({"writer": k, "i": i, "pad": "x" * 256})

    threads = [threading.Thread(target=writer, args=(k,)) for k in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    lines = path.read_text().splitlines()
    assert len(lines) == 1 + 8 * 50
    # no interleaved partial lines: every line parses back
    recs = [json.loads(line) for line in lines]
    assert sum(1 for r in recs if r.get("writer") == 3) == 50


def test_heatmap_empty_and_calibration_pivot():
    db = PerfDB()
    hm = heatmap(db, row_key="batch", col_key="tokens",
                 value_key="result.latency_s", kind="calibration")
    assert hm == {"rows": [], "cols": [], "matrix": [], "row_key": "batch",
                  "col_key": "tokens", "value_key": "result.latency_s"}
    spec = CalibrationSpec(job_id="hm", model=ModelRef(name="gemma2-2b"),
                           chips=4, batches=(1, 2), seqs=(32, 64))
    sweep_calibration(spec, db=db)
    hm = heatmap(db, row_key="batch", col_key="tokens",
                 value_key="result.latency_s", kind="calibration",
                 phase="prefill")
    assert hm["rows"] == [1, 2] and hm["cols"] == [32, 64]
    assert np.isfinite(np.asarray(hm["matrix"])).all()
    # filters that match nothing stay empty, not crashing
    assert heatmap(db, row_key="batch", col_key="tokens",
                   value_key="result.latency_s",
                   kind="no-such-kind")["matrix"] == []
