"""Serving tier: batching policy semantics, simulator conservation laws,
workload generator statistics."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.batching import (NoBatching, PreferredBatcher,
                                    QueuedRequest, WindowBatcher)
from repro.serving.latency_model import LatencyModel, NETWORKS
from repro.serving.simulator import simulate
from repro.serving.workload import POISSON, Request, WorkloadSpec, generate


def _q(i, t):
    return QueuedRequest(Request(i, t, 64, 1, 1000), t)


class TestPolicies:
    def test_window_waits_below_batch(self):
        p = WindowBatcher(max_batch=4, timeout_s=0.01)
        q = [_q(0, 0.0)]
        assert p.next_batch(q, now=0.001, server_free_at=0.0) is None
        batch, t = p.next_batch(q, now=0.02, server_free_at=0.0)
        assert len(batch) == 1 and t >= 0.01

    def test_window_fires_on_full(self):
        p = WindowBatcher(max_batch=2, timeout_s=10.0)
        q = [_q(0, 0.0), _q(1, 0.0), _q(2, 0.0)]
        batch, _ = p.next_batch(q, now=0.0, server_free_at=0.0)
        assert len(batch) == 2

    def test_preferred_is_eager(self):
        p = PreferredBatcher(preferred=(4, 2, 1))
        q = [_q(0, 0.0), _q(1, 0.0), _q(2, 0.0)]
        batch, _ = p.next_batch(q, now=0.0, server_free_at=0.0)
        assert len(batch) == 2          # largest reachable preferred size

    def test_nobatch_single(self):
        p = NoBatching()
        q = [_q(0, 0.0), _q(1, 0.0)]
        batch, _ = p.next_batch(q, now=0.0, server_free_at=0.0)
        assert len(batch) == 1


class TestWorkload:
    def test_poisson_rate(self):
        spec = WorkloadSpec(kind=POISSON, rate=200, duration_s=50, seed=0)
        reqs = generate(spec)
        assert abs(len(reqs) / 50 - 200) / 200 < 0.05
        assert all(0 <= r.arrival_s < 50 for r in reqs)

    def test_deterministic(self):
        a = generate(WorkloadSpec(rate=50, duration_s=5, seed=3))
        b = generate(WorkloadSpec(rate=50, duration_s=5, seed=3))
        assert [r.arrival_s for r in a] == [r.arrival_s for r in b]


class TestSimulator:
    def setup_method(self):
        self.lat = LatencyModel(get_config("gemma2-2b"), chips=4)

    @pytest.mark.parametrize("policy", [
        NoBatching(), WindowBatcher(max_batch=8, timeout_s=0.005),
        PreferredBatcher(preferred=(8, 4, 2, 1))])
    def test_conservation(self, policy):
        wl = WorkloadSpec(rate=100, duration_s=5, seed=1)
        res = simulate(wl, policy, self.lat)
        assert len(res.traces) == len(generate(wl))     # all served once
        assert 0.0 <= res.utilization() <= 1.0
        for t in res.traces:
            assert t.t_queue >= -1e-9 and t.t_inference > 0

    def test_tail_latency_grows_with_rate(self):
        p99 = []
        for rate in (50, 2000, 8000):
            res = simulate(WorkloadSpec(rate=rate, duration_s=3, seed=2),
                           WindowBatcher(max_batch=8, timeout_s=0.002),
                           self.lat)
            p99.append(res.percentile(99))
        assert p99[0] <= p99[-1]        # saturation raises the tail

    def test_network_scenarios_ordered(self):
        lat = {}
        for name in ("lan", "wifi", "4g"):
            res = simulate(WorkloadSpec(rate=20, duration_s=3, seed=4),
                           NoBatching(), self.lat, network=NETWORKS[name])
            lat[name] = res.stage_means()["transmit"]
        assert lat["lan"] < lat["wifi"] < lat["4g"]      # paper Fig. 14b

    def test_energy_cost_positive(self):
        res = simulate(WorkloadSpec(rate=50, duration_s=3, seed=5),
                       NoBatching(), self.lat)
        s = res.summary()
        assert s["energy_j"] > 0 and s["cost_usd"] > 0 and s["co2_kg"] > 0


class TestLatencyModel:
    def test_decode_memory_bound_long_context(self):
        lm = LatencyModel(get_config("yi-9b"), chips=8)
        short = lm.decode_latency(8, 1024)
        long = lm.decode_latency(8, 131072)
        assert long > short                    # KV streaming dominates

    def test_int8_halves_weight_traffic(self):
        cfg = get_config("granite-8b")
        t16 = LatencyModel(cfg, chips=8).decode_latency(1, 128)
        t8 = LatencyModel(cfg, chips=8, int8=True).decode_latency(1, 128)
        assert t8 < t16

    def test_batch_amortizes_weights(self):
        lm = LatencyModel(get_config("granite-8b"), chips=8)
        t1 = lm.decode_latency(1, 1024)
        t32 = lm.decode_latency(32, 1024)
        assert t32 < 32 * t1                  # throughput wins with batch
