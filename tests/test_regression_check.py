"""check_regression.py: the bench-regression CI gate must pass the
committed baseline's own numbers and fail synthetically degraded ones."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.check_regression import compare, get_path, main  # noqa: E402

BASELINE = {
    "default_tolerance": 0.15,
    "metrics": {
        "cluster:ramp/continuous.throughput_rps":
            {"value": 200.0, "direction": "higher"},
        "cluster:ramp/continuous.p99_s":
            {"value": 0.5, "direction": "lower"},
        "calibrate:measured_fc.holdout.mean_rel_err":
            {"value": 0.02, "direction": "lower", "tolerance": 7.0},
    },
}

GOOD = {
    "cluster": {"ramp/continuous": {"throughput_rps": 205.0,
                                    "p99_s": 0.48}},
    "calibrate": {"measured_fc": {"holdout": {"mean_rel_err": 0.05}}},
}


def _degraded():
    bad = json.loads(json.dumps(GOOD))
    bad["cluster"]["ramp/continuous"]["throughput_rps"] = 120.0  # -40%
    bad["cluster"]["ramp/continuous"]["p99_s"] = 1.2             # +140%
    return bad


class TestCompare:
    def test_good_numbers_pass(self):
        rows, failures = compare(BASELINE, GOOD)
        assert failures == []
        assert all(r[-1] == "ok" for r in rows)

    def test_degraded_numbers_fail(self):
        rows, failures = compare(BASELINE, _degraded())
        assert "cluster:ramp/continuous.throughput_rps" in failures
        assert "cluster:ramp/continuous.p99_s" in failures

    def test_improvements_never_fail(self):
        better = json.loads(json.dumps(GOOD))
        better["cluster"]["ramp/continuous"]["throughput_rps"] = 400.0
        better["cluster"]["ramp/continuous"]["p99_s"] = 0.01
        _, failures = compare(BASELINE, better)
        assert failures == []

    def test_missing_metric_fails(self):
        partial = {"cluster": GOOD["cluster"]}   # calibrate file absent
        rows, failures = compare(BASELINE, partial)
        assert "calibrate:measured_fc.holdout.mean_rel_err" in failures
        assert any(r[-1] == "MISSING" for r in rows)

    def test_abs_tolerance_floors_tiny_metrics(self):
        """A near-zero metric (e.g. TPOT) drifting by microseconds is a
        huge relative delta but no regression: abs_tolerance floors it."""
        baseline = {"metrics": {"cluster:disagg.tpot_p99_s": {
            "value": 0.003, "direction": "lower",
            "tolerance": 0.15, "abs_tolerance": 0.002}}}
        tiny_drift = {"cluster": {"disagg": {"tpot_p99_s": 0.004}}}
        _, failures = compare(baseline, tiny_drift)
        assert failures == []           # +33% rel but only +1ms abs
        real_regression = {"cluster": {"disagg": {"tpot_p99_s": 0.008}}}
        _, failures = compare(baseline, real_regression)
        assert failures == ["cluster:disagg.tpot_p99_s"]

    def test_per_metric_tolerance_overrides_default(self):
        # holdout 0.05 is +150% over 0.02 but tolerance is 7.0 (8×)
        _, failures = compare(BASELINE, GOOD)
        assert failures == []
        eightfold = json.loads(json.dumps(GOOD))
        eightfold["calibrate"]["measured_fc"]["holdout"]["mean_rel_err"] \
            = 0.2
        _, failures = compare(BASELINE, eightfold)
        assert failures == ["calibrate:measured_fc.holdout.mean_rel_err"]

    def test_near_direction_fails_both_ways(self):
        """Band metrics (fairness index) regress on drift in *either*
        direction; within-band drift passes."""
        baseline = {"metrics": {"cluster:scenario/iso.fairness_index": {
            "value": 0.9, "direction": "near", "tolerance": 0.1}}}
        for val, ok in ((0.95, True), (0.85, True),
                        (0.70, False), (1.20, False)):
            _, failures = compare(
                baseline,
                {"cluster": {"scenario/iso": {"fairness_index": val}}})
            assert (failures == []) is ok, val

    def test_get_path(self):
        assert get_path({"a": {"b": 1}}, "a.b") == 1
        assert get_path({"a": {"b": 1}}, "a.c") is None
        assert get_path(None, "a") is None


class TestMainExitCodes:
    def _write(self, tmp_path, name, payload):
        p = tmp_path / name
        p.write_text(json.dumps(payload))
        return str(p)

    def test_exit_zero_on_good(self, tmp_path, capsys):
        args = ["--baseline", self._write(tmp_path, "base.json", BASELINE),
                f"cluster={self._write(tmp_path, 'c.json', GOOD['cluster'])}",
                "calibrate="
                + self._write(tmp_path, "k.json", GOOD["calibrate"])]
        assert main(args) == 0
        assert "within tolerance" in capsys.readouterr().out

    def test_exit_nonzero_on_degraded(self, tmp_path, capsys):
        bad = _degraded()
        args = ["--baseline", self._write(tmp_path, "base.json", BASELINE),
                f"cluster={self._write(tmp_path, 'c.json', bad['cluster'])}",
                "calibrate="
                + self._write(tmp_path, "k.json", bad["calibrate"])]
        assert main(args) == 1
        captured = capsys.readouterr()
        assert "REGRESSION" in captured.err
        assert "FAIL" in captured.out

    def test_committed_baseline_schema_is_valid(self):
        path = Path(__file__).resolve().parent.parent / "benchmarks" \
            / "baselines" / "ci_baseline.json"
        baseline = json.loads(path.read_text())
        assert baseline["metrics"], "empty committed baseline"
        for name, entry in baseline["metrics"].items():
            ns, _, rest = name.partition(":")
            assert ns in ("cluster", "calibrate", "sim", "kernels") and rest, name
            assert entry["direction"] in ("higher", "lower", "near")
            float(entry["value"])
        # the issue's headline metrics are all gated
        keys = set(baseline["metrics"])
        assert any("throughput" in k for k in keys)
        assert any("p99" in k for k in keys)
        assert any("holdout" in k for k in keys)
        assert any("prefix_hit_rate" in k for k in keys)
        # the scenario lane gates per-tenant goodput + fairness
        assert any("goodput" in k for k in keys)
        assert any("fairness" in k for k in keys)
        # the simulator lane gates its own event-loop throughput
        assert any("sim_events_per_sec" in k for k in keys)
        # the kernel lane gates reference residuals + the speed-mode win
        assert any("max_err_vs_ref" in k for k in keys)
        assert any("best_is_non_fp16" in k for k in keys)
