"""Pallas-kernel correctness: shape/dtype sweeps vs the pure-jnp oracles,
executed in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.wkv6 import wkv6

KEY = jax.random.key(0)


def rand(k, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, k), shape) * scale
            ).astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,H,K,S,d", [
    (1, 4, 4, 128, 64), (2, 8, 4, 256, 64), (1, 8, 2, 256, 128),
    (2, 4, 1, 128, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("opts", [
    dict(causal=True), dict(causal=True, window=64),
    dict(causal=True, softcap=30.0), dict(causal=False),
])
def test_flash_attention(B, H, K, S, d, dtype, opts):
    q = rand(1, (B, H, S, d), dtype)
    k = rand(2, (B, K, S, d), dtype)
    v = rand(3, (B, K, S, d), dtype)
    out = flash_attention(q, k, v, interpret=True, **opts)
    want = ref.mha_reference(q, k, v, **opts)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype] * 10)


@pytest.mark.parametrize("B,H,K,T,d", [
    (2, 8, 2, 1024, 64), (1, 4, 4, 512, 128), (3, 16, 4, 2048, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, H, K, T, d, dtype):
    q = rand(1, (B, H, d), dtype)
    k = rand(2, (B, K, T, d), dtype)
    v = rand(3, (B, K, T, d), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, T + 1, size=B), jnp.int32)
    out = decode_attention(q, k, v, lengths, interpret=True)
    want = ref.decode_attention_reference(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype] * 10)


@pytest.mark.parametrize("B,S,H,N,chunk", [
    (2, 128, 4, 64, 32), (1, 64, 2, 32, 16), (2, 96, 4, 64, 32),
])
def test_wkv6_kernel(B, S, H, N, chunk):
    r = rand(4, (B, S, H, N), scale=0.5)
    k = rand(5, (B, S, H, N), scale=0.5)
    v = rand(6, (B, S, H, N))
    logw = -jnp.exp(rand(7, (B, S, H, N), scale=0.5))
    u = rand(8, (H, N), scale=0.1)
    s0 = rand(9, (B, H, N, N), scale=0.1)
    out, state = wkv6(r, k, v, logw, u, s0, chunk=chunk, interpret=True)
    want_o, want_s = ref.wkv6_reference(r, k, v, logw, u, s0)
    np.testing.assert_allclose(out, want_o, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(state, want_s, atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("B,S,R,chunk,block_r", [
    (2, 256, 512, 128, 512), (1, 128, 256, 64, 128), (3, 64, 1024, 64, 256),
])
def test_rglru_kernel(B, S, R, chunk, block_r):
    a = jax.random.uniform(jax.random.fold_in(KEY, 10), (B, S, R),
                           minval=0.8, maxval=0.999)
    b = rand(11, (B, S, R), scale=0.1)
    s0 = rand(12, (B, R))
    seq, last = rglru_scan(a, b, s0, chunk=chunk, block_r=block_r,
                           interpret=True)
    want_seq, want_last = ref.rglru_reference(a, b, s0)
    np.testing.assert_allclose(seq, want_seq, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(last, want_last, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("M,K,N", [(128, 512, 128), (256, 1024, 256),
                                   (128, 2048, 384)])
def test_int8_matmul(M, K, N):
    x = rand(13, (M, K))
    w = rand(14, (K, N))
    xq, sx = ref.quantize_rowwise(x)
    wq_t, sw = ref.quantize_rowwise(w.T)
    wq = wq_t.T
    out = int8_matmul(xq, wq, sx, sw, interpret=True)
    want = ref.int8_matmul_reference(xq, wq, sx, sw)
    np.testing.assert_allclose(out, want, atol=1e-3, rtol=1e-4)
    # quantized result close to the fp32 matmul (end-to-end sanity)
    rel = np.linalg.norm(out - x @ w) / np.linalg.norm(x @ w)
    assert rel < 0.05


def test_wkv_chunked_model_path_matches_kernel():
    """The model's associative-scan WKV == the Pallas chunk kernel."""
    from repro.models.rwkv6 import wkv_chunked
    B, S, H, N = 2, 128, 4, 32
    r = rand(20, (B, S, H, N), scale=0.5)
    k = rand(21, (B, S, H, N), scale=0.5)
    v = rand(22, (B, S, H, N))
    logw = -jnp.exp(rand(23, (B, S, H, N), scale=0.5))
    u = rand(24, (H, N), scale=0.1)
    s0 = rand(25, (B, H, N, N), scale=0.1)
    o1, s1 = wkv_chunked(r, k, v, logw, u, s0)
    o2, s2 = wkv6(r, k, v, logw, u, s0, interpret=True)
    np.testing.assert_allclose(o1, o2, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(s1, s2, atol=2e-4, rtol=1e-3)
