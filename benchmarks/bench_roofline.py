"""Paper Fig. 10 — Roofline analysis.

(a) real-world archs: arithmetic intensity + attained FLOP/s per (arch ×
    shape) from the multi-pod dry-run artifacts (experiments/dryrun/);
(b) generated canonical models: measured on CPU against the CPU ceiling.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax

from repro import hw as hw_lib
from repro.core import generator as gen
from repro.core.analysis import roofline_point
from repro.serving.latency_model import MeasuredLatency

from benchmarks.common import emit, save_json

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def run() -> None:
    out = {"real": {}, "generated": {}}
    hw = hw_lib.TPU_V5E
    # (a) real-world models from the dry-run roofline pass
    for f in sorted(DRYRUN_DIR.glob("*__single.json")):
        rec = json.loads(f.read_text())
        r = rec.get("roofline")
        if not rec.get("ok") or not r:
            continue
        intensity = r["flops_per_device"] / max(r["bytes_model_per_device"], 1)
        attained = r["flops_per_device"] / max(r["step_time_s"], 1e-12)
        bound = ("memory" if intensity < hw.ridge_intensity() else "compute")
        out["real"][f"{rec['arch']}/{rec['shape']}"] = {
            "intensity": intensity, "attained_tflops": attained / 1e12,
            "roofline_bound": bound, "dominant_term": r["dominant"],
        }
        emit(f"fig10a.{rec['arch']}.{rec['shape']}", 0.0,
             f"AI={intensity:.1f};attained_TF={attained/1e12:.2f};{bound}")
    # (b) generated models, measured (CPU ceiling)
    for family in ("fc", "transformer"):
        for W in (128, 512):
            for b in (1, 16):
                spec = gen.GeneratedSpec(family=family, layers=4, width=W,
                                         batch=b, seq=32)
                params, fn, inputs = gen.build(spec)
                lat = MeasuredLatency(jax.jit(fn), warmup=1, iters=3
                                      ).measure(params, *inputs)
                flops = b * gen.flops_estimate(spec)
                bytes_moved = gen.param_bytes(params)
                pt = roofline_point(flops, bytes_moved, lat)
                out["generated"][spec.name + f"/b{b}"] = pt
                emit(f"fig10b.{family}.W{W}.b{b}", lat * 1e6,
                     f"AI={pt['intensity']:.1f};"
                     f"attained_GF={pt['attained_flops']/1e9:.2f}")
    save_json("fig10_roofline", out)


if __name__ == "__main__":
    run()
