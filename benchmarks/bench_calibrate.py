"""Calibration + capacity planning: the measure → model → plan loop.

Three sections:
  (a) measured fc-family calibration — real CPU execution over a batch
      grid, least-squares fit, held-out grid points must be predicted
      within 15% mean relative error;
  (b) oracle calibration of a registered arch (gemma2-2b on tpu-v5e) —
      the roofline model compressed into a portable profile, with fit
      diagnostics;
  (c) SLO-aware capacity plan driven by the fitted profile — a
      2-replica grid searched for the cheapest configuration meeting a
      p(e2e ≤ SLO) ≥ target, re-verified with ``simulate_cluster``.

``--smoke`` keeps grids/durations CI-sized (it is already small; smoke
mainly trims the plan grid).
"""
from __future__ import annotations

import sys
from pathlib import Path

# allow `python benchmarks/bench_calibrate.py` (script dir is on sys.path,
# repo root is not)
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.calibrate import plan_capacity
from repro.core import BenchmarkSession, CalibrationSpec, ModelRef, PlanSpec
from repro.core.analysis import fit_report, plan_table
from repro.serving.workload import WorkloadSpec

from benchmarks.common import emit, save_json, timed

HOLDOUT_TARGET = 0.15        # mean relative error on held-out grid points
SLO_S = 0.25
SLO_TARGET = 0.99


def measured_fc_calibration(session, smoke, out):
    # wall-clocking on a shared CI box is jittery even with the min
    # reducer: re-sweep up to 3 times and keep the best-generalizing fit
    m = None
    for attempt in range(3):
        spec = CalibrationSpec(
            job_id=f"cal-fc-a{attempt}",
            model=ModelRef(kind="generated", family="fc", layers=4,
                           width=256),
            batches=(16, 32, 64, 96, 128, 192, 256),
            holdout_fraction=0.25)
        handle = session.submit(spec)
        _, us = timed(session.run)
        attempt_m = handle.result().metrics
        if m is None or (attempt_m["holdout"]["mean_rel_err"]
                         < m["holdout"]["mean_rel_err"]):
            m = attempt_m
        if m["holdout"]["mean_rel_err"] <= HOLDOUT_TARGET / 2:
            break
    out["measured_fc"] = {k: v for k, v in m.items() if k != "profile"}
    out["measured_fc_profile"] = m["profile"]
    holdout = m["holdout"]["mean_rel_err"]
    emit("calibrate.measured.fc", us,
         f"n={m['n_records']};fit_err={m['prefill_mean_rel_err']:.1%};"
         f"holdout_err={holdout:.1%};r2={m['prefill_r2']:.3f}")
    print(fit_report(m["profile"]))
    assert holdout <= HOLDOUT_TARGET, \
        (f"fc calibration generalizes poorly: held-out mean rel err "
         f"{holdout:.1%} > {HOLDOUT_TARGET:.0%}")
    emit("calibrate.finding.holdout_within_15pct", 0.0,
         f"holdout_err={holdout:.1%};target={HOLDOUT_TARGET:.0%}")


def oracle_gemma_calibration(session, smoke, profile_dir, out):
    spec = CalibrationSpec(
        job_id="cal-gemma2", model=ModelRef(name="gemma2-2b"),
        hardware="tpu-v5e", chips=4,
        batches=(1, 2, 4, 8, 16), seqs=(32, 64, 128, 256, 512),
        holdout_fraction=0.25, profile_dir=str(profile_dir))
    handle = session.submit(spec)
    _, us = timed(session.run)
    m = handle.result().metrics
    out["oracle_gemma2"] = {k: v for k, v in m.items() if k != "profile"}
    emit("calibrate.oracle.gemma2", us,
         f"n={m['n_records']};prefill_err={m['prefill_mean_rel_err']:.1%};"
         f"decode_err={m['decode_mean_rel_err']:.1%};"
         f"profile={m['profile_key']}")
    print(fit_report(m["profile"]))
    return m["profile_path"]


def capacity_plan(session, smoke, profile_path, out):
    # offered load sized so a single replica misses the SLO — the planner
    # has to actually discriminate, not rubber-stamp the smallest config
    wl = WorkloadSpec(kind="poisson", rate=600 if smoke else 900,
                      duration_s=2 if smoke else 4, prompt_tokens=128,
                      output_tokens=4, output_tokens_max=16, seed=0)
    spec = PlanSpec(
        job_id="plan-gemma2", profile=str(profile_path), workload=wl,
        slo_latency_s=SLO_S, slo_target=SLO_TARGET,
        replicas=(1, 2) if smoke else (1, 2, 4, 8),
        policies=("tfs", "continuous"),
        routers=("least-loaded",) if smoke
        else ("round-robin", "least-loaded"))
    handle = session.submit(spec)
    _, us = timed(session.run)
    m = handle.result().metrics
    out["plan"] = {k: v for k, v in m.items() if k != "plan"}
    best = m["best"]
    assert best is not None, "no planned configuration met the SLO target"
    emit("calibrate.plan.best", us,
         f"replicas={best['replicas']};policy={best['policy']};"
         f"router={best['router']};slo={best['metrics']['slo_attainment']:.2f};"
         f"{m['objective']}=${best['objective']:.5f}")

    # independent re-verification: drive the simulator once more at the
    # planned configuration and confirm the SLO holds
    verify = plan_capacity(
        str(profile_path), wl, slo_latency_s=SLO_S, slo_target=SLO_TARGET,
        replicas=(best["replicas"],), policies=(best["policy"],),
        routers=(best["router"],))
    att = verify.candidates[0].metrics["slo_attainment"]
    assert att >= SLO_TARGET, \
        f"planned config failed re-verification: attainment {att:.3f}"
    emit("calibrate.finding.plan_verified", 0.0,
         f"slo_attainment={att:.2f};target={SLO_TARGET:.0%}")


def run(smoke: bool = False) -> None:
    out = {}
    session = BenchmarkSession(n_workers=2)
    profile_dir = Path(__file__).resolve().parent.parent / "experiments" \
        / "bench" / "profiles"
    measured_fc_calibration(session, smoke, out)
    profile_path = oracle_gemma_calibration(session, smoke, profile_dir, out)
    capacity_plan(session, smoke, profile_path, out)
    out["calibration_records_in_perfdb"] = len(
        session.db.query(kind="calibration"))
    save_json("calibrate", out)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grids/durations for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)
