"""Calibration + capacity planning: the measure → model → plan loop.

Five sections:
  (a) measured fc-family calibration — real CPU execution over a batch
      grid, least-squares fit, held-out grid points must be predicted
      within 15% mean relative error;
  (b) oracle calibration of a registered arch (gemma2-2b on tpu-v5e) —
      the roofline model compressed into a portable profile, with fit
      diagnostics;
  (c) SLO-aware capacity plan driven by the fitted profile — a
      2-replica grid searched for the cheapest configuration meeting a
      p(e2e ≤ SLO) ≥ target, re-verified with ``simulate_cluster``;
  (d) memory-aware planning — the same profile planned under a KV-cache
      budget: a latency-feasible decode-slot count must be *rejected*
      for exceeding HBM, with the reason reported;
  (e) kernel-calibrated speed modes — the Pallas-kernel backend sweeps
      real kernels into ``backend="pallas-kernel"`` PerfDB records and a
      kernels+speed_modes profile, then a KV-bound plan over
      ``speed_modes=("fp16", "int8", "speculative")`` must recommend a
      *non-fp16* config on cost-per-goodput, re-verified by independent
      simulation.

``--smoke`` keeps grids/durations CI-sized (it is already small; smoke
mainly trims the plan grid); ``--json PATH`` writes the metrics dict to
PATH and ``--perfdb PATH`` persists the session's PerfDB JSONL (both
consumed by the perf-regression CI lane).
"""
from __future__ import annotations

import sys
from pathlib import Path

# allow `python benchmarks/bench_calibrate.py` (script dir is on sys.path,
# repo root is not)
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.analysis.memory_model import kv_bytes_per_token
from repro.calibrate import plan_capacity, simulate_candidate
from repro.configs import get_config
from repro.core import (BenchmarkSession, CalibrationSpec, MemorySpec,
                        ModelRef, PerfDB, PlanSpec)
from repro.core.analysis import fit_report, plan_table
from repro.serving.workload import WorkloadSpec

from benchmarks.common import dump_json, emit, save_json, timed

HOLDOUT_TARGET = 0.15        # mean relative error on held-out grid points
SLO_S = 0.25
SLO_TARGET = 0.99


def measured_fc_calibration(session, smoke, out):
    # wall-clocking on a shared CI box is jittery even with the min
    # reducer: re-sweep up to 3 times and keep the best-generalizing fit
    m = None
    for attempt in range(3):
        spec = CalibrationSpec(
            job_id=f"cal-fc-a{attempt}",
            model=ModelRef(kind="generated", family="fc", layers=4,
                           width=256),
            batches=(16, 32, 64, 96, 128, 192, 256),
            holdout_fraction=0.25)
        handle = session.submit(spec)
        _, us = timed(session.run)
        attempt_m = handle.result().metrics
        if m is None or (attempt_m["holdout"]["mean_rel_err"]
                         < m["holdout"]["mean_rel_err"]):
            m = attempt_m
        if m["holdout"]["mean_rel_err"] <= HOLDOUT_TARGET / 2:
            break
    out["measured_fc"] = {k: v for k, v in m.items() if k != "profile"}
    out["measured_fc_profile"] = m["profile"]
    holdout = m["holdout"]["mean_rel_err"]
    emit("calibrate.measured.fc", us,
         f"n={m['n_records']};fit_err={m['prefill_mean_rel_err']:.1%};"
         f"holdout_err={holdout:.1%};r2={m['prefill_r2']:.3f}")
    print(fit_report(m["profile"]))
    assert holdout <= HOLDOUT_TARGET, \
        (f"fc calibration generalizes poorly: held-out mean rel err "
         f"{holdout:.1%} > {HOLDOUT_TARGET:.0%}")
    emit("calibrate.finding.holdout_within_15pct", 0.0,
         f"holdout_err={holdout:.1%};target={HOLDOUT_TARGET:.0%}")


def oracle_gemma_calibration(session, smoke, profile_dir, out):
    spec = CalibrationSpec(
        job_id="cal-gemma2", model=ModelRef(name="gemma2-2b"),
        hardware="tpu-v5e", chips=4,
        batches=(1, 2, 4, 8, 16), seqs=(32, 64, 128, 256, 512),
        holdout_fraction=0.25, profile_dir=str(profile_dir))
    handle = session.submit(spec)
    _, us = timed(session.run)
    m = handle.result().metrics
    out["oracle_gemma2"] = {k: v for k, v in m.items() if k != "profile"}
    emit("calibrate.oracle.gemma2", us,
         f"n={m['n_records']};prefill_err={m['prefill_mean_rel_err']:.1%};"
         f"decode_err={m['decode_mean_rel_err']:.1%};"
         f"profile={m['profile_key']}")
    print(fit_report(m["profile"]))
    return m["profile_path"]


def capacity_plan(session, smoke, profile_path, out):
    # offered load sized so a single replica misses the SLO — the planner
    # has to actually discriminate, not rubber-stamp the smallest config
    wl = WorkloadSpec(kind="poisson", rate=600 if smoke else 900,
                      duration_s=2 if smoke else 4, prompt_tokens=128,
                      output_tokens=4, output_tokens_max=16, seed=0)
    spec = PlanSpec(
        job_id="plan-gemma2", profile=str(profile_path), workload=wl,
        slo_latency_s=SLO_S, slo_target=SLO_TARGET,
        replicas=(1, 2) if smoke else (1, 2, 4, 8),
        policies=("tfs", "continuous"),
        routers=("least-loaded",) if smoke
        else ("round-robin", "least-loaded"))
    handle = session.submit(spec)
    _, us = timed(session.run)
    m = handle.result().metrics
    out["plan"] = {k: v for k, v in m.items() if k != "plan"}
    best = m["best"]
    assert best is not None, "no planned configuration met the SLO target"
    emit("calibrate.plan.best", us,
         f"replicas={best['replicas']};policy={best['policy']};"
         f"router={best['router']};slo={best['metrics']['slo_attainment']:.2f};"
         f"{m['objective']}=${best['objective']:.5f}")

    # independent re-verification: drive the simulator once more at the
    # planned configuration and confirm the SLO holds
    verify = plan_capacity(
        str(profile_path), wl, slo_latency_s=SLO_S, slo_target=SLO_TARGET,
        replicas=(best["replicas"],), policies=(best["policy"],),
        routers=(best["router"],))
    att = verify.candidates[0].metrics["slo_attainment"]
    assert att >= SLO_TARGET, \
        f"planned config failed re-verification: attainment {att:.3f}"
    emit("calibrate.finding.plan_verified", 0.0,
         f"slo_attainment={att:.2f};target={SLO_TARGET:.0%}")


def memory_aware_plan(session, smoke, profile_path, out):
    """Acceptance: the planner must reject a latency-feasible slot count
    whose KV working set exceeds the HBM budget, and say why."""
    wl = WorkloadSpec(kind="poisson", rate=400, duration_s=2,
                      prompt_tokens=128, output_tokens=4,
                      output_tokens_max=16, seed=0)
    # profiles carry no model config, so ground the memory model
    # explicitly from the arch the profile was fitted on
    kv_b = kv_bytes_per_token(get_config("gemma2-2b"))
    memory = MemorySpec(hbm_gb=0.2, kv_bytes_per_token=kv_b)
    common = dict(slo_latency_s=SLO_S, slo_target=SLO_TARGET,
                  replicas=(2,), policies=("continuous",),
                  routers=("least-loaded",), max_batches=(8, 256))
    free = plan_capacity(str(profile_path), wl, **common)
    bound = plan_capacity(str(profile_path), wl, memory=memory, **common)
    print(plan_table(bound))

    big_free = next(c for c in free.candidates if c.max_batch == 256)
    big_bound = next(c for c in bound.candidates if c.max_batch == 256)
    small_bound = next(c for c in bound.candidates if c.max_batch == 8)
    assert big_free.meets_slo, \
        "256-slot config should be latency-feasible without a memory model"
    assert big_bound.infeasible_reason is not None, \
        "memory-aware plan failed to reject the over-committed config"
    assert small_bound.infeasible_reason is None
    out["plan_memory"] = {
        "rejected": sum(c.infeasible_reason is not None
                        for c in bound.candidates),
        "rejected_reason": big_bound.infeasible_reason,
        "latency_feasible_without_memory": big_free.meets_slo,
        "best_max_batch": bound.best.max_batch if bound.best else None,
    }
    emit("calibrate.finding.plan_rejects_oom_config", 0.0,
         f"max_batch=256 latency-feasible but rejected: "
         f"{big_bound.infeasible_reason}")


def kernel_speed_mode_plan(session, smoke, profile_dir, out):
    """Acceptance: kernel-calibrated profile + speed-mode planning.

    The Pallas-kernel backend must land ``backend="pallas-kernel"``
    records in the PerfDB and a kernels+speed_modes profile; a KV-bound
    plan over fp16/int8/speculative must then recommend a non-fp16
    config on cost-per-goodput, and that recommendation must survive an
    independent re-simulation."""
    spec = CalibrationSpec(
        job_id="cal-kernels", model=ModelRef(name="gemma2-2b"),
        hardware="tpu-v5e", chips=1,
        batches=(1, 2) if smoke else (1, 2, 4),
        seqs=(64, 128) if smoke else (64, 128, 256),
        repeats=2 if smoke else 3,
        kernels=("flash_attention", "int8_matmul") if smoke
        else ("flash_attention", "decode_attention", "int8_matmul",
              "wkv6", "rglru_scan"),
        profile_dir=str(profile_dir))
    handle = session.submit(spec)
    _, us = timed(session.run)
    m = handle.result().metrics
    krecs = session.db.query(kind="calibration", backend="pallas-kernel")
    assert krecs, "no backend=pallas-kernel records landed in the PerfDB"
    profile = m["profile"]
    assert profile.get("kernels"), "profile carries no kernel fits"
    assert set(profile.get("speed_modes", {})) >= {"int8", "speculative"}
    emit("calibrate.kernels.records", us,
         f"n={m['n_kernel_records']};kernels={','.join(m['kernels'])};"
         f"fits={len(profile['kernels'])}")

    # KV-bound plan: long contexts against a tight per-replica budget —
    # fp16's big batches are memory-rejected, int8's half-size KV entries
    # fit, so the quantized config must win on $/SLO-meeting request
    wl = WorkloadSpec(kind="poisson", rate=4.0,
                      duration_s=10 if smoke else 20,
                      prompt_tokens=2048, output_tokens=256, seed=0)
    kv_b = kv_bytes_per_token(get_config("gemma2-2b"))
    memory = MemorySpec(hbm_gb=2.0, kv_bytes_per_token=kv_b)
    plan_kw = dict(slo_latency_s=20.0, slo_target=0.9,
                   replicas=(1,), policies=("continuous",),
                   routers=("least-loaded",), max_batches=(8, 16),
                   memory=memory, objective="cost_per_goodput")
    plan = plan_capacity(str(m["profile_path"]), wl,
                         speed_modes=("fp16", "int8", "speculative"),
                         **plan_kw)
    print(plan_table(plan))
    best = plan.best
    assert best is not None, "no speed-mode candidate met the SLO"
    assert best.speed_mode != "fp16", \
        (f"expected a quantized/speculative winner on the KV-bound "
         f"workload, got {best.speed_mode}")
    rejected_fp16 = [c for c in plan.candidates
                     if c.speed_mode == "fp16" and c.infeasible_reason]
    assert rejected_fp16, "fp16 was never memory-rejected — not KV-bound"

    # independent re-verification of the winner, outside the plan grid
    res = simulate_candidate(str(m["profile_path"]), wl, best,
                             memory=memory)
    att = res.slo_attainment(20.0)
    assert att >= 0.9, \
        f"speed-mode winner failed re-verification: attainment {att:.3f}"
    out["speed_modes"] = {
        "n_kernel_records": m["n_kernel_records"],
        "kernel_fits": len(profile["kernels"]),
        "perfdb_kernel_records": len(krecs),
        "best_mode": best.speed_mode,
        "best_is_non_fp16": int(best.speed_mode != "fp16"),
        "best_objective": best.objective,
        "fp16_rejected": len(rejected_fp16),
        "reverify_attainment": att,
    }
    emit("calibrate.finding.speed_mode_wins", 0.0,
         f"best={best.speed_mode};max_batch={best.max_batch};"
         f"objective=${best.objective:.6f};reverified_slo={att:.2f}")


def run(smoke: bool = False, json_path: str | None = None,
        perfdb_path: str | None = None) -> None:
    out = {}
    db = None
    if perfdb_path:
        Path(perfdb_path).parent.mkdir(parents=True, exist_ok=True)
        db = PerfDB(perfdb_path)
    session = BenchmarkSession(n_workers=2, db=db)
    profile_dir = Path(__file__).resolve().parent.parent / "experiments" \
        / "bench" / "profiles"
    measured_fc_calibration(session, smoke, out)
    profile_path = oracle_gemma_calibration(session, smoke, profile_dir, out)
    capacity_plan(session, smoke, profile_path, out)
    memory_aware_plan(session, smoke, profile_path, out)
    kernel_speed_mode_plan(session, smoke, profile_dir, out)
    out["calibration_records_in_perfdb"] = len(
        session.db.query(kind="calibration"))
    save_json("calibrate", out)
    if json_path:
        dump_json(json_path, out)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grids/durations for CI")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the metrics dict to PATH "
                         "(perf-regression lane input)")
    ap.add_argument("--perfdb", metavar="PATH", default=None,
                    help="persist the session PerfDB JSONL here "
                         "(uploaded as a CI artifact)")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json, perfdb_path=args.perfdb)
