"""Simulator throughput: events/sec of the discrete-event core itself.

The cluster simulator is the instrument every serving benchmark in this
repo reads from, so its own speed bounds how much configuration space a
sweep can cover.  This bench wall-clocks the indexed event loop on a
cluster-scale scenario — 16 continuous-batching replicas behind a
least-loaded router at an offered rate that generates >=50k requests in
the full run — and reports:

  sim_events_per_sec — engine iterations + arrival/migration pops per
                       wall-clock second (the headline; wall-clocked, so
                       the CI baseline carries a wide tolerance);
  sim_obs_overhead_frac — relative wall-clock cost of attaching the
                       repro.obs recorder (also wall-clocked: the CI
                       gate carries an absolute noise floor);
  events / n_requests / throughput_rps / p99_s — deterministic given the
                       seed (tight tolerance: they catch semantic drift,
                       not machine noise).

Three sections: (a) full trace recording (the default), (b)
``trace_sample=0.1`` — per-request stage accounting kept for a 10%
deterministic hash-sample while aggregate throughput/served counts stay
exact (the bench asserts that equivalence) — and (c) observer overhead:
the same scenario with the ``repro.obs`` time-series recorder attached,
reported as ``sim_obs_overhead_frac`` (relative wall-clock cost vs. the
recorder-off run, best-of-3 each; the CI baseline gates it ≤ 5%).  The
bench also asserts the recorder run's summary is identical to the
recorder-off run — observability must never move a simulated number.

``--smoke`` shrinks the workload window for CI (same 16-replica
topology); ``--json PATH`` writes the metrics dict for the
perf-regression lane.
"""
from __future__ import annotations

import sys
from pathlib import Path

# allow `python benchmarks/bench_simulator.py` (script dir is on
# sys.path, repo root is not)
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import dataclasses

from repro.configs import get_config
from repro.obs.spec import ObsSpec
from repro.serving.batching import make_policy
from repro.serving.cluster import ClusterSpec, simulate_cluster
from repro.serving.latency_model import LatencyModel
from repro.serving.workload import WorkloadSpec

from benchmarks.common import dump_json, emit, save_json, timed

MODEL = "gemma2-2b"
CHIPS = 4
RATE_RPS = 3200.0
REPLICAS = 16
SEED = 42


def _scenario(smoke: bool):
    wl = WorkloadSpec(rate=RATE_RPS, duration_s=2.0 if smoke else 16.0,
                      seed=SEED)
    cluster = ClusterSpec(replicas=REPLICAS, router="least-loaded")
    policy = lambda: make_policy("continuous", max_batch=16, max_prefill=8)
    return wl, policy, cluster


def run(smoke: bool = False, json_path: str | None = None) -> None:
    lm = LatencyModel(get_config(MODEL), chips=CHIPS)
    wl, policy, cluster = _scenario(smoke)
    out = {}

    # (a) full per-request trace recording
    res, us = timed(simulate_cluster, wl, policy(), lm, cluster=cluster)
    wall = us / 1e6
    eps = res.events / wall
    s = res.summary()
    out["full"] = {
        "sim_events_per_sec": eps,
        "events": res.events,
        "n_requests": len(res.traces),
        "throughput_rps": s["throughput_rps"],
        "p99_s": s["p99_s"],
        "wall_s": wall,
    }
    emit("sim.full", us,
         f"events={res.events};ev_per_s={eps/1e3:.1f}k;"
         f"n={len(res.traces)};thr={s['throughput_rps']:.0f}rps;"
         f"p99={s['p99_s']*1e3:.0f}ms")

    # (b) sampled stage accounting: aggregates must match the full run
    res_s, us_s = timed(simulate_cluster, wl, policy(), lm,
                        cluster=cluster, trace_sample=0.1)
    wall_s = us_s / 1e6
    out["sampled"] = {
        "sim_events_per_sec": res_s.events / wall_s,
        "requests_served": res_s.requests_served,
        "traces_kept": len(res_s.traces),
        "throughput_rps": res_s.summary()["throughput_rps"],
        "wall_s": wall_s,
    }
    emit("sim.sampled", us_s,
         f"served={res_s.requests_served};"
         f"kept={len(res_s.traces)};"
         f"ev_per_s={res_s.events/wall_s/1e3:.1f}k")
    assert res_s.requests_served == len(res.traces), \
        (f"sampling changed the served count: "
         f"{res_s.requests_served} != {len(res.traces)}")
    assert res_s.events == res.events, \
        f"sampling changed the event count: {res_s.events} != {res.events}"
    emit("sim.finding.sampling_exact_aggregates", 0.0,
         f"served_match=True;events_match=True;"
         f"kept_fraction={len(res_s.traces)/max(res_s.requests_served, 1):.3f}")

    # (c) observer overhead: time-series recorder on vs. off, best-of-5
    # each, interleaved (single-run wall clocks are too noisy for a 5%
    # gate).  The
    # timeline stays off so the measurement isolates the recorder's
    # hot-loop cost (counters + tick sampling), not span-list appends.
    obs_cluster = dataclasses.replace(cluster,
                                      obs=ObsSpec(timeline=False))
    us_off_best = None
    res_obs = None
    us_on_best = None
    for _ in range(5):      # interleaved so clock drift hits both sides
        us_off = timed(simulate_cluster, wl, policy(), lm,
                       cluster=cluster)[1]
        if us_off_best is None or us_off < us_off_best:
            us_off_best = us_off
        r, us_on = timed(simulate_cluster, wl, policy(), lm,
                         cluster=obs_cluster)
        if us_on_best is None or us_on < us_on_best:
            us_on_best, res_obs = us_on, r
    wall_off = us_off_best / 1e6
    wall_on = us_on_best / 1e6
    overhead = max(wall_on / wall_off - 1.0, 0.0)
    assert res_obs.summary() == s, \
        "observability changed the simulated summary"
    ts = res_obs.timeseries
    assert ts.counter_total("completions") == res_obs.requests_served, \
        (f"completions counter {ts.counter_total('completions')} != "
         f"served {res_obs.requests_served}")
    out["obs"] = {
        "sim_obs_overhead_frac": overhead,
        "wall_off_s": wall_off,
        "wall_on_s": wall_on,
        "ticks": len(ts.times),
    }
    emit("sim.obs_overhead", us_on_best,
         f"overhead={overhead:.1%};ticks={len(ts.times)};"
         f"off={wall_off*1e3:.0f}ms;on={wall_on*1e3:.0f}ms")

    save_json("simulator_fastpath", out)
    if json_path:
        dump_json(json_path, out)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short workload window for CI (same topology)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the metrics dict to PATH "
                         "(perf-regression lane input)")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)
