"""Perf-regression gate for the ``bench-regression`` CI lane.

Compares the JSON metric dumps produced by ``bench_cluster.py --json`` /
``bench_calibrate.py --json`` / ``bench_simulator.py --json`` (the
``sim`` namespace: event-loop throughput, where ``sim_events_per_sec``
is wall-clocked and carries a wide tolerance while the event/request
counts are seed-deterministic) against a committed baseline
(``benchmarks/baselines/ci_baseline.json``), prints a delta table, and
exits non-zero when any metric regressed beyond its tolerance.

Baseline schema::

    {
      "default_tolerance": 0.15,
      "metrics": {
        "<namespace>:<dotted.path>": {
          "value": 123.4,            # the committed reference number
          "direction": "higher",     # "higher" | "lower" is better, or
                                     # "near": the value must stay within
                                     # tolerance of the baseline in either
                                     # direction (band metrics like a
                                     # fairness index)
          "tolerance": 0.15,         # optional per-metric override
          "abs_tolerance": 0.001     # optional absolute floor: a metric
                                     # only fails when it moved in the bad
                                     # direction by more than `tolerance`
                                     # relatively AND `abs_tolerance`
                                     # absolutely (for near-zero metrics
                                     # like per-token latencies, where a
                                     # microsecond of drift is a huge
                                     # relative delta but no regression)
        }, ...
      }
    }

``<namespace>`` names one of the input files (``cluster=out/a.json``);
``<dotted.path>`` walks into its JSON.  Simulated metrics (throughput,
p99, prefix hit rate) are deterministic given the seeds, so they carry
the tight default tolerance; wall-clocked ones (the calibration holdout
error) get a wide per-metric override.

Usage::

    python benchmarks/check_regression.py \\
        --baseline benchmarks/baselines/ci_baseline.json \\
        cluster=out/bench_cluster.json calibrate=out/bench_calibrate.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

DEFAULT_TOLERANCE = 0.15

Row = Tuple[str, float, Optional[float], Optional[float], str]


def get_path(node: Any, path: str) -> Optional[Any]:
    """Walk a dotted path into nested dicts (None on any miss)."""
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def compare(baseline: Dict[str, Any], inputs: Dict[str, Dict[str, Any]],
            default_tolerance: Optional[float] = None
            ) -> Tuple[List[Row], List[str]]:
    """Evaluate every baseline metric against the inputs.

    Returns (table rows, failed metric names).  A metric fails when it
    moved in the *bad* direction by more than its tolerance, or when it
    is missing from the inputs (a silently dropped metric must not turn
    the lane green).
    """
    tol0 = default_tolerance if default_tolerance is not None \
        else float(baseline.get("default_tolerance", DEFAULT_TOLERANCE))
    rows: List[Row] = []
    failures: List[str] = []
    for name, entry in baseline["metrics"].items():
        ns, _, path = name.partition(":")
        base = float(entry["value"])
        direction = entry.get("direction", "higher")
        if direction not in ("higher", "lower", "near"):
            raise ValueError(f"{name}: bad direction {direction!r}")
        tol = float(entry.get("tolerance", tol0))
        cur = get_path(inputs.get(ns), path)
        if cur is None:
            rows.append((name, base, None, None, "MISSING"))
            failures.append(name)
            continue
        cur = float(cur)
        if base != 0:
            delta = (cur - base) / abs(base)
        else:
            delta = 0.0 if cur == 0 else float("inf") * (1 if cur > 0
                                                         else -1)
        if direction == "near":
            # band metric (e.g. a fairness index): drift in *either*
            # direction beyond tolerance is a regression
            worse = abs(delta)
        else:
            worse = -delta if direction == "higher" else delta
        failed = worse > tol
        abs_tol = entry.get("abs_tolerance")
        if failed and abs_tol is not None:
            if direction == "near":
                worse_abs = abs(cur - base)
            else:
                worse_abs = (base - cur) if direction == "higher" \
                    else (cur - base)
            failed = worse_abs > float(abs_tol)
        status = "FAIL" if failed else "ok"
        if status == "FAIL":
            failures.append(name)
        rows.append((name, base, cur, delta, status))
    return rows, failures


def render(rows: List[Row]) -> str:
    w = max((len(r[0]) for r in rows), default=20) + 2
    lines = [f"{'metric':<{w}}{'baseline':>12}{'current':>12}"
             f"{'delta':>9}  status"]
    for name, base, cur, delta, status in rows:
        cur_s = f"{cur:>12.5g}" if cur is not None else f"{'-':>12}"
        delta_s = f"{delta:>+8.1%}" if delta is not None else f"{'-':>8}"
        lines.append(f"{name:<{w}}{base:>12.5g}{cur_s}{delta_s}  {status}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True,
                    help="committed baseline JSON")
    ap.add_argument("--default-tolerance", type=float, default=None,
                    help="override the baseline's default tolerance")
    ap.add_argument("inputs", nargs="+", metavar="NAME=PATH",
                    help="bench JSON dumps, namespaced by NAME")
    args = ap.parse_args(argv)

    baseline = json.loads(Path(args.baseline).read_text())
    inputs: Dict[str, Dict[str, Any]] = {}
    for item in args.inputs:
        name, _, path = item.partition("=")
        if not path:
            ap.error(f"input {item!r} is not NAME=PATH")
        inputs[name] = json.loads(Path(path).read_text())

    rows, failures = compare(baseline, inputs, args.default_tolerance)
    print(render(rows))
    if failures:
        print(f"\nREGRESSION: {len(failures)} metric(s) beyond tolerance: "
              + ", ".join(failures), file=sys.stderr)
        return 1
    print(f"\nall {len(rows)} metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
