"""Paper Fig. 8 — energy / CO2 / cloud cost per request vs batch size."""
from __future__ import annotations

from repro import hw as hw_lib
from repro.configs import get_config
from repro.serving.latency_model import LatencyModel

from benchmarks.common import emit, save_json

MODEL = "gemma2-2b"                 # the ResNet50 analog in our pool
HW = ("tpu-v5e", "v100", "t4", "p4")
BATCHES = (1, 4, 16, 64)


def run() -> None:
    cfg = get_config(MODEL)
    out = {}
    for hw_name in HW:
        hwm = hw_lib.HARDWARE[hw_name]
        lm = LatencyModel(cfg, hw=hwm, chips=1)
        for b in BATCHES:
            lat = lm.prefill_latency(b, 128)
            util = min(lm.flops_per_token * b * 128
                       / (lat * hwm.peak_flops), 1.0)
            joules = hw_lib.energy_joules(hwm, lat, util) / b
            co2 = hw_lib.co2_kg(joules)
            out[f"{hw_name}/b{b}"] = {
                "j_per_req": joules, "co2_g_per_req": co2 * 1e3,
                "latency_s": lat,
            }
            emit(f"fig8a.energy.{hw_name}.b{b}", lat * 1e6,
                 f"J/req={joules:.4f};gCO2/req={co2*1e3:.5f}")
        # cloud cost per 1k requests, per provider/instance
        for inst, rate in hw_lib.CLOUD_RATES_USD_PER_HOUR.get(hw_name,
                                                              {}).items():
            for b in BATCHES:
                lat = lm.prefill_latency(b, 128)
                cost = rate * lat / 3600.0 / b * 1000
                out[f"{hw_name}/{inst}/b{b}"] = {"usd_per_1k_req": cost}
                emit(f"fig8b.cloud.{hw_name}.{inst.replace('/','_')}.b{b}",
                     0.0, f"usd_per_1k_req={cost:.5f}")
    save_json("fig8_cost", out)


if __name__ == "__main__":
    run()
