"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit) and
writes JSON artifacts to experiments/bench/.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_cost, bench_dynamic_batching,
                            bench_kernels, bench_latency_throughput,
                            bench_pipeline, bench_roofline,
                            bench_scheduler, bench_sensitivity,
                            bench_tail_latency)
    suites = [
        ("fig7_latency_throughput", bench_latency_throughput.run),
        ("fig8_cost", bench_cost.run),
        ("fig9_sensitivity", bench_sensitivity.run),
        ("fig10_roofline", bench_roofline.run),
        ("fig11_tail_latency", bench_tail_latency.run),
        ("fig12_dynamic_batching", bench_dynamic_batching.run),
        ("fig14_pipeline", bench_pipeline.run),
        ("fig15_scheduler", bench_scheduler.run),
        ("kernels_micro", bench_kernels.run),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, fn in suites:
        if only and only not in name:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        fn()
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
