"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit) and
writes JSON artifacts to experiments/bench/.

Two modes:

  figure suites     PYTHONPATH=src python benchmarks/run.py [filter]
  declarative jobs  PYTHONPATH=src python benchmarks/run.py \
                        --config configs/jobs/quickstart.json \
                        [--executor concurrent] [--workers 4] [--db out.jsonl]
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

# allow `python benchmarks/run.py` (script dir is on sys.path, repo root not)
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def run_config(args) -> None:
    from repro.core import (BenchmarkSession, ConcurrentFollowerExecutor,
                            InlineExecutor, PerfDB, PlanSpec)
    from repro.core.analysis import leaderboard, recommend

    executor = (ConcurrentFollowerExecutor() if args.executor == "concurrent"
                else InlineExecutor())
    session = BenchmarkSession(
        n_workers=args.workers,
        db=PerfDB(args.db) if args.db else None,
        executor=executor)
    handles = session.submit_file(args.config)
    print(f"# {len(handles)} jobs from {args.config} "
          f"({executor.name} executor, {args.workers} followers)")
    t0 = time.time()
    results = session.run()
    print(f"# executed {len(results)} jobs in {time.time()-t0:.1f}s")
    print(leaderboard(session.db, sort_by="throughput_rps", limit=20))
    slos = sorted({r.spec.slo_latency_s for r in results
                   if getattr(r.spec, "slo_latency_s", None) is not None
                   and not isinstance(r.spec, PlanSpec)})
    for slo in slos:
        print(f"\n# top configs under p99 <= {slo*1e3:.0f} ms:")
        for rec in recommend(session.db, slo_latency_s=slo):
            print(f"#   {rec['job_id']:24s} policy={rec['policy']:5s} "
                  f"chips={rec['chips']}")
    if args.db:
        print(f"# PerfDB records appended to {args.db}")


def run_suites(only) -> None:
    from benchmarks import (bench_cluster, bench_cost,
                            bench_dynamic_batching, bench_kernels,
                            bench_latency_throughput, bench_pipeline,
                            bench_roofline, bench_scheduler,
                            bench_sensitivity, bench_tail_latency)
    suites = [
        ("fig7_latency_throughput", bench_latency_throughput.run),
        ("fig8_cost", bench_cost.run),
        ("fig9_sensitivity", bench_sensitivity.run),
        ("fig10_roofline", bench_roofline.run),
        ("fig11_tail_latency", bench_tail_latency.run),
        ("fig12_dynamic_batching", bench_dynamic_batching.run),
        ("fig14_pipeline", bench_pipeline.run),
        ("fig15_scheduler", bench_scheduler.run),
        ("cluster_scale", bench_cluster.run),
        ("kernels_micro", bench_kernels.run),
    ]
    print("name,us_per_call,derived")
    for name, fn in suites:
        if only and only not in name:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        fn()
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("filter", nargs="?", default=None,
                        help="substring filter for figure suites")
    parser.add_argument("--config", default=None,
                        help="JSON/TOML job or sweep config to execute")
    parser.add_argument("--executor", choices=("inline", "concurrent"),
                        default="concurrent")
    parser.add_argument("--workers", type=int, default=4)
    parser.add_argument("--db", default=None,
                        help="PerfDB JSONL path to append records to")
    args = parser.parse_args()
    if args.config:
        run_config(args)
    else:
        run_suites(args.filter)


if __name__ == "__main__":
    main()
