"""Paper Fig. 14 — pipeline decomposition: per-stage latency vs batch size,
end-to-end latency under LAN / WiFi / 4G, and cold-start times."""
from __future__ import annotations

from repro.configs import get_config
from repro.serving.batching import make_policy
from repro.serving.latency_model import NETWORKS, LatencyModel
from repro.serving.simulator import simulate
from repro.serving.workload import WorkloadSpec

from benchmarks.common import emit, save_json, timed

MODEL = "gemma2-2b"


def run() -> None:
    cfg = get_config(MODEL)
    lm = LatencyModel(cfg, chips=4)
    out = {}
    # (a) stage decomposition vs batch size
    for mb in (1, 8, 32):
        pol = make_policy("tfs", max_batch=mb, timeout_s=0.002)
        res, us = timed(simulate,
                        WorkloadSpec(rate=3000, duration_s=3, seed=0),
                        pol, lm)
        st = res.stage_means()
        total = sum(st.values())
        out[f"stages_b{mb}"] = st
        emit(f"fig14a.stages.b{mb}", us,
             ";".join(f"{k}={v/total*100:.0f}%" for k, v in st.items()))
    # (b) network scenarios
    for net in ("lan", "wifi", "4g"):
        res, us = timed(simulate,
                        WorkloadSpec(rate=50, duration_s=3, seed=1),
                        make_policy("none"), lm, network=NETWORKS[net])
        s = res.summary()
        out[f"net_{net}"] = dict(s, stages=res.stage_means())
        emit(f"fig14b.e2e.{net}", us, f"p50={s['p50_s']*1e3:.2f}ms")
    # (c) cold start per model × int8 on/off (the "software" analog)
    for model in ("whisper-tiny", "gemma2-2b", "granite-8b", "dbrx-132b"):
        for int8 in (False, True):
            lmm = LatencyModel(get_config(model), chips=8, int8=int8)
            cs = lmm.cold_start()
            out[f"cold_{model}_{'int8' if int8 else 'bf16'}"] = cs
            emit(f"fig14c.coldstart.{model}.{'int8' if int8 else 'bf16'}",
                 0.0, f"cold_start_s={cs:.2f}")
    save_json("fig14_pipeline", out)


if __name__ == "__main__":
    run()
