"""Cluster-scale serving: throughput & p99-SLO attainment across
replicas × batching policy × router.

Eight sections:
  (a) ramp knee-finding — window vs preferred vs continuous batching on a
      stepped-rate generation workload (continuous should win throughput
      at equal-or-better p99);
  (b) replicas × router sweep at a fixed overload rate — SLO attainment;
  (c) saturation scaling — highest sustained rate for 1 replica vs a
      4-replica least-loaded cluster (target: ≥ 3× scaling);
  (d) reactive autoscaler under a bursty workload;
  (e) memory pressure — paged KV-cache accounting: prefix caching must
      sustain ≥ 1.3× throughput on a shared-prefix chat workload at equal
      HBM budget, and a halved budget must preempt/recompute rather than
      over-allocate while every request still completes;
  (f) disaggregated prefill/decode serving — at a matched chip count on a
      mixed long-prefill/short-decode workload, a 3+1 split must beat 4
      colocated replicas on p99 TTFT (and TPOT);
  (g) scenario library — a flash crowd must degrade tail latency vs a
      steady Poisson stream at *equal mean rate* (burstiness, not volume,
      is what hurts); a two-tenant cluster must keep the small tenant's
      goodput within tolerance when the big tenant bursts (isolation);
      and a tenant-mix capacity plan's cheapest-feasible config must
      survive independent re-simulation with every tenant meeting its
      own SLOs;
  (h) heterogeneous fleet — a mixed v5e+t4 fleet must beat the all-v5e
      fleet on cost per goodput at equal SLO attainment, turning the t4
      pool spot must cut the bill further with bounded preemption-induced
      goodput loss, and the capacity planner searching the fleet grid
      under ``cost_per_goodput`` must discover the winning fleet itself
      (winner re-simulated at >= 0.9 attainment).

``--smoke`` shrinks durations/grids for CI; ``--json PATH`` additionally
writes the metrics dict to PATH (the perf-regression lane's input).
"""
from __future__ import annotations

import sys
from pathlib import Path

# allow `python benchmarks/bench_cluster.py` (script dir is on sys.path,
# repo root is not)
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.configs import get_config
from repro.core.analysis import saturation_knee
from repro.serving.batching import make_policy
from repro.serving.cluster import (ClusterSpec, DisaggSpec, PoolSpec,
                                   simulate_cluster)
from repro.serving.latency_model import LatencyModel
from repro.serving.memory import MemorySpec
from repro.serving.simulator import simulate
from repro.serving.workload import WorkloadSpec, generate, ramp_step_rates

from benchmarks.common import dump_json, emit, save_json, timed

MODEL = "gemma2-2b"
CHIPS = 4
SLO_S = 0.25


def _policies():
    return {
        "window": lambda: make_policy("tfs", max_batch=16, timeout_s=0.01),
        "preferred": lambda: make_policy("tris",
                                         preferred=(16, 8, 4, 2, 1)),
        "continuous": lambda: make_policy("continuous", max_batch=16,
                                          max_prefill=8),
    }


def _gen_workload(**kw) -> WorkloadSpec:
    base = dict(prompt_tokens=128, output_tokens=8, output_tokens_max=32)
    base.update(kw)
    return WorkloadSpec(**base)


def ramp_comparison(lm, smoke, out):
    wl = _gen_workload(kind="ramp", duration_s=2 if smoke else 6,
                       ramp_min_rate=50, ramp_max_rate=500,
                       ramp_steps=3 if smoke else 6, seed=0)
    stats = {}
    for name, factory in _policies().items():
        res, us = timed(simulate, wl, factory(), lm)
        s = dict(res.summary(), slo_attainment=res.slo_attainment(SLO_S))
        stats[name] = s
        out[f"ramp/{name}"] = s
        emit(f"cluster.ramp.{name}", us,
             f"thr={s['throughput_rps']:.0f}rps;"
             f"p99={s['p99_s']*1e3:.0f}ms;"
             f"slo={s['slo_attainment']:.2f}")
    cont, win = stats["continuous"], stats["window"]
    emit("cluster.finding.continuous_vs_window", 0.0,
         f"thr_ratio={cont['throughput_rps']/max(win['throughput_rps'],1e-9):.2f}x;"
         f"p99_ratio={cont['p99_s']/max(win['p99_s'],1e-12):.2f}x")


def replica_router_sweep(lm, smoke, out):
    wl = _gen_workload(rate=150 if smoke else 600,
                       duration_s=2 if smoke else 4, seed=1)
    replica_grid = (1, 2) if smoke else (1, 2, 4, 8)
    for reps in replica_grid:
        for router in ("round-robin", "least-loaded", "affinity"):
            res, us = timed(
                simulate_cluster, wl,
                make_policy("continuous", max_batch=16), lm,
                cluster=ClusterSpec(replicas=reps, router=router))
            s = dict(res.summary(), slo_attainment=res.slo_attainment(SLO_S))
            out[f"sweep/r{reps}/{router}"] = s
            emit(f"cluster.sweep.r{reps}.{router}", us,
                 f"thr={s['throughput_rps']:.0f}rps;"
                 f"p99={s['p99_s']*1e3:.0f}ms;"
                 f"slo={s['slo_attainment']:.2f}")


def _saturation_rate(lm, cluster, rates, duration_s):
    """Highest offered rate the config sustains: the run's makespan stays
    within 10% of the workload window (no unbounded backlog) and p99
    meets the SLO."""
    tested, p99s = [], []
    for rate in rates:
        wl = _gen_workload(rate=rate, duration_s=duration_s, seed=2)
        res = simulate_cluster(wl, make_policy("continuous", max_batch=16),
                               lm, cluster=cluster)
        p99 = res.percentile(99)
        if res.duration_s > 1.1 * wl.duration_s:
            p99 = float("inf")      # unbounded backlog never sustains
        tested.append(rate)
        p99s.append(p99)
        if p99 > SLO_S:
            break
    return saturation_knee(tested, p99s, SLO_S), p99s


def saturation_scaling(lm, smoke, out):
    duration = 2 if smoke else 4
    rates = [50, 100, 150, 200, 300, 400, 600, 800, 1000, 1200, 1600, 2000]
    single, _ = _saturation_rate(
        lm, ClusterSpec(replicas=1), rates, duration)
    quad, _ = _saturation_rate(
        lm, ClusterSpec(replicas=4, router="least-loaded"), rates, duration)
    ratio = quad / single if single and quad else None
    out["saturation"] = {"single_rps": single, "quad_rps": quad,
                         "ratio": ratio}
    emit("cluster.finding.scaling_4x", 0.0,
         f"single={single}rps;quad_least_loaded={quad}rps;"
         + (f"ratio={ratio:.2f}x" if ratio is not None
            else "ratio=n/a (no sustained rate)"))


def autoscale_demo(lm, smoke, out):
    wl = _gen_workload(kind="burst", rate=100 if smoke else 300,
                       duration_s=2 if smoke else 6, burst_factor=8,
                       output_tokens=4, output_tokens_max=0, seed=3)
    for scale in (False, True):
        res, us = timed(
            simulate_cluster, wl, make_policy("continuous", max_batch=16),
            lm, cluster=ClusterSpec(
                replicas=1, autoscale=scale, max_replicas=6,
                scale_interval_s=0.25, spawn_delay_s=0.2))
        s = dict(res.summary(), slo_attainment=res.slo_attainment(SLO_S))
        out[f"autoscale/{'on' if scale else 'off'}"] = s
        emit(f"cluster.autoscale.{'on' if scale else 'off'}", us,
             f"replicas={res.replicas};p99={s['p99_s']*1e3:.0f}ms;"
             f"slo={s['slo_attainment']:.2f}")


def memory_pressure(lm, smoke, out):
    """Paged KV-cache accounting: prefix caching + preemption."""
    # (e1) shared-prefix chat at a rate that saturates the cache-less
    # config: prefix caching skips most prefill compute, so the same
    # replica sustains the offered rate where the cold config backs up
    wl = _gen_workload(rate=600, duration_s=2 if smoke else 4,
                       prompt_tokens=512, prefix_tokens=480,
                       output_tokens=2, output_tokens_max=4,
                       session_count=8, seed=4)
    stats = {}
    for pc in (True, False):
        label = "prefix_on" if pc else "prefix_off"
        res, us = timed(
            simulate_cluster, wl, make_policy("continuous", max_batch=16),
            lm, cluster=ClusterSpec(memory=MemorySpec(prefix_caching=pc)))
        s = dict(res.summary(), slo_attainment=res.slo_attainment(SLO_S))
        stats[pc] = s
        out[f"memory/{label}"] = s
        emit(f"cluster.memory.{label}", us,
             f"thr={s['throughput_rps']:.0f}rps;"
             f"p99={s['p99_s']*1e3:.0f}ms;"
             f"hit_rate={s['prefix_hit_rate']:.2f};"
             f"peak_occ={s['kv_peak_occupancy']:.2f}")
    ratio = stats[True]["throughput_rps"] \
        / max(stats[False]["throughput_rps"], 1e-9)
    out["memory/prefix_ratio"] = {"throughput_ratio": ratio}
    emit("cluster.finding.prefix_cache_speedup", 0.0,
         f"thr_ratio={ratio:.2f}x;target>=1.3x")
    assert ratio >= 1.3, \
        f"prefix caching gained only {ratio:.2f}x throughput (< 1.3x)"

    # (e2) long decodes against a full vs halved KV budget: the halved
    # budget must preempt (evict + recompute) instead of over-allocating,
    # and every admitted request must still complete
    wl = _gen_workload(rate=60, duration_s=2 if smoke else 4,
                       prompt_tokens=96, output_tokens=128,
                       output_tokens_max=256, session_count=4, seed=5)
    expected = len(generate(wl))
    for gb, label in ((0.6, "full"), (0.3, "halved")):
        res, us = timed(
            simulate_cluster, wl, make_policy("continuous", max_batch=16),
            lm, cluster=ClusterSpec(
                memory=MemorySpec(hbm_gb=gb, prefix_caching=False)))
        m = res.memory
        s = dict(res.summary(), completed=len(res.traces),
                 peak_blocks=m["peak_blocks"],
                 total_blocks=m["total_blocks_per_replica"])
        out[f"memory/budget_{label}"] = s
        emit(f"cluster.memory.budget_{label}", us,
             f"blocks={m['peak_blocks']}/{m['total_blocks_per_replica']};"
             f"preempt={s['preemptions']};done={len(res.traces)}")
        assert len(res.traces) == expected, \
            f"{label}: {len(res.traces)} of {expected} completed"
        assert m["peak_blocks"] <= m["total_blocks_per_replica"], \
            f"{label}: over-allocated {m['peak_blocks']} blocks"
        if label == "halved":
            assert s["preemptions"] > 0, \
                "halved budget never preempted — memory pressure unmodeled"
    emit("cluster.finding.preempt_not_overallocate", 0.0,
         f"halved_preemptions={out['memory/budget_halved']['preemptions']};"
         f"all_{expected}_completed=True")


def disaggregation_smoke(lm, smoke, out):
    """(f) prefill/decode disaggregation vs colocated at matched chips on
    a mixed long-prefill/short-decode workload: the split pools must win
    p99 TTFT (and TPOT) — phase-aware serving's core claim."""
    ttft_slo, tpot_slo = 0.35, 0.03
    wl = _gen_workload(rate=280, duration_s=2 if smoke else 4,
                       prompt_tokens=64, prompt_tokens_max=4096,
                       output_tokens=2, output_tokens_max=8, seed=6)
    configs = {
        "colocated": ClusterSpec(replicas=4, router="least-loaded"),
        "disaggregated": ClusterSpec(disaggregation=DisaggSpec(
            prefill_replicas=3, decode_replicas=1,
            prefill_chunk_tokens=512, prefill_max_batch=8)),
    }
    stats = {}
    for label, cluster in configs.items():
        res, us = timed(
            simulate_cluster, wl,
            make_policy("continuous", max_batch=16, max_prefill=8), lm,
            cluster=cluster)
        s = dict(res.summary(),
                 goodput_rps=res.goodput(ttft_slo, tpot_slo))
        stats[label] = s
        out[f"disagg/{label}"] = s
        emit(f"cluster.disagg.{label}", us,
             f"ttft_p99={s['ttft_p99_s']*1e3:.0f}ms;"
             f"tpot_p99={s['tpot_p99_s']*1e3:.1f}ms;"
             f"goodput={s['goodput_rps']:.0f}rps")
    dis, col = stats["disaggregated"], stats["colocated"]
    ttft_ratio = col["ttft_p99_s"] / max(dis["ttft_p99_s"], 1e-12)
    tpot_ratio = col["tpot_p99_s"] / max(dis["tpot_p99_s"], 1e-12)
    out["disagg/ratios"] = {"ttft_p99_ratio": ttft_ratio,
                            "tpot_p99_ratio": tpot_ratio}
    emit("cluster.finding.disagg_vs_colocated", 0.0,
         f"ttft_p99_ratio={ttft_ratio:.2f}x;"
         f"tpot_p99_ratio={tpot_ratio:.2f}x;target>1x")
    assert dis["ttft_p99_s"] < col["ttft_p99_s"], \
        (f"disaggregated p99 TTFT {dis['ttft_p99_s']:.3f}s did not beat "
         f"colocated {col['ttft_p99_s']:.3f}s at matched chip count")
    assert dis["tpot_p99_s"] < col["tpot_p99_s"], \
        (f"disaggregated p99 TPOT {dis['tpot_p99_s']:.4f}s did not beat "
         f"colocated {col['tpot_p99_s']:.4f}s")


def mixed_fleet_smoke(lm, smoke, out):
    """(h) heterogeneous fleet: swapping half the v5e replicas for cheap
    t4s must cut cost per goodput at equal SLO attainment, spot pricing
    on the t4 pool must cut it further with bounded preemption-induced
    goodput loss, and the planner must find the winning fleet itself."""
    from repro.calibrate.planner import plan_capacity, simulate_candidate

    slo = 0.4  # e2e; loose enough that a healthy t4 pool can meet it
    wl = _gen_workload(rate=120, duration_s=3 if smoke else 6, seed=21)
    mixed = ({"name": "v5e", "replicas": 2},
             {"name": "t4", "hardware": "t4", "replicas": 2})
    spot = ({"name": "v5e", "replicas": 2},
            {"name": "t4", "hardware": "t4", "replicas": 2,
             "pricing": "spot", "preempt_mtbf_s": 2.0})
    fleets = {
        "all_v5e": (PoolSpec(name="v5e", replicas=4),),
        "mixed": tuple(PoolSpec.from_dict(p) for p in mixed),
        "mixed_spot": tuple(PoolSpec.from_dict(p) for p in spot),
    }
    stats = {}
    for label, pools in fleets.items():
        res, us = timed(
            simulate_cluster, wl,
            make_policy("continuous", max_batch=16, max_prefill=8), lm,
            cluster=ClusterSpec(pools=pools, router="cost-weighted"))
        gp = res.goodput(e2e_slo_s=slo)
        s = {
            "slo_attainment": res.slo_attainment(slo),
            "goodput_rps": gp,
            "cost_usd": res.cost_usd(),
            "cost_per_goodput": res.cost_usd() / (gp * res.duration_s)
            if gp > 0 else float("inf"),
            "spot_preemptions": res.fleet["spot_preemptions"],
            "goodput_loss_rps": res.preemption_goodput_loss(e2e_slo_s=slo),
        }
        stats[label] = s
        out[f"fleet/{label}"] = s
        emit(f"cluster.fleet.{label}", us,
             f"att={s['slo_attainment']:.3f};"
             f"cost_per_goodput={s['cost_per_goodput']:.3e};"
             f"kills={s['spot_preemptions']}")
    v5e, mix, spt = (stats[k] for k in ("all_v5e", "mixed", "mixed_spot"))
    emit("cluster.finding.mixed_beats_flat", 0.0,
         f"cpg_ratio={v5e['cost_per_goodput'] / mix['cost_per_goodput']:.2f}x;"
         f"target>1x")
    assert mix["slo_attainment"] >= v5e["slo_attainment"] - 1e-9, \
        "mixed fleet lost SLO attainment vs all-v5e"
    assert mix["cost_per_goodput"] < v5e["cost_per_goodput"], \
        (f"mixed fleet cost/goodput {mix['cost_per_goodput']:.3e} did not "
         f"beat all-v5e {v5e['cost_per_goodput']:.3e}")
    assert spt["spot_preemptions"] > 0, \
        "spot pool saw no kills — the preemption path went unexercised"
    assert spt["cost_usd"] < mix["cost_usd"], \
        (f"spot fleet bill {spt['cost_usd']:.5f} not below reserved "
         f"{mix['cost_usd']:.5f}")
    assert spt["goodput_loss_rps"] <= 0.05 * spt["goodput_rps"], \
        (f"preemption-induced goodput loss {spt['goodput_loss_rps']:.2f}rps "
         f"exceeds 5% of goodput {spt['goodput_rps']:.1f}rps")

    # plan over the fleet grid: the spot-backed mixed fleet must win on
    # $/goodput-req against both the reserved mix and a flat cluster,
    # and the winner must survive independent re-simulation
    target = 0.9
    plan, us = timed(plan_capacity, lm, wl, slo_latency_s=slo,
                     slo_target=target, replicas=(3,),
                     policies=("continuous",), routers=("cost-weighted",),
                     max_batch=16, objective="cost_per_goodput",
                     fleets=(mixed, spot))
    best = plan.best
    assert best is not None, "no feasible fleet for the workload"
    assert best.fleet is not None, \
        "planner picked the flat cluster over the cheaper mixed fleets"
    assert any(p["pricing"] == "spot" for p in best.fleet), \
        "planner left the spot discount on the table"
    res = simulate_candidate(lm, wl, best)
    resim_att = res.slo_attainment(slo)
    assert resim_att >= target, \
        (f"re-simulated fleet winner attains {resim_att:.2f} < {target}")
    out["fleet/plan"] = {
        "pools": [f"{p['replicas']}x{p['hardware'] or 'base'}"
                  f"({p['pricing']})" for p in best.fleet],
        "cost_per_goodput": best.objective,
        "resim_attainment": resim_att,
    }
    emit("cluster.fleet.plan", us,
         f"best={'+'.join(out['fleet/plan']['pools'])};"
         f"obj={best.objective:.3e};resim_att={resim_att:.2f}")


def scenario_section(lm, smoke, out):
    """(g) scenario library: burstiness vs volume, tenant isolation, and
    plan-then-verify for a tenant mix."""
    from repro.calibrate.planner import plan_capacity, simulate_candidate
    from repro.scenarios import tenant_report
    from repro.scenarios.arrivals import mean_rate

    # (g1) flash crowd vs steady Poisson at equal mean rate: same offered
    # work, so any p99 gap is pure burstiness
    dur = 4 if smoke else 12
    flash = _gen_workload(kind="flash-crowd", rate=150, duration_s=dur,
                          burst_factor=10, seed=7)
    steady = _gen_workload(rate=mean_rate(flash), duration_s=dur, seed=7)
    cluster = ClusterSpec(replicas=2, router="least-loaded")
    stats = {}
    for label, wl in (("flash", flash), ("steady", steady)):
        res, us = timed(simulate_cluster, wl,
                        make_policy("continuous", max_batch=16), lm,
                        cluster=cluster)
        s = dict(res.summary(), slo_attainment=res.slo_attainment(SLO_S))
        stats[label] = s
        out[f"scenario/{label}"] = s
        emit(f"cluster.scenario.{label}", us,
             f"thr={s['throughput_rps']:.0f}rps;"
             f"p99={s['p99_s']*1e3:.0f}ms;"
             f"slo={s['slo_attainment']:.2f}")
    p99_ratio = stats["flash"]["p99_s"] / max(stats["steady"]["p99_s"],
                                              1e-12)
    out["scenario/flash_ratio"] = {"p99_ratio": p99_ratio,
                                   "mean_rate_rps": mean_rate(flash)}
    emit("cluster.finding.flash_vs_steady_equal_mean_rate", 0.0,
         f"mean_rate={mean_rate(flash):.0f}rps;"
         f"p99_ratio={p99_ratio:.2f}x;target>1x")
    assert stats["flash"]["p99_s"] > stats["steady"]["p99_s"], \
        (f"flash crowd p99 {stats['flash']['p99_s']:.3f}s did not degrade "
         f"vs steady {stats['steady']['p99_s']:.3f}s at equal mean rate")

    # (g2) two-tenant isolation: the small tenant's goodput must survive
    # the big tenant switching from steady to bursting
    def mix(big_overrides):
        return WorkloadSpec(
            rate=200, duration_s=4 if smoke else 8,
            prompt_tokens=128, output_tokens=8, output_tokens_max=32,
            seed=8,
            tenants=({"name": "big", "share": 4.0,
                      "slo_latency_s": SLO_S,
                      "workload": big_overrides},
                     {"name": "small", "share": 1.0,
                      "slo_latency_s": SLO_S}))
    goodputs = {}
    for label, overrides in (("steady", {}),
                             ("burst", {"kind": "burst",
                                        "burst_factor": 10.0})):
        wl = mix(overrides)
        res, us = timed(simulate_cluster, wl,
                        make_policy("continuous", max_batch=16), lm,
                        cluster=cluster)
        rep = tenant_report(res, wl.tenants)
        per = rep["per_tenant"]
        goodputs[label] = per["small"]["goodput_rps"]
        out[f"scenario/isolation_{label}"] = {
            "fairness_index": rep["fairness_index"],
            "worst_tenant": rep["worst_tenant"],
            "small_goodput_rps": per["small"]["goodput_rps"],
            "big_goodput_rps": per["big"]["goodput_rps"],
            "small_p99_s": per["small"]["p99_s"],
            "big_p99_s": per["big"]["p99_s"],
        }
        emit(f"cluster.scenario.isolation_{label}", us,
             f"small_goodput={per['small']['goodput_rps']:.0f}rps;"
             f"big_goodput={per['big']['goodput_rps']:.0f}rps;"
             f"fairness={rep['fairness_index']:.3f}")
    retained = goodputs["burst"] / max(goodputs["steady"], 1e-9)
    out["scenario/isolation_retained"] = {"small_goodput_ratio": retained}
    emit("cluster.finding.tenant_isolation", 0.0,
         f"small_goodput_retained={retained:.2f}x;target>=0.7x")
    assert retained >= 0.7, \
        (f"big tenant's burst cut the small tenant's goodput to "
         f"{retained:.2f}x of steady (< 0.7x) — isolation failed")

    # (g3) tenant-mix capacity plan, then verify the winner by
    # independent re-simulation: every tenant must meet its own SLOs
    target = 0.9
    base = WorkloadSpec(rate=16, duration_s=3 if smoke else 6, seed=11)
    tenants = ({"name": "chatbot", "share": 3.0, "scenario": "chat"},
               {"name": "classifier", "share": 1.0,
                "scenario": "classification"})
    plan, us = timed(plan_capacity, lm, base, tenants=tenants,
                     slo_target=target, replicas=(1, 2),
                     policies=("continuous",), max_batch=16)
    best = plan.best
    assert best is not None, "no feasible config for the tenant mix"
    res = simulate_candidate(lm, base, best, tenants=tenants)
    rep = tenant_report(res, tenants)
    for name, per in rep["per_tenant"].items():
        assert per["slo_attainment"] >= target, \
            (f"re-simulated best config misses tenant {name}: "
             f"attainment {per['slo_attainment']:.2f} < {target}")
    out["scenario/plan"] = {
        "replicas": best.replicas, "policy": best.policy,
        "objective": best.objective,
        "fairness_index": rep["fairness_index"],
        "worst_attainment": rep["worst_tenant_attainment"],
        "min_goodput_rps": rep["min_goodput_rps"],
    }
    emit("cluster.scenario.plan", us,
         f"best={best.replicas}x{best.policy};"
         f"worst_att={rep['worst_tenant_attainment']:.2f};"
         f"fairness={rep['fairness_index']:.3f}")


def run(smoke: bool = False, json_path: str | None = None) -> None:
    lm = LatencyModel(get_config(MODEL), chips=CHIPS)
    out = {}
    ramp_comparison(lm, smoke, out)
    replica_router_sweep(lm, smoke, out)
    saturation_scaling(lm, smoke, out)
    autoscale_demo(lm, smoke, out)
    memory_pressure(lm, smoke, out)
    disaggregation_smoke(lm, smoke, out)
    scenario_section(lm, smoke, out)
    mixed_fleet_smoke(lm, smoke, out)
    # knee of the ramp per policy (for the writeup)
    wl = _gen_workload(kind="ramp", duration_s=2 if smoke else 6,
                       ramp_min_rate=50, ramp_max_rate=500,
                       ramp_steps=3 if smoke else 6, seed=0)
    out["ramp_step_rates"] = ramp_step_rates(wl)
    save_json("cluster_scale", out)
    if json_path:
        dump_json(json_path, out)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grids/durations for CI")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the metrics dict to PATH "
                         "(perf-regression lane input)")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)
