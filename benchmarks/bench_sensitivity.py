"""Paper Fig. 9 — hyper-parameter sensitivity heat maps over *generated*
canonical models, measured for real on CPU (layers × width → latency &
utilization-proxy)."""
from __future__ import annotations

import jax

from repro.core import generator as gen
from repro.core.analysis import heatmap, render_heatmap
from repro.core.perfdb import PerfDB
from repro.serving.latency_model import MeasuredLatency

from benchmarks.common import emit, save_json

LAYERS = (2, 4, 8)
WIDTHS = (128, 256, 512)
FAMILIES = ("fc", "transformer")     # the paper's CNN/Transformer pair analog


def run() -> None:
    db = PerfDB()
    for family in FAMILIES:
        for L in LAYERS:
            for W in WIDTHS:
                spec = gen.GeneratedSpec(family=family, layers=L, width=W,
                                         batch=4, seq=32)
                params, fn, inputs = gen.build(spec)
                lat = MeasuredLatency(jax.jit(fn), warmup=1, iters=3
                                      ).measure(params, *inputs)
                flops = spec.batch * gen.flops_estimate(spec)
                db.insert({
                    "generated": {"family": family, "layers": L, "width": W},
                    "result": {"latency_s": lat,
                               "attained_gflops": flops / lat / 1e9},
                })
                emit(f"fig9.{family}.L{L}.W{W}", lat * 1e6,
                     f"gflops={flops/lat/1e9:.2f}")
    maps = {}
    for family in FAMILIES:
        for value in ("result.latency_s", "result.attained_gflops"):
            hm = heatmap(db, row_key="generated.layers",
                         col_key="generated.width", value_key=value,
                         **{"generated.family": family})
            maps[f"{family}/{value}"] = hm
            print(render_heatmap(hm))
    save_json("fig9_sensitivity", maps)


if __name__ == "__main__":
    run()
