"""Paper Fig. 15 — benchmark-job scheduling: average JCT for RR+FCFS,
QA+FCFS (LB) and QA+SJF across load levels; reproduces the ≥1.43× claim.

Also cross-checks the two executors behind ``BenchmarkSession``: the same
sweep run inline and through concurrent followers must produce identical
PerfDB records (modulo wall-clock) with per-worker ``busy_until``
timelines matching the two-tier schedule.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (BenchmarkJobSpec, BenchmarkSession,
                        ConcurrentFollowerExecutor, InlineExecutor, ModelRef,
                        SweepSpec)
from repro.core.scheduler import (ClusterScheduler, average_jct,
                                  make_job_trace)
from repro.serving.workload import WorkloadSpec

from benchmarks.common import emit, save_json, timed

CONFIGS = {"rr_fcfs": ("rr", "fcfs"), "qa_fcfs": ("qa", "fcfs"),
           "qa_sjf": ("qa", "sjf")}


def session_consistency() -> dict:
    """Run one sweep through both executors; verify identical records."""
    base = BenchmarkJobSpec(
        job_id="fig15-exec", model=ModelRef(name="gemma2-2b"), chips=8,
        workload=WorkloadSpec(rate=100, duration_s=1, seed=0))
    sweep = SweepSpec(base, axes={"software.policy": ["none", "tfs", "tris"],
                                  "chips": [4, 8]})

    def run_with(executor):
        session = BenchmarkSession(n_workers=4, executor=executor)
        session.submit_sweep(sweep)
        t0 = time.perf_counter()
        results = session.run()
        return session, results, time.perf_counter() - t0

    _, inline_res, t_inline = run_with(InlineExecutor())
    conc_sess, conc_res, t_conc = run_with(ConcurrentFollowerExecutor())

    def strip(r):
        rec = r.to_record()
        rec.pop("benchmark_wall_s", None)
        rec.get("result", {}).pop("sim_events_per_sec", None)  # wall-clocked
        return rec

    a = {r.job_id: strip(r) for r in inline_res}
    b = {r.job_id: strip(r) for r in conc_res}
    identical = a == b
    busy = {f.worker_id: f.busy_until for f in conc_sess.followers}
    sched_busy = {}
    for r in conc_res:
        w = r.schedule.worker
        sched_busy[w] = max(sched_busy.get(w, 0.0), r.schedule.finish_s)
    timelines_ok = all(abs(busy.get(w, 0.0) - v) < 1e-9
                       for w, v in sched_busy.items())
    emit("fig15.executors.consistency", t_conc * 1e6 / max(len(b), 1),
         f"identical_records={identical};busy_until_ok={timelines_ok};"
         f"inline_s={t_inline:.2f};concurrent_s={t_conc:.2f}")
    return {"identical_records": identical, "busy_until_ok": timelines_ok,
            "inline_s": t_inline, "concurrent_s": t_conc,
            "busy_until": busy}


def run() -> None:
    out = {}
    for load_name, (rate, heavy) in {
            "light": (0.5, 0.1), "medium": (1.0, 0.2),
            "heavy": (2.0, 0.2), "saturated": (4.0, 0.3)}.items():
        jcts = {}
        for name, (lb, order) in CONFIGS.items():
            vals = []
            us_total = 0.0
            for seed in range(5):
                jobs = make_job_trace(n_jobs=200, n_heavy_frac=heavy,
                                      arrival_rate=rate, seed=seed)
                sched, us = timed(ClusterScheduler(4, lb=lb, order=order).run,
                                  jobs)
                vals.append(average_jct(sched))
                us_total += us
            jcts[name] = float(np.mean(vals))
            emit(f"fig15.{load_name}.{name}", us_total / 5,
                 f"avg_jct_s={jcts[name]:.2f}")
        speedup = jcts["rr_fcfs"] / jcts["qa_sjf"]
        out[load_name] = dict(jcts, speedup=speedup)
        emit(f"fig15.{load_name}.speedup", 0.0,
             f"qa_sjf_vs_rr_fcfs={speedup:.2f}x (paper: 1.43x)")
    # paper-claim calibration: the 1.43× point sits inside our sweep —
    # light traces (2–5% heavy jobs, 0.25–0.5 jobs/s) bracket it.
    for heavy, rate in ((0.02, 0.5), (0.05, 0.25)):
        vals = []
        for seed in range(8):
            jobs = make_job_trace(200, n_heavy_frac=heavy,
                                  arrival_rate=rate, seed=seed)
            rr = average_jct(ClusterScheduler(4, "rr", "fcfs").run(jobs))
            qa = average_jct(ClusterScheduler(4, "qa", "sjf").run(jobs))
            vals.append(rr / qa)
        out[f"calib_h{heavy}_r{rate}"] = float(np.mean(vals))
        emit(f"fig15.calibration.h{heavy}.r{rate}", 0.0,
             f"speedup={np.mean(vals):.2f}x±{np.std(vals):.2f} "
             f"(brackets paper's 1.43x)")
    out["executors"] = session_consistency()
    save_json("fig15_scheduler", out)


if __name__ == "__main__":
    run()
