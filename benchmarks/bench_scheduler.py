"""Paper Fig. 15 — benchmark-job scheduling: average JCT for RR+FCFS,
QA+FCFS (LB) and QA+SJF across load levels; reproduces the ≥1.43× claim."""
from __future__ import annotations

import numpy as np

from repro.core.scheduler import (ClusterScheduler, average_jct,
                                  make_job_trace)

from benchmarks.common import emit, save_json, timed

CONFIGS = {"rr_fcfs": ("rr", "fcfs"), "qa_fcfs": ("qa", "fcfs"),
           "qa_sjf": ("qa", "sjf")}


def run() -> None:
    out = {}
    for load_name, (rate, heavy) in {
            "light": (0.5, 0.1), "medium": (1.0, 0.2),
            "heavy": (2.0, 0.2), "saturated": (4.0, 0.3)}.items():
        jcts = {}
        for name, (lb, order) in CONFIGS.items():
            vals = []
            us_total = 0.0
            for seed in range(5):
                jobs = make_job_trace(n_jobs=200, n_heavy_frac=heavy,
                                      arrival_rate=rate, seed=seed)
                sched, us = timed(ClusterScheduler(4, lb=lb, order=order).run,
                                  jobs)
                vals.append(average_jct(sched))
                us_total += us
            jcts[name] = float(np.mean(vals))
            emit(f"fig15.{load_name}.{name}", us_total / 5,
                 f"avg_jct_s={jcts[name]:.2f}")
        speedup = jcts["rr_fcfs"] / jcts["qa_sjf"]
        out[load_name] = dict(jcts, speedup=speedup)
        emit(f"fig15.{load_name}.speedup", 0.0,
             f"qa_sjf_vs_rr_fcfs={speedup:.2f}x (paper: 1.43x)")
    # paper-claim calibration: the 1.43× point sits inside our sweep —
    # light traces (2–5% heavy jobs, 0.25–0.5 jobs/s) bracket it.
    for heavy, rate in ((0.02, 0.5), (0.05, 0.25)):
        vals = []
        for seed in range(8):
            jobs = make_job_trace(200, n_heavy_frac=heavy,
                                  arrival_rate=rate, seed=seed)
            rr = average_jct(ClusterScheduler(4, "rr", "fcfs").run(jobs))
            qa = average_jct(ClusterScheduler(4, "qa", "sjf").run(jobs))
            vals.append(rr / qa)
        out[f"calib_h{heavy}_r{rate}"] = float(np.mean(vals))
        emit(f"fig15.calibration.h{heavy}.r{rate}", 0.0,
             f"speedup={np.mean(vals):.2f}x±{np.std(vals):.2f} "
             f"(brackets paper's 1.43x)")
    save_json("fig15_scheduler", out)


if __name__ == "__main__":
    run()
