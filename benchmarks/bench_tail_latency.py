"""Paper Fig. 11 + Fig. 13 — tail latency vs batch size / arrival rate /
serving software, and utilization under varied workloads."""
from __future__ import annotations

from repro.configs import get_config
from repro.serving.batching import make_policy
from repro.serving.latency_model import LatencyModel
from repro.serving.simulator import simulate
from repro.serving.workload import WorkloadSpec

from benchmarks.common import emit, save_json, timed

MODEL = "gemma2-2b"
CHIPS = 4


def run() -> None:
    cfg = get_config(MODEL)
    lm = LatencyModel(cfg, chips=CHIPS)
    out = {}
    # (a) batch size vs tail, fixed rate
    for mb in (1, 8, 32):
        pol = make_policy("tfs", max_batch=mb, timeout_s=0.004)
        res, us = timed(simulate,
                        WorkloadSpec(rate=2000, duration_s=5, seed=0),
                        pol, lm)
        s = res.summary()
        out[f"batch{mb}"] = s
        emit(f"fig11a.tfs.batch{mb}", us,
             f"p50={s['p50_s']*1e3:.2f}ms;p99={s['p99_s']*1e3:.2f}ms")
    # (b,c) arrival-rate sweep
    for rate in (500, 2000, 8000, 16000):
        pol = make_policy("tfs", max_batch=8, timeout_s=0.004)
        res, us = timed(simulate,
                        WorkloadSpec(rate=rate, duration_s=4, seed=1),
                        pol, lm)
        s = res.summary()
        out[f"rate{rate}"] = s
        emit(f"fig11bc.rate{rate}", us,
             f"p99={s['p99_s']*1e3:.2f}ms;util={s['utilization']:.2f}")
    # (d) software comparison at one rate
    for name, pol in [
            ("none", make_policy("none")),
            ("tfs", make_policy("tfs", max_batch=8, timeout_s=0.004)),
            ("tris", make_policy("tris", preferred=(8, 4, 2, 1)))]:
        res, us = timed(simulate,
                        WorkloadSpec(rate=4000, duration_s=4, seed=2),
                        pol, lm)
        s = res.summary()
        xs, qs = res.cdf(points=20)
        out[f"sw_{name}"] = dict(s, cdf_x=xs, cdf_q=qs)
        emit(f"fig11d.{name}", us,
             f"p50={s['p50_s']*1e3:.2f}ms;p99={s['p99_s']*1e3:.2f}ms")
    # Fig 13 — utilization under light vs heavy workloads, two models
    for model, rate in (("granite-8b", 30), ("gemma2-2b", 160)):
        lmm = LatencyModel(get_config(model), chips=CHIPS)
        res, us = timed(simulate,
                        WorkloadSpec(rate=rate, duration_s=4, seed=3),
                        make_policy("none"), lmm)
        s = res.summary()
        out[f"util_{model}"] = s
        emit(f"fig13.util.{model}.rate{rate}", us,
             f"util={s['utilization']:.3f}")
    save_json("fig11_tail_latency", out)


if __name__ == "__main__":
    run()
