"""Paper Fig. 7 — latency & throughput vs batch size across hardware,
plus the GPU(TPU)/CPU speedup-under-SLO table.

Each (model, hardware, batch) point is a declarative ``BenchmarkJobSpec``
with a closed-loop workload of ``batch`` clients and a batching policy
pinned to that batch size, executed through a ``BenchmarkSession`` with
concurrent followers; the per-batch inference latency is read off the
typed ``JobResult`` stage breakdown.
"""
from __future__ import annotations

from repro.core import (BenchmarkJobSpec, BenchmarkSession,
                        ConcurrentFollowerExecutor, ModelRef, SoftwareSpec)
from repro.serving.workload import WorkloadSpec

from benchmarks.common import emit, save_json

MODELS = ("granite-8b", "gemma2-2b")          # BERT-Large / ResNet50 analogs
HW = ("tpu-v5e", "v100", "t4", "p4", "cpu-xeon")
BATCHES = (1, 2, 4, 8, 16, 32, 64)
PROMPT = 128
DURATION_S = 0.25


def _spec(model: str, hw_name: str, b: int) -> BenchmarkJobSpec:
    return BenchmarkJobSpec(
        job_id=f"fig7-{model}-{hw_name}-b{b}",
        model=ModelRef(name=model),
        hardware=hw_name,
        chips=1,
        software=(SoftwareSpec(policy="none") if b == 1
                  else SoftwareSpec(policy="tris", preferred=(b,))),
        workload=WorkloadSpec(kind="closed", concurrency=b,
                              duration_s=DURATION_S, prompt_tokens=PROMPT),
    )


def run() -> None:
    session = BenchmarkSession(n_workers=4,
                               executor=ConcurrentFollowerExecutor())
    handles = {}
    for model in MODELS:
        for hw_name in HW:
            for b in (BATCHES if hw_name != "cpu-xeon" else (1,)):
                h = session.submit(_spec(model, hw_name, b))
                handles[(model, hw_name, b)] = h
    session.run()

    table = {}
    for (model, hw_name, b), h in handles.items():
        res = h.result()
        lat = res.stages.inference
        table[f"{model}/{hw_name}/b{b}"] = {
            "latency_s": lat, "throughput_rps": b / lat,
            "closed_loop_rps": res.metric("throughput_rps")}
        emit(f"fig7.latency.{model}.{hw_name}.b{b}", lat * 1e6,
             f"latency_ms={lat*1e3:.3f};thr={b/lat:.1f}rps")
    # speedup under the CPU-latency SLO (paper Fig. 7c)
    for model in MODELS:
        cpu = table[f"{model}/cpu-xeon/b1"]["latency_s"]
        best = {}
        for hw_name in HW[:-1]:
            ok = [(b, table[f"{model}/{hw_name}/b{b}"])
                  for b in BATCHES
                  if table[f"{model}/{hw_name}/b{b}"]["latency_s"] <= cpu]
            if ok:
                b, rec = max(ok, key=lambda kv: kv[1]["throughput_rps"])
                speedup = rec["throughput_rps"] / (1 / cpu)
                best[hw_name] = {"batch": b, "speedup": speedup}
                emit(f"fig7.speedup.{model}.{hw_name}", 0.0,
                     f"best_batch={b};speedup_vs_cpu={speedup:.1f}x")
    save_json("fig7_latency_throughput", table)


if __name__ == "__main__":
    run()
