"""Paper Fig. 7 — latency & throughput vs batch size across hardware,
plus the GPU(TPU)/CPU speedup-under-SLO table."""
from __future__ import annotations

from repro import hw as hw_lib
from repro.configs import get_config
from repro.serving.latency_model import LatencyModel

from benchmarks.common import emit, save_json, timed

MODELS = ("granite-8b", "gemma2-2b")          # BERT-Large / ResNet50 analogs
HW = ("tpu-v5e", "v100", "t4", "p4", "cpu-xeon")
BATCHES = (1, 2, 4, 8, 16, 32, 64)
PROMPT = 128


def run() -> None:
    table = {}
    for model in MODELS:
        cfg = get_config(model)
        for hw_name in HW:
            lm = LatencyModel(cfg, hw=hw_lib.HARDWARE[hw_name], chips=1)
            for b in (BATCHES if hw_name != "cpu-xeon" else (1,)):
                (lat, us) = timed(lm.prefill_latency, b, PROMPT)
                table[f"{model}/{hw_name}/b{b}"] = {
                    "latency_s": lat, "throughput_rps": b / lat}
                emit(f"fig7.latency.{model}.{hw_name}.b{b}", us,
                     f"latency_ms={lat*1e3:.3f};thr={b/lat:.1f}rps")
    # speedup under the CPU-latency SLO (paper Fig. 7c)
    for model in MODELS:
        cpu = table[f"{model}/cpu-xeon/b1"]["latency_s"]
        best = {}
        for hw_name in HW[:-1]:
            ok = [(b, table[f"{model}/{hw_name}/b{b}"])
                  for b in BATCHES
                  if table[f"{model}/{hw_name}/b{b}"]["latency_s"] <= cpu]
            if ok:
                b, rec = max(ok, key=lambda kv: kv[1]["throughput_rps"])
                speedup = rec["throughput_rps"] / (1 / cpu)
                best[hw_name] = {"batch": b, "speedup": speedup}
                emit(f"fig7.speedup.{model}.{hw_name}", 0.0,
                     f"best_batch={b};speedup_vs_cpu={speedup:.1f}x")
    save_json("fig7_latency_throughput", table)


if __name__ == "__main__":
    run()
