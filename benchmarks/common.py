"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Any, Callable, Dict, List

OUT_DIR = Path(__file__).resolve().parent.parent / "experiments" / "bench"

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def save_json(name: str, payload: Any) -> None:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"{name}.json").write_text(json.dumps(payload, indent=2,
                                                     default=str))


def dump_json(path: str, payload: Any) -> None:
    """Write a metrics dict to an explicit path (the --json flag the
    perf-regression CI lane consumes)."""
    out = Path(path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(payload, indent=2, default=str))


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
