"""Paper Fig. 12 — dynamic batching throughput vs concurrency, TFS-style
window batching vs TrIS-style preferred-size batching."""
from __future__ import annotations

from repro.configs import get_config
from repro.serving.batching import make_policy
from repro.serving.latency_model import LatencyModel
from repro.serving.simulator import simulate
from repro.serving.workload import WorkloadSpec

from benchmarks.common import emit, save_json, timed

MODEL = "gemma2-2b"


def run() -> None:
    cfg = get_config(MODEL)
    lm = LatencyModel(cfg, chips=4)
    out = {}
    for conc in (1, 2, 4, 8, 16, 32):
        rate = conc * 400.0      # open-loop proxy for concurrency level
        for name, pol in [
                ("tfs", make_policy("tfs", max_batch=16, timeout_s=0.01)),
                ("tris", make_policy("tris", preferred=(16, 8, 4, 2, 1)))]:
            res, us = timed(simulate,
                            WorkloadSpec(rate=rate, duration_s=4, seed=conc),
                            pol, lm)
            s = res.summary()
            out[f"{name}/c{conc}"] = s
            emit(f"fig12.{name}.conc{conc}", us,
                 f"thr={s['throughput_rps']:.0f}rps;"
                 f"p99={s['p99_s']*1e3:.2f}ms")
    # paper's finding: window batching underperforms at low concurrency
    low_tfs = out["tfs/c1"]["p99_s"]
    low_tris = out["tris/c1"]["p99_s"]
    emit("fig12.finding.low_concurrency", 0.0,
         f"tfs_p99/tris_p99={low_tfs/max(low_tris,1e-12):.2f}x")
    save_json("fig12_dynamic_batching", out)


if __name__ == "__main__":
    run()
