"""Kernel micro-benchmarks: interpret-mode Pallas vs pure-jnp reference
wall times on CPU (correctness-path timings; TPU perf is in §Roofline),
plus the analytic speedup the flash-decode layout buys on TPU v5e.

``--smoke`` instead runs the calibration backend
(``repro.calibrate.kernel_bench``) over a CI-sized grid for every
registered kernel — verified against the references, fitted per
(kernel, dtype) — and ``--json PATH`` dumps the metrics for the
perf-regression lane (wall-clocked latencies carry wide tolerances in
the baseline; the record/fit counts and verification residuals are
deterministic)."""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import jax.numpy as jnp

from repro import hw as hw_lib
from repro.kernels import ref
from repro.serving.latency_model import MeasuredLatency

from benchmarks.common import dump_json, emit, save_json


def run() -> None:
    out = {}
    key = jax.random.key(0)
    # reference attention wall-time scaling (B=1, growing S)
    for S in (256, 1024):
        q = jax.random.normal(key, (1, 8, S, 64))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, S, 64))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 4, S, 64))
        fn = jax.jit(lambda q, k, v: ref.mha_reference(q, k, v, causal=True))
        us = MeasuredLatency(fn, warmup=1, iters=3).measure(q, k, v) * 1e6
        out[f"mha_ref_S{S}"] = us
        emit(f"kernels.mha_ref.S{S}", us, "cpu-jnp-reference")
    # analytic: naive decode attention (logits materialized in HBM) vs
    # flash-decode (stream KV once) on TPU v5e — bytes-based latency bound
    hw = hw_lib.TPU_V5E
    B, H, K, T, d = 64, 32, 8, 32768, 128
    kv_bytes = 2 * B * T * K * d * 2
    logits_bytes = 2 * B * H * T * 4          # write + read, fp32
    naive = (kv_bytes + logits_bytes) / hw.hbm_bw
    flash = kv_bytes / hw.hbm_bw
    out["decode_flash_speedup"] = naive / flash
    emit("kernels.flash_decode.analytic", 0.0,
         f"naive_ms={naive*1e3:.2f};flash_ms={flash*1e3:.2f};"
         f"speedup={naive/flash:.2f}x")
    # wkv6: associative-scan chunk path vs sequential reference (CPU, real)
    Bw, Sw, Hw, N = 2, 512, 4, 64
    r = jax.random.normal(key, (Bw, Sw, Hw, N)) * 0.5
    kk = jax.random.normal(jax.random.fold_in(key, 3), (Bw, Sw, Hw, N)) * 0.5
    vv = jax.random.normal(jax.random.fold_in(key, 4), (Bw, Sw, Hw, N))
    lw = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 5),
                                    (Bw, Sw, Hw, N)) * 0.5)
    u = jax.random.normal(jax.random.fold_in(key, 6), (Hw, N)) * 0.1
    s0 = jnp.zeros((Bw, Hw, N, N))
    from repro.models.rwkv6 import wkv_chunked
    t_seq = MeasuredLatency(jax.jit(ref.wkv6_reference), warmup=1, iters=3
                            ).measure(r, kk, vv, lw, u, s0)
    t_chunk = MeasuredLatency(jax.jit(wkv_chunked), warmup=1, iters=3
                              ).measure(r, kk, vv, lw, u, s0)
    out["wkv_seq_s"] = t_seq
    out["wkv_chunk_s"] = t_chunk
    emit("kernels.wkv6.chunked_vs_sequential", t_chunk * 1e6,
         f"sequential_us={t_seq*1e6:.0f};speedup={t_seq/t_chunk:.2f}x")
    save_json("kernels_micro", out)


def run_smoke(json_path: str | None = None) -> None:
    """CI lane: sweep every registered kernel through the calibration
    backend on a tiny grid, verify against references, fit, and dump
    per-(kernel, dtype) metrics."""
    from repro.calibrate import (fit_kernel_records, kernel_records,
                                 kernel_registry)
    names = sorted(kernel_registry())
    records = kernel_records(names, batches=(1, 2), seqs=(64, 128),
                             dtypes=("float32",), repeats=2,
                             meta={"job_id": "bench-kernels"})
    fits = fit_kernel_records(records)
    out = {"n_records": len(records), "n_fits": len(fits),
           "verified_pairs": len({(r["kernel"], r["dtype"])
                                  for r in records
                                  if r["result"]["max_err_vs_ref"]
                                  is not None}),
           "kernels": {}}
    for key, fit in sorted(fits.items()):
        series = [r["result"]["latency_s"] for r in records
                  if f"{r['kernel']}/{r['dtype']}" == key]
        entry = {"latency_s_min": min(series),
                 "latency_s_max": max(series),
                 "n_points": fit["n_points"],
                 "max_err_vs_ref": fit["max_err_vs_ref"]}
        out["kernels"][key] = entry
        emit(f"kernels.calib.{key}", entry["latency_s_min"] * 1e6,
             f"points={fit['n_points']};"
             f"max_err={fit['max_err_vs_ref']:.2e};"
             f"mode={records[0]['result']['mode']}")
    save_json("kernels_calib", out)
    if json_path:
        dump_json(json_path, out)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized calibration-backend sweep instead of "
                         "the micro-benchmarks")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the metrics dict to PATH "
                         "(perf-regression lane input; implies --smoke)")
    args = ap.parse_args()
    if args.smoke or args.json:
        run_smoke(json_path=args.json)
    else:
        run()
