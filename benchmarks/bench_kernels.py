"""Kernel micro-benchmarks: interpret-mode Pallas vs pure-jnp reference
wall times on CPU (correctness-path timings; TPU perf is in §Roofline),
plus the analytic speedup the flash-decode layout buys on TPU v5e."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import hw as hw_lib
from repro.kernels import ref
from repro.serving.latency_model import MeasuredLatency

from benchmarks.common import emit, save_json


def run() -> None:
    out = {}
    key = jax.random.key(0)
    # reference attention wall-time scaling (B=1, growing S)
    for S in (256, 1024):
        q = jax.random.normal(key, (1, 8, S, 64))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, S, 64))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 4, S, 64))
        fn = jax.jit(lambda q, k, v: ref.mha_reference(q, k, v, causal=True))
        us = MeasuredLatency(fn, warmup=1, iters=3).measure(q, k, v) * 1e6
        out[f"mha_ref_S{S}"] = us
        emit(f"kernels.mha_ref.S{S}", us, "cpu-jnp-reference")
    # analytic: naive decode attention (logits materialized in HBM) vs
    # flash-decode (stream KV once) on TPU v5e — bytes-based latency bound
    hw = hw_lib.TPU_V5E
    B, H, K, T, d = 64, 32, 8, 32768, 128
    kv_bytes = 2 * B * T * K * d * 2
    logits_bytes = 2 * B * H * T * 4          # write + read, fp32
    naive = (kv_bytes + logits_bytes) / hw.hbm_bw
    flash = kv_bytes / hw.hbm_bw
    out["decode_flash_speedup"] = naive / flash
    emit("kernels.flash_decode.analytic", 0.0,
         f"naive_ms={naive*1e3:.2f};flash_ms={flash*1e3:.2f};"
         f"speedup={naive/flash:.2f}x")
    # wkv6: associative-scan chunk path vs sequential reference (CPU, real)
    Bw, Sw, Hw, N = 2, 512, 4, 64
    r = jax.random.normal(key, (Bw, Sw, Hw, N)) * 0.5
    kk = jax.random.normal(jax.random.fold_in(key, 3), (Bw, Sw, Hw, N)) * 0.5
    vv = jax.random.normal(jax.random.fold_in(key, 4), (Bw, Sw, Hw, N))
    lw = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 5),
                                    (Bw, Sw, Hw, N)) * 0.5)
    u = jax.random.normal(jax.random.fold_in(key, 6), (Hw, N)) * 0.1
    s0 = jnp.zeros((Bw, Hw, N, N))
    from repro.models.rwkv6 import wkv_chunked
    t_seq = MeasuredLatency(jax.jit(ref.wkv6_reference), warmup=1, iters=3
                            ).measure(r, kk, vv, lw, u, s0)
    t_chunk = MeasuredLatency(jax.jit(wkv_chunked), warmup=1, iters=3
                              ).measure(r, kk, vv, lw, u, s0)
    out["wkv_seq_s"] = t_seq
    out["wkv_chunk_s"] = t_chunk
    emit("kernels.wkv6.chunked_vs_sequential", t_chunk * 1e6,
         f"sequential_us={t_seq*1e6:.0f};speedup={t_seq/t_chunk:.2f}x")
    save_json("kernels_micro", out)


if __name__ == "__main__":
    run()
