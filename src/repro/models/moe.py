"""Capacity-based top-k Mixture-of-Experts FFN (GShard/Switch-style).

Dispatch is scatter-based (no (S, E, C) one-hot blowup): every token's k
assignments get a position-in-expert via a cumulative sum, tokens beyond
an expert's capacity are dropped (weight renormalised), and activations
are scattered into an (E, C, d) buffer that the expert matmuls consume.

Distribution — three modes, selected by the active sharding rules:

  gspmd (default)   scatter/gather wrapped in ``shard_map`` over the batch
                    axes (GSPMD partitions a scatter-add by splitting the
                    updates over the model axis and all-reducing partial
                    multi-GB buffers — measured: the whole MoE family was
                    collective-bound at <1% MFU); the expert matmuls stay
                    in GSPMD-land so ffn-TP / expert-EP rules apply (dbrx).

  local             rules map "moe_local" → whole MoE block inside
                    ``shard_map`` over (batch[, seq via "moe_seq"→model])
                    with expert weights replicated — zero collectives in
                    the block.  Right for small-expert MoE (granite-moe:
                    d_ff=512, expert weights ~190 MB).  With "moe_seq" the
                    dispatch is per-sequence-shard (GShard grouping), i.e.
                    capacity is enforced per group.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd


def moe_init(key, d: int, d_ff: int, num_experts: int, dtype) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    def w(k, shape):
        return (jax.random.normal(k, shape, dtype=jnp.float32) * scale).astype(dtype)
    return {
        "router": w(k1, (d, num_experts)),
        "wi": w(k2, (num_experts, d, d_ff)),
        "wg": w(k3, (num_experts, d, d_ff)),
        "wo": (jax.random.normal(k4, (num_experts, d_ff, d), dtype=jnp.float32)
               * (1.0 / math.sqrt(d_ff))).astype(dtype),
    }


def moe_axes() -> Dict:
    return {
        "router": ("embed", "expert"),
        "wi": ("expert", "embed", "ffn"),
        "wg": ("expert", "embed", "ffn"),
        "wo": ("expert", "ffn", "embed"),
    }


def _capacity(tokens: int, num_experts: int, k: int, factor: float) -> int:
    cap = int(math.ceil(tokens * k / num_experts * factor))
    return max(cap, k)


# --------------------------------------------------------------------------
# the pure per-shard MoE math (works on whatever (B, S, d) slice it sees)
# --------------------------------------------------------------------------
def _route(params, x, E, k):
    gates = jax.nn.softmax(
        jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                   params["router"].astype(jnp.float32)), axis=-1)
    topk_w, topk_idx = jax.lax.top_k(gates, k)
    topk_w = topk_w / jnp.clip(topk_w.sum(-1, keepdims=True), 1e-9)
    return gates, topk_w, topk_idx


def _dispatch_indices(topk_idx, E, C):
    B, S, k = topk_idx.shape
    onehot = jax.nn.one_hot(topk_idx.reshape(B, S * k), E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(
        pos, topk_idx.reshape(B, S * k)[..., None], axis=-1)[..., 0]
    keep = pos < C
    return onehot, jnp.where(keep, pos, 0), keep


def _scatter_local(xk, eidx, pos, *, E: int, C: int):
    B = xk.shape[0]
    b = jnp.broadcast_to(jnp.arange(B)[:, None], eidx.shape)
    buf = jnp.zeros((B, E, C, xk.shape[-1]), xk.dtype)
    return buf.at[b, eidx, pos].add(xk)


def _gather_local(buf, eidx, pos):
    B = buf.shape[0]
    b = jnp.broadcast_to(jnp.arange(B)[:, None], eidx.shape)
    return buf[b, eidx, pos]


def _expert_ffn(params, buf):
    h = jnp.einsum("becd,edf->becf", buf, params["wi"])
    g = jnp.einsum("becd,edf->becf", buf, params["wg"])
    h = jax.nn.silu(g) * h
    return jnp.einsum("becf,efd->becd", h, params["wo"])


def _moe_core(params, x, *, E: int, k: int, capacity_factor: float):
    """Full MoE block on a local (B, S, d) slice — no collectives."""
    B, S, d = x.shape
    C = _capacity(S, E, k, capacity_factor)
    _, topk_w, topk_idx = _route(params, x, E, k)
    _, pos, keep = _dispatch_indices(topk_idx, E, C)
    eidx = topk_idx.reshape(B, S * k)
    xk = jnp.where(keep[..., None], jnp.repeat(x, k, axis=1), 0)
    buf = _scatter_local(xk, eidx, pos, E=E, C=C)
    out_buf = _expert_ffn(params, buf)
    yk = _gather_local(out_buf, eidx, pos)
    w = (topk_w.reshape(B, S * k) * keep).astype(x.dtype)
    return (yk * w[..., None]).reshape(B, S, k, d).sum(axis=2)


def _aux_loss(params, x, E, k):
    gates, _, topk_idx = _route(params, x, E, k)
    B, S, _ = topk_idx.shape
    onehot = jax.nn.one_hot(topk_idx.reshape(B, S * k), E, dtype=jnp.float32)
    me = gates.mean(axis=(0, 1))
    ce = (onehot.sum(axis=1) / (S * k)).mean(axis=0)
    return E * jnp.sum(me * ce)


# --------------------------------------------------------------------------
# distribution modes
# --------------------------------------------------------------------------
def _mesh_mode(B: int, Sk: int, E: int):
    """Resolve (mesh, batch_axes, mode, seq_axis) from the active rules."""
    ctx = shd._ACT_CTX[0]
    if ctx is None:
        return None
    mesh, rules = ctx
    sizes = dict(mesh.shape)
    b_axes = tuple(a for a in ("pod", "data") if a in sizes)
    n_b = math.prod(sizes[a] for a in b_axes) if b_axes else 1
    if not b_axes or B % n_b:
        return None
    if (rules.get("moe_ep_local") and "model" in sizes
            and E % sizes["model"] == 0):
        mode = "ep_local"
    elif not rules.get("ffn") and not rules.get("expert"):
        mode = "local"
    else:
        mode = "gspmd"
    seq_ok = (rules.get("moe_seq") and "model" in sizes
              and Sk % sizes["model"] == 0)
    return mesh, b_axes, mode, ("model" if mode == "local" and seq_ok else None)


def _moe_ep_local(params, x, *, E: int, k: int, capacity_factor: float,
                  mesh, b_axes):
    """Expert-parallel local dispatch: every model shard owns E/m experts,
    routes its (replicated) tokens to its own experts locally, and the
    combined outputs are summed with ONE psum of (B, S, d) per layer —
    instead of GSPMD's multi-GB partial-buffer all-reduces."""
    m = dict(mesh.shape)["model"]
    E_l = E // m

    def block(p, x_l):
        B_l, S, d = x_l.shape
        C = _capacity(S, E, k, capacity_factor)
        _, topk_w, topk_idx = _route(p, x_l, E, k)     # router is replicated
        _, pos, keep = _dispatch_indices(topk_idx, E, C)
        eidx = topk_idx.reshape(B_l, S * k)
        first = jax.lax.axis_index("model") * E_l
        mine = keep & (eidx >= first) & (eidx < first + E_l)
        xk = jnp.where(mine[..., None], jnp.repeat(x_l, k, axis=1), 0)
        e_loc = jnp.where(mine, eidx - first, 0)
        p_loc = jnp.where(mine, pos, 0)
        buf = _scatter_local(xk, e_loc, p_loc, E=E_l, C=C)
        out_buf = _expert_ffn(p, buf)
        yk = _gather_local(out_buf, e_loc, p_loc)
        w = (topk_w.reshape(B_l, S * k) * mine).astype(x_l.dtype)
        y = (yk * w[..., None]).reshape(B_l, S, k, d).sum(axis=2)
        return jax.lax.psum(y, "model")

    pspec = {"router": P(), "wi": P("model", None, None),
             "wg": P("model", None, None), "wo": P("model", None, None)}
    xspec = P(b_axes, None, None)
    return shard_map(block, mesh=mesh, in_specs=(pspec, xspec),
                     out_specs=xspec, check_rep=False)(params, x)


def moe_apply(params: Dict, x: jnp.ndarray, *, num_experts: int, k: int,
              capacity_factor: float = 1.25,
              return_aux: bool = False):
    """x: (B, S, d) → (B, S, d) plus optional load-balancing aux loss."""
    B, S, d = x.shape
    E = num_experts
    mode = _mesh_mode(B, S, E)
    core = functools.partial(_moe_core, E=E, k=k,
                             capacity_factor=capacity_factor)

    if mode is None:
        y = core(params, x)
    else:
        mesh, b_axes, kind, seq_ax = mode
        if kind == "ep_local":
            y = _moe_ep_local(params, x, E=E, k=k,
                              capacity_factor=capacity_factor,
                              mesh=mesh, b_axes=b_axes)
        elif kind == "local":
            # whole block local per (batch[, seq]) shard; weights replicated
            xspec = P(b_axes, seq_ax, None)
            y = shard_map(core, mesh=mesh,
                          in_specs=(P(), xspec), out_specs=xspec,
                          check_rep=False)(params, x)
        else:
            # dispatch local, expert matmuls under GSPMD (TP/EP rules)
            C = _capacity(S, E, k, capacity_factor)
            _, topk_w, topk_idx = _route(params, x, E, k)
            _, pos, keep = _dispatch_indices(topk_idx, E, C)
            eidx = topk_idx.reshape(B, S * k)
            xk = jnp.where(keep[..., None], jnp.repeat(x, k, axis=1), 0)
            spec3, spec2 = P(b_axes, None, None), P(b_axes, None)
            spec4 = P(b_axes, None, None, None)
            buf = shard_map(functools.partial(_scatter_local, E=E, C=C),
                            mesh=mesh, in_specs=(spec3, spec2, spec2),
                            out_specs=spec4, check_rep=False)(xk, eidx, pos)
            out_buf = _expert_ffn(params, buf)
            yk = shard_map(_gather_local, mesh=mesh,
                           in_specs=(spec4, spec2, spec2), out_specs=spec3,
                           check_rep=False)(out_buf, eidx, pos)
            w = (topk_w.reshape(B, S * k) * keep).astype(x.dtype)
            y = (yk * w[..., None]).reshape(B, S, k, d).sum(axis=2)

    if not return_aux:
        return y
    return y, _aux_loss(params, x, E, k)
