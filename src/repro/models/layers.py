"""Core model building blocks, written functionally (init fn + apply fn).

Every block here is pure JAX; the Pallas kernels in ``repro.kernels`` are
numerically-equivalent accelerated paths the engine can switch in (see
``repro.kernels.ops``).  Parameter pytrees are plain nested dicts; each init
also has a ``*_axes`` twin returning the logical sharding axes of each leaf
(consumed by ``repro.dist.sharding``).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain_attn_q

Params = Dict[str, Any]


# Leaves that must stay fp32 even under bf16 compute (log-space decays etc.)
_F32_LEAVES = frozenset({"lam", "decay_w0", "bonus_u"})


def cast_layer_params(params: Params, dtype) -> Params:
    """Mixed precision: cast weights to the compute dtype at point of use
    (fp32 masters stay in the optimizer)."""
    def cast(path, leaf):
        name = getattr(path[-1], "key", None)
        if name in _F32_LEAVES or not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        return leaf.astype(dtype)
    return jax.tree_util.tree_map_with_path(cast, params)


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------
def _dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm_axes() -> Params:
    return {"scale": ("embed",)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd) ; positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs       # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                              # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA + optional local window + optional logit softcap)
# --------------------------------------------------------------------------
def attention_init(key, d: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, dtype) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": _dense_init(kq, d, num_heads * head_dim, dtype).reshape(
            d, num_heads, head_dim),
        "wk": _dense_init(kk, d, num_kv_heads * head_dim, dtype).reshape(
            d, num_kv_heads, head_dim),
        "wv": _dense_init(kv, d, num_kv_heads * head_dim, dtype).reshape(
            d, num_kv_heads, head_dim),
        "wo": _dense_init(ko, num_heads * head_dim, d, dtype).reshape(
            num_heads, head_dim, d),
    }


def attention_axes() -> Params:
    return {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }


def _softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0.0:
        return cap * jnp.tanh(logits / cap)
    return logits


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           q_pos: jnp.ndarray, k_pos: jnp.ndarray,
           *, causal: bool, window: int = 0, softcap: float = 0.0,
           k_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Grouped-query attention core.

    q: (B, S, H, hd); k/v: (B, T, K, hd); q_pos: (B, S); k_pos: (B, T).
    k_valid: optional (B, T) bool mask of live cache slots.
    Returns (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = _softcap(logits, softcap)
    mask = jnp.ones((B, S, T), dtype=bool)
    if causal:
        mask &= k_pos[:, None, :] <= q_pos[:, :, None]
    # window may be a traced per-layer scalar (scan over mixed local/global
    # stacks) — apply the mask unconditionally unless statically disabled.
    if window is not None and not (isinstance(window, int) and window <= 0):
        mask &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    if k_valid is not None:
        mask &= k_valid[:, None, :]
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


# Attention implementation toggle: "xla" (pure jnp, default — what the
# dry-run lowers) or "pallas" (the flash kernel from repro.kernels; used on
# TPU, validated in interpret mode on CPU).  The kernel path is only legal
# for dense self-attention with static windows and no ragged k_valid mask.
_ATTENTION_IMPL = ["xla"]


def set_attention_impl(impl: str) -> None:
    assert impl in ("xla", "pallas")
    _ATTENTION_IMPL[0] = impl


def _flash_ok(positions, window, softcap, k_valid) -> bool:
    return (_ATTENTION_IMPL[0] == "pallas"
            and k_valid is None
            and isinstance(window, int))


def attention_apply(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
                    *, rope_theta: float, causal: bool = True,
                    window: int = 0, softcap: float = 0.0,
                    kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    kv_pos: Optional[jnp.ndarray] = None,
                    k_valid: Optional[jnp.ndarray] = None,
                    return_kv: bool = False):
    """Full attention block: projections + RoPE + attend + output proj.

    When ``kv`` is given it is used as the key/value source (decode against a
    cache, or cross-attention); otherwise self-attention over ``x``.
    """
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if kv is None:
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        k = rope(k, positions, rope_theta)
        kv_pos_eff = positions
    else:
        k, v = kv
        kv_pos_eff = kv_pos
    q = rope(q, positions, rope_theta)
    q = constrain_attn_q(q)
    if kv is None and _flash_ok(positions, window, softcap, k_valid):
        from repro.kernels import ops as kops
        w_eff = 0 if (window or 0) >= (1 << 29) else int(window or 0)
        out = kops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=w_eff,
            softcap=float(softcap)).transpose(0, 2, 1, 3)
    else:
        out = attend(q, k, v, positions, kv_pos_eff, causal=causal,
                     window=window, softcap=softcap, k_valid=k_valid)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if return_kv:
        return y, (k, v)
    return y


def cross_attention_kv(params: Params, enc_out: jnp.ndarray):
    """Precompute cross-attention K/V from encoder output (no RoPE)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
    return k, v


def cross_attention_apply(params: Params, x: jnp.ndarray,
                          kv: Tuple[jnp.ndarray, jnp.ndarray]) -> jnp.ndarray:
    """Cross attention: queries from x, keys/values precomputed (no RoPE)."""
    B, S, _ = x.shape
    k, v = kv
    T = k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q = constrain_attn_q(q)
    zero_q = jnp.zeros((B, S), dtype=jnp.int32)
    zero_k = jnp.zeros((B, T), dtype=jnp.int32)
    out = attend(q, k, v, zero_q, zero_k, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


# --------------------------------------------------------------------------
# gated MLP (SwiGLU)
# --------------------------------------------------------------------------
def mlp_init(key, d: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": _dense_init(k1, d, d_ff, dtype),
        "wg": _dense_init(k2, d, d_ff, dtype),
        "wo": _dense_init(k3, d_ff, d, dtype),
    }


def mlp_axes() -> Params:
    return {"wi": ("embed", "ffn"), "wg": ("embed", "ffn"), "wo": ("ffn", "embed")}


def mlp_apply(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.einsum("bsd,df->bsf", x, params["wi"])
    g = jnp.einsum("bsd,df->bsf", x, params["wg"])
    h = jax.nn.silu(g) * h
    return jnp.einsum("bsf,fd->bsd", h, params["wo"])
