"""RWKV6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

The WKV recurrence per head (state S ∈ R^{N×N}, N = head dim):

    S_t = diag(w_t) · S_{t-1} + k_t v_tᵀ
    o_t = r_tᵀ (diag(u) k_t v_tᵀ + S_{t-1})

with per-channel data-dependent decay  w_t = exp(−exp(w0 + tanh(x Wa) Wb)).
Sequence mode uses the exact *chunked* algorithm: within a chunk of T
tokens the pairwise decay tensor exp(Σ logw) is materialised (it is ≤ 1 so
this is overflow-safe), across chunks the N×N state is carried by a scan.
``repro.kernels.wkv6`` is the Pallas TPU kernel of the same algorithm.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

CHUNK = 32
DECAY_LORA = 64


# --------------------------------------------------------------------------
# pure WKV math (shared with kernels/ref.py)
# --------------------------------------------------------------------------
def wkv_chunked(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                logw: jnp.ndarray, u: jnp.ndarray,
                state0: jnp.ndarray, chunk: int = CHUNK
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r/k/v/logw: (B, S, H, N); u: (H, N); state0: (B, H, N, N) fp32.

    Returns (out (B,S,H,N), final_state (B,H,N,N)).  S must divide by chunk.

    All intra-chunk terms are computed for every chunk *in parallel*
    (batched over a chunk axis); only the chunk-boundary states go through
    a log-depth ``associative_scan`` with the combine
        (d₂, U₂) ∘ (d₁, U₁) = (d₁·d₂, diag(d₂)·U₁ + U₂)
    which is both faster on TPU (no length-S/T sequential loop) and exactly
    cost-countable by XLA (no while op).
    """
    B, S, H, N = r.shape
    assert S % chunk == 0, f"seq {S} not divisible by chunk {chunk}"
    nc = S // chunk
    f32 = jnp.float32
    # (B, C, H, T, N)
    rc = r.astype(f32).reshape(B, nc, chunk, H, N).transpose(0, 1, 3, 2, 4)
    kc = k.astype(f32).reshape(B, nc, chunk, H, N).transpose(0, 1, 3, 2, 4)
    vc = v.astype(f32).reshape(B, nc, chunk, H, N).transpose(0, 1, 3, 2, 4)
    wc = logw.astype(f32).reshape(B, nc, chunk, H, N).transpose(0, 1, 3, 2, 4)
    u32 = u.astype(f32)

    lc = jnp.cumsum(wc, axis=3)                   # inclusive Σ logw in chunk
    lc_excl = lc - wc

    # ---- per-chunk summaries (parallel over the chunk axis) -------------
    # chunk decay d_c = e^{lc_T}; injected state U_c = Σ_s diag(e^{lc_T−lc_s}) k_s v_sᵀ
    d = jnp.exp(lc[:, :, :, -1, :])                            # (B,C,H,N)
    k_dec = kc * jnp.exp(lc[:, :, :, -1:, :] - lc)
    U = jnp.einsum("bchsd,bchse->bchde", k_dec, vc)            # (B,C,H,N,N)

    # ---- chunk-level recurrence via associative scan --------------------
    def combine(c1, c2):
        d1, u1 = c1
        d2, u2 = c2
        return d1 * d2, d2[..., None] * u1 + u2

    d_acc, U_acc = jax.lax.associative_scan(combine, (d, U), axis=1)
    # state *before* chunk c: shift by one, fold in state0
    s_before = jnp.concatenate(
        [jnp.zeros_like(U_acc[:, :1]), U_acc[:, :-1]], axis=1)
    d_before = jnp.concatenate(
        [jnp.ones_like(d_acc[:, :1]), d_acc[:, :-1]], axis=1)
    s_before = s_before + d_before[..., None] * state0.astype(f32)[:, None]
    final_state = (d_acc[:, -1][..., None] * state0.astype(f32)
                   + U_acc[:, -1])

    # ---- outputs (parallel over chunks) ----------------------------------
    r_dec = rc * jnp.exp(lc_excl)
    o_inter = jnp.einsum("bchtd,bchde->bchte", r_dec, s_before)
    decay = jnp.exp(lc_excl[:, :, :, :, None, :] - lc[:, :, :, None, :, :])
    A = jnp.einsum("bchtd,bchsd,bchtsd->bchts", rc, kc, decay)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    A = jnp.where(tri[None, None, None], A, 0.0)
    diag = jnp.einsum("bchtd,hd,bchtd->bcht", rc, u32, kc)
    o_intra = jnp.einsum("bchts,bchse->bchte", A, vc) + diag[..., None] * vc
    out = (o_inter + o_intra).transpose(0, 1, 3, 2, 4).reshape(B, S, H, N)
    return out.astype(r.dtype), final_state


def wkv_step(r, k, v, logw, u, state0):
    """One decode step. r/k/v/logw: (B, H, N); state0: (B, H, N, N) fp32."""
    f32 = jnp.float32
    rr, kk, vv = r.astype(f32), k.astype(f32), v.astype(f32)
    o = (jnp.einsum("bhd,bhde->bhe", rr, state0)
         + jnp.einsum("bhd,hd,bhd,bhe->bhe", rr, u.astype(f32), kk, vv))
    new_state = (jnp.exp(logw.astype(f32))[..., None] * state0
                 + jnp.einsum("bhd,bhe->bhde", kk, vv))
    return o.astype(r.dtype), new_state


# --------------------------------------------------------------------------
# RWKV6 layer (time-mix + channel-mix)
# --------------------------------------------------------------------------
def rwkv_init(key, d: int, d_ff: int, head_dim: int, dtype) -> Dict:
    H = d // head_dim
    ks = jax.random.split(key, 12)
    def w(k, i, o, s=None):
        return (jax.random.normal(k, (i, o), dtype=jnp.float32)
                / math.sqrt(s or i)).astype(dtype)
    def mu(k):
        return jax.random.uniform(k, (d,), minval=0.0, maxval=1.0).astype(dtype)
    return {
        "mu_r": mu(ks[0]), "mu_k": mu(jax.random.fold_in(ks[0], 1)),
        "mu_v": mu(jax.random.fold_in(ks[0], 2)),
        "mu_g": mu(jax.random.fold_in(ks[0], 3)),
        "mu_w": mu(jax.random.fold_in(ks[0], 4)),
        "w_r": w(ks[1], d, d), "w_k": w(ks[2], d, d), "w_v": w(ks[3], d, d),
        "w_g": w(ks[4], d, d), "w_o": w(ks[5], d, d),
        "decay_w0": jnp.full((d,), -1.0, jnp.float32),
        "decay_a": w(ks[6], d, DECAY_LORA),
        "decay_b": (jax.random.normal(ks[7], (DECAY_LORA, d), dtype=jnp.float32)
                    * 0.01).astype(dtype),
        "bonus_u": (jax.random.normal(ks[8], (H, head_dim), dtype=jnp.float32)
                    * 0.1).astype(jnp.float32),
        "ln_out": jnp.ones((d,), dtype),
        # channel mix
        "mu_cr": mu(ks[9]), "mu_ck": mu(jax.random.fold_in(ks[9], 1)),
        "w_cr": w(ks[10], d, d), "w_ck": w(ks[11], d, d_ff),
        "w_cv": w(jax.random.fold_in(ks[11], 1), d_ff, d),
    }


def rwkv_axes() -> Dict:
    e, f = "embed", "ffn"
    return {
        "mu_r": (e,), "mu_k": (e,), "mu_v": (e,), "mu_g": (e,), "mu_w": (e,),
        "w_r": (e, "embed_out"), "w_k": (e, "embed_out"), "w_v": (e, "embed_out"),
        "w_g": (e, "embed_out"), "w_o": ("embed_out", e),
        "decay_w0": (e,), "decay_a": (e, None), "decay_b": (None, e),
        "bonus_u": ("heads", None), "ln_out": (e,),
        "mu_cr": (e,), "mu_ck": (e,),
        "w_cr": (e, "embed_out"), "w_ck": (e, f), "w_cv": (f, e),
    }


def _group_norm(x: jnp.ndarray, scale: jnp.ndarray, H: int) -> jnp.ndarray:
    """Per-head LayerNorm on the WKV output. x: (..., D)."""
    shp = x.shape
    xh = x.reshape(*shp[:-1], H, shp[-1] // H).astype(jnp.float32)
    mean = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mean) * jax.lax.rsqrt(var + 1e-5)
    return (xh.reshape(shp) * scale.astype(jnp.float32)).astype(x.dtype)


def _shift(x: jnp.ndarray, prev: jnp.ndarray) -> jnp.ndarray:
    """Token shift: x_{t-1}, with `prev` (B, D) feeding position 0."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def time_mix_seq(params: Dict, x: jnp.ndarray, head_dim: int,
                 state: Dict, valid=None,
                 use_kernel: bool = False) -> Tuple[jnp.ndarray, Dict]:
    """x: (B,S,D); state = {"shift": (B,D), "wkv": (B,H,N,N) fp32}.

    ``valid`` (B,S) masks right padding: pad steps leave the WKV state and
    shift untouched (k → 0, logw → 0, shift gathered at the last valid pos).
    """
    B, S, D = x.shape
    H = D // head_dim
    xp = _shift(x, state["shift"])
    def mix(mu):
        return x + (xp - x) * mu
    r = jnp.einsum("bsd,de->bse", mix(params["mu_r"]), params["w_r"])
    k = jnp.einsum("bsd,de->bse", mix(params["mu_k"]), params["w_k"])
    v = jnp.einsum("bsd,de->bse", mix(params["mu_v"]), params["w_v"])
    g = jnp.einsum("bsd,de->bse", mix(params["mu_g"]), params["w_g"])
    xw = mix(params["mu_w"]).astype(jnp.float32)
    logw = -jnp.exp(params["decay_w0"]
                    + jnp.tanh(xw @ params["decay_a"].astype(jnp.float32))
                    @ params["decay_b"].astype(jnp.float32))
    if valid is not None:
        vm = valid[..., None]
        k = k * vm.astype(k.dtype)
        logw = logw * vm.astype(logw.dtype)
    rs = r.reshape(B, S, H, head_dim)
    ks_ = k.reshape(B, S, H, head_dim)
    vs = v.reshape(B, S, H, head_dim)
    ws = logw.reshape(B, S, H, head_dim)
    if use_kernel:
        from repro.kernels import ops as kops
        out, wkv_state = kops.wkv6(rs, ks_, vs, ws, params["bonus_u"], state["wkv"])
    else:
        out, wkv_state = wkv_chunked(rs, ks_, vs, ws, params["bonus_u"], state["wkv"])
    out = out.reshape(B, S, D)
    out = _group_norm(out, params["ln_out"], H) * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", out, params["w_o"])
    shift = x[:, -1] if valid is None else _last_valid(x, valid)
    return out, {"shift": shift, "wkv": wkv_state}


def _last_valid(x: jnp.ndarray, valid) -> jnp.ndarray:
    lens = valid.sum(axis=1).astype(jnp.int32)
    b = jnp.arange(x.shape[0])
    return x[b, jnp.maximum(lens - 1, 0)]


def time_mix_step(params: Dict, x: jnp.ndarray, head_dim: int,
                  state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One decode token. x: (B, D)."""
    B, D = x.shape
    H = D // head_dim
    xp = state["shift"]
    def mix(mu):
        return x + (xp - x) * mu
    r = mix(params["mu_r"]) @ params["w_r"]
    k = mix(params["mu_k"]) @ params["w_k"]
    v = mix(params["mu_v"]) @ params["w_v"]
    g = mix(params["mu_g"]) @ params["w_g"]
    xw = mix(params["mu_w"]).astype(jnp.float32)
    logw = -jnp.exp(params["decay_w0"]
                    + jnp.tanh(xw @ params["decay_a"].astype(jnp.float32))
                    @ params["decay_b"].astype(jnp.float32))
    out, wkv_state = wkv_step(
        r.reshape(B, H, head_dim), k.reshape(B, H, head_dim),
        v.reshape(B, H, head_dim), logw.reshape(B, H, head_dim),
        params["bonus_u"], state["wkv"])
    out = out.reshape(B, D)
    out = _group_norm(out, params["ln_out"], H) * jax.nn.silu(g)
    return out @ params["w_o"], {"shift": x, "wkv": wkv_state}


def channel_mix_seq(params: Dict, x: jnp.ndarray, state: jnp.ndarray,
                    valid=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xp = _shift(x, state)
    xr = x + (xp - x) * params["mu_cr"]
    xk = x + (xp - x) * params["mu_ck"]
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["w_cr"]))
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, params["w_ck"])))
    shift = x[:, -1] if valid is None else _last_valid(x, valid)
    return rr * jnp.einsum("bsf,fd->bsd", kk, params["w_cv"]), shift


def channel_mix_step(params: Dict, x: jnp.ndarray,
                     state: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xp = state
    xr = x + (xp - x) * params["mu_cr"]
    xk = x + (xp - x) * params["mu_ck"]
    rr = jax.nn.sigmoid(xr @ params["w_cr"])
    kk = jnp.square(jax.nn.relu(xk @ params["w_ck"]))
    return rr * (kk @ params["w_cv"]), x


def init_state(batch: int, d: int, head_dim: int, dtype) -> Dict:
    H = d // head_dim
    return {
        "tm": {"shift": jnp.zeros((batch, d), dtype),
               "wkv": jnp.zeros((batch, H, head_dim, head_dim), jnp.float32)},
        "cm": jnp.zeros((batch, d), dtype),
    }
