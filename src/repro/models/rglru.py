"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block = [x-branch: linear → causal depthwise conv(4) → RG-LRU] ⊙ gelu(y-branch),
then output projection.  The RG-LRU is a gated diagonal linear recurrence

    r_t = σ(W_a h_x + b_a)          (recurrence gate)
    i_t = σ(W_x h_x + b_x)          (input gate)
    a_t = exp(c · r_t · log σ(Λ))   (per-channel data-dependent decay, c = 8)
    s_t = a_t ⊙ s_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ h_x)

Sequence mode uses an associative scan (O(log S) depth); decode mode is the
one-step recurrence against carried state.  The Pallas kernel in
``repro.kernels.rglru_scan`` implements the sequential-in-VMEM variant.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

C_CONST = 8.0
CONV_WIDTH = 4


def rglru_init(key, d: int, r: int, dtype) -> Dict:
    ks = jax.random.split(key, 6)
    def w(k, i, o):
        return (jax.random.normal(k, (i, o), dtype=jnp.float32)
                / math.sqrt(i)).astype(dtype)
    # Λ initialised so that σ(Λ) ∈ (0.9, 0.999) — the Griffin init.
    u = jax.random.uniform(ks[5], (r,), minval=0.9, maxval=0.999)
    lam = jnp.log(u / (1 - u))
    return {
        "w_x_in": w(ks[0], d, r),
        "w_y_in": w(ks[1], d, r),
        "conv": (jax.random.normal(ks[2], (CONV_WIDTH, r), dtype=jnp.float32)
                 / math.sqrt(CONV_WIDTH)).astype(dtype),
        "w_a": w(ks[3], r, r),
        "w_i": w(ks[4], r, r),
        "b_a": jnp.zeros((r,), dtype),
        "b_i": jnp.zeros((r,), dtype),
        "lam": lam.astype(jnp.float32),
        "w_out": w(jax.random.fold_in(ks[0], 7), r, d),
    }


def rglru_axes() -> Dict:
    return {
        "w_x_in": ("embed", "rnn"), "w_y_in": ("embed", "rnn"),
        "conv": (None, "rnn"),
        "w_a": ("rnn", "rnn_in"), "w_i": ("rnn", "rnn_in"),
        "b_a": ("rnn",), "b_i": ("rnn",), "lam": ("rnn",),
        "w_out": ("rnn", "embed"),
    }


def _gates(params: Dict, hx: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-step decay a_t and input branch (both fp32). hx: (..., R)."""
    h32 = hx.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(h32 @ params["w_a"].astype(jnp.float32)
                            + params["b_a"].astype(jnp.float32))
    i_gate = jax.nn.sigmoid(h32 @ params["w_i"].astype(jnp.float32)
                            + params["b_i"].astype(jnp.float32))
    log_a = C_CONST * r_gate * jax.nn.log_sigmoid(params["lam"])
    a = jnp.exp(log_a)
    gated_in = i_gate * h32
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * gated_in


def rglru_scan_seq(params: Dict, hx: jnp.ndarray,
                   s0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Associative scan over the sequence. hx: (B,S,R), s0: (B,R) fp32."""
    a, b = _gates(params, hx)                                     # (B,S,R) fp32
    # fold initial state into the first step: s_1 = a_1 s_0 + b_1
    b = b.at[:, 0].add(a[:, 0] * s0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, s = jax.lax.associative_scan(combine, (a, b), axis=1)
    return s.astype(hx.dtype), s[:, -1]


def rglru_step(params: Dict, hx: jnp.ndarray,
               s0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One decode step. hx: (B,R), s0: (B,R) fp32."""
    a, b = _gates(params, hx)
    s1 = a * s0 + b
    return s1.astype(hx.dtype), s1


def _causal_conv_seq(w: jnp.ndarray, x: jnp.ndarray,
                     state: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. x: (B,S,R), state: (B,W-1,R) past inputs."""
    full = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(full[:, i:i + x.shape[1]] * w[i] for i in range(CONV_WIDTH))
    new_state = full[:, -(CONV_WIDTH - 1):]
    return out, new_state


def rglru_block_seq(params: Dict, x: jnp.ndarray, state: Dict,
                    valid=None) -> Tuple[jnp.ndarray, Dict]:
    """Full Griffin recurrent block over a sequence.

    x: (B, S, D); state = {"s": (B,R) fp32, "conv": (B, 3, R)}.
    ``valid`` (B, S) masks right-padding: masked steps leave the recurrent
    and conv states untouched so a later decode resumes exactly.
    """
    B, S, _ = x.shape
    hx = jnp.einsum("bsd,dr->bsr", x, params["w_x_in"])
    hy = jnp.einsum("bsd,dr->bsr", x, params["w_y_in"])
    if valid is not None:
        hx = hx * valid[..., None].astype(hx.dtype)
    full = jnp.concatenate([state["conv"].astype(hx.dtype), hx], axis=1)
    conv_out = sum(full[:, i:i + S] * params["conv"][i] for i in range(CONV_WIDTH))
    if valid is None:
        conv_state = full[:, -(CONV_WIDTH - 1):]
        s_seq, s_last = rglru_scan_seq(params, conv_out, state["s"])
    else:
        lens = valid.sum(axis=1).astype(jnp.int32)
        # conv state = inputs at positions len-3..len-1 → full[:, len:len+3]
        idx = (lens[:, None] + jnp.arange(CONV_WIDTH - 1)[None, :])
        conv_state = jnp.take_along_axis(full, idx[..., None], axis=1)
        a, b = _gates(params, conv_out)
        v = valid[..., None].astype(jnp.float32)
        a = jnp.where(v > 0, a, 1.0)   # pad steps: s ← 1·s + 0
        b = b * v
        b = b.at[:, 0].add(a[:, 0] * state["s"])
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        _, s = jax.lax.associative_scan(combine, (a, b), axis=1)
        s_seq, s_last = s.astype(hx.dtype), s[:, -1]
    y = s_seq * jax.nn.gelu(hy)
    out = jnp.einsum("bsr,rd->bsd", y, params["w_out"])
    return out, {"s": s_last, "conv": conv_state.astype(state["conv"].dtype)}


def rglru_block_step(params: Dict, x: jnp.ndarray, state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode step. x: (B, D)."""
    hx = x @ params["w_x_in"]
    hy = x @ params["w_y_in"]
    w = params["conv"]
    conv_in = jnp.concatenate([state["conv"].astype(x.dtype), hx[:, None]], axis=1)
    hx_c = sum(conv_in[:, i] * w[i] for i in range(CONV_WIDTH))
    s1_act, s1 = rglru_step(params, hx_c, state["s"])
    y = s1_act * jax.nn.gelu(hy)
    out = y @ params["w_out"]
    return out, {"s": s1, "conv": conv_in[:, 1:].astype(state["conv"].dtype)}


def init_state(batch: int, r: int, dtype=jnp.float32) -> Dict:
    return {"s": jnp.zeros((batch, r), jnp.float32),
            "conv": jnp.zeros((batch, CONV_WIDTH - 1, r), dtype)}
