"""Decoder-only LM covering the dense / MoE / SSM / hybrid families.

Layers are *stacked* (leading layer axis) and iterated with ``jax.lax.scan``
so the HLO stays O(one layer) regardless of depth — essential for fast
multi-pod lowering and for remat.  Heterogeneous hybrids (RecurrentGemma's
(rec, rec, local-attn) pattern) scan over stacked *periods* plus an
unrolled remainder.

Three entry points per model:
  forward(params, tokens, ...)             teacher-forced full-sequence pass
  prefill(params, cache, tokens, lengths)  fill KV/recurrent caches
  decode_step(params, cache, tokens)       one token per sequence

Caches carry per-sequence ``lengths`` so ragged/continuous batching works.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain_act
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models.config import (ATTN_GLOBAL, ATTN_LOCAL, RGLRU, RWKV6,
                                 ModelConfig)

GLOBAL_WINDOW = 1 << 30


def _stack_init(key, n: int, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _stack_axes(axes_tree):
    return jax.tree.map(lambda a: ("layers",) + tuple(a),
                        axes_tree, is_leaf=lambda x: isinstance(x, tuple))


def _gather_last(logits: jnp.ndarray, lengths: jnp.ndarray) -> jnp.ndarray:
    """logits: (B, S, V) → (B, V) at position lengths-1."""
    b = jnp.arange(logits.shape[0])
    return logits[b, jnp.maximum(lengths - 1, 0)]


def scan_layers(body, carry, xs, unroll: bool = False):
    """lax.scan, or a Python unroll in cost-accounting mode (cfg.cost_unroll)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


class DecoderLM:
    """Decoder-only LM; family behaviour is driven entirely by the config."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.kinds = cfg.layer_kinds()
        self.pdt = jnp.dtype(cfg.param_dtype)
        # hybrid layout: full periods scanned + remainder unrolled
        pat = cfg.layer_pattern
        self.period_len = len(pat)
        self.n_periods = cfg.num_layers // self.period_len
        self.tail_kinds = self.kinds[self.n_periods * self.period_len:]
        self.homogeneous = len(set(pat)) == 1 or set(pat) <= {ATTN_GLOBAL, ATTN_LOCAL}

    # ------------------------------------------------------------------ init
    def _layer_init(self, kind: str):
        cfg = self.cfg
        def init(key):
            k1, k2, k3 = jax.random.split(key, 3)
            p: Dict[str, Any] = {"ln1": L.rmsnorm_init(cfg.d_model, self.pdt),
                                 "ln2": L.rmsnorm_init(cfg.d_model, self.pdt)}
            if kind in (ATTN_GLOBAL, ATTN_LOCAL):
                p["attn"] = L.attention_init(k1, cfg.d_model, cfg.num_heads,
                                             cfg.num_kv_heads, cfg.head_dim, self.pdt)
            elif kind == RGLRU:
                p["rec"] = rglru_lib.rglru_init(k1, cfg.d_model, cfg.rglru_d_rnn,
                                                self.pdt)
            elif kind == RWKV6:
                p["tm_cm"] = rwkv_lib.rwkv_init(k1, cfg.d_model, cfg.d_ff,
                                                cfg.rwkv_head_dim, self.pdt)
            if kind != RWKV6:  # rwkv's channel-mix is its FFN
                if cfg.is_moe:
                    p["ffn"] = moe_lib.moe_init(k2, cfg.d_model, cfg.d_ff,
                                                cfg.num_experts, self.pdt)
                else:
                    p["ffn"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, self.pdt)
            return p
        return init

    def _layer_axes(self, kind: str) -> Dict:
        cfg = self.cfg
        p: Dict[str, Any] = {"ln1": L.rmsnorm_axes(), "ln2": L.rmsnorm_axes()}
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            p["attn"] = L.attention_axes()
        elif kind == RGLRU:
            p["rec"] = rglru_lib.rglru_axes()
        elif kind == RWKV6:
            p["tm_cm"] = rwkv_lib.rwkv_axes()
        if kind != RWKV6:
            p["ffn"] = moe_lib.moe_axes() if cfg.is_moe else L.mlp_axes()
        return p

    def init(self, key) -> Dict:
        cfg = self.cfg
        ke, kl, kt = jax.random.split(key, 3)
        params: Dict[str, Any] = {
            "embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model, self.pdt),
            "final_norm": L.rmsnorm_init(cfg.d_model, self.pdt),
        }
        if self.homogeneous:
            params["layers"] = _stack_init(kl, cfg.num_layers,
                                           self._layer_init(self.kinds[0]))
            # attention sub-params identical across kinds in {global, local}
        else:
            def period_init(key):
                keys = jax.random.split(key, self.period_len)
                return {f"l{i}": self._layer_init(self.cfg.layer_pattern[i])(keys[i])
                        for i in range(self.period_len)}
            params["periods"] = _stack_init(kl, self.n_periods, period_init)
            tails = {}
            tkeys = jax.random.split(kt, max(len(self.tail_kinds), 1))
            for i, kind in enumerate(self.tail_kinds):
                tails[f"t{i}"] = self._layer_init(kind)(tkeys[i])
            params["tail"] = tails
        return params

    def logical_axes(self) -> Dict:
        axes: Dict[str, Any] = {
            "embed": ("vocab", "embed"),
            "final_norm": L.rmsnorm_axes(),
        }
        if self.homogeneous:
            axes["layers"] = _stack_axes(self._layer_axes(self.kinds[0]))
        else:
            period = {f"l{i}": self._layer_axes(self.cfg.layer_pattern[i])
                      for i in range(self.period_len)}
            axes["periods"] = _stack_axes(period)
            axes["tail"] = {f"t{i}": self._layer_axes(kind)
                            for i, kind in enumerate(self.tail_kinds)}
        return axes

    # ------------------------------------------------------------- embeddings
    def _embed(self, params, tokens, prefix_embeds=None):
        cfg = self.cfg
        x = params["embed"][tokens].astype(cfg.activation_dtype)
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.activation_dtype)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        return constrain_act(x, "batch", "seq", "act_embed")

    def _logits(self, params, x):
        cfg = self.cfg
        logits = jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                            params["embed"].astype(jnp.float32))
        if cfg.final_logit_softcap:
            logits = L._softcap(logits, cfg.final_logit_softcap)
        return logits

    # -------------------------------------------------------- full-seq blocks
    def _attn_block(self, p, x, positions, window, valid):
        cfg = self.cfg
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        h = L.attention_apply(
            p["attn"], h, positions, rope_theta=cfg.rope_theta, causal=True,
            window=window, softcap=cfg.attn_logit_softcap,
            k_valid=valid)
        return x + h

    def _ffn_block(self, p, x):
        cfg = self.cfg
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.is_moe:
            h, aux = moe_lib.moe_apply(
                p["ffn"], h, num_experts=cfg.num_experts,
                k=cfg.experts_per_token,
                capacity_factor=cfg.moe_capacity_factor, return_aux=True)
            return x + h, aux
        return x + L.mlp_apply(p["ffn"], h), jnp.float32(0.0)

    def _layer_seq(self, kind, p, x, positions, window, valid, rec_state):
        """One layer over a full sequence. Returns (x, aux, new_rec_state)."""
        cfg = self.cfg
        p = L.cast_layer_params(p, cfg.activation_dtype)
        x = constrain_act(x, "batch", "seq", "act_embed")
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            x = self._attn_block(p, x, positions, window, valid)
            x, aux = self._ffn_block(p, x)
            return x, aux, rec_state
        if kind == RGLRU:
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            h, new_state = rglru_lib.rglru_block_seq(p["rec"], h, rec_state)
            x = x + h
            x, aux = self._ffn_block(p, x)
            return x, aux, new_state
        if kind == RWKV6:
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            h, tm_state = rwkv_lib.time_mix_seq(p["tm_cm"], h, cfg.rwkv_head_dim,
                                                rec_state["tm"])
            x = x + h
            h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
            h2, cm_state = rwkv_lib.channel_mix_seq(p["tm_cm"], h2, rec_state["cm"])
            return x + h2, jnp.float32(0.0), {"tm": tm_state, "cm": cm_state}
        raise ValueError(kind)

    # --------------------------------------------------------------- forward
    def forward(self, params, tokens, *, prefix_embeds=None, lengths=None,
                remat: bool = False,
                return_hidden: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Teacher-forced pass → (logits (B,S,V), aux_loss scalar).

        ``return_hidden=True`` returns the final-norm hidden states instead
        of logits so the caller can do a vocab-chunked cross-entropy (the
        full (B,S,V) logits tensor is prohibitive for 256k vocabs).
        """
        cfg = self.cfg
        x = self._embed(params, tokens, prefix_embeds)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        valid = (positions < lengths[:, None]) if lengths is not None else None

        if self.homogeneous:
            kind0 = self.kinds[0]
            windows = jnp.asarray(
                [cfg.local_window if k == ATTN_LOCAL else GLOBAL_WINDOW
                 for k in self.kinds], dtype=jnp.int32)
            if kind0 == RWKV6:
                states = jax.vmap(
                    lambda _: rwkv_lib.init_state(B, cfg.d_model,
                                                  cfg.rwkv_head_dim,
                                                  cfg.activation_dtype)
                )(jnp.arange(cfg.num_layers))
                def body(carry, xs):
                    x, aux = carry
                    p, st = xs
                    x, a, _ = self._layer_seq(RWKV6, p, x, positions,
                                              GLOBAL_WINDOW, valid, st)
                    return (x, aux + a), None
                body = jax.checkpoint(body) if remat else body
                (x, aux), _ = scan_layers(body, (x, jnp.float32(0.0)),
                                          (params["layers"], states),
                                          cfg.cost_unroll)
            else:
                def body(carry, xs):
                    x, aux = carry
                    p, w = xs
                    p = L.cast_layer_params(p, cfg.activation_dtype)
                    x = constrain_act(x, "batch", "seq", "act_embed")
                    x = self._attn_block(p, x, positions, w, valid)
                    x, a = self._ffn_block(p, x)
                    return (x, aux + a), None
                body = jax.checkpoint(body) if remat else body
                (x, aux), _ = scan_layers(body, (x, jnp.float32(0.0)),
                                          (params["layers"], windows),
                                          cfg.cost_unroll)
        else:
            x, aux = self._forward_hybrid(params, x, positions, valid, remat)

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if return_hidden:
            return x, aux
        return self._logits(params, x), aux

    def _forward_hybrid(self, params, x, positions, valid, remat):
        cfg = self.cfg
        B = x.shape[0]
        def fresh_state(kind):
            if kind == RGLRU:
                return rglru_lib.init_state(B, cfg.rglru_d_rnn,
                                            cfg.activation_dtype)
            return None
        def period_body(carry, p):
            x, aux = carry
            for i, kind in enumerate(cfg.layer_pattern):
                w = cfg.local_window if kind == ATTN_LOCAL else GLOBAL_WINDOW
                x, a, _ = self._layer_seq(kind, p[f"l{i}"], x, positions, w,
                                          valid, fresh_state(kind))
                aux = aux + a
            return (x, aux), None
        body = jax.checkpoint(period_body) if remat else period_body
        (x, aux), _ = scan_layers(body, (x, jnp.float32(0.0)), params["periods"],
                                  cfg.cost_unroll)
        for i, kind in enumerate(self.tail_kinds):
            w = cfg.local_window if kind == ATTN_LOCAL else GLOBAL_WINDOW
            x, a, _ = self._layer_seq(kind, params["tail"][f"t{i}"], x,
                                      positions, w, valid, fresh_state(kind))
            aux = aux + a
        return x, aux

    # ----------------------------------------------------------------- cache
    def _attn_cache_len(self, kind: str, max_len: int) -> int:
        if kind == ATTN_LOCAL and self.cfg.local_window:
            return min(self.cfg.local_window, max_len)
        return max_len

    def _layer_cache(self, kind: str, batch: int, max_len: int, dtype):
        cfg = self.cfg
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            W = self._attn_cache_len(kind, max_len)
            return {
                "k": jnp.zeros((batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
                "slot_pos": jnp.full((batch, W), -1, jnp.int32),
            }
        if kind == RGLRU:
            return rglru_lib.init_state(batch, cfg.rglru_d_rnn, dtype)
        if kind == RWKV6:
            return rwkv_lib.init_state(batch, cfg.d_model, cfg.rwkv_head_dim, dtype)
        raise ValueError(kind)

    def init_cache(self, batch: int, max_len: int, dtype=None) -> Dict:
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.serve_param_dtype)
        cache: Dict[str, Any] = {"lengths": jnp.zeros((batch,), jnp.int32)}
        if self.homogeneous:
            # uniform cache length across layers keeps the stack scannable;
            # mixed local/global dense archs pay full length on local layers.
            kind = (ATTN_LOCAL if set(self.kinds) == {ATTN_LOCAL} else
                    (RWKV6 if self.kinds[0] == RWKV6 else ATTN_GLOBAL))
            cache["layers"] = jax.vmap(
                lambda _: self._layer_cache(kind, batch, max_len, dtype)
            )(jnp.arange(cfg.num_layers))
        else:
            def period_cache(_):
                return {f"l{i}": self._layer_cache(cfg.layer_pattern[i], batch,
                                                   max_len, dtype)
                        for i in range(self.period_len)}
            cache["periods"] = jax.vmap(period_cache)(jnp.arange(self.n_periods))
            cache["tail"] = {f"t{i}": self._layer_cache(kind, batch, max_len, dtype)
                             for i, kind in enumerate(self.tail_kinds)}
        return cache

    def _layer_cache_axes(self, kind: str):
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            return {"k": ("batch", "kv", "kv_heads", "head_dim"),
                    "v": ("batch", "kv", "kv_heads", "head_dim"),
                    "slot_pos": ("batch", "kv")}
        if kind == RGLRU:
            return {"s": ("batch", "rnn"),
                    "conv": ("batch", None, "rnn")}
        if kind == RWKV6:
            return {"tm": {"shift": ("batch", "act_embed"),
                           "wkv": ("batch", "heads", None, None)},
                    "cm": ("batch", "act_embed")}
        raise ValueError(kind)

    def cache_axes(self) -> Dict:
        """Logical sharding axes mirroring init_cache's structure."""
        cfg = self.cfg
        axes: Dict[str, Any] = {"lengths": ("batch",)}
        if self.homogeneous:
            kind = (RWKV6 if self.kinds[0] == RWKV6 else
                    (ATTN_LOCAL if set(self.kinds) == {ATTN_LOCAL}
                     else ATTN_GLOBAL))
            axes["layers"] = _stack_axes(self._layer_cache_axes(kind))
        else:
            period = {f"l{i}": self._layer_cache_axes(cfg.layer_pattern[i])
                      for i in range(self.period_len)}
            axes["periods"] = _stack_axes(period)
            axes["tail"] = {f"t{i}": self._layer_cache_axes(kind)
                            for i, kind in enumerate(self.tail_kinds)}
        return axes

    # --------------------------------------------------- cached attention ops
    def _attn_prefill(self, p, x, positions, window, valid, lc):
        """Self-attn over the prompt, writing into an (unrotated) cache."""
        cfg = self.cfg
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, (k, v) = L.attention_apply(
            p["attn"], h, positions, rope_theta=cfg.rope_theta, causal=True,
            window=window, softcap=cfg.attn_logit_softcap, k_valid=valid,
            return_kv=True)
        W = lc["k"].shape[1]
        S = x.shape[1]
        if W >= S:
            kc = lc["k"].at[:, :S].set(k.astype(lc["k"].dtype))
            vc = lc["v"].at[:, :S].set(v.astype(lc["v"].dtype))
            pos = positions
            slot_pos = lc["slot_pos"].at[:, :S].set(
                jnp.where(valid if valid is not None else jnp.ones_like(pos, bool),
                          pos, -1))
        else:
            # Ring buffer: slot s must hold the *latest valid* position
            # p ≡ s (mod W).  A gather (one winner per slot) avoids the
            # unordered-duplicate-scatter hazard:
            #   p(s) = len-1 − ((len-1−s) mod W)
            B = x.shape[0]
            lens = (valid.sum(axis=1).astype(jnp.int32) if valid is not None
                    else jnp.full((B,), S, jnp.int32))
            s_idx = jnp.arange(W)[None, :]                       # (1, W)
            last = lens[:, None] - 1 - ((lens[:, None] - 1 - s_idx) % W)
            ok = (last >= 0) & (lens[:, None] > 0)
            gidx = jnp.clip(last, 0, S - 1)
            b = jnp.arange(B)[:, None]
            kc = k[b, gidx].astype(lc["k"].dtype)
            vc = v[b, gidx].astype(lc["v"].dtype)
            slot_pos = jnp.where(ok, last, -1)
        return x + y, {"k": kc, "v": vc, "slot_pos": slot_pos}

    def _attn_decode(self, p, x, q_pos, window, lc):
        """One-token attention against the cache; x: (B, 1, D)."""
        cfg = self.cfg
        B = x.shape[0]
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
        k_new = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"])
        q = L.rope(q, q_pos[:, None], cfg.rope_theta)
        k_new = L.rope(k_new, q_pos[:, None], cfg.rope_theta)
        W = lc["k"].shape[1]
        slot = q_pos % W
        b = jnp.arange(B)
        kc = lc["k"].at[b, slot].set(k_new[:, 0].astype(lc["k"].dtype))
        vc = lc["v"].at[b, slot].set(v_new[:, 0].astype(lc["v"].dtype))
        slot_pos = lc["slot_pos"].at[b, slot].set(q_pos)
        k_valid = slot_pos >= 0
        out = L.attend(q, kc.astype(q.dtype), vc.astype(q.dtype),
                       q_pos[:, None], slot_pos, causal=True, window=window,
                       softcap=cfg.attn_logit_softcap, k_valid=k_valid)
        y = jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
        return x + y, {"k": kc, "v": vc, "slot_pos": slot_pos}

    # ---------------------------------------------------------------- prefill
    def _layer_prefill(self, kind, p, x, positions, window, valid, lc):
        cfg = self.cfg
        p = L.cast_layer_params(p, cfg.activation_dtype)
        x = constrain_act(x, "batch", "seq", "act_embed")
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            x, lc = self._attn_prefill(p, x, positions, window, valid, lc)
            x, _ = self._ffn_block(p, x)
            return x, lc
        if kind == RGLRU:
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            h, lc = rglru_lib.rglru_block_seq(p["rec"], h, lc, valid=valid)
            x = x + h
            x, _ = self._ffn_block(p, x)
            return x, lc
        if kind == RWKV6:
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            h, tm = rwkv_lib.time_mix_seq(p["tm_cm"], h, cfg.rwkv_head_dim,
                                          lc["tm"], valid=valid)
            x = x + h
            h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
            h2, cm = rwkv_lib.channel_mix_seq(p["tm_cm"], h2, lc["cm"],
                                              valid=valid)
            return x + h2, {"tm": tm, "cm": cm}
        raise ValueError(kind)

    def prefill(self, params, cache, tokens, lengths,
                prefix_embeds=None) -> Tuple[Dict, jnp.ndarray]:
        """Process prompts (right-padded to S) → (cache, last-token logits)."""
        cfg = self.cfg
        x = self._embed(params, tokens, prefix_embeds)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        valid = positions < lengths[:, None]

        if self.homogeneous:
            windows = jnp.asarray(
                [cfg.local_window if k == ATTN_LOCAL else GLOBAL_WINDOW
                 for k in self.kinds], dtype=jnp.int32)
            kind0 = RWKV6 if self.kinds[0] == RWKV6 else ATTN_GLOBAL
            def body(x, xs):
                p, w, lc = xs
                x, lc = self._layer_prefill(
                    self.kinds[0] if kind0 == RWKV6 else ATTN_GLOBAL,
                    p, x, positions, w, valid, lc)
                return x, lc
            x, new_layers = scan_layers(body, x,
                                        (params["layers"], windows,
                                         cache["layers"]), cfg.cost_unroll)
            new_cache = {"lengths": lengths, "layers": new_layers}
        else:
            def body(x, xs):
                p, lc = xs
                new_lc = {}
                for i, kind in enumerate(cfg.layer_pattern):
                    w = cfg.local_window if kind == ATTN_LOCAL else GLOBAL_WINDOW
                    x, new_lc[f"l{i}"] = self._layer_prefill(
                        kind, p[f"l{i}"], x, positions, w, valid, lc[f"l{i}"])
                return x, new_lc
            x, new_periods = scan_layers(body, x,
                                         (params["periods"],
                                          cache["periods"]), cfg.cost_unroll)
            new_tail = {}
            for i, kind in enumerate(self.tail_kinds):
                w = cfg.local_window if kind == ATTN_LOCAL else GLOBAL_WINDOW
                x, new_tail[f"t{i}"] = self._layer_prefill(
                    kind, params["tail"][f"t{i}"], x, positions, w, valid,
                    cache["tail"][f"t{i}"])
            new_cache = {"lengths": lengths, "periods": new_periods,
                         "tail": new_tail}

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return new_cache, _gather_last(self._logits(params, x), lengths)

    # ------------------------------------------------------------ decode step
    def _layer_decode(self, kind, p, x, q_pos, window, lc):
        cfg = self.cfg
        p = L.cast_layer_params(p, cfg.activation_dtype)
        x = constrain_act(x, "batch", "seq", "act_embed")
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            x, lc = self._attn_decode(p, x, q_pos, window, lc)
            x, _ = self._ffn_block(p, x)
            return x, lc
        if kind == RGLRU:
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            h1, lc = rglru_lib.rglru_block_step(p["rec"], h[:, 0], lc)
            x = x + h1[:, None]
            x, _ = self._ffn_block(p, x)
            return x, lc
        if kind == RWKV6:
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            h1, tm = rwkv_lib.time_mix_step(p["tm_cm"], h[:, 0],
                                            cfg.rwkv_head_dim, lc["tm"])
            x = x + h1[:, None]
            h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
            h2s, cm = rwkv_lib.channel_mix_step(p["tm_cm"], h2[:, 0], lc["cm"])
            return x + h2s[:, None], {"tm": tm, "cm": cm}
        raise ValueError(kind)

    def decode_step(self, params, cache, tokens) -> Tuple[Dict, jnp.ndarray]:
        """tokens: (B,) next input token per sequence → (cache, logits (B,V))."""
        cfg = self.cfg
        x = self._embed(params, tokens[:, None])
        q_pos = cache["lengths"]

        if self.homogeneous:
            windows = jnp.asarray(
                [cfg.local_window if k == ATTN_LOCAL else GLOBAL_WINDOW
                 for k in self.kinds], dtype=jnp.int32)
            kind0 = self.kinds[0] if self.kinds[0] == RWKV6 else ATTN_GLOBAL
            def body(x, xs):
                p, w, lc = xs
                x, lc = self._layer_decode(kind0, p, x, q_pos, w, lc)
                return x, lc
            x, new_layers = scan_layers(body, x,
                                        (params["layers"], windows,
                                         cache["layers"]), cfg.cost_unroll)
            new_cache = {"lengths": q_pos + 1, "layers": new_layers}
        else:
            def body(x, xs):
                p, lc = xs
                new_lc = {}
                for i, kind in enumerate(cfg.layer_pattern):
                    w = cfg.local_window if kind == ATTN_LOCAL else GLOBAL_WINDOW
                    x, new_lc[f"l{i}"] = self._layer_decode(
                        kind, p[f"l{i}"], x, q_pos, w, lc[f"l{i}"])
                return x, new_lc
            x, new_periods = scan_layers(body, x,
                                         (params["periods"],
                                          cache["periods"]), cfg.cost_unroll)
            new_tail = {}
            for i, kind in enumerate(self.tail_kinds):
                w = cfg.local_window if kind == ATTN_LOCAL else GLOBAL_WINDOW
                x, new_tail[f"t{i}"] = self._layer_decode(
                    kind, params["tail"][f"t{i}"], x, q_pos, w,
                    cache["tail"][f"t{i}"])
            new_cache = {"lengths": q_pos + 1, "periods": new_periods,
                         "tail": new_tail}

        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return new_cache, self._logits(params, x[:, 0])
