"""Model configuration for every architecture family the framework serves.

One dataclass covers the whole assigned pool: dense GQA decoders, MoE,
hybrid (RG-LRU + local attention), attention-free SSM (RWKV6), encoder-
decoder (whisper) and VLM backbones.  A config is pure data — the model
builders in ``repro.models.registry`` interpret it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

# Block kinds a layer can be (hybrids mix them).
ATTN_GLOBAL = "attn_global"
ATTN_LOCAL = "attn_local"
RGLRU = "rglru"
RWKV6 = "rwkv6"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 → d_model // num_heads
    max_seq_len: int = 8192           # context limit (prompt + generation);
                                      # bounds serving KV-cache accounting

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0              # 0 → dense FFN
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # --- attention variants --------------------------------------------------
    local_window: int = 0             # >0 → sliding-window size for local layers
    layer_pattern: Tuple[str, ...] = ()   # repeating block pattern; () → all global
    attn_logit_softcap: float = 0.0   # gemma2-style soft capping (0 = off)
    final_logit_softcap: float = 0.0
    rope_theta: float = 10_000.0

    # --- recurrent families ---------------------------------------------------
    rglru_d_rnn: int = 0              # RG-LRU recurrence width (0 → d_model)
    rwkv_head_dim: int = 64

    # --- encoder-decoder ------------------------------------------------------
    encoder_layers: int = 0           # >0 → enc-dec; num_layers = decoder layers

    # --- multimodal stub frontends -------------------------------------------
    frontend: str = "none"            # none | audio_frames | vision_patches
    num_frontend_tokens: int = 0      # patch/frame tokens supplied by input_specs

    # --- cost-accounting mode -------------------------------------------------
    # XLA's cost_analysis counts a while-loop body ONCE, so the dry-run
    # measures FLOPs/bytes on depth-reduced model variants with all scans
    # unrolled and extrapolates (see launch/dryrun.py).  This flag switches
    # every internal lax.scan to a Python loop; never set it for execution.
    cost_unroll: bool = False

    # --- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"      # master parameter dtype (training)
    serve_param_dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.family == "hybrid" and self.rglru_d_rnn == 0:
            object.__setattr__(self, "rglru_d_rnn", self.d_model)
        if not self.layer_pattern:
            object.__setattr__(self, "layer_pattern", (ATTN_GLOBAL,))

    # ---- derived properties --------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def block_kind(self, layer_idx: int) -> str:
        return self.layer_pattern[layer_idx % len(self.layer_pattern)]

    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.block_kind(i) for i in range(self.num_layers))

    @property
    def sub_quadratic(self) -> bool:
        """True iff no layer does *global* full attention (long_500k eligible)."""
        return ATTN_GLOBAL not in set(self.layer_kinds())

    @property
    def q_per_kv(self) -> int:
        return max(self.num_heads // max(self.num_kv_heads, 1), 1)

    # ---- analytic parameter / FLOP accounting (for MODEL_FLOPS = 6·N·D) ----
    def param_count(self, active_only: bool = False) -> int:
        """Total (or routed-active) parameter count, analytic."""
        d, h, k, hd, ff = (self.d_model, self.num_heads, self.num_kv_heads,
                           self.head_dim, self.d_ff)
        embed = self.vocab_size * d
        n = embed  # tied output head assumed untied → add once more below
        n += embed  # lm head
        kinds = self.layer_kinds()
        for kind in kinds:
            if kind in (ATTN_GLOBAL, ATTN_LOCAL):
                n += d * h * hd + 2 * d * k * hd + h * hd * d   # q, k+v, o
            elif kind == RGLRU:
                r = self.rglru_d_rnn
                n += 2 * d * r + r * d + 3 * r                  # in x/y, out, gates
            elif kind == RWKV6:
                n += 4 * d * d + 6 * d                          # r,k,v,o + mix/decay
            # FFN (every layer has one in all assigned archs)
            if self.is_moe:
                e = self.experts_per_token if active_only else self.num_experts
                n += e * 3 * d * ff + d * self.num_experts      # experts + router
            else:
                n += 3 * d * ff                                 # gated MLP
        if self.is_encdec:
            # encoder self-attn + mlp per encoder layer, decoder cross-attn
            enc = self.encoder_layers * (4 * d * h * hd + 3 * d * ff)
            cross = self.num_layers * (d * h * hd + 2 * d * k * hd + h * hd * d)
            n += enc + cross
        return int(n)

    def model_flops_per_token(self, active_only: bool = True) -> float:
        """6·N (dense) or 6·N_active (MoE) per trained token."""
        return 6.0 * self.param_count(active_only=active_only)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    base = dict(
        num_layers=min(cfg.num_layers, 2 * len(cfg.layer_pattern)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        num_experts=min(cfg.num_experts, 4) if cfg.is_moe else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.is_moe else 0,
        local_window=min(cfg.local_window, 16) if cfg.local_window else 0,
        rglru_d_rnn=64 if cfg.family == "hybrid" else 0,
        rwkv_head_dim=16,
        encoder_layers=min(cfg.encoder_layers, 2) if cfg.is_encdec else 0,
        num_frontend_tokens=min(cfg.num_frontend_tokens, 8),
        dtype="float32",
        param_dtype="float32",
        serve_param_dtype="float32",
    )
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
