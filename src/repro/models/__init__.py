from repro.models.config import ModelConfig, reduced
from repro.models.registry import build_model, count_params, model_flops_per_token

__all__ = ["ModelConfig", "reduced", "build_model", "count_params",
           "model_flops_per_token"]
