"""Model construction + parameter accounting."""
from __future__ import annotations

from typing import Any, Dict, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM

ModelT = Union[DecoderLM, EncDecLM]


def build_model(cfg: ModelConfig) -> ModelT:
    if cfg.is_encdec:
        return EncDecLM(cfg)
    return DecoderLM(cfg)


def param_shapes(model: ModelT) -> Any:
    """abstract param pytree (no allocation)."""
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def count_params(tree, exclude_embed: bool = False) -> int:
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if exclude_embed and any(
                getattr(k, "key", None) == "embed" for k in path):
            continue
        total += int(np.prod(leaf.shape))
    return total


def model_flops_per_token(cfg: ModelConfig) -> float:
    """MODEL_FLOPS/token = 6·N (dense) or 6·N_active (MoE), N excl. embeddings.

    Computed from the *real* parameter pytree so it tracks the implementation
    exactly; for MoE, the expert weights are scaled by k/E to get N_active.
    """
    model = build_model(cfg)
    shapes = param_shapes(model)
    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        keys = [getattr(k, "key", None) for k in path]
        if "embed" in keys:
            continue
        n = float(np.prod(leaf.shape))
        if cfg.is_moe and any(k in ("wi", "wg", "wo") for k in keys) \
                and "ffn" in keys:
            n *= cfg.experts_per_token / cfg.num_experts
        total += n
    return 6.0 * total
