"""Whisper-style encoder–decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, S_enc, d_model) straight into the encoder.
Encoder layers are bidirectional self-attn + MLP; decoder layers are causal
self-attn + cross-attn + MLP.  Both stacks scan over stacked layer params.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain_act
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.transformer import (_gather_last, _stack_axes,
                                      _stack_init, scan_layers)


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.encoder_layers > 0
        self.cfg = cfg
        self.pdt = jnp.dtype(cfg.param_dtype)

    # ----------------------------------------------------------------- init
    def _enc_layer_init(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": L.rmsnorm_init(cfg.d_model, self.pdt),
            "attn": L.attention_init(k1, cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.head_dim, self.pdt),
            "ln2": L.rmsnorm_init(cfg.d_model, self.pdt),
            "ffn": L.mlp_init(k2, cfg.d_model, cfg.d_ff, self.pdt),
        }

    def _dec_layer_init(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": L.rmsnorm_init(cfg.d_model, self.pdt),
            "attn": L.attention_init(k1, cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.head_dim, self.pdt),
            "lnx": L.rmsnorm_init(cfg.d_model, self.pdt),
            "xattn": L.attention_init(k2, cfg.d_model, cfg.num_heads,
                                      cfg.num_kv_heads, cfg.head_dim, self.pdt),
            "ln2": L.rmsnorm_init(cfg.d_model, self.pdt),
            "ffn": L.mlp_init(k3, cfg.d_model, cfg.d_ff, self.pdt),
        }

    def init(self, key) -> Dict:
        cfg = self.cfg
        ke, k1, k2 = jax.random.split(key, 3)
        return {
            "embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model, self.pdt),
            "enc_layers": _stack_init(k1, cfg.encoder_layers,
                                      self._enc_layer_init),
            "enc_norm": L.rmsnorm_init(cfg.d_model, self.pdt),
            "dec_layers": _stack_init(k2, cfg.num_layers, self._dec_layer_init),
            "final_norm": L.rmsnorm_init(cfg.d_model, self.pdt),
        }

    def logical_axes(self) -> Dict:
        enc = {"ln1": L.rmsnorm_axes(), "attn": L.attention_axes(),
               "ln2": L.rmsnorm_axes(), "ffn": L.mlp_axes()}
        dec = {"ln1": L.rmsnorm_axes(), "attn": L.attention_axes(),
               "lnx": L.rmsnorm_axes(), "xattn": L.attention_axes(),
               "ln2": L.rmsnorm_axes(), "ffn": L.mlp_axes()}
        return {
            "embed": ("vocab", "embed"),
            "enc_layers": _stack_axes(enc),
            "enc_norm": L.rmsnorm_axes(),
            "dec_layers": _stack_axes(dec),
            "final_norm": L.rmsnorm_axes(),
        }

    # ---------------------------------------------------------------- encode
    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        """frames: (B, S_enc, d_model) stub-frontend embeddings."""
        cfg = self.cfg
        x = frames.astype(cfg.activation_dtype)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def body(x, p):
            p = L.cast_layer_params(p, cfg.activation_dtype)
            x = constrain_act(x, "batch", "seq", "act_embed")
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            h = L.attention_apply(p["attn"], h, positions,
                                  rope_theta=cfg.rope_theta, causal=False)
            x = x + h
            h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
            return x + L.mlp_apply(p["ffn"], h), None

        x, _ = scan_layers(body, x, params["enc_layers"], cfg.cost_unroll)
        return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    def _embed(self, params, tokens):
        cfg = self.cfg
        x = params["embed"][tokens].astype(cfg.activation_dtype)
        return x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)

    def _logits(self, params, x):
        return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                          params["embed"].astype(jnp.float32))

    # --------------------------------------------------------------- forward
    def forward(self, params, tokens, *, frames, lengths=None,
                remat: bool = False,
                return_hidden: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Teacher-forced pass: (frames, tokens) → (logits, aux=0)."""
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        x = self._embed(params, tokens)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        valid = (positions < lengths[:, None]) if lengths is not None else None

        def body(x, p):
            p = L.cast_layer_params(p, cfg.activation_dtype)
            x = constrain_act(x, "batch", "seq", "act_embed")
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            h = L.attention_apply(p["attn"], h, positions,
                                  rope_theta=cfg.rope_theta, causal=True,
                                  k_valid=valid)
            x = x + h
            h = L.rmsnorm(p["lnx"], x, cfg.norm_eps)
            kv = L.cross_attention_kv(p["xattn"], enc_out)
            x = x + L.cross_attention_apply(p["xattn"], h, kv)
            h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
            return x + L.mlp_apply(p["ffn"], h), None

        body = jax.checkpoint(body) if remat else body
        x, _ = scan_layers(body, x, params["dec_layers"], cfg.cost_unroll)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if return_hidden:
            return x, jnp.float32(0.0)
        return self._logits(params, x), jnp.float32(0.0)

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int, dtype=None,
                   enc_len: int = 0) -> Dict:
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.serve_param_dtype)
        enc_len = enc_len or max_len
        def self_cache(_):
            return {
                "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                               dtype),
                "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                               dtype),
                "slot_pos": jnp.full((batch, max_len), -1, jnp.int32),
            }
        Lc = cfg.num_layers
        return {
            "lengths": jnp.zeros((batch,), jnp.int32),
            "self": jax.vmap(self_cache)(jnp.arange(Lc)),
            "cross_k": jnp.zeros((Lc, batch, enc_len, cfg.num_kv_heads,
                                  cfg.head_dim), dtype),
            "cross_v": jnp.zeros((Lc, batch, enc_len, cfg.num_kv_heads,
                                  cfg.head_dim), dtype),
        }

    def cache_axes(self) -> Dict:
        kv = ("batch", "kv", "kv_heads", "head_dim")
        return {
            "lengths": ("batch",),
            "self": _stack_axes({"k": kv, "v": kv,
                                 "slot_pos": ("batch", "kv")}),
            "cross_k": ("layers",) + kv,
            "cross_v": ("layers",) + kv,
        }

    # ---------------------------------------------------------------- prefill
    def prefill(self, params, cache, tokens, lengths, *,
                frames) -> Tuple[Dict, jnp.ndarray]:
        """Encode frames, precompute cross K/V, run decoder over the prompt."""
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        x = self._embed(params, tokens)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        valid = positions < lengths[:, None]

        def body(x, xs):
            p, lc = xs
            p = L.cast_layer_params(p, cfg.activation_dtype)
            x = constrain_act(x, "batch", "seq", "act_embed")
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            y, (k, v) = L.attention_apply(
                p["attn"], h, positions, rope_theta=cfg.rope_theta, causal=True,
                k_valid=valid, return_kv=True)
            x = x + y
            W = lc["k"].shape[1]
            kc = lc["k"].at[:, :S].set(k.astype(lc["k"].dtype))
            vc = lc["v"].at[:, :S].set(v.astype(lc["v"].dtype))
            slot_pos = lc["slot_pos"].at[:, :S].set(
                jnp.where(valid, positions, -1))
            ck, cv = L.cross_attention_kv(p["xattn"], enc_out)
            h = L.rmsnorm(p["lnx"], x, cfg.norm_eps)
            x = x + L.cross_attention_apply(p["xattn"], h, (ck, cv))
            h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
            x = x + L.mlp_apply(p["ffn"], h)
            return x, ({"k": kc, "v": vc, "slot_pos": slot_pos},
                       ck.astype(lc["k"].dtype), cv.astype(lc["v"].dtype))

        x, (new_self, ck, cv) = scan_layers(body, x,
                                            (params["dec_layers"],
                                             cache["self"]), cfg.cost_unroll)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        new_cache = {"lengths": lengths, "self": new_self,
                     "cross_k": ck, "cross_v": cv}
        return new_cache, _gather_last(self._logits(params, x), lengths)

    # ------------------------------------------------------------ decode step
    def decode_step(self, params, cache, tokens) -> Tuple[Dict, jnp.ndarray]:
        cfg = self.cfg
        x = self._embed(params, tokens[:, None])
        q_pos = cache["lengths"]
        B = x.shape[0]

        def body(x, xs):
            p, lc, ck, cv = xs
            p = L.cast_layer_params(p, cfg.activation_dtype)
            x = constrain_act(x, "batch", "seq", "act_embed")
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
            k_new = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"])
            v_new = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"])
            q = L.rope(q, q_pos[:, None], cfg.rope_theta)
            k_new = L.rope(k_new, q_pos[:, None], cfg.rope_theta)
            b = jnp.arange(B)
            kc = lc["k"].at[b, q_pos].set(k_new[:, 0].astype(lc["k"].dtype))
            vc = lc["v"].at[b, q_pos].set(v_new[:, 0].astype(lc["v"].dtype))
            slot_pos = lc["slot_pos"].at[b, q_pos].set(q_pos)
            out = L.attend(q, kc.astype(q.dtype), vc.astype(q.dtype),
                           q_pos[:, None], slot_pos, causal=True,
                           k_valid=slot_pos >= 0)
            x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
            h = L.rmsnorm(p["lnx"], x, cfg.norm_eps)
            x = x + L.cross_attention_apply(
                p["xattn"], h, (ck.astype(x.dtype), cv.astype(x.dtype)))
            h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
            x = x + L.mlp_apply(p["ffn"], h)
            return x, {"k": kc, "v": vc, "slot_pos": slot_pos}

        x, new_self = scan_layers(body, x,
                                  (params["dec_layers"], cache["self"],
                                   cache["cross_k"], cache["cross_v"]),
                                  cfg.cost_unroll)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        new_cache = dict(cache, lengths=q_pos + 1, self=new_self)
        return new_cache, self._logits(params, x[:, 0])
