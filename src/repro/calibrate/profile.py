"""Calibration profiles — named, persisted latency-model fits.

A profile is the JSON artifact that closes the measure→model→plan loop:
the microbenchmark harness measures a (batch × seq) grid, the fitter
turns the records into the parametric coefficients below, and the
capacity planner reloads them (by path or ``model@hardware`` key) to
drive the cluster simulator without re-running any benchmark.

Profiles live under ``configs/profiles/`` as
``<model>__<hardware>.json``; the schema is documented in
``configs/profiles/README.md`` and versioned via the ``schema`` field.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Dict, Optional, Sequence, Tuple, Union

PROFILE_SCHEMA = "repro.calibration-profile.v1"
DEFAULT_PROFILE_DIR = "configs/profiles"

PREFILL_TERMS = ("base_s", "per_token_s", "per_token_per_prompt_s")
DECODE_TERMS = ("base_s", "alpha_s", "beta_s")


@dataclasses.dataclass(frozen=True)
class PhaseFit:
    """One phase's fitted coefficients + residual diagnostics.

    ``coef`` is ordered like the phase's design matrix —
    prefill: ``(base, per-token, per-token·prompt)``;
    decode: ``(base, α per-sequence, β per-cached-token)``.
    """
    coef: Tuple[float, float, float]
    n_points: int = 0
    mean_rel_err: float = 0.0
    max_rel_err: float = 0.0
    r2: float = 1.0
    derived_from: Optional[str] = None   # e.g. decode reused a prefill fit

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PhaseFit":
        d = dict(d)
        d["coef"] = tuple(float(c) for c in d["coef"])
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class CalibrationProfile:
    """A (model, hardware) latency fit, persistable as JSON."""
    model: str
    hardware: str
    chips: int
    source: str                       # measured-cpu | oracle
    prefill: PhaseFit
    decode: PhaseFit
    cold_start_s: float = 2.0
    holdout: Optional[Dict[str, float]] = None   # held-out validation errs
    grid: Optional[Dict[str, Sequence[int]]] = None
    # per-kernel microbench fits keyed "<kernel>/<dtype>" — PhaseFit dict
    # plus provenance (backend, phase, n_points, max_err vs reference);
    # produced by repro.calibrate.kernel_bench, absent on plain profiles
    kernels: Optional[Dict[str, Dict[str, Any]]] = None
    # calibrated SpeedMode parameter dicts keyed by mode name ("int8",
    # "speculative", ...) — resolve_speed_mode() consults these before
    # the built-in presets when the planner expands its speed_modes axis
    speed_modes: Optional[Dict[str, Dict[str, Any]]] = None
    created_ts: Optional[float] = None
    schema: str = PROFILE_SCHEMA

    @property
    def key(self) -> str:
        return f"{self.model}@{self.hardware}"

    # ---- serialization ----------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["prefill"] = self.prefill.to_dict()
        d["decode"] = self.decode.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CalibrationProfile":
        d = dict(d)
        schema = d.get("schema", PROFILE_SCHEMA)
        if schema != PROFILE_SCHEMA:
            raise ValueError(f"unsupported profile schema {schema!r} "
                             f"(this build reads {PROFILE_SCHEMA!r})")
        d["prefill"] = PhaseFit.from_dict(d["prefill"])
        d["decode"] = PhaseFit.from_dict(d["decode"])
        return cls(**d)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "CalibrationProfile":
        return cls.from_dict(json.loads(text))

    def save(self, profile_dir: Union[str, Path] = DEFAULT_PROFILE_DIR
             ) -> Path:
        path = profile_path(profile_dir, self.model, self.hardware)
        path.parent.mkdir(parents=True, exist_ok=True)
        prof = self if self.created_ts is not None else \
            dataclasses.replace(self, created_ts=time.time())
        path.write_text(prof.to_json() + "\n")
        return path

    # ---- use --------------------------------------------------------------
    def to_latency_model(self):
        """The simulator-facing oracle for this profile."""
        from repro.serving.latency_model import FittedLatencyModel
        return FittedLatencyModel.from_profile(self)

    def predict(self, phase: str, batch: int, tokens: int) -> float:
        """Closed-form prediction for one grid point (diagnostics/tests)."""
        lm = self.to_latency_model()
        if phase == "prefill":
            return lm.prefill_latency(batch, tokens)
        if phase == "decode":
            return lm.decode_latency(batch, tokens)
        raise ValueError(f"unknown phase {phase!r}")


def profile_path(profile_dir: Union[str, Path], model: str,
                 hardware: str) -> Path:
    return Path(profile_dir) / f"{model}__{hardware}.json"


def load_profile(ref: Union[str, Path],
                 profile_dir: Union[str, Path] = DEFAULT_PROFILE_DIR
                 ) -> CalibrationProfile:
    """Load a profile by JSON path or ``model@hardware`` key.

    A key is resolved to ``<profile_dir>/<model>__<hardware>.json``.
    """
    path = Path(ref)
    if not path.exists() and "@" in str(ref):
        model, _, hardware = str(ref).partition("@")
        path = profile_path(profile_dir, model, hardware)
    if not path.exists():
        have = sorted(p.name for p in Path(profile_dir).glob("*.json")) \
            if Path(profile_dir).is_dir() else []
        raise FileNotFoundError(
            f"no calibration profile at {ref!r} (profile_dir={profile_dir}; "
            f"available: {have or 'none'})")
    return CalibrationProfile.from_json(path.read_text())
