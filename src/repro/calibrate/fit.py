"""Least-squares calibration fitter (measure → model).

Turns ``kind="calibration"`` PerfDB records — one measured or oracle
latency per (phase, batch, tokens) grid point — into the parametric
:class:`~repro.serving.latency_model.FittedLatencyModel` coefficients:

    prefill(b, s) = p0 + p1·(b·s) + p2·(b·s²)      (FLOPs + attention)
    decode(b, c)  = d0 + α·b + β·(b·c)             (step + KV read)

Both forms are linear in their parameters, so the fit is an ordinary
least-squares solve with a non-negativity projection (a negative latency
slope is always a fitting artifact, never physics).  Degenerate design
columns — e.g. an fc-family grid where the prompt never varies, or a
CPU decode sweep with no KV context — are detected and dropped, their
coefficients pinned to zero, instead of poisoning the solve.

Residual diagnostics (mean/max relative error, R²) ride along in the
profile; an optional deterministic holdout split reports how well the
fit predicts grid points it never saw.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.calibrate.profile import CalibrationProfile, PhaseFit

PREFILL, DECODE = "prefill", "decode"
_EPS = 1e-12


# ---- record plumbing -------------------------------------------------------
def _point(rec: Dict[str, Any]) -> Tuple[str, float, float, float]:
    """(phase, batch, tokens, latency_s) from a calibration record."""
    res = rec.get("result", rec)
    lat = res.get("latency_s")
    if lat is None:
        raise ValueError(f"calibration record without result.latency_s: "
                         f"{sorted(rec)}")
    return (str(rec.get("phase", PREFILL)), float(rec.get("batch", 1)),
            float(rec.get("tokens", 0)), float(lat))


def split_points(records: Iterable[Dict[str, Any]]
                 ) -> Dict[str, List[Tuple[float, float, float]]]:
    """Group records into per-phase (batch, tokens, latency) points."""
    phases: Dict[str, List[Tuple[float, float, float]]] = {PREFILL: [],
                                                           DECODE: []}
    for rec in records:
        phase, batch, tokens, lat = _point(rec)
        phases.setdefault(phase, []).append((batch, tokens, lat))
    return phases


def _design(phase: str, batch: np.ndarray, tokens: np.ndarray) -> np.ndarray:
    if phase == PREFILL:
        toks = batch * tokens
        return np.stack([np.ones_like(toks), toks, toks * tokens], axis=1)
    if phase == DECODE:
        return np.stack([np.ones_like(batch), batch, batch * tokens], axis=1)
    raise ValueError(f"unknown phase {phase!r}")


# ---- the solve -------------------------------------------------------------
def _lstsq_nonneg(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    """OLS with degenerate-column dropping and a non-negativity projection.

    Columns with no variation (beyond the intercept) or that duplicate an
    earlier kept column are excluded up front; any column whose fitted
    coefficient comes back negative is zeroed and the rest refit (a crude
    active-set NNLS — exact for these tiny, well-conditioned systems).
    """
    n, k = X.shape
    keep: List[int] = [0]                       # intercept always in
    for j in range(1, k):
        col = X[:, j]
        if np.ptp(col) <= _EPS * max(1.0, float(np.abs(col).max(initial=0))):
            continue                            # constant → intercept's job
        if any(np.allclose(col, X[:, i]) for i in keep[1:]):
            continue                            # duplicate column
        keep.append(j)
    active = list(keep)
    coef = np.zeros(k)
    for _ in range(k + 1):
        sol, *_ = np.linalg.lstsq(X[:, active], y, rcond=None)
        neg = [active[i] for i, c in enumerate(sol)
               if c < -_EPS and active[i] != 0]
        if not neg:
            coef[:] = 0.0
            for i, j in enumerate(active):
                coef[j] = max(float(sol[i]), 0.0)
            return coef
        active = [j for j in active if j not in neg]
    coef[:] = 0.0
    coef[0] = max(float(np.mean(y)), 0.0)       # pathological fallback
    return coef


def _diagnostics(X: np.ndarray, y: np.ndarray,
                 coef: np.ndarray) -> Tuple[float, float, float]:
    pred = X @ coef
    rel = np.abs(pred - y) / np.maximum(np.abs(y), _EPS)
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - np.mean(y)) ** 2))
    r2 = 1.0 - ss_res / ss_tot if ss_tot > _EPS else 1.0
    return float(np.mean(rel)), float(np.max(rel)), r2


def fit_phase(points: Sequence[Tuple[float, float, float]],
              phase: str) -> PhaseFit:
    """Least-squares fit of one phase's (batch, tokens, latency) points."""
    if not points:
        raise ValueError(f"no {phase} points to fit")
    arr = np.asarray(points, dtype=float)
    batch, tokens, y = arr[:, 0], arr[:, 1], arr[:, 2]
    X = _design(phase, batch, tokens)
    coef = _lstsq_nonneg(X, y)
    mean_rel, max_rel, r2 = _diagnostics(X, y, coef)
    return PhaseFit(coef=(float(coef[0]), float(coef[1]), float(coef[2])),
                    n_points=len(points), mean_rel_err=mean_rel,
                    max_rel_err=max_rel, r2=r2)


def _phase_predict(fit: PhaseFit, phase: str, batch: float,
                   tokens: float) -> float:
    X = _design(phase, np.asarray([batch], float), np.asarray([tokens], float))
    return float(X[0] @ np.asarray(fit.coef))


def _holdout_split(points: Sequence[Tuple[float, float, float]],
                   fraction: float) -> Tuple[list, list]:
    """Deterministic split: every k-th point (in grid order) held out."""
    if fraction <= 0.0 or len(points) < 4:
        return list(points), []
    k = max(int(round(1.0 / fraction)), 2)
    pts = sorted(points)
    train = [p for i, p in enumerate(pts) if i % k != k - 1]
    held = [p for i, p in enumerate(pts) if i % k == k - 1]
    return (train, held) if train else (list(pts), [])


def _holdout_errs(fit: PhaseFit, phase: str, held: Sequence) -> List[float]:
    return [abs(_phase_predict(fit, phase, b, t) - y) / max(abs(y), _EPS)
            for b, t, y in held]


# ---- public entry ----------------------------------------------------------
def fit_records(records: Iterable[Dict[str, Any]], *, model: str,
                hardware: str, chips: int = 1, source: str = "measured-cpu",
                holdout_fraction: float = 0.0,
                cold_start_s: float = 2.0,
                grid: Optional[Dict[str, Sequence[int]]] = None
                ) -> CalibrationProfile:
    """Fit a :class:`CalibrationProfile` from calibration records.

    With ``holdout_fraction > 0`` each phase is first fit on a
    deterministic train split and scored on the held-out grid points
    (``profile.holdout``); the shipped coefficients are then refit on
    *all* points — the holdout numbers measure generalization, the final
    fit uses every measurement.

    A grid with no usable decode points (e.g. fc/cnn generated families,
    which have no autoregressive phase) derives the decode fit from the
    prefill coefficients at prompt length 1, so the profile always
    drives the full simulator interface.
    """
    phases = split_points(records)
    if not phases[PREFILL] and not phases[DECODE]:
        raise ValueError("no calibration records to fit")
    if not phases[PREFILL]:
        # decode-only sweep: a decode step *is* a 1-token prefill
        phases[PREFILL] = [(b, 1.0, y) for b, _, y in phases[DECODE]]

    holdout: Dict[str, float] = {}
    fits: Dict[str, PhaseFit] = {}
    for phase in (PREFILL, DECODE):
        pts = phases[phase]
        if not pts:
            continue
        train, held = _holdout_split(pts, holdout_fraction)
        if held:
            probe = fit_phase(train, phase)
            errs = _holdout_errs(probe, phase, held)
            holdout[f"{phase}_mean_rel_err"] = float(np.mean(errs))
            holdout[f"{phase}_max_rel_err"] = float(np.max(errs))
            holdout[f"{phase}_points"] = len(held)
        fits[phase] = fit_phase(pts, phase)

    if DECODE not in fits:
        p0, p1, p2 = fits[PREFILL].coef
        fits[DECODE] = PhaseFit(coef=(p0, p1 + p2, 0.0),
                                n_points=0, derived_from=PREFILL)
    if holdout:
        errs = [v for k, v in holdout.items() if k.endswith("mean_rel_err")]
        holdout["mean_rel_err"] = float(np.mean(errs))

    return CalibrationProfile(
        model=model, hardware=hardware, chips=chips, source=source,
        prefill=fits[PREFILL], decode=fits[DECODE],
        cold_start_s=float(cold_start_s),
        holdout=holdout or None, grid=dict(grid) if grid else None)
