"""Pallas-kernel calibration backend (measure → model, hardware edition).

The prefill/decode microbenches clock whole generated models; this
backend clocks the repo's own Pallas kernels (``repro.kernels``) over a
(batch × seq × dtype) grid and feeds the results into the same
PerfDB → fit → profile pipeline, so the planner's latency model is
anchored to the hardware-shaped code the serving engine actually runs.

Per grid point one ``kind="calibration"`` record is emitted carrying
``backend="pallas-kernel"`` provenance plus the kernel name and dtype.
Timing target:

  * **CPU (this container)** — the pure-jnp references are wall-clocked
    (they are the numerics the interpret-mode kernels validate against;
    interpret-mode Pallas itself runs a Python grid loop whose overhead
    would swamp any scaling signal).  Each (kernel, dtype) is still
    executed once through the real ``repro.kernels.ops`` entry point at
    the smallest grid shape and checked ``allclose`` against its
    reference, so every record is backed by a verified kernel.
  * **TPU** — the compiled Mosaic kernels are clocked directly
    (``target="kernel"`` is forced automatically off-CPU).

Per-kernel coefficients are fit with the existing least-squares designs
(:func:`repro.calibrate.fit.fit_phase`): sequence kernels (flash
attention, wkv6, rglru, int8 matmul) use the prefill design
``t = c0 + c1·(b·s) + c2·(b·s²)``; decode attention uses the decode
design ``t = c0 + α·b + β·(b·T)``.  The fits land in
``CalibrationProfile.kernels`` and the derived serving
:class:`~repro.serving.latency_model.SpeedMode` parameter dicts in
``CalibrationProfile.speed_modes``, which the capacity planner's
``speed_modes`` grid axis resolves before the built-in presets.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

from repro.calibrate.fit import fit_phase
from repro.calibrate.profile import CalibrationProfile

BACKEND = "pallas-kernel"

#: allclose tolerance per dtype for the kernel-vs-reference check
#: (matches tests/test_kernels.py)
VERIFY_TOL = {"float32": 2e-5, "bfloat16": 2e-2, "int8": 2e-5}

DEFAULT_BATCHES = (1, 2, 4)
DEFAULT_SEQS = (64, 128, 256)
DEFAULT_DTYPES = ("float32", "bfloat16")


@dataclasses.dataclass(frozen=True)
class KernelCase:
    """One benchable kernel: how to build inputs and which fit design
    its latencies follow.

    Attributes:
        name: registry key ("flash_attention", ...).
        phase: fit design — "prefill" (cost grows with b·s and b·s²)
            or "decode" (cost grows with b and b·context).
        dtypes: dtypes this kernel sweeps (int8 matmul is int8-only).
        make: ``make(batch, seq, dtype, seed)`` → (args, static_kwargs)
            for both the kernel and its reference.
        kernel_fn: the jitted ``repro.kernels.ops`` entry point.
        ref_fn: the pure-jnp reference it must match.
    """
    name: str
    phase: str
    dtypes: Sequence[str]
    make: Callable[[int, int, str, int], tuple]
    kernel_fn: Callable
    ref_fn: Callable


def _rand(key, shape, dtype, scale=1.0):
    import jax
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def _make_flash(batch: int, seq: int, dtype: str, seed: int):
    import jax
    heads, kv_heads, d = 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(ks[0], (batch, heads, seq, d), dtype)
    k = _rand(ks[1], (batch, kv_heads, seq, d), dtype)
    v = _rand(ks[2], (batch, kv_heads, seq, d), dtype)
    block = min(128, seq)
    return (q, k, v), {"causal": True, "block_q": block, "block_k": block}


def _make_decode(batch: int, context: int, dtype: str, seed: int):
    import jax
    import jax.numpy as jnp
    heads, kv_heads, d = 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(ks[0], (batch, heads, d), dtype)
    k = _rand(ks[1], (batch, kv_heads, context, d), dtype)
    v = _rand(ks[2], (batch, kv_heads, context, d), dtype)
    lengths = jnp.full((batch,), context, dtype=jnp.int32)
    return (q, k, v, lengths), {"block_k": min(512, context)}


def _make_wkv6(batch: int, seq: int, dtype: str, seed: int):
    import jax
    heads, n = 2, 32
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = _rand(ks[0], (batch, seq, heads, n), dtype)
    k = _rand(ks[1], (batch, seq, heads, n), dtype)
    v = _rand(ks[2], (batch, seq, heads, n), dtype)
    logw = -jax.nn.softplus(_rand(ks[3], (batch, seq, heads, n),
                                  "float32")).astype(dtype)
    u = _rand(ks[4], (heads, n), dtype)
    s0 = _rand(ks[5], (batch, heads, n, n), "float32")
    return (r, k, v, logw, u, s0), {"chunk": min(32, seq)}


def _make_rglru(batch: int, seq: int, dtype: str, seed: int):
    import jax
    width = 256
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = (0.2 + 0.7 * jax.random.uniform(ks[0], (batch, seq, width))
         ).astype(dtype)
    b = _rand(ks[1], (batch, seq, width), dtype)
    s0 = _rand(ks[2], (batch, width), "float32")
    return (a, b, s0), {"chunk": min(128, seq), "block_r": width}


def _make_int8_matmul(batch: int, seq: int, dtype: str, seed: int):
    import jax
    from repro.kernels import ref
    d_in, d_out = 512, 512
    m = batch * seq
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (m, d_in), dtype="float32")
    w = jax.random.normal(ks[1], (d_in, d_out), dtype="float32")
    x_q, sx = ref.quantize_rowwise(x)
    w_q_t, sw = ref.quantize_rowwise(w.T)
    return (x_q, w_q_t.T, sx, sw), {"bm": min(64, m),
                                    "bn": 128, "bk": 512}


def _registry() -> Dict[str, KernelCase]:
    from repro.kernels import ops, ref
    return {
        "flash_attention": KernelCase(
            "flash_attention", "prefill", DEFAULT_DTYPES, _make_flash,
            ops.flash_attention,
            lambda q, k, v, **kw: ref.mha_reference(
                q, k, v, causal=kw.get("causal", True),
                window=kw.get("window", 0),
                softcap=kw.get("softcap", 0.0))),
        "decode_attention": KernelCase(
            "decode_attention", "decode", DEFAULT_DTYPES, _make_decode,
            ops.decode_attention,
            lambda q, k, v, lengths, **kw: ref.decode_attention_reference(
                q, k, v, lengths)),
        "wkv6": KernelCase(
            "wkv6", "prefill", DEFAULT_DTYPES, _make_wkv6,
            ops.wkv6,
            lambda r, k, v, logw, u, s0, **kw: ref.wkv6_reference(
                r, k, v, logw, u, s0)),
        "rglru_scan": KernelCase(
            "rglru_scan", "prefill", DEFAULT_DTYPES, _make_rglru,
            ops.rglru_scan,
            lambda a, b, s0, **kw: ref.rglru_reference(a, b, s0)),
        "int8_matmul": KernelCase(
            "int8_matmul", "prefill", ("int8",), _make_int8_matmul,
            ops.int8_matmul,
            lambda x_q, w_q, sx, sw, **kw: ref.int8_matmul_reference(
                x_q, w_q, sx, sw)),
    }


@functools.lru_cache(maxsize=1)
def kernel_registry() -> Dict[str, KernelCase]:
    """Name → :class:`KernelCase` for every benchable Pallas kernel."""
    return _registry()


def _first_leaf(out):
    return out[0] if isinstance(out, tuple) else out


def _verify(case: KernelCase, dtype: str, batch: int, seq: int,
            seed: int) -> float:
    """Run the real ops entry point vs the reference at one shape;
    return the max abs error (raises if outside tolerance)."""
    import jax
    import numpy as _np
    args, kwargs = case.make(batch, seq, dtype, seed)
    got = _first_leaf(jax.block_until_ready(case.kernel_fn(*args, **kwargs)))
    want = _first_leaf(jax.block_until_ready(case.ref_fn(*args, **kwargs)))
    want64 = _np.asarray(want, dtype=_np.float64)
    err = float(_np.max(_np.abs(_np.asarray(got, dtype=_np.float64)
                                - want64)))
    # scale by output magnitude: kernels that accumulate over a long
    # contraction (int8 matmul) have proportionally larger abs error
    tol = VERIFY_TOL.get(dtype, 2e-2) \
        * max(1.0, float(_np.max(_np.abs(want64))))
    if err > tol:
        raise AssertionError(
            f"kernel {case.name!r} ({dtype}) disagrees with its reference "
            f"at batch={batch} seq={seq}: max_err={err:.3e} > tol={tol:g}")
    return err


def resolve_target(target: str = "auto") -> str:
    """Which implementation the sweep clocks: "kernel" | "reference"."""
    if target in ("kernel", "reference"):
        return target
    from repro.kernels import ops
    return "reference" if ops.interpret_mode() else "kernel"


def kernel_records(kernels: Optional[Sequence[str]] = None, *,
                   batches: Sequence[int] = DEFAULT_BATCHES,
                   seqs: Sequence[int] = DEFAULT_SEQS,
                   dtypes: Optional[Sequence[str]] = None,
                   repeats: int = 3, target: str = "auto",
                   verify: bool = True, seed: int = 0,
                   meta: Optional[Dict[str, Any]] = None
                   ) -> List[Dict[str, Any]]:
    """Wall-clock the kernel grid; one PerfDB record per point.

    Records look like the model-sweep calibration records (``phase``,
    ``batch``, ``tokens``, ``result.latency_s``) so the same fitter
    consumes them, plus ``kernel``, ``dtype`` and
    ``backend="pallas-kernel"`` provenance.
    """
    import jax
    from repro.serving.latency_model import MeasuredLatency

    reg = kernel_registry()
    names = list(kernels) if kernels else sorted(reg)
    unknown = [n for n in names if n not in reg]
    if unknown:
        raise KeyError(f"unknown kernels {unknown} (known: {sorted(reg)})")
    mode = resolve_target(target)
    meta = dict(meta or {})
    records: List[Dict[str, Any]] = []
    for name in names:
        case = reg[name]
        sweep_dtypes = tuple(dtypes) if dtypes else tuple(case.dtypes)
        sweep_dtypes = tuple(d for d in sweep_dtypes if d in case.dtypes) \
            or tuple(case.dtypes)
        for dt in sweep_dtypes:
            max_err = _verify(case, dt, min(batches), min(seqs), seed) \
                if verify else None
            for b in batches:
                for s in seqs:
                    args, kwargs = case.make(b, s, dt, seed)
                    if mode == "kernel":
                        fn = functools.partial(case.kernel_fn, **kwargs)
                    else:
                        fn = jax.jit(functools.partial(case.ref_fn,
                                                       **kwargs))
                    clock = MeasuredLatency(fn, warmup=1,
                                            iters=max(repeats, 1),
                                            reducer="min")
                    lat = clock.measure(*args)
                    rec = dict(meta, kind="calibration", phase=case.phase,
                               batch=int(b), tokens=int(s),
                               kernel=name, dtype=dt, backend=BACKEND,
                               result={"latency_s": float(lat),
                                       "mode": f"{mode}-"
                                               f"{jax.default_backend()}"})
                    if max_err is not None:
                        rec["result"]["max_err_vs_ref"] = max_err
                    records.append(rec)
    return records


def fit_kernel_records(records: Iterable[Dict[str, Any]]
                       ) -> Dict[str, Dict[str, Any]]:
    """Per-(kernel, dtype) least-squares fits from kernel records.

    Returns ``{"<kernel>/<dtype>": {coef, n_points, mean_rel_err, ...,
    phase, backend, max_err_vs_ref}}`` — the dict stored under
    ``CalibrationProfile.kernels``.
    """
    groups: Dict[tuple, List[tuple]] = {}
    errs: Dict[tuple, float] = {}
    for rec in records:
        if rec.get("backend") != BACKEND:
            continue
        key = (rec["kernel"], rec.get("dtype", "float32"), rec["phase"])
        res = rec.get("result", {})
        groups.setdefault(key, []).append(
            (float(rec["batch"]), float(rec["tokens"]),
             float(res["latency_s"])))
        if "max_err_vs_ref" in res:
            errs[key] = max(errs.get(key, 0.0),
                            float(res["max_err_vs_ref"]))
    fits: Dict[str, Dict[str, Any]] = {}
    for (kernel, dtype, phase), pts in sorted(groups.items()):
        fit = fit_phase(pts, phase)
        d = fit.to_dict()
        d.update(phase=phase, backend=BACKEND, kernel=kernel, dtype=dtype)
        if (kernel, dtype, phase) in errs:
            d["max_err_vs_ref"] = errs[(kernel, dtype, phase)]
        fits[f"{kernel}/{dtype}"] = d
    return fits


def _measured_int8_compute_scale(repeats: int = 3,
                                 seed: int = 0) -> Optional[float]:
    """Measured dequant overhead: int8 reference matmul vs the same
    shape in plain float32.  Clamped to [1.0, 1.5] so scheduler noise on
    shared CI runners cannot produce an absurd scale."""
    try:
        import jax
        import jax.numpy as jnp
        from repro.kernels import ref
        from repro.serving.latency_model import MeasuredLatency
        (x_q, w_q, sx, sw), _ = _make_int8_matmul(2, 128, "int8", seed)
        x = jnp.asarray(x_q, jnp.float32) * sx[:, None]
        w = jnp.asarray(w_q, jnp.float32) * sw[None, :]
        t_i8 = MeasuredLatency(jax.jit(ref.int8_matmul_reference),
                               warmup=1, iters=repeats,
                               reducer="min").measure(x_q, w_q, sx, sw)
        t_fp = MeasuredLatency(jax.jit(jnp.dot), warmup=1, iters=repeats,
                               reducer="min").measure(x, w)
        if t_fp <= 0:
            return None
        return float(min(max(t_i8 / t_fp, 1.0), 1.5))
    except Exception:
        return None


def derive_speed_modes(kernel_fits: Optional[Dict[str, Dict[str, Any]]]
                       = None, *, measure_compute_scale: bool = False,
                       repeats: int = 3) -> Dict[str, Dict[str, Any]]:
    """Speed-mode parameter dicts for ``CalibrationProfile.speed_modes``.

    Byte scales are exact dtype arithmetic (int8 weights + KV are half
    of bf16) and need no measurement; the int8 ``compute_scale`` —
    quant/dequant overhead — optionally comes from clocking the int8
    reference matmul against plain float32 (CPU proxy; a real TPU run
    refines it from the compiled kernel).  Speculative parameters are
    workload properties, so the conventional defaults ship unless a
    scenario overrides them.
    """
    from repro.serving.latency_model import SPEED_MODES
    modes = {name: mode.to_dict() for name, mode in SPEED_MODES.items()}
    if measure_compute_scale:
        scale = _measured_int8_compute_scale(repeats=repeats)
        if scale is not None:
            modes["int8"]["compute_scale"] = scale
    return modes


def attach_kernel_calibration(profile: CalibrationProfile,
                              records: Iterable[Dict[str, Any]], *,
                              measure_compute_scale: bool = False
                              ) -> CalibrationProfile:
    """Return ``profile`` with kernel fits + derived speed modes merged
    in (existing fields untouched)."""
    fits = fit_kernel_records(records)
    modes = derive_speed_modes(
        fits, measure_compute_scale=measure_compute_scale)
    return dataclasses.replace(profile, kernels=fits or None,
                               speed_modes=modes)
