"""SLO-aware capacity planner (the plan step of measure → model → plan).

Loads a calibration profile, clocks the multi-replica cluster simulator
with its fitted latency oracle, and searches a replicas × batching-policy
× router grid for the cheapest configuration whose SLO attainment meets
the target.  Cost comes from the same ``repro.hw`` cloud-rate/energy
model the benchmark results use, so planned and benchmarked dollars are
directly comparable.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.calibrate.profile import CalibrationProfile, load_profile
from repro.core.results import JobResult
from repro.core.spec import PlanSpec
from repro.serving.cluster import (ClusterSpec, DisaggSpec, PoolSpec,
                                   simulate_cluster)
from repro.serving.latency_model import (NETWORKS, SpeedMode,
                                         apply_speed_mode,
                                         resolve_speed_mode)
from repro.serving.memory import (GiB, KVBudgetError, MemorySpec,
                                  resolve_memory, scaled_memory_spec)
from repro.serving.workload import WorkloadSpec


@dataclasses.dataclass(frozen=True)
class PlanCandidate:
    """One configuration of the planning grid.

    ``infeasible_reason`` is set when the memory check rejected the
    candidate before simulation (its KV working set cannot fit the
    per-replica HBM budget, however good its latency would be).
    ``split`` is ``(prefill_replicas, decode_replicas)`` for a
    disaggregated candidate, None for colocated; ``replicas`` is always
    the total chip-normalizing replica count.  ``speed_mode`` names the
    serving mode the candidate was simulated under ("fp16" when the
    plan searched none).  ``fleet`` is the heterogeneous composition the
    candidate simulated — a tuple of ``PoolSpec`` dicts (JSON-able, and
    accepted back by ``ClusterSpec(pools=...)``) — or None for a flat
    identical-replica cluster.
    """
    replicas: int
    policy: str
    router: str
    metrics: Dict[str, float]       # SimResult.summary() + slo_attainment
    meets_slo: bool
    objective: float                # the minimized metric's value
    max_batch: int = 0              # 0 in legacy single-max_batch plans
    split: Optional[Sequence[int]] = None
    speed_mode: str = "fp16"
    infeasible_reason: Optional[str] = None
    fleet: Optional[Sequence[Dict[str, Any]]] = None

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class PlanResult:
    """The full grid, sorted feasible-first then by objective."""
    profile_key: str
    slo_latency_s: Optional[float]
    slo_target: float
    objective: str
    candidates: List[PlanCandidate]
    ttft_slo_s: Optional[float] = None
    tpot_slo_s: Optional[float] = None

    @property
    def best(self) -> Optional[PlanCandidate]:
        feasible = [c for c in self.candidates if c.meets_slo]
        return min(feasible, key=lambda c: c.objective) if feasible else None

    def to_dict(self) -> Dict[str, Any]:
        best = self.best
        return {
            "profile_key": self.profile_key,
            "slo_latency_s": self.slo_latency_s,
            "slo_target": self.slo_target,
            "objective": self.objective,
            "best": best.to_dict() if best else None,
            "candidates": [c.to_dict() for c in self.candidates],
        }


def _policy(name: str, max_batch: int, max_prefill: int):
    from repro.core.session import resolve_policy
    from repro.core.spec import SoftwareSpec
    return resolve_policy(SoftwareSpec(policy=name, max_batch=max_batch,
                                       max_prefill=max_prefill))


def _memory_working_set_reason(memory: MemorySpec, oracle,
                               workload: WorkloadSpec,
                               max_batch: int) -> Optional[str]:
    """Static admission check: can ``max_batch`` concurrent sequences at
    their full context length ever fit the per-replica KV budget?  The
    estimate is conservative (every slot at max length) — that is the
    regime a capacity plan must survive."""
    resolved = resolve_memory(memory, oracle)
    # mixed-prompt workloads size the check at the longest prompt they
    # can draw — the conservative regime the plan must survive
    prompt = max(workload.prompt_tokens, workload.prompt_tokens_max)
    out_max = workload.output_tokens_max
    if out_max is None:
        # unbounded generation: the engine clamps each sequence at
        # max_model_len, so that is the per-slot working set
        tokens = max(resolved.max_model_len, prompt + 1)
    else:
        tokens = prompt + max(workload.output_tokens, out_max, 1)
        tokens = min(tokens, max(resolved.max_model_len, prompt + 1))
    bt = memory.block_tokens
    blocks = -(-tokens // bt) * max_batch
    if blocks <= resolved.total_blocks:
        return None
    ws_gib = blocks * bt * resolved.kv_bytes_per_token / GiB
    return (f"KV working set {ws_gib:.2f} GiB "
            f"(max_batch={max_batch} × {tokens} tok × "
            f"{resolved.kv_bytes_per_token:.0f} B/tok) exceeds the "
            f"per-replica KV budget of "
            f"{resolved.budget_bytes / GiB:.2f} GiB "
            f"({resolved.total_blocks} × {bt}-token blocks)")


_CONTINUOUS_NAMES = ("continuous", "orca", "vllm")


def plan_capacity(profile, workload: WorkloadSpec, *,
                  slo_latency_s: Optional[float] = None,
                  slo_target: float = 0.99,
                  ttft_slo_s: Optional[float] = None,
                  tpot_slo_s: Optional[float] = None,
                  tenants: Sequence[Any] = (),
                  replicas: Sequence[int] = (1, 2, 4),
                  policies: Sequence[str] = ("tfs", "continuous"),
                  routers: Sequence[str] = ("least-loaded",),
                  max_batch: int = 16,
                  max_batches: Sequence[int] = (),
                  max_prefill: int = 8,
                  prefill_decode_splits: Sequence[Sequence[int]] = (),
                  kv_network: str = "infiniband",
                  network: str = "lan",
                  objective: str = "cost_per_1k_req",
                  speed_modes: Sequence[Any] = (),
                  memory: Optional[MemorySpec] = None,
                  fleets: Sequence[Any] = ()) -> PlanResult:
    """Search the configuration grid for the cheapest SLO-meeting setup.

    ``profile`` may be a :class:`CalibrationProfile`, its dict/JSON-path/
    ``model@hardware`` form, or any ready ``LatencyOracle`` (so a plan
    can also be run against the analytic roofline model directly).

    SLOs: ``slo_latency_s`` constrains e2e latency; ``ttft_slo_s`` /
    ``tpot_slo_s`` constrain the phases real LLM deployments are judged
    by.  Attainment is joint — a request counts only when it meets
    *every* provided SLO — and at least one SLO must be given.

    ``tenants`` plans for a traffic mix instead of one stream: the
    workload is split across the given ``TenantSpec``s (shares, per-
    tenant scenarios/overrides) and a candidate is feasible only when
    its *worst* tenant meets that tenant's own resolved SLOs at the
    target, so the plan is the cheapest config under which every tenant
    survives.  Candidate metrics gain the per-tenant slices plus
    ``fairness_index``/``min_goodput_rps``; the top-level SLO arguments
    become optional (each tenant must resolve at least one SLO).

    ``prefill_decode_splits`` adds disaggregated candidates to the grid:
    each ``(prefill, decode)`` pair is simulated as split pools (total
    replicas = prefill + decode, KV handoff over ``kv_network``) under
    every continuous-batching policy/router/slot combination, so the
    planner can recommend colocated vs disaggregated per workload.

    With ``memory`` set the plan is memory-*and*-latency-aware: every
    candidate whose KV working set cannot fit the per-replica HBM budget
    is rejected up front (``infeasible_reason`` says why), and surviving
    candidates are simulated under that budget, so preemption/thrashing
    shows up in their latency numbers.  ``max_batches`` widens the grid
    over decode-slot counts (default: just ``max_batch``).

    ``speed_modes`` multiplies the grid by serving speed modes (names,
    :class:`SpeedMode` instances, or parameter dicts): each candidate is
    simulated under the mode-scaled oracle *and* the mode-scaled memory
    budget (int8 KV entries are half-size, so the same HBM admits bigger
    batches), letting the planner recommend a quantized or speculative
    config when it wins on the objective.  Names resolve through the
    profile's calibrated ``speed_modes`` section first, then the
    built-in presets.

    ``fleets`` adds heterogeneous compositions to the grid: each entry
    is a sequence of ``PoolSpec``s (or their dicts) — e.g. 2×v5e
    reserved + a spot t4 overflow pool vs. 3×v5e reserved — simulated
    under every router/policy/slot combination, so the planner can
    answer the paper's headline question (which *mix* of devices serves
    this traffic cheapest) under the same ``cost_per_goodput``
    objective.  Fleets with spot preemption pair only with continuous
    policies (kills requeue through the decode loop); per-pool memory
    grounding happens inside the simulation, so infeasible fleet
    budgets surface as ``KVBudgetError`` rejections.
    """
    tenant_specs = ()
    if tenants:
        from repro.scenarios.tenants import (coerce_tenants,
                                             resolve_tenant_slos,
                                             tenant_workload)
        tenant_specs = coerce_tenants(tenants)
        for t in tenant_specs:
            if all(v is None for v in resolve_tenant_slos(t).values()):
                raise ValueError(
                    f"tenant {t.name!r} resolves no SLO: give it "
                    "slo_latency_s/slo_ttft_s/slo_tpot_s or a scenario "
                    "whose profile carries defaults")
        workload = dataclasses.replace(workload, tenants=tenant_specs)
    elif slo_latency_s is None and ttft_slo_s is None and tpot_slo_s is None:
        raise ValueError("plan_capacity needs at least one SLO: "
                         "slo_latency_s, ttft_slo_s, or tpot_slo_s")
    if isinstance(profile, dict):
        profile = CalibrationProfile.from_dict(profile)
    elif isinstance(profile, str):
        profile = load_profile(profile)
    mode_overrides = None
    if isinstance(profile, CalibrationProfile):
        oracle, key = profile.to_latency_model(), profile.key
        mode_overrides = profile.speed_modes
    else:
        oracle, key = profile, getattr(profile, "name", "oracle")
    if isinstance(memory, dict):
        memory = MemorySpec.from_dict(memory)
    mbs = tuple(max_batches) or (max_batch,)
    phase_slos = ttft_slo_s is not None or tpot_slo_s is not None

    # the speed-mode axis: calibrated profile parameters win over the
    # built-in presets; duplicates (by name) collapse to the first
    modes: List[SpeedMode] = []
    for m in (speed_modes or ("fp16",)):
        sm = resolve_speed_mode(m, mode_overrides)
        if all(sm.name != seen.name for seen in modes):
            modes.append(sm)

    # grid rows: (total_replicas, policy, router, max_batch, split, fleet)
    grid: List[tuple] = [
        (int(n), pol, router, int(mb), None, None)
        for n, pol, router, mb in itertools.product(replicas, policies,
                                                    routers, mbs)]
    # disaggregation needs a decode loop to migrate into, so split
    # candidates only pair with continuous policies (falling back to
    # plain "continuous" when the grid has none)
    disagg_pols = [p for p in policies if p in _CONTINUOUS_NAMES] \
        or ["continuous"]
    for split in prefill_decode_splits:
        pre, dec = int(split[0]), int(split[1])
        for pol, router, mb in itertools.product(disagg_pols, routers,
                                                 mbs):
            grid.append((pre + dec, pol, router, int(mb), (pre, dec),
                         None))
    # heterogeneous compositions: one row per fleet × policy × router ×
    # slots (spot-preempting fleets need the continuous decode loop)
    for f in fleets:
        pools = tuple(PoolSpec.from_dict(p) if isinstance(p, dict) else p
                      for p in f)
        n = sum(p.replicas for p in pools)
        fleet_pols = disagg_pols \
            if any(p.preempt_mtbf_s > 0 for p in pools) else policies
        for pol, router, mb in itertools.product(fleet_pols, routers,
                                                 mbs):
            grid.append((n, pol, router, int(mb), None, pools))

    # the static memory check sizes at the longest-context slice of the
    # traffic; for a tenant mix that is each tenant's own specialized
    # workload, not the parent shell
    sizing_workloads = [workload]
    if tenant_specs:
        sizing_workloads = [tenant_workload(workload, t, i, workload.rate)
                            for i, t in enumerate(tenant_specs)]

    candidates: List[PlanCandidate] = []
    for mode in modes:
        # mode-scaled serving physics: the oracle's latencies, KV
        # footprint, and resident weights all shift together, and an
        # explicit memory budget re-grounds at the smaller KV entry size
        oracle_m = apply_speed_mode(oracle, mode)
        memory_m = scaled_memory_spec(memory, mode)
        for n, pol, router, mb, split, fleet in grid:
            fleet_dicts = tuple(dataclasses.asdict(p) for p in fleet) \
                if fleet is not None else None
            reason = None
            # fleet budgets ground per pool against each pool's own
            # oracle inside the simulation, so the flat working-set
            # estimate doesn't apply — KVBudgetError covers them below
            if memory_m is not None and fleet is None:
                reason = next(
                    (r for r in (_memory_working_set_reason(memory_m,
                                                            oracle_m,
                                                            wl, mb)
                                 for wl in sizing_workloads)
                     if r is not None), None)
            if reason is not None:
                candidates.append(PlanCandidate(
                    replicas=n, policy=pol, router=router, metrics={},
                    meets_slo=False, objective=float("inf"),
                    max_batch=mb, split=split, speed_mode=mode.name,
                    infeasible_reason=reason))
                continue
            if fleet is not None:
                cluster = ClusterSpec(pools=fleet, router=router,
                                      memory=memory_m)
            elif split is None:
                cluster = ClusterSpec(replicas=n, router=router,
                                      memory=memory_m)
            else:
                cluster = ClusterSpec(
                    replicas=n, router=router, memory=memory_m,
                    disaggregation=DisaggSpec(
                        prefill_replicas=split[0],
                        decode_replicas=split[1],
                        prefill_router=router, decode_router=router,
                        prefill_max_batch=max_prefill,
                        kv_network=kv_network))
            try:
                res = simulate_cluster(
                    workload, _policy(pol, mb, max_prefill), oracle_m,
                    cluster=cluster, network=NETWORKS[network])
            except KVBudgetError as exc:
                # budget validation caught something the static estimate
                # missed (e.g. per-request lengths from a replayed
                # trace): reject the candidate, not the whole grid
                candidates.append(PlanCandidate(
                    replicas=n, policy=pol, router=router, metrics={},
                    meets_slo=False, objective=float("inf"),
                    max_batch=mb, split=split, speed_mode=mode.name,
                    infeasible_reason=str(exc), fleet=fleet_dicts))
                continue
            if tenant_specs:
                # a tenant mix is judged by its weakest member: every
                # tenant must hit its *own* resolved SLOs at the target
                from repro.scenarios.tenants import tenant_report
                report = tenant_report(res, tenant_specs)
                att = report["worst_tenant_attainment"]
                metrics = dict(res.summary(), slo_attainment=att,
                               fairness_index=report["fairness_index"],
                               worst_tenant=report["worst_tenant"],
                               min_goodput_rps=report["min_goodput_rps"],
                               tenants=report["per_tenant"])
            else:
                if phase_slos:
                    att = res.phase_slo_attainment(
                        ttft_slo_s=ttft_slo_s, tpot_slo_s=tpot_slo_s,
                        e2e_slo_s=slo_latency_s)
                else:
                    att = res.slo_attainment(slo_latency_s)
                metrics = dict(res.summary(), slo_attainment=att)
                metrics["goodput_rps"] = res.goodput(
                    ttft_slo_s, tpot_slo_s, slo_latency_s)
            # $/goodput-req: the speed-mode tiebreaker — a mode only wins
            # by serving more SLO-meeting traffic per dollar, not by raw
            # throughput
            gp = metrics.get("goodput_rps",
                             metrics.get("min_goodput_rps", 0.0))
            metrics["cost_per_goodput"] = \
                metrics.get("cost_usd", 0.0) / (gp * res.duration_s) \
                if gp > 0 and res.duration_s else float("inf")
            if objective not in metrics:
                raise ValueError(
                    f"unknown plan objective {objective!r} "
                    f"(available: {sorted(metrics)})")
            candidates.append(PlanCandidate(
                replicas=n, policy=pol, router=router, metrics=metrics,
                meets_slo=att >= slo_target,
                objective=float(metrics[objective]), max_batch=mb,
                split=split, speed_mode=mode.name, fleet=fleet_dicts))
    candidates.sort(key=lambda c: (not c.meets_slo, c.objective))
    return PlanResult(profile_key=key, slo_latency_s=slo_latency_s,
                      slo_target=slo_target, objective=objective,
                      candidates=candidates,
                      ttft_slo_s=ttft_slo_s, tpot_slo_s=tpot_slo_s)


def simulate_candidate(profile, workload: WorkloadSpec,
                       candidate: PlanCandidate, *,
                       tenants: Sequence[Any] = (),
                       max_prefill: int = 8,
                       kv_network: str = "infiniband",
                       network: str = "lan",
                       memory: Optional[MemorySpec] = None):
    """Re-simulate one plan candidate and return the raw ``SimResult``.

    This is the verification half of plan → verify: rebuild exactly the
    cluster a :class:`PlanCandidate` describes — including its
    ``speed_mode`` — and run the workload through it, so a caller can
    independently confirm the planner's claimed attainment (e.g.
    per-tenant SLOs of the cheapest feasible config) rather than trust
    the grid numbers.
    """
    if isinstance(profile, dict):
        profile = CalibrationProfile.from_dict(profile)
    elif isinstance(profile, str):
        profile = load_profile(profile)
    mode_overrides = None
    if isinstance(profile, CalibrationProfile):
        oracle = profile.to_latency_model()
        mode_overrides = profile.speed_modes
    else:
        oracle = profile
    if isinstance(memory, dict):
        memory = MemorySpec.from_dict(memory)
    mode = resolve_speed_mode(candidate.speed_mode, mode_overrides)
    oracle = apply_speed_mode(oracle, mode)
    memory = scaled_memory_spec(memory, mode)
    if tenants:
        from repro.scenarios.tenants import coerce_tenants
        workload = dataclasses.replace(workload,
                                       tenants=coerce_tenants(tenants))
    if getattr(candidate, "fleet", None):
        cluster = ClusterSpec(pools=candidate.fleet,
                              router=candidate.router, memory=memory)
    elif candidate.split is None:
        cluster = ClusterSpec(replicas=candidate.replicas,
                              router=candidate.router, memory=memory)
    else:
        pre, dec = candidate.split
        cluster = ClusterSpec(
            replicas=candidate.replicas, router=candidate.router,
            memory=memory,
            disaggregation=DisaggSpec(
                prefill_replicas=pre, decode_replicas=dec,
                prefill_router=candidate.router,
                decode_router=candidate.router,
                prefill_max_batch=max_prefill, kv_network=kv_network))
    mb = candidate.max_batch or 16
    return simulate_cluster(workload,
                            _policy(candidate.policy, mb, max_prefill),
                            oracle, cluster=cluster,
                            network=NETWORKS[network])


def plan_from_spec(spec: PlanSpec) -> PlanResult:
    profile = load_profile(spec.profile, spec.profile_dir)
    return plan_capacity(
        profile, spec.workload, slo_latency_s=spec.slo_latency_s,
        slo_target=spec.slo_target,
        ttft_slo_s=spec.ttft_slo_s, tpot_slo_s=spec.tpot_slo_s,
        tenants=spec.tenants,
        replicas=spec.replicas,
        policies=spec.policies, routers=spec.routers,
        max_batch=spec.max_batch, max_batches=spec.max_batches,
        max_prefill=spec.max_prefill,
        prefill_decode_splits=spec.prefill_decode_splits,
        kv_network=spec.kv_network,
        network=spec.network, objective=spec.objective,
        speed_modes=spec.speed_modes,
        memory=spec.memory, fleets=spec.fleets)


def run_plan_job(spec: PlanSpec) -> JobResult:
    """BenchmarkSession stage runner for a plan submission."""
    t0 = time.time()
    plan = plan_from_spec(spec)
    best = plan.best
    metrics: Dict[str, Any] = {
        "mode": "plan",
        "profile_key": plan.profile_key,
        "slo_latency_s": spec.slo_latency_s,
        "ttft_slo_s": spec.ttft_slo_s,
        "tpot_slo_s": spec.tpot_slo_s,
        "slo_target": spec.slo_target,
        "objective": spec.objective,
        "candidates": len(plan.candidates),
        "feasible": sum(c.meets_slo for c in plan.candidates),
        "rejected_memory": sum(c.infeasible_reason is not None
                               for c in plan.candidates),
        "best": best.to_dict() if best else None,
        "plan": plan.to_dict(),
    }
    return JobResult(spec=spec, metrics=metrics,
                     benchmark_wall_s=time.time() - t0)
