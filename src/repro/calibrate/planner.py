"""SLO-aware capacity planner (the plan step of measure → model → plan).

Loads a calibration profile, clocks the multi-replica cluster simulator
with its fitted latency oracle, and searches a replicas × batching-policy
× router grid for the cheapest configuration whose SLO attainment meets
the target.  Cost comes from the same ``repro.hw`` cloud-rate/energy
model the benchmark results use, so planned and benchmarked dollars are
directly comparable.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.calibrate.profile import CalibrationProfile, load_profile
from repro.core.results import JobResult
from repro.core.spec import PlanSpec
from repro.serving.cluster import ClusterSpec, simulate_cluster
from repro.serving.latency_model import NETWORKS
from repro.serving.memory import (GiB, KVBudgetError, MemorySpec,
                                  resolve_memory)
from repro.serving.workload import WorkloadSpec


@dataclasses.dataclass(frozen=True)
class PlanCandidate:
    """One configuration of the planning grid.

    ``infeasible_reason`` is set when the memory check rejected the
    candidate before simulation (its KV working set cannot fit the
    per-replica HBM budget, however good its latency would be).
    """
    replicas: int
    policy: str
    router: str
    metrics: Dict[str, float]       # SimResult.summary() + slo_attainment
    meets_slo: bool
    objective: float                # the minimized metric's value
    max_batch: int = 0              # 0 in legacy single-max_batch plans
    infeasible_reason: Optional[str] = None

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class PlanResult:
    """The full grid, sorted feasible-first then by objective."""
    profile_key: str
    slo_latency_s: float
    slo_target: float
    objective: str
    candidates: List[PlanCandidate]

    @property
    def best(self) -> Optional[PlanCandidate]:
        feasible = [c for c in self.candidates if c.meets_slo]
        return min(feasible, key=lambda c: c.objective) if feasible else None

    def to_dict(self) -> Dict[str, Any]:
        best = self.best
        return {
            "profile_key": self.profile_key,
            "slo_latency_s": self.slo_latency_s,
            "slo_target": self.slo_target,
            "objective": self.objective,
            "best": best.to_dict() if best else None,
            "candidates": [c.to_dict() for c in self.candidates],
        }


def _policy(name: str, max_batch: int, max_prefill: int):
    from repro.core.session import resolve_policy
    from repro.core.spec import SoftwareSpec
    return resolve_policy(SoftwareSpec(policy=name, max_batch=max_batch,
                                       max_prefill=max_prefill))


def _memory_working_set_reason(memory: MemorySpec, oracle,
                               workload: WorkloadSpec,
                               max_batch: int) -> Optional[str]:
    """Static admission check: can ``max_batch`` concurrent sequences at
    their full context length ever fit the per-replica KV budget?  The
    estimate is conservative (every slot at max length) — that is the
    regime a capacity plan must survive."""
    resolved = resolve_memory(memory, oracle)
    out_max = workload.output_tokens_max
    if out_max is None:
        # unbounded generation: the engine clamps each sequence at
        # max_model_len, so that is the per-slot working set
        tokens = max(resolved.max_model_len, workload.prompt_tokens + 1)
    else:
        tokens = workload.prompt_tokens + max(workload.output_tokens,
                                              out_max, 1)
        tokens = min(tokens, max(resolved.max_model_len,
                                 workload.prompt_tokens + 1))
    bt = memory.block_tokens
    blocks = -(-tokens // bt) * max_batch
    if blocks <= resolved.total_blocks:
        return None
    ws_gib = blocks * bt * resolved.kv_bytes_per_token / GiB
    return (f"KV working set {ws_gib:.2f} GiB "
            f"(max_batch={max_batch} × {tokens} tok × "
            f"{resolved.kv_bytes_per_token:.0f} B/tok) exceeds the "
            f"per-replica KV budget of "
            f"{resolved.budget_bytes / GiB:.2f} GiB "
            f"({resolved.total_blocks} × {bt}-token blocks)")


def plan_capacity(profile, workload: WorkloadSpec, *,
                  slo_latency_s: float, slo_target: float = 0.99,
                  replicas: Sequence[int] = (1, 2, 4),
                  policies: Sequence[str] = ("tfs", "continuous"),
                  routers: Sequence[str] = ("least-loaded",),
                  max_batch: int = 16,
                  max_batches: Sequence[int] = (),
                  max_prefill: int = 8,
                  network: str = "lan",
                  objective: str = "cost_per_1k_req",
                  memory: Optional[MemorySpec] = None) -> PlanResult:
    """Search the configuration grid for the cheapest SLO-meeting setup.

    ``profile`` may be a :class:`CalibrationProfile`, its dict/JSON-path/
    ``model@hardware`` form, or any ready ``LatencyOracle`` (so a plan
    can also be run against the analytic roofline model directly).

    With ``memory`` set the plan is memory-*and*-latency-aware: every
    candidate whose KV working set cannot fit the per-replica HBM budget
    is rejected up front (``infeasible_reason`` says why), and surviving
    candidates are simulated under that budget, so preemption/thrashing
    shows up in their latency numbers.  ``max_batches`` widens the grid
    over decode-slot counts (default: just ``max_batch``).
    """
    if isinstance(profile, CalibrationProfile):
        oracle, key = profile.to_latency_model(), profile.key
    elif isinstance(profile, (str, dict)):
        from repro.serving.latency_model import FittedLatencyModel
        oracle = FittedLatencyModel.from_profile(profile)
        key = oracle.name
    else:
        oracle, key = profile, getattr(profile, "name", "oracle")
    if isinstance(memory, dict):
        memory = MemorySpec.from_dict(memory)
    mbs = tuple(max_batches) or (max_batch,)

    candidates: List[PlanCandidate] = []
    for n, pol, router, mb in itertools.product(replicas, policies,
                                                routers, mbs):
        reason = None
        if memory is not None:
            reason = _memory_working_set_reason(memory, oracle, workload,
                                                int(mb))
        if reason is not None:
            candidates.append(PlanCandidate(
                replicas=int(n), policy=pol, router=router, metrics={},
                meets_slo=False, objective=float("inf"),
                max_batch=int(mb), infeasible_reason=reason))
            continue
        try:
            res = simulate_cluster(
                workload, _policy(pol, int(mb), max_prefill), oracle,
                cluster=ClusterSpec(replicas=int(n), router=router,
                                    memory=memory),
                network=NETWORKS[network])
        except KVBudgetError as exc:
            # budget validation caught something the static estimate
            # missed (e.g. per-request lengths from a replayed trace):
            # reject the candidate instead of failing the whole grid
            candidates.append(PlanCandidate(
                replicas=int(n), policy=pol, router=router, metrics={},
                meets_slo=False, objective=float("inf"),
                max_batch=int(mb), infeasible_reason=str(exc)))
            continue
        metrics = dict(res.summary(),
                       slo_attainment=res.slo_attainment(slo_latency_s))
        if objective not in metrics:
            raise ValueError(
                f"unknown plan objective {objective!r} "
                f"(available: {sorted(metrics)})")
        candidates.append(PlanCandidate(
            replicas=int(n), policy=pol, router=router, metrics=metrics,
            meets_slo=metrics["slo_attainment"] >= slo_target,
            objective=float(metrics[objective]), max_batch=int(mb)))
    candidates.sort(key=lambda c: (not c.meets_slo, c.objective))
    return PlanResult(profile_key=key, slo_latency_s=slo_latency_s,
                      slo_target=slo_target, objective=objective,
                      candidates=candidates)


def plan_from_spec(spec: PlanSpec) -> PlanResult:
    profile = load_profile(spec.profile, spec.profile_dir)
    return plan_capacity(
        profile, spec.workload, slo_latency_s=spec.slo_latency_s,
        slo_target=spec.slo_target, replicas=spec.replicas,
        policies=spec.policies, routers=spec.routers,
        max_batch=spec.max_batch, max_batches=spec.max_batches,
        max_prefill=spec.max_prefill,
        network=spec.network, objective=spec.objective,
        memory=spec.memory)


def run_plan_job(spec: PlanSpec) -> JobResult:
    """BenchmarkSession stage runner for a plan submission."""
    t0 = time.time()
    plan = plan_from_spec(spec)
    best = plan.best
    metrics: Dict[str, Any] = {
        "mode": "plan",
        "profile_key": plan.profile_key,
        "slo_latency_s": spec.slo_latency_s,
        "slo_target": spec.slo_target,
        "objective": spec.objective,
        "candidates": len(plan.candidates),
        "feasible": sum(c.meets_slo for c in plan.candidates),
        "rejected_memory": sum(c.infeasible_reason is not None
                               for c in plan.candidates),
        "best": best.to_dict() if best else None,
        "plan": plan.to_dict(),
    }
    return JobResult(spec=spec, metrics=metrics,
                     benchmark_wall_s=time.time() - t0)
