"""SLO-aware capacity planner (the plan step of measure → model → plan).

Loads a calibration profile, clocks the multi-replica cluster simulator
with its fitted latency oracle, and searches a replicas × batching-policy
× router grid for the cheapest configuration whose SLO attainment meets
the target.  Cost comes from the same ``repro.hw`` cloud-rate/energy
model the benchmark results use, so planned and benchmarked dollars are
directly comparable.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.calibrate.profile import CalibrationProfile, load_profile
from repro.core.results import JobResult
from repro.core.spec import PlanSpec
from repro.serving.cluster import ClusterSpec, simulate_cluster
from repro.serving.latency_model import NETWORKS
from repro.serving.workload import WorkloadSpec


@dataclasses.dataclass(frozen=True)
class PlanCandidate:
    """One simulated configuration of the planning grid."""
    replicas: int
    policy: str
    router: str
    metrics: Dict[str, float]       # SimResult.summary() + slo_attainment
    meets_slo: bool
    objective: float                # the minimized metric's value

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class PlanResult:
    """The full grid, sorted feasible-first then by objective."""
    profile_key: str
    slo_latency_s: float
    slo_target: float
    objective: str
    candidates: List[PlanCandidate]

    @property
    def best(self) -> Optional[PlanCandidate]:
        feasible = [c for c in self.candidates if c.meets_slo]
        return min(feasible, key=lambda c: c.objective) if feasible else None

    def to_dict(self) -> Dict[str, Any]:
        best = self.best
        return {
            "profile_key": self.profile_key,
            "slo_latency_s": self.slo_latency_s,
            "slo_target": self.slo_target,
            "objective": self.objective,
            "best": best.to_dict() if best else None,
            "candidates": [c.to_dict() for c in self.candidates],
        }


def _policy(name: str, max_batch: int, max_prefill: int):
    from repro.core.session import resolve_policy
    from repro.core.spec import SoftwareSpec
    return resolve_policy(SoftwareSpec(policy=name, max_batch=max_batch,
                                       max_prefill=max_prefill))


def plan_capacity(profile, workload: WorkloadSpec, *,
                  slo_latency_s: float, slo_target: float = 0.99,
                  replicas: Sequence[int] = (1, 2, 4),
                  policies: Sequence[str] = ("tfs", "continuous"),
                  routers: Sequence[str] = ("least-loaded",),
                  max_batch: int = 16, max_prefill: int = 8,
                  network: str = "lan",
                  objective: str = "cost_per_1k_req") -> PlanResult:
    """Search the configuration grid for the cheapest SLO-meeting setup.

    ``profile`` may be a :class:`CalibrationProfile`, its dict/JSON-path/
    ``model@hardware`` form, or any ready ``LatencyOracle`` (so a plan
    can also be run against the analytic roofline model directly).
    """
    if isinstance(profile, CalibrationProfile):
        oracle, key = profile.to_latency_model(), profile.key
    elif isinstance(profile, (str, dict)):
        from repro.serving.latency_model import FittedLatencyModel
        oracle = FittedLatencyModel.from_profile(profile)
        key = oracle.name
    else:
        oracle, key = profile, getattr(profile, "name", "oracle")

    candidates: List[PlanCandidate] = []
    for n, pol, router in itertools.product(replicas, policies, routers):
        res = simulate_cluster(
            workload, _policy(pol, max_batch, max_prefill), oracle,
            cluster=ClusterSpec(replicas=int(n), router=router),
            network=NETWORKS[network])
        metrics = dict(res.summary(),
                       slo_attainment=res.slo_attainment(slo_latency_s))
        if objective not in metrics:
            raise ValueError(
                f"unknown plan objective {objective!r} "
                f"(available: {sorted(metrics)})")
        candidates.append(PlanCandidate(
            replicas=int(n), policy=pol, router=router, metrics=metrics,
            meets_slo=metrics["slo_attainment"] >= slo_target,
            objective=float(metrics[objective])))
    candidates.sort(key=lambda c: (not c.meets_slo, c.objective))
    return PlanResult(profile_key=key, slo_latency_s=slo_latency_s,
                      slo_target=slo_target, objective=objective,
                      candidates=candidates)


def plan_from_spec(spec: PlanSpec) -> PlanResult:
    profile = load_profile(spec.profile, spec.profile_dir)
    return plan_capacity(
        profile, spec.workload, slo_latency_s=spec.slo_latency_s,
        slo_target=spec.slo_target, replicas=spec.replicas,
        policies=spec.policies, routers=spec.routers,
        max_batch=spec.max_batch, max_prefill=spec.max_prefill,
        network=spec.network, objective=spec.objective)


def run_plan_job(spec: PlanSpec) -> JobResult:
    """BenchmarkSession stage runner for a plan submission."""
    t0 = time.time()
    plan = plan_from_spec(spec)
    best = plan.best
    metrics: Dict[str, Any] = {
        "mode": "plan",
        "profile_key": plan.profile_key,
        "slo_latency_s": spec.slo_latency_s,
        "slo_target": spec.slo_target,
        "objective": spec.objective,
        "candidates": len(plan.candidates),
        "feasible": sum(c.meets_slo for c in plan.candidates),
        "best": best.to_dict() if best else None,
        "plan": plan.to_dict(),
    }
    return JobResult(spec=spec, metrics=metrics,
                     benchmark_wall_s=time.time() - t0)
