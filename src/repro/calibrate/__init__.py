"""repro.calibrate — automatic latency-model calibration + SLO-aware
capacity planning (measure → model → plan).

The loop InferBench promises (§4.2.5: the *system* turns measurements
into deployment insight):

  1. **measure** — :mod:`.microbench` sweeps prefill/decode latency over
     a (batch × seq) grid (real CPU execution for generated models, the
     kernel-validated roofline oracle for registered archs), emitting
     ``kind="calibration"`` PerfDB records;
  2. **model** — :mod:`.fit` least-squares fits the parametric
     ``FittedLatencyModel`` coefficients with residual diagnostics and
     persists them as named :mod:`.profile` JSONs under
     ``configs/profiles/``, keyed by (model, hardware);
  3. **plan** — :mod:`.planner` reloads a profile and searches a
     replicas × batching-policy × router grid with the cluster simulator
     for the cheapest configuration meeting a latency SLO target.

All three run through ``BenchmarkSession.submit`` via
``CalibrationSpec`` / ``PlanSpec``, the ``benchmarks/bench_calibrate.py``
CLI, or directly through the functions re-exported here.
"""
from repro.calibrate.fit import fit_phase, fit_records, split_points
from repro.calibrate.kernel_bench import (attach_kernel_calibration,
                                          derive_speed_modes,
                                          fit_kernel_records, kernel_records,
                                          kernel_registry)
from repro.calibrate.microbench import (fit_calibration, measured_records,
                                        oracle_records, run_calibration_job,
                                        sweep_calibration)
from repro.calibrate.planner import (PlanCandidate, PlanResult, plan_capacity,
                                     plan_from_spec, run_plan_job,
                                     simulate_candidate)
from repro.calibrate.profile import (DEFAULT_PROFILE_DIR, PROFILE_SCHEMA,
                                     CalibrationProfile, PhaseFit,
                                     load_profile, profile_path)

__all__ = [
    "CalibrationProfile", "PhaseFit", "PlanCandidate", "PlanResult",
    "DEFAULT_PROFILE_DIR", "PROFILE_SCHEMA",
    "attach_kernel_calibration", "derive_speed_modes", "fit_calibration",
    "fit_kernel_records", "fit_phase", "fit_records", "kernel_records",
    "kernel_registry", "load_profile", "measured_records", "oracle_records",
    "plan_capacity", "plan_from_spec", "profile_path", "run_calibration_job",
    "run_plan_job", "simulate_candidate", "split_points",
    "sweep_calibration",
]
