"""Microbenchmark harness (the measure step of measure → model → plan).

Sweeps prefill/decode latency over a (batch × seq) grid and emits one
PerfDB record per grid point under ``kind="calibration"``:

  * **measured mode** — §4.2.2 generated canonical models (fc / cnn /
    lstm / transformer) are built and jitted per grid point and
    wall-clocked for real on CPU (``MeasuredLatency``).  Families with a
    sequence axis yield prefill points at every (batch, seq) plus
    per-step decode points at seq 1; fc/cnn have no autoregressive
    phase, so their forward cost becomes prompt-length-1 prefill points
    and the fitter derives the decode curve.
  * **oracle mode** — registered archs are swept through the analytic
    roofline ``LatencyModel`` (the same math the dry-run validates
    against compiled HLO and the Pallas kernel references), which is how
    TPU-class profiles are produced on a CPU-only container.

``run_calibration_job`` is the :class:`BenchmarkSession` stage runner
for :class:`~repro.core.spec.CalibrationSpec` submissions: sweep, fit,
optionally persist the named profile, and return a typed ``JobResult``
whose ``extra_records`` carry the raw grid for PerfDB.
"""
from __future__ import annotations

import math
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence

from repro import hw as hw_lib
from repro.calibrate.fit import fit_records
from repro.calibrate.profile import CalibrationProfile
from repro.core.results import JobResult
from repro.core.spec import CalibrationSpec, ModelRef

SEQ_FAMILIES = ("lstm", "transformer")     # generated families with a seq axis


def _record(spec_meta: Dict[str, Any], phase: str, batch: int, tokens: int,
            latency_s: float, mode: str) -> Dict[str, Any]:
    return dict(spec_meta, kind="calibration", phase=phase,
                batch=int(batch), tokens=int(tokens),
                result={"latency_s": float(latency_s), "mode": mode})


def oracle_records(oracle, *, batches: Sequence[int], seqs: Sequence[int],
                   contexts: Optional[Sequence[int]] = None,
                   meta: Optional[Dict[str, Any]] = None
                   ) -> List[Dict[str, Any]]:
    """Sweep any ``LatencyOracle`` analytically over the grid.

    Used by ``LatencyModel.to_profile`` (roofline → profile round-trip)
    and by tests that synthesize records from a known fitted model.
    """
    contexts = tuple(contexts) if contexts else tuple(seqs)
    meta = dict(meta or {})
    records = []
    for b in batches:
        for s in seqs:
            records.append(_record(meta, "prefill", b, s,
                                   oracle.prefill_latency(b, s), "oracle"))
        for c in contexts:
            records.append(_record(meta, "decode", b, c,
                                   oracle.decode_latency(b, c), "oracle"))
    return records


def measured_records(spec: CalibrationSpec,
                     meta: Optional[Dict[str, Any]] = None
                     ) -> List[Dict[str, Any]]:
    """Execute the generated model for real on CPU at every grid point."""
    import jax

    from repro.core import generator as gen_lib
    from repro.serving.latency_model import MeasuredLatency

    model = spec.model
    if model.kind != "generated":
        raise ValueError("measured calibration needs a generated model "
                         f"(got {model.kind!r}:{model.name!r}); registered "
                         "archs calibrate through the oracle mode")
    meta = dict(meta or {})
    has_seq = model.family in SEQ_FAMILIES
    seqs = tuple(spec.seqs) if has_seq else (1,)

    # params are independent of (batch, seq) — build once, jit once, and
    # let the jit cache hold one executable per input shape
    base = gen_lib.GeneratedSpec(family=model.family, layers=model.layers,
                                 width=model.width)
    params, apply_fn, _ = gen_lib.build(base)
    jitted = jax.jit(apply_fn)
    clock = MeasuredLatency(jitted, iters=max(spec.repeats, 1),
                            reducer="min")

    def inputs_for(batch: int, seq: int):
        point = gen_lib.GeneratedSpec(family=model.family,
                                      layers=model.layers, width=model.width,
                                      batch=batch, seq=seq)
        return gen_lib.example_inputs(point)

    # (phase, batch, tokens, input shape) for every grid point
    points = []
    for b in spec.batches:
        for s in seqs:
            # a full forward over s tokens is the prefill analog; fc/cnn
            # collapse to prompt length 1 (one "token" per example)
            points.append(("prefill", b, s, inputs_for(b, s)))
        if has_seq:
            # one-token step = the decode analog (no KV context on the
            # stateless generated models — the fitter pins β to zero)
            points.append(("decode", b, 0, inputs_for(b, 1)))

    # two sweeps over the grid, keeping the per-point minimum: the second
    # pass runs against a warm jit cache, washing out first-touch effects
    # (CPU frequency ramp, allocator growth) that would bias early points
    best = [math.inf] * len(points)
    for _ in range(2):
        for i, (_, _, _, inputs) in enumerate(points):
            best[i] = min(best[i], clock.measure(params, *inputs))

    return [_record(meta, phase, b, toks, lat, "measured-cpu")
            for (phase, b, toks, _), lat in zip(points, best)]


def resolve_mode(spec: CalibrationSpec) -> str:
    if spec.mode in ("measured", "oracle"):
        return spec.mode
    return "measured" if spec.model.kind == "generated" else "oracle"


def sweep_calibration(spec: CalibrationSpec,
                      db=None) -> List[Dict[str, Any]]:
    """Run the microbenchmark sweep; append records to ``db`` if given."""
    meta = {"job_id": spec.job_id, "user": spec.user,
            "arch": spec.model.label, "hardware": spec.hardware,
            "chips": spec.chips}
    if resolve_mode(spec) == "measured":
        records = measured_records(spec, meta)
    else:
        from repro.configs import get_config
        from repro.serving.latency_model import LatencyModel
        hwm = hw_lib.HARDWARE[spec.hardware]
        oracle = LatencyModel(get_config(spec.model.name), hw=hwm,
                              chips=spec.chips)
        records = oracle_records(oracle, batches=spec.batches,
                                 seqs=spec.seqs, contexts=spec.contexts,
                                 meta=meta)
    if db is not None:
        for rec in records:
            db.append(rec)
    return records


def fit_calibration(spec: CalibrationSpec,
                    records: Iterable[Dict[str, Any]]) -> CalibrationProfile:
    """Fit the sweep's records into this spec's named profile."""
    mode = resolve_mode(spec)
    cold_start_s = 2.0
    if mode == "oracle":
        from repro.configs import get_config
        from repro.serving.latency_model import LatencyModel
        cold_start_s = LatencyModel(get_config(spec.model.name),
                                    hw=hw_lib.HARDWARE[spec.hardware],
                                    chips=spec.chips).cold_start()
    records = list(records)
    # grid metadata comes from the records actually measured — measured
    # fc/cnn sweeps collapse the seq axis, so the spec's grid would lie
    grid = {
        "batches": sorted({r["batch"] for r in records}),
        "seqs": sorted({r["tokens"] for r in records
                        if r["phase"] == "prefill"}),
        "contexts": sorted({r["tokens"] for r in records
                            if r["phase"] == "decode"}),
    }
    return fit_records(
        records, model=spec.model.label, hardware=spec.hardware,
        chips=spec.chips, source="measured-cpu" if mode == "measured"
        else "oracle", holdout_fraction=spec.holdout_fraction,
        cold_start_s=cold_start_s, grid=grid)


def run_calibration_job(spec: CalibrationSpec) -> JobResult:
    """BenchmarkSession stage runner for a calibration submission."""
    t0 = time.time()
    records = sweep_calibration(spec)
    profile = fit_calibration(spec, records)
    kernel_recs: List[Dict[str, Any]] = []
    if spec.kernels:
        # Pallas-kernel backend: microbench the requested kernels on the
        # same (batch × seq) grid and fold their fits + derived speed
        # modes into the profile (records keep backend provenance)
        from repro.calibrate import kernel_bench
        meta = {"job_id": spec.job_id, "user": spec.user,
                "arch": spec.model.label, "hardware": spec.hardware,
                "chips": spec.chips}
        kernel_recs = kernel_bench.kernel_records(
            spec.kernels, batches=spec.batches, seqs=spec.seqs,
            repeats=max(spec.repeats, 1), target=spec.kernel_target,
            meta=meta)
        profile = kernel_bench.attach_kernel_calibration(
            profile, kernel_recs)
        records = records + kernel_recs
    saved: Optional[str] = None
    if spec.profile_dir:
        saved = str(profile.save(spec.profile_dir))
    metrics: Dict[str, Any] = {
        "mode": profile.source,
        "n_records": len(records),
        "prefill_mean_rel_err": profile.prefill.mean_rel_err,
        "prefill_r2": profile.prefill.r2,
        "decode_mean_rel_err": profile.decode.mean_rel_err,
        "decode_r2": profile.decode.r2,
        "profile_key": profile.key,
        "profile_path": saved,
        "profile": profile.to_dict(),
    }
    if kernel_recs:
        metrics["n_kernel_records"] = len(kernel_recs)
        metrics["kernels"] = sorted({r["kernel"] for r in kernel_recs})
    if profile.holdout:
        metrics["holdout"] = dict(profile.holdout)
    return JobResult(spec=spec, metrics=metrics, extra_records=records,
                     benchmark_wall_s=time.time() - t0)
