"""Static HTML run reports from PerfDB records (zero dependencies).

``render_report`` turns one or more PerfDB records (the JSONL rows
``JobResult.to_record`` writes) into a single self-contained HTML file:
summary cards, latency-percentile tables, stage breakdowns, inline-SVG
time-series charts for records that carried a ``Timeseries`` (ObsSpec
runs), provenance (events, ``sim_events_per_sec``) and, when a baseline
plus bench dumps are supplied, the CI regression delta table.

Entry points::

    python -m repro.obs.report out/perfdb.jsonl -o out/report.html \\
        --baseline benchmarks/baselines/ci_baseline.json \\
        --bench sim=out/bench_simulator.json

    BenchmarkSession.report("report.html")      # the session's results

The chart styling follows the repo-wide viz conventions: categorical
series colors in fixed slot order, one axis per chart, a legend for
two-series charts, ink tokens (never series colors) for text, and a
selected dark mode via ``prefers-color-scheme``.
"""
from __future__ import annotations

import argparse
import html as _html
import json
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs.recorder import Timeseries

# ---- palette (validated default; see docs: dataviz reference) --------------
_CSS = """
:root { color-scheme: light; }
body {
  margin: 0; padding: 24px 32px; background: #f9f9f7; color: #0b0b0b;
  font-family: system-ui, -apple-system, "Segoe UI", sans-serif;
  --surface-1: #fcfcfb; --text-primary: #0b0b0b;
  --text-secondary: #52514e; --muted: #898781; --grid: #e1e0d9;
  --baseline: #c3c2b7; --border: rgba(11,11,11,0.10);
  --series-1: #2a78d6; --series-2: #eb6834; --series-3: #1baf7a;
  --good: #0ca30c; --bad: #d03b3b; --warn-bg: #fff3da;
  --warn-border: #fab219;
}
@media (prefers-color-scheme: dark) {
  :root { color-scheme: dark; }
  body {
    background: #0d0d0d; color: #ffffff;
    --surface-1: #1a1a19; --text-primary: #ffffff;
    --text-secondary: #c3c2b7; --muted: #898781; --grid: #2c2c2a;
    --baseline: #383835; --border: rgba(255,255,255,0.10);
    --series-1: #3987e5; --series-2: #d95926; --series-3: #199e70;
    --good: #0ca30c; --bad: #e66767; --warn-bg: #332a12;
    --warn-border: #fab219;
  }
}
h1 { font-size: 22px; margin: 0 0 4px; }
h2 { font-size: 16px; margin: 28px 0 10px; color: var(--text-primary); }
.sub { color: var(--text-secondary); font-size: 13px; margin-bottom: 18px; }
.warn {
  background: var(--warn-bg); border: 1px solid var(--warn-border);
  border-radius: 6px; padding: 10px 14px; margin: 14px 0; font-size: 13px;
}
.cards { display: flex; flex-wrap: wrap; gap: 12px; }
.card {
  background: var(--surface-1); border: 1px solid var(--border);
  border-radius: 8px; padding: 12px 16px; min-width: 150px;
}
.card .k { color: var(--text-secondary); font-size: 12px; }
.card .v { font-size: 20px; margin-top: 2px; }
.card .u { color: var(--muted); font-size: 12px; }
table {
  border-collapse: collapse; background: var(--surface-1);
  border: 1px solid var(--border); border-radius: 8px; font-size: 13px;
}
th, td { padding: 6px 12px; text-align: right;
         font-variant-numeric: tabular-nums; }
th { color: var(--text-secondary); font-weight: 600;
     border-bottom: 1px solid var(--grid); }
td:first-child, th:first-child { text-align: left;
                                 font-variant-numeric: normal; }
tr + tr td { border-top: 1px solid var(--grid); }
.ok { color: var(--good); }
.fail { color: var(--bad); font-weight: 600; }
.charts { display: flex; flex-wrap: wrap; gap: 16px; }
.chart {
  background: var(--surface-1); border: 1px solid var(--border);
  border-radius: 8px; padding: 10px 12px;
}
.chart .t { font-size: 13px; color: var(--text-primary);
            margin-bottom: 4px; }
.legend { font-size: 12px; color: var(--text-secondary); margin-top: 2px; }
.legend .sw { display: inline-block; width: 10px; height: 10px;
              border-radius: 2px; margin: 0 4px 0 10px;
              vertical-align: -1px; }
svg text { fill: var(--muted); font-size: 10px;
           font-variant-numeric: tabular-nums; }
"""

_SERIES_VARS = ("--series-1", "--series-2", "--series-3")


def _esc(s: Any) -> str:
    return _html.escape(str(s), quote=True)


def _fmt(v: Any, digits: int = 4) -> str:
    if isinstance(v, float):
        if v != v:                                  # NaN
            return "–"
        if v == 0:
            return "0"
        if abs(v) >= 1000:
            return f"{v:,.0f}"
        return f"{v:.{digits}g}"
    return str(v)


# ---- inline-SVG line chart -------------------------------------------------
def _downsample(xs: List[float], ys: List[float],
                limit: int = 600) -> Tuple[List[float], List[float]]:
    n = len(xs)
    if n <= limit:
        return xs, ys
    stride = n / limit
    idx = sorted({int(i * stride) for i in range(limit)} | {n - 1})
    return [xs[i] for i in idx], [ys[i] for i in idx]


def svg_chart(title: str, series: Sequence[Tuple[str, List[float],
                                                 List[float]]],
              *, width: int = 420, height: int = 160,
              y_unit: str = "") -> str:
    """One chart: ≤3 named series over a shared x (seconds) axis."""
    pad_l, pad_r, pad_t, pad_b = 44, 8, 6, 18
    iw, ih = width - pad_l - pad_r, height - pad_t - pad_b
    x_max = max((xs[-1] for _, xs, _ in series if xs), default=1.0) or 1.0
    y_max = max((max(ys) for _, _, ys in series if ys), default=1.0)
    y_max = y_max * 1.05 or 1.0

    def X(x: float) -> float:
        return pad_l + x / x_max * iw

    def Y(y: float) -> float:
        return pad_t + ih - y / y_max * ih

    parts = [f'<svg width="{width}" height="{height}" role="img" '
             f'aria-label="{_esc(title)}">']
    for frac in (0.5, 1.0):                       # hairline gridlines
        gy = Y(y_max / 1.05 * frac)
        parts.append(f'<line x1="{pad_l}" y1="{gy:.1f}" '
                     f'x2="{width - pad_r}" y2="{gy:.1f}" '
                     'stroke="var(--grid)" stroke-width="1"/>')
        parts.append(f'<text x="{pad_l - 4}" y="{gy + 3:.1f}" '
                     f'text-anchor="end">{_fmt(y_max / 1.05 * frac, 3)}'
                     '</text>')
    base_y = Y(0)
    parts.append(f'<line x1="{pad_l}" y1="{base_y:.1f}" '
                 f'x2="{width - pad_r}" y2="{base_y:.1f}" '
                 'stroke="var(--baseline)" stroke-width="1"/>')
    for xf in (0.0, 0.5, 1.0):                    # x ticks (seconds)
        parts.append(f'<text x="{X(x_max * xf):.1f}" '
                     f'y="{height - 4}" text-anchor="middle">'
                     f'{_fmt(x_max * xf, 3)}s</text>')
    for si, (_, xs, ys) in enumerate(series):
        if not xs:
            continue
        dxs, dys = _downsample(xs, ys)
        pts = " ".join(f"{X(x):.1f},{Y(y):.1f}"
                       for x, y in zip(dxs, dys))
        color = f"var({_SERIES_VARS[min(si, 2)]})"
        parts.append(f'<polyline points="{pts}" fill="none" '
                     f'stroke="{color}" stroke-width="2" '
                     'stroke-linejoin="round"/>')
    parts.append("</svg>")
    legend = ""
    if len(series) >= 2:
        legend = '<div class="legend">' + "".join(
            f'<span class="sw" style="background:'
            f'var({_SERIES_VARS[min(i, 2)]})"></span>{_esc(name)}'
            for i, (name, _, _) in enumerate(series)) + "</div>"
    if y_unit:
        title = f"{title} ({y_unit})"
    return (f'<div class="chart"><div class="t">{_esc(title)}</div>'
            + "".join(parts) + legend + "</div>")


# ---- record sections -------------------------------------------------------
_CARD_KEYS = [
    ("throughput_rps", "throughput", "req/s"),
    ("goodput_rps", "goodput", "req/s"),
    ("p99_s", "p99 latency", "s"),
    ("ttft_p99_s", "TTFT p99", "s"),
    ("tpot_p99_s", "TPOT p99", "s"),
    ("slo_attainment", "SLO attainment", ""),
    ("phase_slo_attainment", "phase SLO", ""),
    ("utilization", "utilization", ""),
    ("cost_per_1k_req", "cost / 1k req", "$"),
    ("sim_events_per_sec", "sim events/s", ""),
]

_PCT_COLS = [("p50_s", "p50"), ("p95_s", "p95"), ("p99_s", "p99"),
             ("mean_s", "mean"), ("ttft_p50_s", "TTFT p50"),
             ("ttft_p99_s", "TTFT p99"), ("tpot_p50_s", "TPOT p50"),
             ("tpot_p99_s", "TPOT p99")]

_STAGES = ["preprocess", "transmit", "queue", "batch_wait", "kv_transfer",
           "inference", "postprocess"]


def _record_label(rec: Dict[str, Any]) -> str:
    spec = rec.get("spec", {})
    bits = [str(rec.get("job_id", "run"))]
    arch = rec.get("arch") or rec.get("profile")
    if arch:
        bits.append(str(arch))
    hwd = rec.get("hardware")
    if hwd:
        bits.append(f"{hwd}×{rec.get('chips', 1)}")
    pol = rec.get("policy") or spec.get("software", {}).get("policy")
    if pol:
        bits.append(str(pol))
    return " · ".join(bits)


def _cards_html(res: Dict[str, Any]) -> str:
    cards = []
    for key, label, unit in _CARD_KEYS:
        v = res.get(key)
        if v is None:
            continue
        unit_s = f' <span class="u">{_esc(unit)}</span>' if unit else ""
        cards.append(f'<div class="card"><div class="k">{_esc(label)}'
                     f'</div><div class="v">{_fmt(v)}{unit_s}</div></div>')
    return f'<div class="cards">{"".join(cards)}</div>' if cards else ""


def _timeseries_html(ts: Timeseries) -> str:
    charts = []
    t = ts.times
    if not t:
        return ""
    charts.append(svg_chart("Queue depth (cluster total)",
                            [("queue", t, ts.total("queue_depth"))],
                            y_unit="requests"))
    arr, comp = ts.rate("arrivals"), ts.rate("completions")
    if any(arr) or any(comp):
        charts.append(svg_chart("Arrival vs completion rate",
                                [("arrivals", t, arr),
                                 ("completions", t, comp)],
                                y_unit="req/s"))
    occ = ts.total("batch_occupancy")
    if any(occ):
        charts.append(svg_chart("Batch occupancy (slots in use)",
                                [("slots", t, occ)]))
    if "kv_occupancy" in ts.gauges:
        charts.append(svg_chart("KV occupancy (mean fraction)",
                                [("kv", t, ts.total("kv_occupancy",
                                                    mean=True))]))
    live = [float(v) for v in ts.live_replicas]
    if live and (max(live) != min(live)):
        charts.append(svg_chart("Live replicas",
                                [("replicas", t, live)]))
    return f'<div class="charts">{"".join(charts)}</div>'


def _percentile_table(records: List[Dict[str, Any]]) -> str:
    rows = []
    for rec in records:
        res = rec.get("result", {})
        if not any(k in res for k, _ in _PCT_COLS):
            continue
        cells = "".join(f"<td>{_fmt(res.get(k, float('nan')))}</td>"
                        for k, _ in _PCT_COLS)
        rows.append(f"<tr><td>{_esc(_record_label(rec))}</td>{cells}</tr>")
    if not rows:
        return ""
    head = "".join(f"<th>{_esc(lbl)}</th>" for _, lbl in _PCT_COLS)
    return ("<h2>Latency percentiles (s)</h2><table><tr><th>run</th>"
            f"{head}</tr>{''.join(rows)}</table>")


def _stage_table(records: List[Dict[str, Any]]) -> str:
    rows = []
    for rec in records:
        st = rec.get("stages")
        if not st:
            continue
        cells = "".join(f"<td>{_fmt(st.get(k, 0.0))}</td>"
                        for k in _STAGES)
        rows.append(f"<tr><td>{_esc(_record_label(rec))}</td>{cells}</tr>")
    if not rows:
        return ""
    head = "".join(f"<th>{_esc(k)}</th>" for k in _STAGES)
    return ("<h2>Mean stage latency (s)</h2><table><tr><th>run</th>"
            f"{head}</tr>{''.join(rows)}</table>")


def _provenance_table(records: List[Dict[str, Any]]) -> str:
    rows = []
    for rec in records:
        res = rec.get("result", {})
        if "sim_events_per_sec" not in res and "events" not in res:
            continue
        rows.append(
            f"<tr><td>{_esc(_record_label(rec))}</td>"
            f"<td>{_fmt(res.get('events', float('nan')))}</td>"
            f"<td>{_fmt(res.get('requests_served', float('nan')))}</td>"
            f"<td>{_fmt(res.get('sim_events_per_sec', float('nan')))}</td>"
            f"<td>{_fmt(rec.get('benchmark_wall_s', float('nan')))}</td>"
            "</tr>")
    if not rows:
        return ""
    return ("<h2>Simulator provenance</h2><table><tr><th>run</th>"
            "<th>events</th><th>served</th><th>events/s</th>"
            f"<th>wall (s)</th></tr>{''.join(rows)}</table>")


# ---- baseline delta table --------------------------------------------------
def _compare_baseline(baseline: Dict[str, Any],
                      inputs: Dict[str, Dict[str, Any]]
                      ) -> List[Tuple[str, float, Optional[float],
                                      Optional[float], str]]:
    """Same semantics as ``benchmarks/check_regression.py`` (the gate);
    re-implemented here because the installed ``repro`` package cannot
    import the repo's ``benchmarks/`` scripts."""
    def get_path(node, path):
        for part in path.split("."):
            if not isinstance(node, dict) or part not in node:
                return None
            node = node[part]
        return node

    tol0 = float(baseline.get("default_tolerance", 0.15))
    rows = []
    for name, entry in baseline.get("metrics", {}).items():
        ns, _, path = name.partition(":")
        base = float(entry["value"])
        direction = entry.get("direction", "higher")
        tol = float(entry.get("tolerance", tol0))
        cur = get_path(inputs.get(ns), path)
        if cur is None:
            rows.append((name, base, None, None, "MISSING"))
            continue
        cur = float(cur)
        delta = (cur - base) / abs(base) if base != 0 else (
            0.0 if cur == 0 else float("inf"))
        worse = abs(delta) if direction == "near" else (
            -delta if direction == "higher" else delta)
        failed = worse > tol
        abs_tol = entry.get("abs_tolerance")
        if failed and abs_tol is not None:
            worse_abs = abs(cur - base) if direction == "near" else (
                (base - cur) if direction == "higher" else (cur - base))
            failed = worse_abs > float(abs_tol)
        rows.append((name, base, cur, delta,
                     "FAIL" if failed else "ok"))
    return rows


def _baseline_table(baseline: Dict[str, Any],
                    inputs: Dict[str, Dict[str, Any]]) -> str:
    rows = _compare_baseline(baseline, inputs)
    if not rows:
        return ""
    body = []
    for name, base, cur, delta, status in rows:
        cls = "ok" if status == "ok" else "fail"
        cur_s = _fmt(cur) if cur is not None else "–"
        delta_s = f"{delta:+.1%}" if delta is not None else "–"
        body.append(f"<tr><td>{_esc(name)}</td><td>{_fmt(base)}</td>"
                    f"<td>{cur_s}</td><td>{delta_s}</td>"
                    f'<td class="{cls}">{_esc(status)}</td></tr>')
    return ("<h2>Baseline deltas</h2><table><tr><th>metric</th>"
            "<th>baseline</th><th>current</th><th>delta</th><th>status"
            f"</th></tr>{''.join(body)}</table>")


# ---- top-level render ------------------------------------------------------
def render_report(records: Sequence[Dict[str, Any]], *,
                  title: str = "Benchmark run report",
                  baseline: Optional[Dict[str, Any]] = None,
                  bench_inputs: Optional[Dict[str, Dict[str, Any]]] = None
                  ) -> str:
    records = list(records)
    parts = [f"<h1>{_esc(title)}</h1>",
             f'<div class="sub">{len(records)} PerfDB record(s)</div>']
    sampled = [rec for rec in records
               if rec.get("result", {}).get("sampling_rate", 1.0)
               < 1.0 - 1e-9]
    if sampled:
        rates = ", ".join(
            f"{_esc(rec.get('job_id', '?'))}: "
            f"{rec['result']['sampling_rate']:.1%}" for rec in sampled)
        parts.append(
            '<div class="warn">⚠ Per-request traces were <b>sampled</b> '
            f"(trace_sample &lt; 1) — {rates}. Percentiles and the span "
            "timeline cover the sampled subset; counting aggregates are "
            "exact.</div>")
    for rec in records:
        res = rec.get("result", {})
        parts.append(f"<h2>{_esc(_record_label(rec))}</h2>")
        parts.append(_cards_html(res))
        ts_dict = res.get("timeseries") or rec.get("timeseries")
        if ts_dict:
            parts.append(_timeseries_html(Timeseries.from_dict(ts_dict)))
    parts.append(_percentile_table(records))
    parts.append(_stage_table(records))
    parts.append(_provenance_table(records))
    if baseline is not None:
        parts.append(_baseline_table(baseline, bench_inputs or {}))
    body = "\n".join(p for p in parts if p)
    return ("<!DOCTYPE html>\n<html><head><meta charset=\"utf-8\">"
            f"<title>{_esc(title)}</title><style>{_CSS}</style></head>"
            f"<body>\n{body}\n</body></html>\n")


def write_report(records: Sequence[Dict[str, Any]], path: str, *,
                 title: str = "Benchmark run report",
                 baseline: Optional[Dict[str, Any]] = None,
                 bench_inputs: Optional[Dict[str, Dict[str, Any]]] = None
                 ) -> str:
    out = render_report(records, title=title, baseline=baseline,
                        bench_inputs=bench_inputs)
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(out)
    return str(p)


def load_records(path: str) -> List[Dict[str, Any]]:
    """Read PerfDB JSONL (or a JSON list) into record dicts."""
    text = Path(path).read_text()
    stripped = text.lstrip()
    if stripped.startswith("["):
        return list(json.loads(stripped))
    return [json.loads(line) for line in text.splitlines() if line.strip()]


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a static HTML report from PerfDB records")
    ap.add_argument("perfdb", nargs="+",
                    help="PerfDB JSONL file(s) (or JSON record lists)")
    ap.add_argument("-o", "--out", default="report.html",
                    help="output HTML path (default report.html)")
    ap.add_argument("--title", default="Benchmark run report")
    ap.add_argument("--baseline", default=None,
                    help="ci_baseline.json for the delta table")
    ap.add_argument("--bench", action="append", default=[],
                    metavar="NAME=PATH",
                    help="bench --json dump for the delta table "
                         "(repeatable; namespaces match the baseline)")
    args = ap.parse_args(argv)

    records: List[Dict[str, Any]] = []
    for path in args.perfdb:
        records.extend(load_records(path))
    baseline = None
    if args.baseline:
        baseline = json.loads(Path(args.baseline).read_text())
    bench_inputs: Dict[str, Dict[str, Any]] = {}
    for item in args.bench:
        name, _, path = item.partition("=")
        if not path:
            ap.error(f"--bench {item!r} is not NAME=PATH")
        bench_inputs[name] = json.loads(Path(path).read_text())
    write_report(records, args.out, title=args.title, baseline=baseline,
                 bench_inputs=bench_inputs)
    print(f"wrote {args.out} ({len(records)} record(s))")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
