"""Serving observability: span timelines, time-series metrics, reports.

Opt-in via ``ObsSpec`` on ``ClusterSpec``/``BenchmarkJobSpec`` — with it
unset (the default) the simulator's fast path is untouched and golden
summaries stay byte-identical.  See the README "Observability" section.
"""
from repro.obs.recorder import EngineSpan, MetricsRecorder, Timeseries
from repro.obs.spec import ObsSpec
from repro.obs.timeline import build_trace, request_stage_spans, write_trace

__all__ = [
    "ObsSpec", "MetricsRecorder", "Timeseries", "EngineSpan",
    "build_trace", "write_trace", "request_stage_spans",
    "render_report", "write_report",
]


def __getattr__(name):
    # lazy so `python -m repro.obs.report` doesn't import the module
    # twice (runpy would warn about the package-level binding)
    if name in ("render_report", "write_report"):
        from repro.obs import report
        return getattr(report, name)
    raise AttributeError(name)
