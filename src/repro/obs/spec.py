"""ObsSpec — the opt-in switch for the serving observability layer.

Observability is strictly *observational*: enabling it must never move a
simulated number.  The spec therefore only controls what gets recorded
and how densely it is sampled; the engines and the event loop behave
byte-identically with it on or off (asserted by the golden-summary
tests in ``tests/test_obs.py``).

``ObsSpec`` hangs off ``ClusterSpec.obs`` (and, for the declarative
path, ``BenchmarkJobSpec.obs``).  ``None`` — the default everywhere —
keeps the fast path untouched: no recorder is constructed, no hook
fires, and seeded golden summaries stay byte-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

# sampling the gauges more often than this many times per run buys no
# insight and bloats the persisted time-series; the auto interval and
# the explicit-interval floor both respect it
MAX_SAMPLES_PER_RUN = 100_000
# auto interval: ~this many ticks across the workload window
AUTO_TICKS = 200
# fallback tick for workloads with no declared window (trace replay)
DEFAULT_INTERVAL_S = 0.05


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """What the observability layer records for one run.

    ``timeseries``        — drive the ``MetricsRecorder``: fixed-tick
                            gauges (queue depth, batch/KV occupancy,
                            live replicas) + cumulative counters
                            (arrivals, completions, preemptions),
                            sliceable per replica / pool / tenant and
                            attached to ``SimResult.timeseries``.
    ``timeline``          — collect per-engine iteration/batch activity
                            spans so ``repro.obs.timeline`` can export a
                            Chrome-trace JSON with engine lanes next to
                            the per-request stage spans (which are
                            derived from ``RequestTrace`` and need no
                            recording).
    ``sample_interval_s`` — gauge sampling tick; 0 (default) derives it
                            from the workload window (~200 ticks/run,
                            50 ms for windowless trace replay).
    """
    timeseries: bool = True
    timeline: bool = True
    sample_interval_s: float = 0.0

    def __post_init__(self):
        if self.sample_interval_s < 0:
            raise ValueError("ObsSpec.sample_interval_s must be >= 0 "
                             f"(got {self.sample_interval_s}; 0 = auto)")

    @property
    def enabled(self) -> bool:
        return self.timeseries or self.timeline

    def resolve_interval(self, window_s: float) -> float:
        """Concrete sampling tick for a run with the given workload
        window (0 = no declared window, e.g. trace replay)."""
        if self.sample_interval_s > 0:
            interval = self.sample_interval_s
        elif window_s > 0:
            interval = window_s / AUTO_TICKS
        else:
            interval = DEFAULT_INTERVAL_S
        if window_s > 0:
            # hard cap on ticks per run, whatever the caller asked for
            interval = max(interval, window_s / MAX_SAMPLES_PER_RUN)
        return interval

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ObsSpec":
        return cls(**dict(d))
