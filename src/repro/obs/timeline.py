"""Chrome-trace timeline export (``chrome://tracing`` / Perfetto).

Per-request stage spans are *derived* from ``RequestTrace`` after the
run — the simulator already records every stage duration, so the hot
loop pays nothing for them.  Per-engine activity spans (continuous-
batching iterations, request-level batch occupations) come from the
``MetricsRecorder`` engine hooks and need ``ObsSpec.timeline`` on.

Lane layout (one Chrome-trace *process* per replica):

  pid = replica_id + 1      process_name "replica 3 · decode"
    tid 0                   "engine" — iteration/batch activity spans
    tid req_id + 1          "req 17 · tenantA" — that request's stages:
                            preprocess → transmit → queue (batch-wait
                            nested at its tail) → prefill → kv-transfer
                            → decode → postprocess

Span derivation is anchored at both ends of the trace: queue duration
is exactly ``t_queue``; for non-preempted requests the prefill and
decode spans partition ``t_inference`` exactly (asserted by the
reconciliation test).  Preempted/migrated requests interleave wait and
service segments the trace only stores as totals, so their interior
boundaries are clamped (never negative, never past ``done_s``) while
the end-to-end extent stays exact.

Under ``trace_sample < 1`` the timeline is a *sample*: an explicit
``sampling_rate`` counter track rides along (and ``metadata.
sampling_rate`` is set) so a partial picture is never mistaken for the
full run — the HTML report surfaces the same warning.

No runtime imports from ``repro.serving`` (results are duck-typed), so
obs stays a leaf the serving layer may import freely.
"""
from __future__ import annotations

import json
from typing import TYPE_CHECKING, Any, Dict, List, Optional

if TYPE_CHECKING:                           # pragma: no cover
    from repro.serving.simulator import RequestTrace, SimResult

US = 1e6                                    # trace timestamps are in µs

# stage → lane color hint (trace-viewer reserved color names)
_STAGE_CNAME = {
    "preprocess": "grey",
    "transmit": "thread_state_runnable",
    "queue": "bad",
    "batch_wait": "terrible",
    "prefill": "thread_state_running",
    "kv_transfer": "yellow",
    "decode": "good",
    "postprocess": "grey",
}


def _event(name: str, start_s: float, end_s: float, pid: int, tid: int,
           args: Optional[Dict[str, Any]] = None,
           cname: Optional[str] = None) -> Dict[str, Any]:
    ev = {"name": name, "ph": "X", "ts": round(start_s * US, 3),
          "dur": round(max(end_s - start_s, 0.0) * US, 3),
          "pid": pid, "tid": tid, "cat": "sim"}
    if args:
        ev["args"] = args
    if cname:
        ev["cname"] = cname
    return ev


def _meta(name: str, pid: int, value: str,
          tid: Optional[int] = None) -> Dict[str, Any]:
    ev: Dict[str, Any] = {"name": name, "ph": "M", "pid": pid,
                          "args": {"name": value}}
    if tid is not None:
        ev["tid"] = tid
    return ev


def request_stage_spans(tr: "RequestTrace") -> List[Dict[str, Any]]:
    """The (name, start_s, end_s) stage spans of one request, derived
    from its trace.  Pure timing — no pid/tid assignment."""
    arr = tr.request.arrival_s
    enqueue = arr + tr.t_preprocess + tr.t_transmit
    svc_start = enqueue + tr.t_queue
    svc_end = tr.done_s - tr.t_postprocess
    spans = [
        ("preprocess", arr, arr + tr.t_preprocess),
        ("transmit", arr + tr.t_preprocess, enqueue),
        ("queue", enqueue, svc_start),
    ]
    if tr.t_batch_wait > 0:
        # the policy-attributable tail of the queue wait, nested inside it
        spans.append(("batch_wait", svc_start - tr.t_batch_wait, svc_start))
    ft = tr.first_token_s
    if ft > 0.0:
        if ft > svc_start:
            spans.append(("prefill", svc_start, min(ft, svc_end)))
        kv_end = ft
        if tr.t_kv_transfer > 0:
            kv_end = min(ft + tr.t_kv_transfer, svc_end)
            spans.append(("kv_transfer", ft, kv_end))
        if svc_end > kv_end:
            spans.append(("decode", kv_end, svc_end))
    elif svc_end > svc_start:
        spans.append(("inference", svc_start, svc_end))
    if tr.t_postprocess > 0:
        spans.append(("postprocess", svc_end, tr.done_s))
    return [(n, s, max(e, s)) for n, s, e in spans]


def build_trace(result: "SimResult", *, title: str = "",
                max_requests: int = 0) -> Dict[str, Any]:
    """Chrome-trace dict for one ``SimResult``.

    ``max_requests`` > 0 caps the request lanes (earliest arrivals
    kept) for very large runs; engine lanes and the counter tracks are
    never capped.
    """
    events: List[Dict[str, Any]] = []
    pools: Dict[int, str] = {}
    # ---- engine activity lanes (needs ObsSpec.timeline) -------------------
    for sp in (result.engine_spans or []):
        pools.setdefault(sp.replica, sp.pool)
        events.append(_event(
            sp.kind, sp.start_s, sp.end_s, sp.replica + 1, 0,
            args={"batch": sp.batch, "n_prefill": sp.n_prefill}))
    # ---- per-request stage lanes (derived from RequestTrace) --------------
    traces = sorted(result.traces, key=lambda t: t.request.arrival_s)
    if max_requests > 0:
        traces = traces[:max_requests]
    for tr in traces:
        pid = tr.replica + 1
        tid = tr.request.req_id + 1
        tenant = tr.request.tenant
        label = f"req {tr.request.req_id}" + (f" · {tenant}" if tenant
                                              else "")
        events.append(_meta("thread_name", pid, label, tid=tid))
        args = {"req_id": tr.request.req_id,
                "prompt_tokens": tr.request.prompt_tokens,
                "tokens_out": tr.tokens_out,
                "batch_size": tr.batch_size,
                "preemptions": tr.preemptions}
        if tenant:
            args["tenant"] = tenant
        for name, start, end in request_stage_spans(tr):
            events.append(_event(name, start, end, pid, tid, args=args,
                                 cname=_STAGE_CNAME.get(name)))
    # ---- process metadata -------------------------------------------------
    pids = sorted({ev["pid"] for ev in events})
    for pid in pids:
        pool = pools.get(pid - 1, "serve")
        events.append(_meta("process_name", pid,
                            f"replica {pid - 1} · {pool}"))
        events.append({"name": "process_sort_index", "ph": "M", "pid": pid,
                       "args": {"sort_index": pid}})
        events.append(_meta("thread_name", pid, "engine", tid=0))
    # ---- sampling disclosure ----------------------------------------------
    served = result.requests_served or len(result.traces)
    rate = len(result.traces) / served if served else 1.0
    metadata: Dict[str, Any] = {"requests_recorded": len(result.traces),
                                "requests_served": served,
                                "sampling_rate": rate,
                                "duration_s": result.duration_s}
    if title:
        metadata["title"] = title
    if rate < 1.0 - 1e-9 and pids:
        # explicit counter track: a sampled timeline must say so
        pid0 = pids[0]
        for t in (0.0, result.duration_s):
            events.append({"name": "sampling_rate", "ph": "C",
                           "ts": round(t * US, 3), "pid": pid0,
                           "args": {"rate": round(rate, 6)}})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "metadata": metadata}


def write_trace(result: "SimResult", path: str, *, title: str = "",
                max_requests: int = 0) -> str:
    """Write the Chrome-trace JSON for ``result`` to ``path`` (load it
    at https://ui.perfetto.dev or chrome://tracing); returns the path."""
    trace = build_trace(result, title=title, max_requests=max_requests)
    with open(path, "w") as f:
        json.dump(trace, f)
    return str(path)
