"""MetricsRecorder — fixed-tick time-series capture for the cluster loop.

The recorder rides the indexed event loop: between any two events the
cluster's state is constant, so sampling every gauge at the tick times
that fall inside that interval is *exact*, not approximate.  The event
loop calls :meth:`sample_ticks` once per pass (before it processes the
events due at the new clock value), and :meth:`finish` once at the end
to flush the remaining ticks and take a final sample at ``duration``.

Gauges (per replica, summable per pool / cluster):
  queue_depth      — requests waiting in the replica's queue
  batch_occupancy  — decode slots in use (continuous engines) or 1/0
                     busy flag (request-level engines)
  kv_occupancy     — resident KV blocks / total blocks (memory-modeled
                     runs only)
  prefix_hit_rate  — cumulative prefix-cache hit-token fraction

Cluster gauges: ``live_replicas`` (non-retired engines — the series
whose step integral reconciles with ``SimResult.replica_seconds``).

Counters (cumulative, snapshotted at each tick; also split per tenant):
  arrivals, completions, preemptions.

Everything lands in a :class:`Timeseries`, a plain JSON-serializable
container attached to ``SimResult.timeseries`` and persisted through
PerfDB records, with slicing helpers (``total`` / ``replica`` /
``pool`` / ``rate``).

This module deliberately imports nothing from ``repro.serving`` — the
engines it samples are duck-typed (``queue``/``active``/``kv``/
``retired``/``replica_id``), which keeps the dependency arrow pointing
serving → obs only.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

EPS = 1e-12

GAUGE_NAMES = ("queue_depth", "batch_occupancy", "kv_occupancy",
               "prefix_hit_rate")
COUNTER_NAMES = ("arrivals", "completions", "preemptions")


@dataclasses.dataclass(slots=True)
class EngineSpan:
    """One engine service span (a continuous-batching iteration or a
    request-level batch occupation), recorded by the ``ReplicaEngine``
    begin/end hooks for the Chrome-trace timeline."""
    replica: int
    pool: str               # "serve" (flat), "prefill"/"decode"
                            # (disagg), or the PoolSpec name (fleet)
    start_s: float
    end_s: float
    kind: str               # iteration | batch
    batch: int              # decode slots in use / batch size
    n_prefill: int = 0      # prefills admitted this iteration


@dataclasses.dataclass
class Timeseries:
    """The recorded run trajectory (JSON-serializable, PerfDB-persisted).

    ``gauges[name][replica_id_str]`` and all counter lists are aligned
    with ``times`` (one value per tick; replicas spawned mid-run are
    zero-padded back to t=0).  Counters are cumulative; use ``rate`` for
    per-second derivatives.
    """
    interval_s: float
    times: List[float]
    live_replicas: List[int]
    gauges: Dict[str, Dict[str, List[float]]]
    counters: Dict[str, List[int]]
    tenant_counters: Dict[str, Dict[str, List[int]]]
    replica_pool: Dict[str, str]

    # ---- slicing ----------------------------------------------------------
    def replicas(self) -> List[str]:
        ids = set()
        for series in self.gauges.values():
            ids.update(series)
        return sorted(ids, key=int)

    def pools(self) -> List[str]:
        return sorted(set(self.replica_pool.values()))

    def replica(self, gauge: str, replica_id) -> List[float]:
        return list(self.gauges.get(gauge, {}).get(str(replica_id), []))

    def total(self, gauge: str, *, pool: Optional[str] = None,
              mean: bool = False) -> List[float]:
        """Sum (or mean) of a gauge across replicas, optionally only the
        replicas of one pool — ``"prefill"``/``"decode"`` for a
        disaggregated cluster, ``"serve"`` for a flat one, or any
        named ``PoolSpec`` of a heterogeneous fleet."""
        series = self.gauges.get(gauge, {})
        cols = [v for rid, v in series.items()
                if pool is None or self.replica_pool.get(rid) == pool]
        if not cols:
            return [0.0] * len(self.times)
        out = [float(sum(vals)) for vals in zip(*cols)]
        if mean:
            out = [v / len(cols) for v in out]
        return out

    def counter(self, name: str, *, tenant: Optional[str] = None
                ) -> List[int]:
        if tenant is not None:
            return list(self.tenant_counters.get(name, {}).get(tenant, []))
        return list(self.counters.get(name, []))

    def counter_total(self, name: str, *, tenant: Optional[str] = None
                      ) -> int:
        c = self.counter(name, tenant=tenant)
        return int(c[-1]) if c else 0

    def tenants(self) -> List[str]:
        names = set()
        for per in self.tenant_counters.values():
            names.update(per)
        return sorted(names)

    def rate(self, name: str, *, tenant: Optional[str] = None
             ) -> List[float]:
        """Per-second rate of a cumulative counter (length == times;
        the first point covers [0, times[0]])."""
        c = self.counter(name, tenant=tenant)
        out: List[float] = []
        prev_t = prev_v = 0.0
        for t, v in zip(self.times, c):
            dt = t - prev_t
            out.append((v - prev_v) / dt if dt > EPS else 0.0)
            prev_t, prev_v = t, v
        return out

    def live_replica_integral(self) -> float:
        """∫ live_replicas dt under the step-function reading (each
        sample holds until the next tick) — reconciles with
        ``SimResult.replica_seconds`` to within one tick per scaling
        event."""
        total = 0.0
        for i in range(len(self.times) - 1):
            total += self.live_replicas[i] * (self.times[i + 1]
                                              - self.times[i])
        return total

    # ---- (de)serialization ------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Timeseries":
        return cls(interval_s=float(d["interval_s"]),
                   times=list(d["times"]),
                   live_replicas=list(d["live_replicas"]),
                   gauges={g: {r: list(v) for r, v in series.items()}
                           for g, series in d.get("gauges", {}).items()},
                   counters={k: list(v)
                             for k, v in d.get("counters", {}).items()},
                   tenant_counters={k: {t: list(v) for t, v in per.items()}
                                    for k, per in
                                    d.get("tenant_counters", {}).items()},
                   replica_pool=dict(d.get("replica_pool", {})))


class MetricsRecorder:
    """Counters + tick-sampled gauges for one ``simulate_cluster`` run.

    Hot-path cost with the recorder attached is one attribute increment
    per arrival/completion/preemption, one float comparison per event-
    loop pass, and one O(replicas) scan per *tick* (not per event) —
    the ``sim_obs_overhead_frac`` bench gate holds it under 5%.
    """

    def __init__(self, spec, interval_s: float):
        self.spec = spec
        self.interval_s = interval_s
        self.next_tick = 0.0
        self.record_spans = bool(spec.timeline)
        self.spans: List[EngineSpan] = []
        self.replica_pool: Dict[str, str] = {}
        # counters (ints bumped by the loop/engine hooks)
        self.arrivals = 0
        self.completions = 0
        self.preemptions = 0
        self._tenant_counts: Dict[str, Dict[str, int]] = {}
        # tick-aligned storage
        self._times: List[float] = []
        self._live: List[int] = []
        self._gauges: Dict[str, Dict[str, List[float]]] = {
            g: {} for g in GAUGE_NAMES}
        self._counters: Dict[str, List[int]] = {c: []
                                                for c in COUNTER_NAMES}
        self._tenant_samples: Dict[str, Dict[str, List[int]]] = {
            c: {} for c in ("arrivals", "completions")}
        # per-replica column refs, resolved once per replica instead of
        # per gauge per tick — tick sampling is the recorder's only
        # O(replicas) hot path, and it must stay inside the ≤5%
        # sim_obs_overhead_frac bench gate
        self._cols: Dict[int, tuple] = {}

    # ---- registration / counter hooks (called by the event loop) ----------
    def register_engine(self, replica_id: int, pool: str) -> None:
        self.replica_pool[str(replica_id)] = pool

    def count_arrival(self, tenant: str = "") -> None:
        self.arrivals += 1
        if tenant:
            self._tenant_counts.setdefault(
                tenant, {"arrivals": 0, "completions": 0})["arrivals"] += 1

    def count_completion(self, tenant: str = "") -> None:
        self.completions += 1
        if tenant:
            self._tenant_counts.setdefault(
                tenant,
                {"arrivals": 0, "completions": 0})["completions"] += 1

    def count_preemption(self) -> None:
        self.preemptions += 1

    def engine_span(self, replica: int, start_s: float, end_s: float,
                    kind: str, batch: int, n_prefill: int = 0) -> None:
        """Engine begin/end hook (no-op unless the timeline is on)."""
        if self.record_spans:
            self.spans.append(EngineSpan(
                replica=replica,
                pool=self.replica_pool.get(str(replica), "serve"),
                start_s=start_s, end_s=end_s, kind=kind, batch=batch,
                n_prefill=n_prefill))

    # ---- tick sampling ----------------------------------------------------
    def _append(self, store: Dict[str, List], key: str, value,
                fill=0) -> None:
        col = store.get(key)
        if col is None:
            col = store[key] = []
        n = len(self._times)
        if len(col) < n - 1:        # spawned/seen mid-run: pad back to t=0
            col.extend([fill] * (n - 1 - len(col)))
        col.append(value)

    def _new_cols(self, e, n: int) -> tuple:
        """Column lists for a replica first seen at tick index ``n``
        (zero-padded back to t=0)."""
        rid = str(e.replica_id)
        g = self._gauges
        q_col = g["queue_depth"][rid] = [0.0] * n
        occ_col = g["batch_occupancy"][rid] = [0.0] * n
        kv_col = hit_col = None
        if e.kv is not None:
            kv_col = g["kv_occupancy"][rid] = [0.0] * n
            hit_col = g["prefix_hit_rate"][rid] = [0.0] * n
        cols = (q_col, occ_col, kv_col, hit_col)
        self._cols[e.replica_id] = cols
        return cols

    def _sample(self, t: float, engines) -> None:
        n = len(self._times)
        self._times.append(t)
        live = 0
        get_cols = self._cols.get
        for e in engines:
            if not e.retired:
                live += 1
            cols = get_cols(e.replica_id)
            if cols is None:
                cols = self._new_cols(e, n)
            q_col, occ_col, kv_col, hit_col = cols
            q_col.append(float(len(e.queue)))
            if e.continuous:
                occ_col.append(float(len(e.active)))
            else:
                occ_col.append(1.0 if e.server_free_at > t + EPS else 0.0)
            if kv_col is not None:
                kv = e.kv
                kv_col.append(kv.resident_blocks / kv.total_blocks)
                served = kv.hit_tokens + kv.miss_tokens
                hit_col.append(kv.hit_tokens / served if served else 0.0)
        self._live.append(live)
        self._counters["arrivals"].append(self.arrivals)
        self._counters["completions"].append(self.completions)
        self._counters["preemptions"].append(self.preemptions)
        for tenant, counts in self._tenant_counts.items():
            for cname in ("arrivals", "completions"):
                self._append(self._tenant_samples[cname], tenant,
                             counts[cname])

    def sample_ticks(self, t_limit: float, engines) -> None:
        """Sample every tick strictly before ``t_limit`` (the event
        loop's next clock value): state is constant on the open interval
        since the last processed event, so those samples are exact."""
        while self.next_tick < t_limit - EPS:
            self._sample(self.next_tick, engines)
            self.next_tick += self.interval_s

    def finish(self, duration_s: float, engines) -> None:
        """Flush remaining ticks and close with a sample at exactly
        ``duration_s`` (so drained queues are visibly drained and the
        live-replica step integral covers the whole run)."""
        self.sample_ticks(duration_s, engines)
        if not self._times or self._times[-1] < duration_s - EPS:
            self._sample(duration_s, engines)

    # ---- result -----------------------------------------------------------
    def build(self) -> Timeseries:
        n = len(self._times)

        def pad(store):
            for col in store.values():
                if len(col) < n:
                    col.extend([0] * (n - len(col)))
            return store

        gauges = {g: pad(series) for g, series in self._gauges.items()
                  if series}
        return Timeseries(
            interval_s=self.interval_s,
            times=self._times,
            live_replicas=self._live,
            gauges=gauges,
            counters=self._counters,
            tenant_counters={c: pad(per) for c, per in
                             self._tenant_samples.items() if per},
            replica_pool=dict(self.replica_pool))
