"""Synthetic trace scaler: grow a small seed trace to production volume.

Replaying a recorded trace is the most realistic workload the simulator
has — but recorded traces are small, and the planner's questions are
about millions-of-users volume.  ``scale_trace`` takes a seed JSONL
trace (the schema in ``configs/traces/README.md``) and emits a trace
``factor×`` larger over the same time window, preserving the three
properties that make a trace *realistic*:

  * **interarrival burstiness** — output interarrival gaps are a
    bootstrap resample of the seed's empirical gaps, compressed by
    ``factor``; the coefficient of variation (CV, the standard
    burstiness statistic) is preserved by construction, where naive
    Poisson superposition would wash it out toward CV = 1;
  * **session-length distribution** — whole seed sessions are cloned as
    templates, so the requests-per-session distribution (and each
    request's prompt/output/payload columns) is resampled, not
    re-modeled;
  * **prefix-sharing structure** — each cloned session keeps its seed
    session's ``prefix_tokens`` pattern under a fresh session id, so
    prefix-cache hit rates scale the way real multiplied traffic would.

The output is plain rows (list of dicts) — ``write_trace_rows`` emits
replayable JSONL for ``WorkloadSpec(kind="trace", trace_path=...)``.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List, Sequence, Union

import numpy as np

RowsOrPath = Union[str, Path, Sequence[Dict[str, Any]]]


def load_trace_rows(path: Union[str, Path]) -> List[Dict[str, Any]]:
    """Parse a JSONL trace file into rows (comments/blank lines skipped,
    sorted by arrival)."""
    rows = []
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        rows.append(json.loads(line))
    rows.sort(key=lambda d: float(d["arrival_s"]))
    return rows


def write_trace_rows(rows: Sequence[Dict[str, Any]],
                     path: Union[str, Path],
                     header: str = "") -> Path:
    """Emit rows as replayable JSONL (optional ``#`` header comment)."""
    path = Path(path)
    lines = [f"# {header}"] if header else []
    lines += [json.dumps(r) for r in rows]
    path.write_text("\n".join(lines) + "\n")
    return path


def _coerce_rows(rows: RowsOrPath) -> List[Dict[str, Any]]:
    if isinstance(rows, (str, Path)):
        return load_trace_rows(rows)
    return sorted((dict(r) for r in rows),
                  key=lambda d: float(d["arrival_s"]))


def _sessions(rows: Sequence[Dict[str, Any]]) -> List[List[Dict[str, Any]]]:
    """Group rows by session id, preserving each session's row order."""
    by_id: Dict[Any, List[Dict[str, Any]]] = {}
    for r in rows:
        by_id.setdefault(r.get("session_id", 0), []).append(r)
    return list(by_id.values())


def trace_stats(rows: RowsOrPath) -> Dict[str, float]:
    """The preservation statistics the scaler is judged by.

    ``interarrival_cv`` is std/mean of the aggregate arrival gaps (1.0
    for a Poisson process, higher = burstier); session lengths count
    requests per session.
    """
    rows = _coerce_rows(rows)
    times = np.array([float(r["arrival_s"]) for r in rows])
    deltas = np.diff(times)
    lens = np.array([len(s) for s in _sessions(rows)], dtype=float)
    mean_gap = float(deltas.mean()) if len(deltas) else 0.0
    return {
        "requests": float(len(rows)),
        "sessions": float(len(lens)),
        "duration_s": float(times[-1] - times[0]) if len(times) else 0.0,
        "mean_interarrival_s": mean_gap,
        "interarrival_cv": (float(deltas.std() / mean_gap)
                            if mean_gap > 0 else 0.0),
        "session_len_p50": float(np.percentile(lens, 50)) if len(lens)
        else 0.0,
        "session_len_p95": float(np.percentile(lens, 95)) if len(lens)
        else 0.0,
        "mean_prompt_tokens": float(np.mean(
            [r.get("prompt_tokens", 0) for r in rows])) if rows else 0.0,
        "mean_prefix_tokens": float(np.mean(
            [r.get("prefix_tokens", 0) for r in rows])) if rows else 0.0,
    }


def scale_trace(seed: RowsOrPath, factor: float, *,
                seed_rng: int = 0) -> List[Dict[str, Any]]:
    """Scale a seed trace ``factor×`` in volume over the same window.

    Arrival times are a cumulative sum of gaps bootstrapped from the
    seed's empirical interarrival distribution and divided by
    ``factor`` (CV-preserving rate scale-up).  Requests are drawn from
    cloned seed sessions: each clone keeps its template's row sequence
    (prompt/output/payload/prefix/tenant columns) under a fresh session
    id, and its requests take arrival slots in template order so
    within-session causality holds.
    """
    rows = _coerce_rows(seed)
    if len(rows) < 2:
        raise ValueError("seed trace needs at least 2 requests to carry "
                         "an interarrival distribution")
    if factor <= 0:
        raise ValueError(f"scale factor must be > 0 (got {factor})")
    rng = np.random.default_rng(seed_rng)
    times = np.array([float(r["arrival_s"]) for r in rows])
    t0 = times[0]
    deltas = np.diff(times)
    templates = _sessions(rows)
    n_out = max(int(round(len(rows) * factor)), 1)

    # clone whole sessions until the request budget is covered; the last
    # clone is truncated to land exactly on n_out (negligible bias at
    # any real factor)
    slots: List[tuple] = []          # (new_session_id, template_row)
    sid = 0
    while len(slots) < n_out:
        tmpl = templates[rng.integers(0, len(templates))]
        for row in tmpl:
            if len(slots) >= n_out:
                break
            slots.append((sid, row))
        sid += 1

    # aggregate arrival times: bootstrapped gaps, compressed by factor
    gaps = rng.choice(deltas, size=n_out) / factor
    out_times = t0 + np.cumsum(gaps)

    # interleave sessions across the timeline, then hand each session's
    # requests its assigned times in ascending order (template order ==
    # time order within a session)
    order = rng.permutation(n_out)
    rows_by_sid: Dict[int, List[Dict[str, Any]]] = {}
    assigned: Dict[int, List[int]] = {}
    for slot_idx, (s, row) in enumerate(slots):
        rows_by_sid.setdefault(s, []).append(row)
        assigned.setdefault(s, []).append(int(order[slot_idx]))
    out: List[Dict[str, Any]] = []
    for new_sid, time_idxs in assigned.items():
        time_idxs.sort()
        for tmpl_row, ti in zip(rows_by_sid[new_sid], time_idxs):
            row = dict(tmpl_row)
            row["arrival_s"] = round(float(out_times[ti]), 6)
            row["session_id"] = new_sid
            out.append(row)
    out.sort(key=lambda r: r["arrival_s"])
    return out
