"""Production arrival processes: diurnal cycles, flash crowds, and
scale-to-saturation sweeps.

These layer under the existing ``WorkloadSpec`` kinds — ``generate``
dispatches ``kind="diurnal" | "flash-crowd" | "sweep"`` here, so every
consumer (single-replica simulator, cluster loop, planner, benches)
gets them for free.  The non-homogeneous kinds sample by Lewis–Shedler
thinning: candidates are drawn from a homogeneous Poisson process at
the envelope rate ``λ_max`` and accepted with probability
``λ(t)/λ_max``, which is exact and stays deterministic under the
workload's seed.

  diurnal      λ(t) = rate · (1 + amplitude · sin(2πt/period))
               — the day/night cycle every consumer service sees,
               compressed to simulation scale via ``diurnal_period_s``.
  flash-crowd  λ(t) = rate, then at ``flash_start_s`` a spike to
               ``rate · burst_factor`` decaying exponentially with time
               constant ``flash_decay_s`` — the retweet/incident shape.
  sweep        geometric rate steps from ``ramp_min_rate`` to
               ``ramp_max_rate`` (the existing ``ramp`` is linear) —
               doubling toward saturation covers decades of load with
               few steps, the shape capacity sweeps actually use.

``mean_rate`` returns the time-averaged λ of any kind analytically, so
a bench can compare a flash crowd against a steady Poisson stream *at
equal mean rate* — same offered work, different burstiness.
"""
from __future__ import annotations

import math
from typing import List

import numpy as np


def _thinned_times(rng: np.random.Generator, lam, lam_max: float,
                   duration_s: float) -> List[float]:
    """Lewis–Shedler thinning of a rate function ``lam(t)`` under the
    envelope ``lam_max`` over [0, duration)."""
    times: List[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / lam_max)
        if t >= duration_s:
            return times
        if rng.random() < lam(t) / lam_max:
            times.append(t)


def diurnal_rate(spec, t: float) -> float:
    """Instantaneous λ(t) of the diurnal cycle."""
    return spec.rate * (1.0 + spec.diurnal_amplitude
                        * math.sin(2.0 * math.pi * t
                                   / spec.diurnal_period_s))


def diurnal_times(spec, rng: np.random.Generator) -> List[float]:
    lam_max = spec.rate * (1.0 + spec.diurnal_amplitude)
    return _thinned_times(rng, lambda t: diurnal_rate(spec, t), lam_max,
                          spec.duration_s)


def flash_params(spec) -> tuple:
    """(start_s, decay_s) with the spec's <0 sentinels resolved to the
    defaults: spike at one third of the window, decaying over a tenth."""
    start = spec.flash_start_s if spec.flash_start_s >= 0 \
        else spec.duration_s / 3.0
    decay = spec.flash_decay_s if spec.flash_decay_s > 0 \
        else spec.duration_s / 10.0
    return start, decay


def flash_rate(spec, t: float) -> float:
    """Instantaneous λ(t): baseline before the spike, then baseline plus
    an exponentially-decaying surge of magnitude (burst_factor−1)·rate."""
    start, decay = flash_params(spec)
    if t < start:
        return spec.rate
    return spec.rate * (1.0 + (spec.burst_factor - 1.0)
                        * math.exp(-(t - start) / decay))


def flash_crowd_times(spec, rng: np.random.Generator) -> List[float]:
    lam_max = spec.rate * max(spec.burst_factor, 1.0)
    return _thinned_times(rng, lambda t: flash_rate(spec, t), lam_max,
                          spec.duration_s)


def sweep_step_rates(spec) -> List[float]:
    """Geometric rate ladder from ``ramp_min_rate`` to ``ramp_max_rate``
    over ``ramp_steps`` equal-length windows (single step → min rate,
    matching the linear ramp's convention)."""
    if spec.ramp_steps == 1:
        return [spec.ramp_min_rate]
    ratio = (spec.ramp_max_rate / spec.ramp_min_rate) \
        ** (1.0 / (spec.ramp_steps - 1))
    return [spec.ramp_min_rate * ratio ** k for k in range(spec.ramp_steps)]


def sweep_times(spec, rng: np.random.Generator) -> List[float]:
    step_len = spec.duration_s / spec.ramp_steps
    times: List[float] = []
    for k, rate in enumerate(sweep_step_rates(spec)):
        t, end = k * step_len, (k + 1) * step_len
        while True:
            t += rng.exponential(1.0 / max(rate, 1e-9))
            if t >= end:
                break
            times.append(t)
    return times


def mean_rate(spec) -> float:
    """Time-averaged λ over the workload window, analytically.

    The steady-Poisson control for any bursty kind: a ``poisson``
    workload at ``mean_rate(spec)`` offers the same total work with
    none of the burstiness.
    """
    kind = spec.kind
    if kind == "diurnal":
        # sinusoid over a fractional number of periods
        w = 2.0 * math.pi / spec.diurnal_period_s
        integral = spec.rate * (spec.duration_s
                                + spec.diurnal_amplitude
                                * (1.0 - math.cos(w * spec.duration_s)) / w)
        return integral / spec.duration_s
    if kind == "flash-crowd":
        start, decay = flash_params(spec)
        start = min(start, spec.duration_s)
        surge = (spec.rate * (spec.burst_factor - 1.0) * decay
                 * (1.0 - math.exp(-(spec.duration_s - start) / decay)))
        return spec.rate + surge / spec.duration_s
    if kind == "sweep":
        return sum(sweep_step_rates(spec)) / spec.ramp_steps
    if kind == "ramp":
        from repro.serving.workload import ramp_step_rates
        return sum(ramp_step_rates(spec)) / spec.ramp_steps
    if kind == "burst":
        return spec.rate * (1.0 + spec.burst_fraction
                            * (spec.burst_factor - 1.0))
    return spec.rate
