"""Scenario library: named production traffic profiles, realistic
arrival processes, multi-tenant workloads, and a synthetic trace scaler.

The workload layer (``repro.serving.workload``) exposes raw primitives
— Poisson/burst/ramp arrival generators, trace replay, session and
prompt-mix knobs.  This package gives them a *vocabulary*:

  * :mod:`repro.scenarios.profiles` — a registry of named production
    scenarios (``chat``, ``code-generation``, ``summarization``,
    ``classification``, ``rag-long-context``) binding prompt/output
    token distributions, session/prefix structure, and default SLOs,
    resolvable from one config line (``"scenario": "chat"``);
  * :mod:`repro.scenarios.arrivals` — diurnal (sinusoid-modulated
    Poisson), flash-crowd (baseline + exponential spike decay), and
    scale-to-saturation sweep arrival processes, surfaced as
    ``WorkloadSpec`` kinds;
  * :mod:`repro.scenarios.tenants` — multi-tenant traffic splits with
    per-tenant scenarios, rate shares, and SLOs, plus fairness/
    isolation metrics over the simulator's per-tenant slices;
  * :mod:`repro.scenarios.synth` — scales a small seed JSONL trace to
    millions-of-users volume while preserving interarrival burstiness,
    session-length distribution, and prefix-sharing structure.
"""
from repro.scenarios.profiles import (ScenarioProfile, catalog_table,
                                      get_profile, list_profiles,
                                      register_profile)
from repro.scenarios.tenants import (TenantSpec, generate_multi_tenant,
                                     resolve_tenant_slos, tenant_report)
from repro.scenarios.synth import (load_trace_rows, scale_trace,
                                   trace_stats, write_trace_rows)

__all__ = [
    "ScenarioProfile", "catalog_table", "get_profile", "list_profiles",
    "register_profile",
    "TenantSpec", "generate_multi_tenant", "resolve_tenant_slos",
    "tenant_report",
    "load_trace_rows", "scale_trace", "trace_stats", "write_trace_rows",
]
