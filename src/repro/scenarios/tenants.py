"""Multi-tenant traffic: split one aggregate workload across named
tenants, each with its own scenario, rate share, and SLOs.

A ``WorkloadSpec`` with ``tenants=[TenantSpec(...), ...]`` generates one
merged arrival stream: each tenant's slice is its own workload (the
parent spec, specialized by the tenant's scenario profile and
overrides) at ``rate = parent rate × normalized share`` (or the
tenant's absolute ``rate``), with disjoint session-id ranges so
affinity routing and the prefix cache never alias across tenants.
Every request carries ``tenant`` through the simulator, so results
slice per tenant and answer the isolation questions production teams
ask: does the small tenant's goodput survive the big tenant's burst?

``tenant_report`` computes the per-tenant view of a ``SimResult`` —
goodput against each tenant's *own* SLOs, attainment, tail latencies —
plus the cross-tenant fairness/isolation metrics: Jain's fairness index
over share-normalized goodput, and the worst tenant by attainment.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

from repro.scenarios.profiles import get_profile

# session-id stride between tenants: far larger than any plausible
# session_count, so per-tenant session ids never collide
_SESSION_STRIDE = 1_000_003
# seed stride between tenants: distinct, deterministic per-tenant rngs
_SEED_STRIDE = 7919


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's slice of the aggregate traffic.

    ``share`` is a relative weight (normalized over all tenants);
    ``rate`` overrides the share split with an absolute requests/s.
    ``scenario`` names a registered profile providing the tenant's
    token/session shape and default SLOs; ``workload`` holds per-tenant
    ``WorkloadSpec`` field overrides (e.g. a different ``kind`` so one
    tenant bursts while the rest stay steady).  SLO fields set here win
    over the scenario's defaults.
    """
    name: str
    share: float = 1.0
    rate: Optional[float] = None
    scenario: Optional[str] = None
    workload: Dict[str, Any] = dataclasses.field(default_factory=dict)
    slo_ttft_s: Optional[float] = None
    slo_tpot_s: Optional[float] = None
    slo_latency_s: Optional[float] = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("TenantSpec needs a non-empty name")
        if self.rate is None and self.share <= 0:
            raise ValueError(f"tenant {self.name!r} needs share > 0 "
                             "or an absolute rate")
        if self.scenario is not None:
            get_profile(self.scenario)      # fail fast on unknown names

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TenantSpec":
        return cls(**dict(d))

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def coerce_tenants(tenants) -> tuple:
    """dicts/TenantSpecs → tuple of TenantSpec, names unique."""
    out = tuple(t if isinstance(t, TenantSpec) else TenantSpec.from_dict(t)
                for t in tenants)
    names = [t.name for t in out]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names: {names}")
    return out


def resolve_tenant_slos(tenant: TenantSpec) -> Dict[str, Optional[float]]:
    """The SLOs this tenant is judged by: its own fields, falling back
    to its scenario profile's defaults."""
    slos = {"slo_ttft_s": tenant.slo_ttft_s,
            "slo_tpot_s": tenant.slo_tpot_s,
            "slo_latency_s": tenant.slo_latency_s}
    if tenant.scenario is not None:
        for k, v in get_profile(tenant.scenario).slos().items():
            if slos[k] is None:
                slos[k] = v
    return slos


def normalized_shares(tenants: Sequence[TenantSpec]) -> Dict[str, float]:
    total = sum(t.share for t in tenants)
    return {t.name: t.share / total for t in tenants}


def tenant_workload(parent, tenant: TenantSpec, index: int,
                    rate: float):
    """The tenant's own WorkloadSpec: parent minus the tenant list,
    specialized by the tenant's scenario profile and field overrides."""
    base = dataclasses.replace(
        parent, tenants=None, rate=rate,
        seed=parent.seed + _SEED_STRIDE * (index + 1))
    if tenant.scenario is not None:
        base = get_profile(tenant.scenario).apply_to_workload(base)
    if tenant.workload:
        base = dataclasses.replace(base, **dict(tenant.workload))
    return base


def generate_multi_tenant(spec) -> List:
    """Merged request stream for a ``WorkloadSpec`` carrying tenants.

    Called from ``repro.serving.workload.generate`` (the single entry
    point every simulator path uses).  Requests are tagged with their
    tenant name, session ids are offset per tenant, and the merged
    stream is re-numbered in arrival order.
    """
    from repro.serving.workload import CLOSED, TRACE, generate
    tenants = coerce_tenants(spec.tenants)
    if spec.kind in (CLOSED, TRACE):
        raise ValueError(
            f"multi-tenant workloads cannot use kind={spec.kind!r}: "
            "closed-loop reissue and trace replay own their own arrival "
            "streams (record tenants in the trace instead)")
    shares = normalized_shares(tenants)
    merged = []
    for i, tenant in enumerate(tenants):
        rate = tenant.rate if tenant.rate is not None \
            else spec.rate * shares[tenant.name]
        sub = tenant_workload(spec, tenant, i, rate)
        offset = _SESSION_STRIDE * i
        for r in generate(sub):
            merged.append(dataclasses.replace(
                r, tenant=tenant.name, session_id=r.session_id + offset))
    merged.sort(key=lambda r: (r.arrival_s, r.tenant))
    return [dataclasses.replace(r, req_id=i) for i, r in enumerate(merged)]


# ---- per-tenant metrics over a SimResult -----------------------------------
def tenant_report(result, tenants) -> Dict[str, Any]:
    """Per-tenant slices + fairness/isolation metrics for one run.

    Each tenant is judged by its *own* resolved SLOs (goodput and
    attainment); the fairness index is Jain's index over
    share-normalized goodput (1.0 = every tenant gets goodput exactly
    proportional to its share; → 1/n as one tenant starves the rest).
    """
    from repro.core.analysis import jain_index
    tenants = coerce_tenants(tenants)
    shares = normalized_shares(tenants)
    per: Dict[str, Dict[str, float]] = {}
    normalized: List[float] = []
    for t in tenants:
        sub = result.tenant_result(t.name)
        slos = resolve_tenant_slos(t)
        has_slo = any(v is not None for v in slos.values())
        goodput = sub.goodput(slos["slo_ttft_s"], slos["slo_tpot_s"],
                              slos["slo_latency_s"])
        att = sub.phase_slo_attainment(
            slos["slo_ttft_s"], slos["slo_tpot_s"], slos["slo_latency_s"]) \
            if has_slo and sub.traces else (1.0 if sub.traces else 0.0)
        per[t.name] = {
            "requests": len(sub.traces),
            "share": shares[t.name],
            "throughput_rps": sub.throughput(),
            "goodput_rps": goodput,
            "slo_attainment": att,
            "p50_s": sub.percentile(50),
            "p99_s": sub.percentile(99),
            "ttft_p99_s": sub.ttft(99),
            "tpot_p99_s": sub.tpot(99),
            "slos": slos,
        }
        normalized.append(goodput / max(shares[t.name], 1e-12))
    worst = min(per, key=lambda n: per[n]["slo_attainment"])
    return {
        "per_tenant": per,
        "fairness_index": jain_index(normalized),
        "worst_tenant": worst,
        "worst_tenant_attainment": per[worst]["slo_attainment"],
        "worst_tenant_p99_s": max(p["p99_s"] for p in per.values()),
        "min_goodput_rps": min(p["goodput_rps"] for p in per.values()),
    }


def tenant_table(report: Dict[str, Any]) -> str:
    """Render a ``tenant_report`` as an aligned table."""
    cols = (f"{'tenant':>14}{'share':>8}{'reqs':>7}{'thr rps':>9}"
            f"{'goodput':>9}{'slo':>6}{'p99 ms':>8}{'ttft99':>8}")
    lines = [f"multi-tenant report  (fairness={report['fairness_index']:.3f}"
             f", worst={report['worst_tenant']})", cols]
    for name, p in report["per_tenant"].items():
        lines.append(
            f"{name:>14}{p['share']:>8.2f}{p['requests']:>7}"
            f"{p['throughput_rps']:>9.1f}{p['goodput_rps']:>9.1f}"
            f"{p['slo_attainment']:>6.2f}{p['p99_s'] * 1e3:>8.1f}"
            f"{p['ttft_p99_s'] * 1e3:>8.1f}")
    return "\n".join(lines)
