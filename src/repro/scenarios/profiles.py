"""Named production traffic profiles (the scenario registry).

A :class:`ScenarioProfile` binds the knobs that distinguish production
use cases — prompt/output token distributions, session/prefix
structure, payload size, and the SLOs each use case is judged by — so a
benchmark job names the scenario instead of re-deriving the numbers:

    {"job_id": "j0", "scenario": "chat", "workload": {"rate": 100}}

``BenchmarkJobSpec`` resolves the name at construction: profile values
fill every workload field the config left at its default, and the
profile's SLOs become the job's SLOs unless the config sets its own.
Explicit config values always win — the profile is a vocabulary of
defaults, not an override.

Token distributions map onto the uniform ``[min, max]`` samplers the
workload layer already has (``prompt_tokens``/``prompt_tokens_max``,
``output_tokens``/``output_tokens_max``); session structure maps onto
``session_count``/``prefix_tokens`` (shared system prompt + history —
the prefix cache's food).  The catalog numbers follow the shapes
production benchmarks report (inference-perf's use-case presets,
inference-benchmarker's chat/code/fixed profiles): chat is mid-prompt /
mid-decode with heavy prefix sharing, code generation is long-prompt /
long-decode, summarization is very-long-prompt / short-decode,
classification is single-token decode, RAG stuffs retrieved context
into the prompt with a shared corpus preamble.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.serving.workload import WorkloadSpec

# workload fields a profile provides defaults for
_WORKLOAD_FIELDS = ("prompt_tokens", "prompt_tokens_max", "output_tokens",
                    "output_tokens_max", "prefix_tokens", "session_count",
                    "payload_bytes")
_DEFAULTS = WorkloadSpec()


@dataclasses.dataclass(frozen=True)
class ScenarioProfile:
    """One named production scenario.

    ``prompt_tokens``/``prompt_tokens_max`` and ``output_tokens``/
    ``output_tokens_max`` are uniform-distribution bounds (``max`` of 0
    means fixed length); ``prefix_tokens`` is the per-session shared
    prompt prefix; the ``slo_*`` fields are the defaults a job inherits
    when it names this scenario without declaring its own SLOs.
    """
    name: str
    description: str
    prompt_tokens: int
    prompt_tokens_max: int = 0
    output_tokens: int = 1
    output_tokens_max: int = 0
    prefix_tokens: int = 0
    session_count: int = 4
    payload_bytes: int = 4 * 1024
    slo_ttft_s: Optional[float] = None
    slo_tpot_s: Optional[float] = None
    slo_e2e_s: Optional[float] = None

    def workload_overrides(self) -> Dict[str, int]:
        """The profile's values for the workload fields it governs."""
        return {f: getattr(self, f) for f in _WORKLOAD_FIELDS}

    def apply_to_workload(self, wl: WorkloadSpec) -> WorkloadSpec:
        """Fill profile values into every governed field the spec left
        at its dataclass default (explicit config values win).
        Idempotent: re-applying to an already-resolved spec is a
        no-op."""
        over = {f: v for f, v in self.workload_overrides().items()
                if getattr(wl, f) == getattr(_DEFAULTS, f)}
        return dataclasses.replace(wl, **over) if over else wl

    def slos(self) -> Dict[str, Optional[float]]:
        return {"slo_ttft_s": self.slo_ttft_s, "slo_tpot_s": self.slo_tpot_s,
                "slo_latency_s": self.slo_e2e_s}


_REGISTRY: Dict[str, ScenarioProfile] = {}


def register_profile(profile: ScenarioProfile,
                     overwrite: bool = False) -> ScenarioProfile:
    """Add a profile to the registry (site-local scenarios welcome)."""
    if profile.name in _REGISTRY and not overwrite:
        raise ValueError(f"scenario {profile.name!r} is already registered "
                         "(pass overwrite=True to replace it)")
    _REGISTRY[profile.name] = profile
    return profile


def get_profile(name: str) -> ScenarioProfile:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown scenario {name!r} "
                         f"(registered: {sorted(_REGISTRY)})") from None


def list_profiles() -> List[str]:
    return sorted(_REGISTRY)


def catalog_table() -> str:
    """Human-readable catalog (README / example output)."""
    cols = (f"{'scenario':>18}{'prompt tok':>12}{'output tok':>12}"
            f"{'prefix':>8}{'sessions':>10}{'ttft':>7}{'tpot':>7}"
            f"{'e2e':>6}")
    lines = ["scenario catalog (token ranges are uniform [min, max])", cols]
    for name in list_profiles():
        p = _REGISTRY[name]
        rng = (lambda lo, hi: f"{lo}-{hi}" if hi > lo else f"{lo}")
        fmt = (lambda v, scale=1.0:
               f"{v * scale:g}" if v is not None else "-")
        lines.append(
            f"{name:>18}{rng(p.prompt_tokens, p.prompt_tokens_max):>12}"
            f"{rng(p.output_tokens, p.output_tokens_max):>12}"
            f"{p.prefix_tokens:>8}{p.session_count:>10}"
            f"{fmt(p.slo_ttft_s):>7}{fmt(p.slo_tpot_s):>7}"
            f"{fmt(p.slo_e2e_s):>6}")
    return "\n".join(lines)


# ---- the built-in catalog --------------------------------------------------
register_profile(ScenarioProfile(
    name="chat",
    description="Interactive chat assistant: mid-length prompts carrying "
                "the running conversation, heavy per-session prefix "
                "sharing (system prompt + history), streaming decode "
                "judged by TTFT/TPOT.",
    prompt_tokens=256, prompt_tokens_max=1024,
    output_tokens=64, output_tokens_max=512,
    prefix_tokens=192, session_count=32, payload_bytes=4 * 1024,
    slo_ttft_s=0.5, slo_tpot_s=0.05))

register_profile(ScenarioProfile(
    name="code-generation",
    description="IDE / agent code completion: long prompts (file context "
                "+ instructions), long generations, a shared repo-level "
                "preamble per session; tolerant TTFT, tight TPOT.",
    prompt_tokens=512, prompt_tokens_max=4096,
    output_tokens=128, output_tokens_max=1024,
    prefix_tokens=256, session_count=16, payload_bytes=16 * 1024,
    slo_ttft_s=1.0, slo_tpot_s=0.04))

register_profile(ScenarioProfile(
    name="summarization",
    description="Document summarization: very long prompts, short "
                "outputs, no cross-request prefix reuse; prefill-bound, "
                "judged mostly by TTFT/e2e.",
    prompt_tokens=2048, prompt_tokens_max=6144,
    output_tokens=64, output_tokens_max=256,
    prefix_tokens=0, session_count=8, payload_bytes=64 * 1024,
    slo_ttft_s=2.0, slo_tpot_s=0.06, slo_e2e_s=20.0))

register_profile(ScenarioProfile(
    name="classification",
    description="Single-token classification / moderation: short fixed "
                "prompts, one decode step, judged by end-to-end latency "
                "(the paper's image-classification regime).",
    prompt_tokens=64, prompt_tokens_max=256,
    output_tokens=1, output_tokens_max=0,
    prefix_tokens=0, session_count=4, payload_bytes=2 * 1024,
    slo_e2e_s=0.2))

register_profile(ScenarioProfile(
    name="rag-long-context",
    description="Retrieval-augmented generation: retrieved chunks stuff "
                "the prompt toward the context limit, a large shared "
                "corpus preamble per session feeds the prefix cache, "
                "short grounded answers.",
    prompt_tokens=3072, prompt_tokens_max=7168,
    output_tokens=64, output_tokens_max=256,
    prefix_tokens=2048, session_count=16, payload_bytes=32 * 1024,
    slo_ttft_s=2.5, slo_tpot_s=0.06))
