"""End-to-end serving driver: REAL execution of a small model behind the
dynamic batcher, driven by a generated workload trace.

Requests arrive per the workload spec; the batcher groups them; the engine
runs actual jitted prefill + decode steps on the host devices and
wall-clock times are recorded per stage — the CPU-scale twin of the
paper's GPU serving experiments.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b \
      --policy tris --rate 20 --duration 5
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model, reduced
from repro.serving.batching import QueuedRequest, make_policy
from repro.serving.engine import make_decode_fn, make_prefill_fn
from repro.serving.workload import WorkloadSpec, generate


def run_server(cfg, policy, workload: WorkloadSpec, *,
               max_len: int = 192, decode_steps: int = 8) -> Dict:
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prefill = jax.jit(make_prefill_fn(model, max_len=max_len))
    decode = jax.jit(make_decode_fn(model), donate_argnums=(1,))

    trace = generate(workload)
    # warmup compile for the batch sizes the policy can emit
    warm_sizes = sorted({1, getattr(policy, "max_batch", 1),
                         *getattr(policy, "preferred", (1,))})
    for b in warm_sizes:
        toks = jnp.ones((b, workload.prompt_tokens), jnp.int32)
        lens = jnp.full((b,), workload.prompt_tokens, jnp.int32)
        cache, logits = prefill(params, toks, lens)
        cache, _ = decode(params, cache, jnp.argmax(logits, -1).astype(jnp.int32))

    t_start = time.perf_counter()
    clock = lambda: time.perf_counter() - t_start
    queue: List[QueuedRequest] = []
    i, n = 0, len(trace)
    lat: List[float] = []
    batch_sizes: List[int] = []
    infer_times: List[float] = []
    while i < n or queue:
        now = clock()
        while i < n and trace[i].arrival_s <= now:
            queue.append(QueuedRequest(request=trace[i], enqueue_s=now))
            i += 1
        decision = policy.next_batch(queue, now, now)
        if decision is None:
            if i < n:
                time.sleep(max(trace[i].arrival_s - clock(), 0.0) + 1e-4)
            elif queue:
                time.sleep(1e-3)
            continue
        batch, _ = decision
        ids = {q.request.req_id for q in batch}
        queue = [q for q in queue if q.request.req_id not in ids]
        b = len(batch)
        toks = jnp.ones((b, workload.prompt_tokens), jnp.int32)
        lens = jnp.full((b,), workload.prompt_tokens, jnp.int32)
        t0 = time.perf_counter()
        cache, logits = prefill(params, toks, lens)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(decode_steps - 1):
            cache, logits = decode(params, cache, nxt)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        done = clock()
        infer_times.append(dt)
        batch_sizes.append(b)
        for q in batch:
            lat.append(done - q.request.arrival_s)
    lat_arr = np.array(lat)
    return {
        "requests": len(lat),
        "throughput_rps": len(lat) / max(clock(), 1e-9),
        "p50_s": float(np.percentile(lat_arr, 50)) if len(lat) else 0.0,
        "p99_s": float(np.percentile(lat_arr, 99)) if len(lat) else 0.0,
        "mean_batch": float(np.mean(batch_sizes)) if batch_sizes else 0.0,
        "mean_infer_s": float(np.mean(infer_times)) if infer_times else 0.0,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--policy", default="tris",
                    choices=["none", "tfs", "tris"])
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--duration", type=float, default=5.0)
    ap.add_argument("--prompt-tokens", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    policy = make_policy(args.policy, **(
        dict(max_batch=args.max_batch, timeout_s=0.01)
        if args.policy == "tfs" else
        dict(preferred=(args.max_batch, 4, 2, 1)) if args.policy == "tris"
        else {}))
    wl = WorkloadSpec(rate=args.rate, duration_s=args.duration,
                      prompt_tokens=args.prompt_tokens, seed=0)
    out = run_server(cfg, policy, wl, decode_steps=args.decode_steps)
    print(f"arch={cfg.name} policy={args.policy} rate={args.rate}")
    for k, v in out.items():
        print(f"  {k:16s} {v:.4f}" if isinstance(v, float) else f"  {k:16s} {v}")


if __name__ == "__main__":
    main()
