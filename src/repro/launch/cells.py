"""Cell construction: (architecture × input shape × mesh) → jittable step.

A "cell" bundles the step function, abstract input operands
(ShapeDtypeStructs — never allocated), and in/out shardings resolved from
the logical-axis rules.  Used by the dry-run, the roofline analysis and
the serving latency model.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, get_config
from repro.configs.shapes import DECODE, PREFILL, TRAIN
from repro.dist import sharding as shd
from repro.models.config import ModelConfig
from repro.models.registry import build_model, model_flops_per_token
from repro.serving.engine import (make_decode_fn, make_prefill_fn,
                                  serving_config)
from repro.training.optimizer import OptimizerConfig, opt_state_axes
from repro.training.step import init_train_state, make_train_step

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    mesh: Mesh
    fn: Callable                     # jit-wrapped step
    args: Tuple[Any, ...]            # ShapeDtypeStruct operands
    model_flops: float               # 6·N·D (train) / 2·N·D (serve)
    tokens: int
    cfg: ModelConfig
    mem_info: Dict[str, float] = dataclasses.field(default_factory=dict)

    def lower(self):
        return self.fn.lower(*self.args)


def _sharding_bytes(shape_tree, sharding_tree, mesh) -> int:
    specs = jax.tree.map(lambda s: s.spec, sharding_tree,
                         is_leaf=lambda x: isinstance(x, NamedSharding))
    return shd.bytes_per_device(shape_tree, specs, mesh)


def _batch_dev(B: int, rules, mesh) -> int:
    spec = shd.partition_spec((B,), ("batch",), rules, mesh)
    sizes = dict(mesh.shape)
    factor = 1
    for entry in spec:
        if entry is None:
            continue
        for a in ((entry,) if isinstance(entry, str) else entry):
            factor *= sizes[a]
    return max(B // factor, 1)


def _vocab_shard_bytes(cfg, rules, mesh) -> float:
    spec = shd.partition_spec((cfg.vocab_size, cfg.d_model),
                              ("vocab", "embed"), rules, mesh)
    sizes = dict(mesh.shape)
    factor = 1
    entry = spec[0] if len(spec) else None
    if entry is not None:
        for a in ((entry,) if isinstance(entry, str) else entry):
            factor *= sizes[a]
    return cfg.vocab_size // factor * 4.0   # f32 logits row per token


def _repl(mesh: Mesh):
    return NamedSharding(mesh, P())


def _batch_inputs(cfg: ModelConfig, shape: ShapeSpec, kind: str):
    """(sds_tree, axes_tree) for the data operands of a cell."""
    B, S = shape.global_batch, shape.seq_len
    act_dt = cfg.activation_dtype
    if kind == TRAIN:
        n_front = cfg.num_frontend_tokens if cfg.frontend == "vision_patches" else 0
        S_tok = S - n_front
        sds = {"tokens": SDS((B, S_tok), jnp.int32),
               "labels": SDS((B, S_tok), jnp.int32),
               "loss_mask": SDS((B, S_tok), jnp.float32)}
        axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq"),
                "loss_mask": ("batch", "seq")}
        if cfg.is_encdec:
            sds["frames"] = SDS((B, S, cfg.d_model), act_dt)
            axes["frames"] = ("batch", "seq", "act_embed")
        if n_front:
            sds["patches"] = SDS((B, n_front, cfg.d_model), act_dt)
            axes["patches"] = ("batch", "seq", "act_embed")
        return sds, axes
    if kind == PREFILL:
        n_front = cfg.num_frontend_tokens if cfg.frontend == "vision_patches" else 0
        S_tok = S - n_front
        sds = {"tokens": SDS((B, S_tok), jnp.int32),
               "lengths": SDS((B,), jnp.int32)}
        axes = {"tokens": ("batch", "seq"), "lengths": ("batch",)}
        if cfg.is_encdec:
            sds["frames"] = SDS((B, S, cfg.d_model), act_dt)
            axes["frames"] = ("batch", "seq", "act_embed")
        if n_front:
            sds["patches"] = SDS((B, n_front, cfg.d_model), act_dt)
            axes["patches"] = ("batch", "seq", "act_embed")
        return sds, axes
    if kind == DECODE:
        return ({"tokens": SDS((B,), jnp.int32)}, {"tokens": ("batch",)})
    raise ValueError(kind)


def _cell_flops(cfg: ModelConfig, shape: ShapeSpec, kind: str) -> Tuple[float, int]:
    per_tok = model_flops_per_token(cfg)          # 6·N_active
    if kind == TRAIN:
        tokens = shape.global_batch * shape.seq_len
        return per_tok * tokens, tokens
    if kind == PREFILL:
        tokens = shape.global_batch * shape.seq_len
        return per_tok / 3.0 * tokens, tokens     # fwd-only: 2·N·D
    tokens = shape.global_batch                    # one token per sequence
    return per_tok / 3.0 * tokens, tokens


def build_train_cell(arch: str, shape: ShapeSpec, mesh: Mesh,
                     rules: Optional[shd.Rules] = None,
                     grad_accum: int = 1, remat: bool = True,
                     cfg: Optional[ModelConfig] = None) -> Cell:
    rules = rules or shd.TRAIN_RULES
    cfg = cfg or get_config(arch)
    model = build_model(cfg)
    params_sds, opt_sds = jax.eval_shape(
        lambda: init_train_state(model, jax.random.key(0)))
    p_axes = model.logical_axes()
    o_axes = opt_state_axes(p_axes)
    p_sh = shd.tree_shardings(params_sds, p_axes, rules, mesh)
    o_sh = shd.tree_shardings(opt_sds, o_axes, rules, mesh)
    batch_sds, b_axes = _batch_inputs(cfg, shape, TRAIN)
    b_sh = shd.tree_shardings(batch_sds, b_axes, rules, mesh)
    metrics_sh = {k: _repl(mesh) for k in
                  ("grad_norm", "lr", "loss", "moe_aux")}
    raw_step = make_train_step(model, OptimizerConfig(), grad_accum=grad_accum,
                               remat=remat)

    def step(params, opt_state, batch):
        shd.set_activation_sharding(mesh, rules)
        try:
            return raw_step(params, opt_state, batch)
        finally:
            shd.set_activation_sharding(None, None)

    fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                 out_shardings=(p_sh, o_sh, metrics_sh),
                 donate_argnums=(0, 1))
    flops, tokens = _cell_flops(cfg, shape, TRAIN)
    mem_info = {
        "params_bytes": _sharding_bytes(params_sds, p_sh, mesh),
        "opt_bytes": _sharding_bytes(opt_sds, o_sh, mesh),
        "cache_bytes": 0.0,
        "batch_dev": _batch_dev(shape.global_batch, rules, mesh),
        "vocab_shard_bytes_per_token": _vocab_shard_bytes(cfg, rules, mesh),
    }
    return Cell(arch, shape, mesh, fn, (params_sds, opt_sds, batch_sds),
                flops, tokens, cfg, mem_info)


def build_prefill_cell(arch: str, shape: ShapeSpec, mesh: Mesh,
                       rules: Optional[shd.Rules] = None,
                       cfg: Optional[ModelConfig] = None) -> Cell:
    rules = rules or shd.SERVE_RULES
    cfg = serving_config(cfg or get_config(arch))
    model = build_model(cfg)
    params_sds = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    p_sh = shd.tree_shardings(params_sds, model.logical_axes(), rules, mesh)
    batch_sds, b_axes = _batch_inputs(cfg, shape, PREFILL)
    b_sh = shd.tree_shardings(batch_sds, b_axes, rules, mesh)
    prefill = make_prefill_fn(model)

    def step(params, batch):
        shd.set_activation_sharding(mesh, rules)
        try:
            return prefill(params, **batch)
        finally:
            shd.set_activation_sharding(None, None)

    cache_sds = jax.eval_shape(step, params_sds, batch_sds)[0]
    c_sh = shd.tree_shardings(cache_sds, model.cache_axes(), rules, mesh)
    logits_sh = NamedSharding(mesh, shd.partition_spec(
        (shape.global_batch, cfg.vocab_size), ("batch", "vocab"), rules, mesh))
    fn = jax.jit(step, in_shardings=(p_sh, b_sh),
                 out_shardings=(c_sh, logits_sh))
    flops, tokens = _cell_flops(cfg, shape, PREFILL)
    mem_info = {
        "params_bytes": _sharding_bytes(params_sds, p_sh, mesh),
        "cache_bytes": _sharding_bytes(cache_sds, c_sh, mesh),
        "batch_dev": _batch_dev(shape.global_batch, rules, mesh),
        "vocab_shard_bytes_per_token": _vocab_shard_bytes(cfg, rules, mesh),
    }
    return Cell(arch, shape, mesh, fn, (params_sds, batch_sds),
                flops, tokens, cfg, mem_info)


def build_decode_cell(arch: str, shape: ShapeSpec, mesh: Mesh,
                      rules: Optional[shd.Rules] = None,
                      cfg: Optional[ModelConfig] = None) -> Cell:
    rules = rules or shd.SERVE_RULES
    cfg = serving_config(cfg or get_config(arch))
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    params_sds = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    p_sh = shd.tree_shardings(params_sds, model.logical_axes(), rules, mesh)
    if cfg.is_encdec:
        cache_sds = jax.eval_shape(lambda: model.init_cache(B, S, enc_len=S))
    else:
        cache_sds = jax.eval_shape(lambda: model.init_cache(B, S))
    c_sh = shd.tree_shardings(cache_sds, model.cache_axes(), rules, mesh)
    tok_sds = SDS((B,), jnp.int32)
    tok_sh = NamedSharding(mesh, shd.partition_spec((B,), ("batch",), rules,
                                                    mesh))
    logits_sh = NamedSharding(mesh, shd.partition_spec(
        (B, cfg.vocab_size), ("batch", "vocab"), rules, mesh))
    raw_decode = make_decode_fn(model)

    def decode(params, cache, tokens):
        shd.set_activation_sharding(mesh, rules)
        try:
            return raw_decode(params, cache, tokens)
        finally:
            shd.set_activation_sharding(None, None)

    fn = jax.jit(decode, in_shardings=(p_sh, c_sh, tok_sh),
                 out_shardings=(c_sh, logits_sh), donate_argnums=(1,))
    flops, tokens = _cell_flops(cfg, shape, DECODE)
    mem_info = {
        "params_bytes": _sharding_bytes(params_sds, p_sh, mesh),
        "cache_bytes": _sharding_bytes(cache_sds, c_sh, mesh),
        "batch_dev": _batch_dev(shape.global_batch, rules, mesh),
        "vocab_shard_bytes_per_token": _vocab_shard_bytes(cfg, rules, mesh),
    }
    return Cell(arch, shape, mesh, fn, (params_sds, cache_sds, tok_sds),
                flops, tokens, cfg, mem_info)


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               rules: Optional[shd.Rules] = None,
               cfg: Optional[ModelConfig] = None, **kw) -> Cell:
    shape = SHAPES[shape_name]
    if shape.kind == TRAIN:
        return build_train_cell(arch, shape, mesh, rules, cfg=cfg, **kw)
    if shape.kind == PREFILL:
        return build_prefill_cell(arch, shape, mesh, rules, cfg=cfg)
    return build_decode_cell(arch, shape, mesh, rules, cfg=cfg)
