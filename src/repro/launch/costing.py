"""Pure helpers for the dry-run cost pass (importable without touching
jax device state — repro.launch.dryrun forces a 512-device host platform
at import, so tests use this module instead)."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.analysis import hlo as hlo_lib

COLL_KINDS = hlo_lib.COLLECTIVE_KINDS


def depth_variants(cfg):
    """Two depth-reduced cost configs + (n1, n_full) period counts."""
    pl_ = len(cfg.layer_pattern)
    if cfg.is_encdec:
        assert cfg.encoder_layers == cfg.num_layers, \
            "depth extrapolation assumes enc depth == dec depth"
        mk = lambda n: dataclasses.replace(cfg, num_layers=n,
                                           encoder_layers=n, cost_unroll=True)
        return mk(1), mk(2), 1, cfg.num_layers
    tail = cfg.num_layers % pl_
    mk = lambda L: dataclasses.replace(cfg, num_layers=L, cost_unroll=True)
    return (mk(pl_ + tail), mk(2 * pl_ + tail), 1, cfg.num_layers // pl_)


def extract_costs(compiled) -> dict:
    ca = compiled.cost_analysis()
    colls = hlo_lib.parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "collectives": colls,
    }


def extrapolate(c1: dict, c2: dict, n1: int, n_full: int) -> dict:
    """cost(d1) + (n_full − n1) · (cost(d2) − cost(d1)), clamped ≥ cost(d1)."""
    mult = n_full - n1

    def ext(a, b):
        return a + mult * max(b - a, 0.0)

    colls = {}
    for kind in COLL_KINDS:
        a = c1["collectives"].get(kind, {"bytes": 0, "count": 0})
        b = c2["collectives"].get(kind, {"bytes": 0, "count": 0})
        colls[kind] = {"bytes": ext(a["bytes"], b["bytes"]),
                       "count": ext(a["count"], b["count"])}
    return {
        "flops": ext(c1["flops"], c2["flops"]),
        "bytes": ext(c1["bytes"], c2["bytes"]),
        "transcendentals": ext(c1["transcendentals"], c2["transcendentals"]),
        "collectives": colls,
    }
