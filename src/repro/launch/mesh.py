"""Production meshes.

Single pod = 16×16 = 256 TPU v5e chips, axes ("data", "model").
Multi-pod = 2 pods = 512 chips, axes ("pod", "data", "model"); the pod
axis extends data parallelism across the (slower) inter-pod links while
model parallelism stays inside a pod's ICI domain.

Defined as functions so importing this module never touches jax device
state (device count is locked at first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """A mesh over whatever devices exist (CPU smoke / single host)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
