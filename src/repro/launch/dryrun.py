import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Two passes per cell:

1. PRODUCTION pass — the real scanned-layers step, compiled on the target
   mesh.  Proves the sharding config lowers+compiles, and provides
   ``memory_analysis()`` (HBM fit) and compile timings.

2. COST pass — XLA's ``cost_analysis()`` counts a while-loop body ONCE, so
   FLOPs/bytes/collectives of the scanned program undercount by the trip
   count.  We therefore compile two depth-reduced variants of the same
   model (same widths/shapes, all internal scans unrolled via
   ``cfg.cost_unroll``) and extrapolate exactly:

       cost(L) = outside + n_periods(L) · per_period
       per_period = cost(d2) − cost(d1);  total = cost(d1) + (n_full − n1)·per_period

   The roofline table (EXPERIMENTS.md §Roofline) reads from this pass.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out experiments/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.launch.costing import depth_variants, extract_costs, extrapolate

from repro.analysis import hlo as hlo_lib
from repro.analysis import memory_model
from repro.analysis import roofline as roofline_lib
from repro.configs import ARCHS, SHAPES, applicable, get_config
from repro.dist import sharding as shd
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh

RULE_SETS = {
    "default": None,
    "tp": shd.SERVE_TP_RULES,
    "ep": shd.MOE_EP_RULES,
    "moe_local": shd.MOE_LOCAL_RULES,
    "moe_sp": shd.MOE_SP_RULES,
    "moe_sp_tp": shd.MOE_SP_TP_RULES,
    "ep_local": shd.MOE_EP_LOCAL_RULES,
}

COLL_KINDS = hlo_lib.COLLECTIVE_KINDS








def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules_name: str = "default", grad_accum: int = 1,
             remat: bool = True, cost_pass: bool = True) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "rules": rules_name, "ok": False}
    if not applicable(shape, cfg):
        rec.update(ok=True, skipped=True,
                   reason="long_500k needs sub-quadratic attention; "
                          "this arch has global full attention")
        return rec
    rules = RULE_SETS[rules_name]
    kw = dict(grad_accum=grad_accum, remat=remat) if shape.kind == "train" else {}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        # ---- production pass --------------------------------------------
        cell = build_cell(arch, shape_name, mesh, rules, **kw)
        t0 = time.time()
        with mesh:
            lowered = cell.lower()
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        prod_costs = extract_costs(compiled)
        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            tokens=cell.tokens,
            memory={
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
                "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0) or 0),
            },
            production_costs_scan_body_once=prod_costs,
        )
        # ---- cost pass (depth-diff, unrolled) ----------------------------
        if cost_pass:
            d1, d2, n1, n_full = depth_variants(cfg)
            costs = []
            for dcfg in (d1, d2):
                c = build_cell(arch, shape_name, mesh, rules, cfg=dcfg, **kw)
                with mesh:
                    costs.append(extract_costs(c.lower().compile()))
            total = extrapolate(costs[0], costs[1], n1, n_full)
            bytes_model = memory_model.estimate_bytes(
                shape.kind, cell.cfg, shape, cell.mem_info)
            report = roofline_lib.analyze(
                flops_per_device=total["flops"],
                bytes_per_device=total["bytes"],
                bytes_model_per_device=bytes_model,
                collectives=total["collectives"],
                chips=chips, model_flops=cell.model_flops)
            rec["roofline"] = report.to_dict()
            rec["cost_depths"] = [d1.num_layers, d2.num_layers, n_full]
    except Exception as e:  # a failed cell is a bug — record it loudly
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--rules", default="default", choices=list(RULE_SETS))
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-cost-pass", action="store_true",
                    help="compile-proof only (skip the roofline cost pass)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape}__{'multi' if multi else 'single'}" \
                      + ("" if args.rules == "default" else f"__{args.rules}")
                rec = run_cell(arch, shape, multi, args.rules,
                               grad_accum=args.grad_accum,
                               remat=not args.no_remat,
                               cost_pass=not args.no_cost_pass)
                (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=2))
                status = ("SKIP" if rec.get("skipped")
                          else "OK" if rec["ok"] else "FAIL")
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
                dom = rec.get("roofline", {}).get("dominant", "-")
                step = rec.get("roofline", {}).get("step_time_s", 0)
                mfu = rec.get("roofline", {}).get("model_flops_util", 0)
                print(f"[{status}] {tag:58s} dom={dom:10s} "
                      f"step={step:.4f}s mfu={mfu:.3f}", flush=True)
                if not rec["ok"]:
                    print("   ", rec.get("error"), flush=True)
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
