"""End-to-end training driver.

Trains an assigned architecture (optionally depth/width-reduced to a
~100M-param CPU-trainable config) with the full production stack: sharded
params on a host mesh, AdamW, remat, data pipeline, async checkpointing,
restart recovery and straggler monitoring.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --reduce --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import build_model, count_params, reduced
from repro.training import checkpoint as ckpt_lib
from repro.training.data import DataConfig, host_batch
from repro.training.ft import RunnerConfig, TrainingRunner
from repro.training.optimizer import OptimizerConfig, opt_state_axes
from repro.training.step import init_train_state, make_train_step


def train_100m_config(cfg):
    """~100M-param same-family config (CPU-trainable)."""
    return dataclasses.replace(
        reduced(cfg),
        num_layers=max(4, 2 * len(cfg.layer_pattern)),
        d_model=512, d_ff=1536,
        num_heads=8, num_kv_heads=min(cfg.num_kv_heads, 4), head_dim=64,
        vocab_size=32_768, rglru_d_rnn=512 if cfg.family == "hybrid" else 0,
        dtype="float32", param_dtype="float32")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduce", action="store_true",
                    help="reduce to a ~100M-param config (CPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny smoke config (fastest)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    base = get_config(args.arch)
    if args.smoke:
        cfg = reduced(base)
    elif args.reduce:
        cfg = train_100m_config(base)
    else:
        cfg = base
    model = build_model(cfg)
    mesh = make_host_mesh(args.model_parallel)
    rules = shd.TRAIN_RULES

    params_sds, opt_sds = jax.eval_shape(
        lambda: init_train_state(model, jax.random.key(0)))
    p_axes = model.logical_axes()
    p_sh = shd.tree_shardings(params_sds, p_axes, rules, mesh)
    o_sh = shd.tree_shardings(opt_sds, opt_state_axes(p_axes), rules, mesh)

    opt_cfg = OptimizerConfig(learning_rate=args.lr,
                              warmup_steps=max(args.steps // 10, 1),
                              total_steps=args.steps)
    step_fn_raw = jax.jit(
        make_train_step(model, opt_cfg, grad_accum=args.grad_accum),
        in_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))

    data_cfg = DataConfig(global_batch=args.batch, seq_len=args.seq)

    def init_state_fn():
        params, opt = init_train_state(model, jax.random.key(0))
        return {"params": params, "opt": opt}

    def step_fn(state, step):
        batch = host_batch(data_cfg, cfg, step)
        params, opt, metrics = step_fn_raw(state["params"], state["opt"],
                                           batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        return {"params": params, "opt": opt}, metrics

    n_params = count_params(jax.eval_shape(
        lambda: model.init(jax.random.key(0))))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={len(jax.devices())} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    runner = TrainingRunner(
        RunnerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     max_steps=args.steps),
        step_fn, init_state_fn)
    t0 = time.time()
    result = runner.run()
    dt = time.time() - t0
    losses = [m["loss"] for m in result["metrics"] if "loss" in m]
    print(f"done: {result['final_step']} steps in {dt:.1f}s "
          f"({dt / max(len(losses), 1):.3f}s/step)")
    if losses:
        k = max(len(losses) // 10, 1)
        print(f"loss: first10={sum(losses[:k])/k:.4f} "
              f"last10={sum(losses[-k:])/k:.4f}")


if __name__ == "__main__":
    main()
