"""Flash attention (prefill) Pallas TPU kernel.

Blockwise online-softmax attention with GQA, sliding-window and logit
soft-capping.  Tiling: grid = (B, H, Sq/bq, Skv/bk); the kv axis is the
fastest (sequentially iterated on TPU), with the running max / sum / output
accumulator held in VMEM scratch.  Block shapes are MXU-aligned (128).

Causal + window structure is exploited: fully-masked kv blocks are skipped
(no FLOPs issued), which is what makes the local-attention layers of
gemma2 / recurrentgemma pay O(S·W) instead of O(S²).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, softcap: float, sm_scale: float,
                  block_q: int, block_k: int, kv_len: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nkv = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_k

    # Block-level structure: skip blocks that are fully masked.
    below_diag = (not causal) or (k_start <= q_start + block_q - 1)
    if window > 0:
        # a kv block is skippable only if its newest key is out of window
        # for the *oldest* query in the q block
        in_window = k_start + block_k - 1 > q_start - window
        run = jnp.logical_and(below_diag, in_window)
    else:
        run = below_diag

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)             # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(logits, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == nkv - 1)
    def _finalize():
        l = l_scr[...]
        denom = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, sm_scale: Optional[float] = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, Sq, d); k/v: (B, K, Skv, d) → (B, H, Sq, d)."""
    B, H, Sq, d = q.shape
    K, Skv = k.shape[1], k.shape[2]
    assert H % K == 0
    G = H // K
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)

    grid = (B, H, Sq // block_q, Skv // block_k)
    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, softcap=softcap,
        sm_scale=sm_scale, block_q=block_q, block_k=block_k, kv_len=Skv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
