"""RWKV6 chunked-WKV Pallas TPU kernel.

Grid = (B, H, S/T): the chunk axis is iterated sequentially (TPU grid
order), carrying the (N, N) per-head state in VMEM scratch.  Within a
chunk the pairwise decay tensor exp(Σ logw) is materialised in VMEM —
it is ≤ 1 everywhere so this is overflow-safe — giving exact WKV with
two (T,N)×(N,N)-shaped MXU contractions per chunk instead of a length-S
sequential recurrence.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 32


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                o_ref, sf_ref, state_scr, *, chunk: int):
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    rr = r_ref[0, :, 0].astype(jnp.float32)      # (T, N)
    kk = k_ref[0, :, 0].astype(jnp.float32)
    vv = v_ref[0, :, 0].astype(jnp.float32)
    lw = lw_ref[0, :, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)             # (N,)
    state = state_scr[...]                       # (N, N)

    lc = jnp.cumsum(lw, axis=0)
    lc_excl = lc - lw
    r_dec = rr * jnp.exp(lc_excl)
    o_inter = jax.lax.dot_general(r_dec, state, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # A[t, s] = Σ_d r_td k_sd e^{lc_excl_t − lc_s}, s < t  (≤1 decay, safe)
    decay = jnp.exp(lc_excl[:, None, :] - lc[None, :, :])        # (T, T, N)
    A = jnp.sum(rr[:, None, :] * kk[None, :, :] * decay, axis=-1)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, A.shape, 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, A.shape, 1)
    A = jnp.where(s_idx < t_idx, A, 0.0)
    diag = jnp.sum(rr * u[None, :] * kk, axis=-1)                # (T,)
    o_intra = jax.lax.dot_general(A, vv, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    o_intra = o_intra + diag[:, None] * vv
    o_ref[0, :, 0] = (o_inter + o_intra).astype(o_ref.dtype)

    k_dec = kk * jnp.exp(lc[-1:, :] - lc)
    state_scr[...] = (jnp.exp(lc[-1, :])[:, None] * state
                      + jax.lax.dot_general(k_dec, vv, (((0,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))

    @pl.when(c == nc - 1)
    def _final():
        sf_ref[0, 0] = state_scr[...].astype(sf_ref.dtype)


def wkv6(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, logw: jnp.ndarray,
         u: jnp.ndarray, state0: jnp.ndarray, *, chunk: int = DEFAULT_CHUNK,
         interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r/k/v/logw: (B, S, H, N); u: (H, N); state0: (B, H, N, N) fp32."""
    B, S, H, N = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    grid = (B, H, S // chunk)

    io_spec = pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0))
    out, state = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            io_spec, io_spec, io_spec, io_spec,
            pl.BlockSpec((1, N), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            io_spec,
            pl.BlockSpec((1, 1, N, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(r.shape, r.dtype),
            jax.ShapeDtypeStruct(state0.shape, jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, state0)
    return out, state
