"""RG-LRU gated-linear-recurrence Pallas TPU kernel.

The recurrence s_t = a_t ⊙ s_{t-1} + b_t is elementwise over the RNN width
R, so the natural TPU mapping is: R on the lane dimension (blocked br),
sequence chunks streamed through VMEM, state carried in VMEM scratch, and
the per-chunk recurrence unrolled as a vector loop (each step is one VPU
FMA over (br,) lanes — there is no matmul to win back, so a sequential
in-VMEM loop IS the roofline-optimal form; HBM traffic = read a,b once,
write s once).  Grid = (B, R/br, S/T), chunk axis sequential.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128
DEFAULT_BLOCK_R = 512


def _rglru_kernel(a_ref, b_ref, s0_ref, out_ref, last_ref, state_scr, *,
                  chunk: int):
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = s0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)      # (T, br)
    b = b_ref[0].astype(jnp.float32)

    def step(t, carry):
        s, outs = carry
        s = a[t] * s + b[t]
        outs = jax.lax.dynamic_update_index_in_dim(outs, s, t, 0)
        return s, outs

    s, outs = jax.lax.fori_loop(
        0, chunk, step, (state_scr[...], jnp.zeros_like(a)))
    out_ref[0] = outs.astype(out_ref.dtype)
    state_scr[...] = s

    @pl.when(c == nc - 1)
    def _final():
        last_ref[0] = s.astype(last_ref.dtype)


def rglru_scan(a: jnp.ndarray, b: jnp.ndarray, s0: jnp.ndarray, *,
               chunk: int = DEFAULT_CHUNK, block_r: int = DEFAULT_BLOCK_R,
               interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """a/b: (B, S, R) fp32; s0: (B, R) fp32 → (s_seq (B,S,R), s_last (B,R))."""
    B, S, R = a.shape
    chunk = min(chunk, S)
    block_r = min(block_r, R)
    assert S % chunk == 0 and R % block_r == 0
    grid = (B, R // block_r, S // chunk)

    seq_spec = pl.BlockSpec((1, chunk, block_r), lambda bi, ri, c: (bi, c, ri))
    vec_spec = pl.BlockSpec((1, block_r), lambda bi, ri, c: (bi, ri))
    out, last = pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk),
        grid=grid,
        in_specs=[seq_spec, seq_spec, vec_spec],
        out_specs=[seq_spec, vec_spec],
        out_shape=[
            jax.ShapeDtypeStruct(a.shape, a.dtype),
            jax.ShapeDtypeStruct(s0.shape, jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_r,), jnp.float32)],
        interpret=interpret,
    )(a, b, s0)
    return out, last
