"""Int8×int8 → fp32 quantized matmul Pallas TPU kernel.

The paper's pre-deployment pipeline includes an INT8-conversion step
(§2.1); this kernel is the serving-side half: weights stored int8 with
per-output-channel scales, activations quantized per-row on the fly, MXU
int8 matmul accumulating int32 in VMEM, dequantised once at the end.
Tiling: grid = (M/bm, N/bn, K/bk), K fastest with an int32 accumulator.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _int8_mm_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_scr):
    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...]
    w = w_ref[...]
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(kk == nk - 1)
    def _final():
        sx = sx_ref[...].astype(jnp.float32)      # (bm,)
        sw = sw_ref[...].astype(jnp.float32)      # (bn,)
        o_ref[...] = (acc_scr[...].astype(jnp.float32)
                      * sx[:, None] * sw[None, :]).astype(o_ref.dtype)


def int8_matmul(x_q: jnp.ndarray, w_q: jnp.ndarray, sx: jnp.ndarray,
                sw: jnp.ndarray, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                bk: int = DEFAULT_BK, out_dtype=jnp.float32,
                interpret: bool = False) -> jnp.ndarray:
    """x_q: (M, K) int8; w_q: (K, N) int8; sx: (M,); sw: (N,) → (M, N)."""
    M, K = x_q.shape
    N = w_q.shape[1]
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    grid = (M // bm, N // bn, K // bk)

    return pl.pallas_call(
        _int8_mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, sx, sw)
