"""Flash-decode Pallas TPU kernel: one query token vs a long KV cache.

Decode attention is memory-bound (the whole KV cache streams HBM→VMEM once
per step), so the kernel's job is to (a) never materialise the (H, T)
logits in HBM and (b) keep per-block work vectorised over the head group.
Tiling: grid = (B, K, T/bk); each step loads a (bk, d) K/V block and all G
queries of the kv-head's group, maintaining online-softmax state per head
in VMEM scratch.  Per-sequence ``lengths`` mask dead cache slots.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _decode_kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   block_k: int, sm_scale: float, softcap: float):
    b = pl.program_id(0)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    k_start = kj * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (G, bk)
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(k_pos < length, logits, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.max(logits, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        l = l_scr[...]
        denom = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lengths: jnp.ndarray, *, softcap: float = 0.0,
                     sm_scale: Optional[float] = None,
                     block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, d); k/v: (B, K, T, d); lengths: (B,) int32 → (B, H, d)."""
    B, H, d = q.shape
    K, T = k.shape[1], k.shape[2]
    assert H % K == 0
    G = H // K
    block_k = min(block_k, T)
    assert T % block_k == 0
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(B, K, G, d)

    grid = (B, K, T // block_k)
    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               sm_scale=sm_scale, softcap=softcap)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, d), lambda b, h, j, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_k, d), lambda b, h, j, *_: (b, h, j, 0)),
                pl.BlockSpec((1, 1, block_k, d), lambda b, h, j, *_: (b, h, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, d), lambda b, h, j, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, G, d), q.dtype),
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.reshape(B, H, d)
