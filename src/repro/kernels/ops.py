"""Public jit'd wrappers over the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode so
every call is still validated end-to-end; on TPU they compile to Mosaic.
``set_use_kernels(False)`` routes callers to the pure-jnp references —
the serving engine flips this per benchmark-job spec ("software tier").
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import int8_matmul as _i8
from repro.kernels import ref
from repro.kernels import rglru_scan as _rg
from repro.kernels import wkv6 as _wkv

_INTERPRET = jax.default_backend() == "cpu"
_USE_KERNELS = True


def interpret_mode() -> bool:
    return _INTERPRET


def set_use_kernels(flag: bool) -> None:
    global _USE_KERNELS
    _USE_KERNELS = flag


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0,
                    block_q: int = _fa.DEFAULT_BLOCK_Q,
                    block_k: int = _fa.DEFAULT_BLOCK_K) -> jnp.ndarray:
    if not _USE_KERNELS:
        return ref.mha_reference(q, k, v, causal=causal, window=window,
                                 softcap=softcap)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, block_q=block_q,
                               block_k=block_k, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("softcap", "block_k"))
def decode_attention(q, k, v, lengths, *, softcap: float = 0.0,
                     block_k: int = _dec.DEFAULT_BLOCK_K) -> jnp.ndarray:
    if not _USE_KERNELS:
        return ref.decode_attention_reference(q, k, v, lengths)
    return _dec.decode_attention(q, k, v, lengths, softcap=softcap,
                                 block_k=block_k, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, logw, u, state0,
         *, chunk: int = _wkv.DEFAULT_CHUNK) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if not _USE_KERNELS:
        return ref.wkv6_reference(r, k, v, logw, u, state0)
    return _wkv.wkv6(r, k, v, logw, u, state0, chunk=chunk,
                     interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("chunk", "block_r"))
def rglru_scan(a, b, s0, *, chunk: int = _rg.DEFAULT_CHUNK,
               block_r: int = _rg.DEFAULT_BLOCK_R):
    if not _USE_KERNELS:
        return ref.rglru_reference(a, b, s0)
    return _rg.rglru_scan(a, b, s0, chunk=chunk, block_r=block_r,
                          interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def int8_matmul(x_q, w_q, sx, sw, *, bm: int = _i8.DEFAULT_BM,
                bn: int = _i8.DEFAULT_BN, bk: int = _i8.DEFAULT_BK):
    if not _USE_KERNELS:
        return ref.int8_matmul_reference(x_q, w_q, sx, sw)
    return _i8.int8_matmul(x_q, w_q, sx, sw, bm=bm, bn=bn, bk=bk,
                           interpret=_INTERPRET)


def quantize_rowwise(x):
    return ref.quantize_rowwise(x)
