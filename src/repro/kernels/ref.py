"""Pure-jnp oracles for every Pallas kernel (naive, obviously-correct math).

These are deliberately written as direct definitions — sequential scans and
dense softmax — independent of the blocked/chunked algorithms the kernels
use, so the allclose tests are meaningful.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def mha_reference(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0, lengths=None) -> jnp.ndarray:
    """q: (B, H, Sq, d); k/v: (B, K, Skv, d) (GQA) → (B, H, Sq, d)."""
    B, H, Sq, d = q.shape
    K, Skv = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, Sq, d).astype(jnp.float32)
    logits = jnp.einsum("bkgsd,bktd->bkgst", qg,
                        k.astype(jnp.float32)) / math.sqrt(d)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    mask = jnp.broadcast_to(mask, (B, Sq, Skv))
    if lengths is not None:
        mask &= (k_pos[None, None, :] < lengths[:, None, None])
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(B, H, Sq, d).astype(q.dtype)


def decode_attention_reference(q, k, v, lengths) -> jnp.ndarray:
    """q: (B, H, d); k/v: (B, K, T, d); lengths: (B,) → (B, H, d)."""
    B, H, d = q.shape
    out = mha_reference(q[:, :, None], k, v, causal=False, lengths=lengths)
    return out[:, :, 0]


def wkv6_reference(r, k, v, logw, u, state0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Naive sequential WKV recurrence.

    r/k/v/logw: (B, S, H, N); u: (H, N); state0: (B, H, N, N) fp32.
    """
    f32 = jnp.float32
    rr, kk, vv = r.astype(f32), k.astype(f32), v.astype(f32)
    lw = logw.astype(f32)

    def step(state, xs):
        rt, kt, vt, wt = xs                           # (B, H, N)
        o = (jnp.einsum("bhd,bhde->bhe", rt, state)
             + jnp.einsum("bhd,hd,bhd,bhe->bhe", rt, u.astype(f32), kt, vt))
        state = (jnp.exp(wt)[..., None] * state
                 + jnp.einsum("bhd,bhe->bhde", kt, vt))
        return state, o

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (rr, kk, vv, lw))
    final, outs = jax.lax.scan(step, state0.astype(f32), xs)
    return outs.transpose(1, 0, 2, 3).astype(r.dtype), final


def rglru_reference(a, b, s0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Naive gated linear recurrence: s_t = a_t s_{t-1} + b_t.

    a/b: (B, S, R) fp32; s0: (B, R) fp32 → (seq (B,S,R), last (B,R)).
    """
    def step(s, xs):
        at, bt = xs
        s = at * s + bt
        return s, s
    xs = (a.transpose(1, 0, 2), b.transpose(1, 0, 2))
    last, seq = jax.lax.scan(step, s0, xs)
    return seq.transpose(1, 0, 2), last


def int8_matmul_reference(x_q, w_q, sx, sw) -> jnp.ndarray:
    """Dequantize-then-matmul oracle.

    x_q: (M, K) int8; w_q: (K, N) int8; sx: (M,) fp32; sw: (N,) fp32.
    """
    x = x_q.astype(jnp.float32) * sx[:, None]
    w = w_q.astype(jnp.float32) * sw[None, :]
    return x @ w


def quantize_rowwise(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-row int8 quantization → (q, scales)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0].astype(jnp.float32)
