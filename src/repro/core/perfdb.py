"""PerfDB — the performance database (paper §4.2.5).

Append-only JSONL (one record per benchmark result) + in-memory query /
aggregation API.  The paper uses MongoDB; a cluster deployment would swap
the storage backend behind the same interface — the schema is the point.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional


class PerfDB:
    def __init__(self, path: Optional[str] = None):
        self.path = Path(path) if path else None
        self._records: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        if self.path and self.path.exists():
            with self.path.open() as f:
                for line in f:
                    line = line.strip()
                    if line:
                        self._records.append(json.loads(line))

    # ---- write ------------------------------------------------------------
    def append(self, record: Dict[str, Any]) -> None:
        """Write-through append: memory + JSONL line under one lock.

        The line is serialized outside the file write and emitted as a
        single ``write`` followed by a flush, so concurrent executor
        workers appending from different threads can never interleave
        partial JSONL lines.
        """
        record = dict(record)
        record.setdefault("ts", time.time())
        line = json.dumps(record) + "\n"
        with self._lock:
            self._records.append(record)
            if self.path:
                self.path.parent.mkdir(parents=True, exist_ok=True)
                with self.path.open("a") as f:
                    f.write(line)
                    f.flush()

    def insert(self, record: Dict[str, Any]) -> None:
        """Alias of :meth:`append` (the original name)."""
        self.append(record)

    # ---- query ------------------------------------------------------------
    @staticmethod
    def get_path(record: Dict[str, Any], key: str) -> Any:
        """Dotted-path lookup into a nested record (``"result.p99_s"``).

        Returns ``None`` when any path component is missing or the node
        it names is not a dict — dotted filters are first-class in both
        :meth:`query` and the analysis heat maps.
        """
        node = record
        for part in key.split("."):
            if not isinstance(node, dict) or part not in node:
                return None
            node = node[part]
        return node

    def query(self, **eq) -> List[Dict[str, Any]]:
        """Equality filter over (possibly dotted) record keys."""
        return [r for r in self._records
                if all(self.get_path(r, k) == v for k, v in eq.items())]

    def all(self) -> List[Dict[str, Any]]:
        return list(self._records)

    def distinct(self, key: str) -> List[Any]:
        seen = []
        for r in self.query():
            v = r.get(key)
            if v not in seen:
                seen.append(v)
        return seen

    def __len__(self) -> int:
        return len(self._records)
