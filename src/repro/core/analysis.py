"""Analysis stage (paper §4.2.5 + §4.3.1): aggregator, CDF, heat maps,
roofline points, configuration recommender, leaderboard.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import hw as hw_lib
from repro.core.perfdb import PerfDB


# ---- CDF (Fig. 11) ---------------------------------------------------------
def cdf(values: Sequence[float], points: int = 100) -> Tuple[List[float], List[float]]:
    v = np.sort(np.asarray(values, dtype=float))
    if len(v) == 0:
        return [], []
    qs = np.linspace(0, 1, points)
    return list(np.quantile(v, qs)), list(qs)


# ---- heat maps (Fig. 9) ----------------------------------------------------
def heatmap(db: PerfDB, *, row_key: str, col_key: str, value_key: str,
            **filters) -> Dict[str, Any]:
    """Pivot PerfDB records into a (rows × cols) matrix of means."""
    recs = db.query(**filters)
    def get(rec, key):
        node = rec
        for p in key.split("."):
            node = node.get(p) if isinstance(node, dict) else None
            if node is None:
                return None
        return node
    rows = sorted({get(r, row_key) for r in recs} - {None})
    cols = sorted({get(r, col_key) for r in recs} - {None})
    mat = np.full((len(rows), len(cols)), np.nan)
    for r in recs:
        rv, cv, val = get(r, row_key), get(r, col_key), get(r, value_key)
        if rv is None or cv is None or val is None:
            continue
        i, j = rows.index(rv), cols.index(cv)
        mat[i, j] = val if np.isnan(mat[i, j]) else (mat[i, j] + val) / 2
    return {"rows": rows, "cols": cols, "matrix": mat.tolist(),
            "row_key": row_key, "col_key": col_key, "value_key": value_key}


def render_heatmap(hm: Dict[str, Any], fmt: str = "{:7.3f}") -> str:
    lines = [f"heatmap: {hm['value_key']}  (rows={hm['row_key']}, "
             f"cols={hm['col_key']})"]
    header = " " * 10 + "".join(f"{c!s:>10}" for c in hm["cols"])
    lines.append(header)
    for rname, row in zip(hm["rows"], hm["matrix"]):
        cells = "".join(f"{fmt.format(v) if v == v else '      -':>10}"
                        for v in row)
        lines.append(f"{rname!s:>10}{cells}")
    return "\n".join(lines)


# ---- roofline points (Fig. 10) ---------------------------------------------
def roofline_point(flops: float, bytes_moved: float,
                   runtime_s: float) -> Dict[str, float]:
    """(arithmetic intensity, attained FLOP/s) for one measured run."""
    return {
        "intensity": flops / max(bytes_moved, 1.0),
        "attained_flops": flops / max(runtime_s, 1e-12),
    }


def roofline_ceiling(hw: hw_lib.HardwareModel,
                     intensities: Sequence[float]) -> List[float]:
    return [hw.attainable_flops(i) for i in intensities]


# ---- SLO attainment + saturation knee (cluster capacity planning) ----------
def slo_attainment(latencies: Sequence[float], slo_latency_s: float) -> float:
    """Fraction of requests whose latency met the SLO."""
    lat = np.asarray(latencies, dtype=float)
    if lat.size == 0:
        return 0.0
    return float(np.mean(lat <= slo_latency_s))


def saturation_knee(rates: Sequence[float], p99s: Sequence[float],
                    slo_latency_s: float) -> Optional[float]:
    """Highest offered rate whose p99 still meets the SLO (ramp sweeps).

    Scans (rate, p99) pairs in increasing-rate order and returns the last
    rate before the SLO is first violated — the serving capacity knee —
    or None if even the lowest rate misses the SLO.
    """
    knee = None
    for rate, p99 in sorted(zip(rates, p99s)):
        if p99 <= slo_latency_s:
            knee = rate
        else:
            break
    return knee


# ---- recommender (paper's utility function) --------------------------------
def recommend(db: PerfDB, *, slo_latency_s: float, metric: str = "p99_s",
              objective: str = "cost_per_1k_req", top: int = 3,
              **filters) -> List[Dict[str, Any]]:
    """Top-k configurations meeting the latency SLO at minimum objective."""
    recs = [r for r in db.query(**filters)
            if r.get("result", {}).get(metric) is not None
            and r["result"][metric] <= slo_latency_s]
    recs.sort(key=lambda r: r["result"].get(objective, float("inf")))
    return recs[:top]


# ---- leaderboard ------------------------------------------------------------
def leaderboard(db: PerfDB, *, sort_by: str = "throughput_rps",
                ascending: bool = False, limit: int = 20,
                **filters) -> str:
    recs = [r for r in db.query(**filters) if "result" in r]
    recs.sort(key=lambda r: r["result"].get(sort_by, 0.0), reverse=not ascending)
    cols = ["job_id", "arch", "policy", "chips", "throughput_rps",
            "p50_s", "p99_s", "utilization", "cost_per_1k_req"]
    lines = ["  ".join(f"{c:>16}" for c in cols)]
    for r in recs[:limit]:
        res = r["result"]
        row = [r.get("job_id", "?"), r.get("arch", "?"),
               r.get("policy", "?"), r.get("chips", "?")]
        row += [f"{res.get(k, float('nan')):.4g}" for k in cols[4:]]
        lines.append("  ".join(f"{str(c):>16}" for c in row))
    return "\n".join(lines)
