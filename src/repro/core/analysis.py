"""Analysis stage (paper §4.2.5 + §4.3.1): aggregator, CDF, heat maps,
roofline points, configuration recommender, leaderboard.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import hw as hw_lib
from repro.core.perfdb import PerfDB


# ---- CDF (Fig. 11) ---------------------------------------------------------
def cdf(values: Sequence[float], points: int = 100) -> Tuple[List[float], List[float]]:
    v = np.sort(np.asarray(values, dtype=float))
    if len(v) == 0:
        return [], []
    qs = np.linspace(0, 1, points)
    return list(np.quantile(v, qs)), list(qs)


# ---- heat maps (Fig. 9) ----------------------------------------------------
def heatmap(db: PerfDB, *, row_key: str, col_key: str, value_key: str,
            **filters) -> Dict[str, Any]:
    """Pivot PerfDB records into a (rows × cols) matrix of means.

    Dotted keys resolve through :meth:`PerfDB.get_path`; zero matching
    records yield an empty matrix rather than an error.
    """
    recs = db.query(**filters)
    get = PerfDB.get_path
    empty = {"rows": [], "cols": [], "matrix": [],
             "row_key": row_key, "col_key": col_key, "value_key": value_key}
    if not recs:
        return empty
    rows = sorted({get(r, row_key) for r in recs} - {None})
    cols = sorted({get(r, col_key) for r in recs} - {None})
    if not rows or not cols:
        return empty
    mat = np.full((len(rows), len(cols)), np.nan)
    for r in recs:
        rv, cv, val = get(r, row_key), get(r, col_key), get(r, value_key)
        if rv is None or cv is None or val is None:
            continue
        i, j = rows.index(rv), cols.index(cv)
        mat[i, j] = val if np.isnan(mat[i, j]) else (mat[i, j] + val) / 2
    return {"rows": rows, "cols": cols, "matrix": mat.tolist(),
            "row_key": row_key, "col_key": col_key, "value_key": value_key}


def render_heatmap(hm: Dict[str, Any], fmt: str = "{:7.3f}") -> str:
    lines = [f"heatmap: {hm['value_key']}  (rows={hm['row_key']}, "
             f"cols={hm['col_key']})"]
    header = " " * 10 + "".join(f"{c!s:>10}" for c in hm["cols"])
    lines.append(header)
    for rname, row in zip(hm["rows"], hm["matrix"]):
        cells = "".join(f"{fmt.format(v) if v == v else '      -':>10}"
                        for v in row)
        lines.append(f"{rname!s:>10}{cells}")
    return "\n".join(lines)


# ---- roofline points (Fig. 10) ---------------------------------------------
def roofline_point(flops: float, bytes_moved: float,
                   runtime_s: float) -> Dict[str, float]:
    """(arithmetic intensity, attained FLOP/s) for one measured run."""
    return {
        "intensity": flops / max(bytes_moved, 1.0),
        "attained_flops": flops / max(runtime_s, 1e-12),
    }


def roofline_ceiling(hw: hw_lib.HardwareModel,
                     intensities: Sequence[float]) -> List[float]:
    return [hw.attainable_flops(i) for i in intensities]


# ---- SLO attainment + saturation knee (cluster capacity planning) ----------
def slo_attainment(latencies: Sequence[float], slo_latency_s: float) -> float:
    """Fraction of requests whose latency met the SLO."""
    lat = np.asarray(latencies, dtype=float)
    if lat.size == 0:
        return 0.0
    return float(np.mean(lat <= slo_latency_s))


def jain_index(values: Sequence[float]) -> float:
    """Jain's fairness index: (Σx)² / (n·Σx²) over per-tenant allocations.

    1.0 when every tenant gets an equal (share-normalized) allocation,
    → 1/n as a single tenant monopolizes; 0.0 for empty/all-zero input
    (nothing was allocated, so no fairness to speak of).
    """
    v = np.asarray(values, dtype=float)
    denom = v.size * float(np.square(v).sum())
    if denom == 0.0:
        return 0.0
    return float(v.sum()) ** 2 / denom


def saturation_knee(rates: Sequence[float], p99s: Sequence[float],
                    slo_latency_s: float) -> Optional[float]:
    """Highest offered rate whose p99 still meets the SLO (ramp sweeps).

    Scans (rate, p99) pairs in increasing-rate order and returns the last
    rate before the SLO is first violated — the serving capacity knee —
    or None if even the lowest rate misses the SLO.
    """
    knee = None
    for rate, p99 in sorted(zip(rates, p99s)):
        if p99 <= slo_latency_s:
            knee = rate
        else:
            break
    return knee


# ---- calibration fit quality + capacity plan (repro.calibrate) -------------
def fit_report(profile) -> str:
    """Human-readable fit-quality report for a ``CalibrationProfile``.

    Duck-typed (profile objects or their dict form) so the analysis
    layer stays import-light.
    """
    if isinstance(profile, dict):
        from repro.calibrate.profile import CalibrationProfile
        profile = CalibrationProfile.from_dict(profile)
    lines = [f"calibration profile: {profile.key}  "
             f"(chips={profile.chips}, source={profile.source})"]
    for phase, names in (("prefill", ("base_s", "per_token_s",
                                      "per_token_per_prompt_s")),
                         ("decode", ("base_s", "alpha_s", "beta_s"))):
        fit = getattr(profile, phase)
        coef = "  ".join(f"{n}={c:.3e}" for n, c in zip(names, fit.coef))
        lines.append(f"  {phase:8s} {coef}")
        if fit.derived_from:
            lines.append(f"  {'':8s} (derived from {fit.derived_from}; "
                         "no measured points)")
        else:
            lines.append(f"  {'':8s} n={fit.n_points}  "
                         f"mean|rel err|={fit.mean_rel_err:.1%}  "
                         f"max={fit.max_rel_err:.1%}  R²={fit.r2:.4f}")
    if profile.holdout:
        h = profile.holdout
        lines.append("  holdout  " + "  ".join(
            f"{k}={v:.1%}" if k.endswith("rel_err") else f"{k}={v:g}"
            for k, v in sorted(h.items())))
    return "\n".join(lines)


def fleet_label(fleet) -> str:
    """Compact one-line label for a fleet composition — a ``+``-joined
    ``N×hardware`` term per pool, annotated with ``(spot)`` pricing and
    ``@region`` placement when set (PoolSpec dicts or instances)."""
    terms = []
    for p in fleet:
        if not isinstance(p, dict):
            p = dataclasses.asdict(p)
        term = f"{p.get('replicas', 1)}x{p.get('hardware') or 'base'}"
        if p.get("pricing", "reserved") != "reserved":
            term += f"({p['pricing']})"
        if p.get("region"):
            term += f"@{p['region']}"
        terms.append(term)
    return "+".join(terms)


def plan_table(plan) -> str:
    """Render a ``PlanResult`` grid: feasible configs first, best starred;
    memory-rejected candidates print their rejection reason.  The
    ``split`` column shows disaggregated candidates as ``P+D``
    (prefill+decode replicas), ``-`` for colocated; the ``fleet``
    column compacts heterogeneous compositions to
    ``2xtpu-v5e+2xt4(spot)``, ``-`` for flat clusters."""
    best = plan.best
    slos = []
    if getattr(plan, "slo_latency_s", None) is not None:
        slos.append(f"e2e ≤ {plan.slo_latency_s * 1e3:.0f}ms")
    if getattr(plan, "ttft_slo_s", None) is not None:
        slos.append(f"ttft ≤ {plan.ttft_slo_s * 1e3:.0f}ms")
    if getattr(plan, "tpot_slo_s", None) is not None:
        slos.append(f"tpot ≤ {plan.tpot_slo_s * 1e3:.1f}ms")
    header = (f"capacity plan vs {plan.profile_key}: "
              f"SLO p({' ∧ '.join(slos)}) ≥ "
              f"{plan.slo_target:.0%}, minimize {plan.objective}")
    cols = f"{'':2s}{'replicas':>9}{'split':>7}{'fleet':>24}" \
           f"{'policy':>12}" \
           f"{'router':>14}{'slots':>7}{'mode':>12}{'thr rps':>9}" \
           f"{'p99 ms':>8}{'ttft99':>8}{'slo':>6}{plan.objective:>18}"
    lines = [header, cols]
    for c in plan.candidates:
        m = c.metrics
        slots = getattr(c, "max_batch", 0) or "-"
        split = getattr(c, "split", None)
        split_s = f"{split[0]}+{split[1]}" if split else "-"
        fleet = getattr(c, "fleet", None)
        fleet_s = fleet_label(fleet) if fleet else "-"
        mode = getattr(c, "speed_mode", "fp16") or "fp16"
        prefix = f"{'':2s}{c.replicas:>9}{split_s:>7}{fleet_s:>24}" \
                 f"{c.policy:>12}" \
                 f"{c.router:>14}{slots:>7}{mode:>12}"
        if getattr(c, "infeasible_reason", None):
            lines.append(f"m {prefix[2:]}  REJECTED: {c.infeasible_reason}")
            continue
        star = "* " if best is not None and c == best else \
            ("  " if c.meets_slo else "x ")
        ttft99 = m.get("ttft_p99_s")
        ttft_s = f"{ttft99 * 1e3:>8.1f}" if ttft99 is not None \
            else f"{'-':>8}"
        lines.append(f"{star}{prefix[2:]}"
                     f"{m['throughput_rps']:>9.1f}{m['p99_s'] * 1e3:>8.1f}"
                     f"{ttft_s}"
                     f"{m['slo_attainment']:>6.2f}{c.objective:>18.6f}")
    if best is None:
        lines.append("  (no configuration met the SLO target)")
    return "\n".join(lines)


# ---- KV-cache memory accounting (memory-aware serving) ---------------------
def memory_table(db: PerfDB, **filters) -> str:
    """Per-job KV-cache occupancy / prefix-hit / preemption table over
    benchmark records that ran with memory accounting enabled."""
    recs = [r for r in db.query(**filters) if r.get("memory")]
    cols = f"{'job_id':>16}{'arch':>14}{'policy':>12}{'blocks':>8}" \
           f"{'peak occ':>10}{'mean occ':>10}{'hit rate':>10}" \
           f"{'preempt':>9}{'evict':>7}"
    lines = ["KV-cache accounting (per-replica blocks)", cols]
    for r in recs:
        m = r["memory"]
        lines.append(
            f"{r.get('job_id', '?'):>16}{r.get('arch', '?'):>14}"
            f"{r.get('policy', '?'):>12}"
            f"{m.get('total_blocks_per_replica', 0):>8}"
            f"{m.get('peak_occupancy', 0.0):>10.2%}"
            f"{m.get('mean_occupancy', 0.0):>10.2%}"
            f"{m.get('prefix_hit_rate', 0.0):>10.2%}"
            f"{m.get('preemptions', 0):>9}{m.get('evictions', 0):>7}")
    if not recs:
        lines.append("  (no records with memory accounting)")
    return "\n".join(lines)


# ---- recommender (paper's utility function) --------------------------------
def recommend(db: PerfDB, *, slo_latency_s: float, metric: str = "p99_s",
              objective: str = "cost_per_1k_req", top: int = 3,
              **filters) -> List[Dict[str, Any]]:
    """Top-k configurations meeting the latency SLO at minimum objective."""
    recs = [r for r in db.query(**filters)
            if r.get("result", {}).get(metric) is not None
            and r["result"][metric] <= slo_latency_s]
    recs.sort(key=lambda r: r["result"].get(objective, float("inf")))
    return recs[:top]


# ---- leaderboard ------------------------------------------------------------
def leaderboard(db: PerfDB, *, sort_by: str = "throughput_rps",
                ascending: bool = False, limit: int = 20,
                **filters) -> str:
    recs = [r for r in db.query(**filters) if "result" in r]
    if "kind" not in filters:
        # calibration grid points / plan records aren't serving results;
        # keep them out unless a kind is asked for explicitly
        recs = [r for r in recs if r.get("kind", "benchmark") == "benchmark"]
    recs.sort(key=lambda r: r["result"].get(sort_by, 0.0), reverse=not ascending)
    cols = ["job_id", "arch", "policy", "chips", "throughput_rps",
            "p50_s", "p99_s", "utilization", "cost_per_1k_req"]
    lines = ["  ".join(f"{c:>16}" for c in cols)]
    for r in recs[:limit]:
        res = r["result"]
        row = [r.get("job_id", "?"), r.get("arch", "?"),
               r.get("policy", "?"), r.get("chips", "?")]
        row += [f"{res.get(k, float('nan')):.4g}" for k in cols[4:]]
        lines.append("  ".join(f"{str(c):>16}" for c in row))
    return "\n".join(lines)
