"""Deprecated leader/follower entry point (paper §4.1, Fig. 1 & 5).

The orchestration now lives in :mod:`repro.core.session` — submissions go
through ``BenchmarkSession`` and a pluggable ``Executor``, and results are
typed ``JobResult`` objects.  This module keeps the old ``Leader.submit()``
+ ``run_all()`` surface (untyped PerfDB record dicts) as a thin shim so
existing scripts keep working for one release.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, List, Optional

from repro.core.perfdb import PerfDB
from repro.core.session import (BenchmarkSession, Follower, InlineExecutor,
                                execute_job)

__all__ = ["Leader", "Follower", "execute_job"]


class Leader:
    """Deprecated: use ``repro.core.session.BenchmarkSession``.

    One behavior change vs the old Leader: duplicate pending ``job_id``s
    are now rejected with ``ValueError`` (the old path silently executed
    both submissions against the last-registered spec, double-writing
    the PerfDB under one id). Give repeated trials distinct job ids.
    """

    def __init__(self, n_workers: int = 4, db: Optional[PerfDB] = None,
                 lb: str = "qa", order: str = "sjf"):
        warnings.warn(
            "repro.core.leader.Leader is deprecated; use "
            "repro.core.session.BenchmarkSession instead",
            DeprecationWarning, stacklevel=2)
        self._session = BenchmarkSession(n_workers=n_workers, db=db,
                                         lb=lb, order=order,
                                         executor=InlineExecutor())

    @property
    def db(self) -> PerfDB:
        return self._session.db

    @property
    def workers(self) -> List[Follower]:
        return self._session.followers

    @property
    def scheduler(self):
        return self._session.scheduler

    def submit(self, spec) -> None:
        self._session.submit(spec)

    def run_all(self) -> List[Dict[str, Any]]:
        """Schedule and execute all queued submissions; returns records."""
        return [r.to_record() for r in self._session.run()]
