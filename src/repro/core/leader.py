"""Leader/follower benchmark orchestration (paper §4.1, Fig. 1 & 5).

The leader accepts job submissions, places them on follower workers via the
two-tier scheduler, and drives each job through the four stages:

  1 Generate — resolve the model (registered arch or canonical generated
               model) + workload trace,
  2 Serve    — run the serving pipeline (simulator clocked by the roofline
               latency oracle, or real CPU execution for generated models),
  3 Collect  — per-stage latencies, utilization, energy/cost,
  4 Analyze  — aggregate into PerfDB; recommender/leaderboard read from it.

On a real cluster the followers are processes on idle nodes; here they are
simulated workers with the same queueing semantics (the scheduler, the
stage pipeline and the PerfDB schema are the production artifacts).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

from repro.configs import ARCHS, get_config
from repro.core import generator as gen_lib
from repro.core.perfdb import PerfDB
from repro.core.scheduler import ClusterScheduler, Job, ScheduledJob
from repro.core.spec import BenchmarkJobSpec
from repro import hw as hw_lib
from repro.serving.batching import make_policy
from repro.serving.latency_model import (LatencyModel, MeasuredLatency,
                                         NETWORKS)
from repro.serving.simulator import simulate


def _resolve_policy(spec: BenchmarkJobSpec):
    sw = spec.software
    if sw.policy in ("none", "nobatch"):
        return make_policy("none")
    if sw.policy in ("tfs", "window"):
        return make_policy("tfs", max_batch=sw.max_batch,
                           timeout_s=sw.timeout_s)
    return make_policy("tris", preferred=tuple(sw.preferred))


def execute_job(spec: BenchmarkJobSpec) -> Dict[str, Any]:
    """Stages 1–3 for one job; returns the PerfDB record."""
    t0 = time.time()
    hwm = hw_lib.HARDWARE[spec.hardware]
    record: Dict[str, Any] = {
        "job_id": spec.job_id,
        "user": spec.user,
        "arch": spec.model.name,
        "hardware": spec.hardware,
        "chips": spec.chips,
        "policy": spec.software.policy,
        "network": spec.network,
        "spec": spec.to_dict(),
    }

    if spec.model.kind == "generated":
        gspec = gen_lib.GeneratedSpec(
            family=spec.model.family, layers=spec.model.layers,
            width=spec.model.width, batch=spec.model.batch_hint)
        import jax
        params, apply_fn, inputs = gen_lib.build(gspec)
        jitted = jax.jit(apply_fn)
        measured = MeasuredLatency(jitted).measure(params, *inputs)
        flops = gspec.batch * gen_lib.flops_estimate(gspec)
        bytes_moved = gen_lib.param_bytes(params) + sum(
            float(x.size * x.dtype.itemsize) for x in inputs)
        record["generated"] = dataclasses.asdict(gspec)
        record["result"] = {
            "latency_s": measured,
            "throughput_rps": gspec.batch / measured,
            "flops": flops,
            "bytes": bytes_moved,
            "intensity": flops / max(bytes_moved, 1.0),
            "attained_flops": flops / measured,
            "mode": "measured-cpu",
        }
    else:
        cfg = get_config(spec.model.name)
        lat = LatencyModel(cfg, hw=hwm, chips=spec.chips,
                           int8=spec.software.int8)
        policy = _resolve_policy(spec)
        res = simulate(spec.workload, policy, lat,
                       network=NETWORKS[spec.network])
        record["result"] = dict(res.summary(), mode="roofline-model")
        record["stages"] = res.stage_means()
        record["cold_start_s"] = lat.cold_start()

    record["benchmark_wall_s"] = time.time() - t0
    return record


@dataclasses.dataclass
class Follower:
    worker_id: int
    busy_until: float = 0.0
    executed: int = 0


class Leader:
    """Accepts submissions, schedules, executes, stores (paper Fig. 5)."""

    def __init__(self, n_workers: int = 4, db: Optional[PerfDB] = None,
                 lb: str = "qa", order: str = "sjf"):
        self.db = db if db is not None else PerfDB()
        self.workers = [Follower(i) for i in range(n_workers)]
        self.scheduler = ClusterScheduler(n_workers, lb=lb, order=order)
        self._submissions: List[BenchmarkJobSpec] = []

    def submit(self, spec: BenchmarkJobSpec) -> None:
        self._submissions.append(spec)

    def run_all(self) -> List[Dict[str, Any]]:
        """Schedule all queued submissions and execute them in order."""
        jobs = [Job(job_id=s.job_id, submit_s=float(i),
                    processing_s=s.est_processing_s)
                for i, s in enumerate(self._submissions)]
        schedule = self.scheduler.run(jobs)
        order = {s.job.job_id: s for s in schedule}
        specs = {s.job_id: s for s in self._submissions}
        results = []
        for sj in sorted(schedule, key=lambda s: s.start_s):
            spec = specs[sj.job.job_id]
            rec = execute_job(spec)
            rec["sched"] = {"worker": sj.worker, "start_s": sj.start_s,
                            "finish_s": sj.finish_s, "jct_s": sj.jct}
            self.workers[sj.worker].executed += 1
            self.db.insert(rec)
            results.append(rec)
        self._submissions.clear()
        return results
