"""Two-tier benchmark-job scheduler (paper §4.3.2, Algorithm 1).

Tier 1: the leader's load balancer places a job on a follower worker —
  RR  (round-robin, the baseline) or
  QA  (queue-aware: the worker with the shortest total queued time).
Tier 2: each worker orders its queue —
  FCFS (arrival order) or SJF (ascending processing time).

The paper's claim: QA-LB + SJF reduces average job-completion time 1.43×
(≈30%) vs RR + FCFS.  ``evaluate_schedulers`` reproduces that experiment
(EXPERIMENTS.md §Paper-claims).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

RR = "rr"
QA = "qa"
FCFS = "fcfs"
SJF = "sjf"


@dataclasses.dataclass
class Job:
    job_id: str
    submit_s: float
    processing_s: float


@dataclasses.dataclass
class ScheduledJob:
    job: Job
    worker: int
    start_s: float
    finish_s: float

    @property
    def jct(self) -> float:
        """Job completion time = waiting + processing (paper's t_j)."""
        return self.finish_s - self.job.submit_s


class ClusterScheduler:
    """Simulates placement + per-worker execution for a job trace."""

    def __init__(self, n_workers: int, lb: str = QA, order: str = SJF):
        assert lb in (RR, QA) and order in (FCFS, SJF)
        self.n_workers = n_workers
        self.lb = lb
        self.order = order

    def run(self, jobs: Sequence[Job]) -> List[ScheduledJob]:
        jobs = sorted(jobs, key=lambda j: j.submit_s)
        free_at = [0.0] * self.n_workers        # worker busy horizon
        queued: List[List[Job]] = [[] for _ in range(self.n_workers)]
        rr_next = 0
        placements: Dict[str, int] = {}

        # Tier 1 — placement at submission time.
        for job in jobs:
            if self.lb == RR:
                w = rr_next
                rr_next = (rr_next + 1) % self.n_workers
            else:  # queue-aware: shortest total outstanding work
                loads = [max(free_at[i], job.submit_s)
                         + sum(j.processing_s for j in queued[i])
                         for i in range(self.n_workers)]
                w = int(np.argmin(loads))
            queued[w].append(job)
            placements[job.job_id] = w

        # Tier 2 — per-worker ordering + sequential execution.
        out: List[ScheduledJob] = []
        for w in range(self.n_workers):
            q = list(queued[w])
            if self.order == SJF:
                # re-order within the scheduling interval (paper: processing
                # times known before execution)
                q.sort(key=lambda j: (j.submit_s, j.processing_s))
                # SJF applies among jobs that are waiting together: simulate
                # by repeatedly picking the shortest *available* job.
                t = 0.0
                remaining = sorted(q, key=lambda j: j.submit_s)
                done: List[ScheduledJob] = []
                while remaining:
                    avail = [j for j in remaining if j.submit_s <= t]
                    if not avail:
                        t = min(j.submit_s for j in remaining)
                        continue
                    nxt = min(avail, key=lambda j: j.processing_s)
                    remaining.remove(nxt)
                    start = max(t, nxt.submit_s)
                    finish = start + nxt.processing_s
                    done.append(ScheduledJob(nxt, w, start, finish))
                    t = finish
                out.extend(done)
            else:  # FCFS
                t = 0.0
                for j in q:
                    start = max(t, j.submit_s)
                    finish = start + j.processing_s
                    out.append(ScheduledJob(j, w, start, finish))
                    t = finish
        return out


def average_jct(schedule: List[ScheduledJob]) -> float:
    return float(np.mean([s.jct for s in schedule])) if schedule else 0.0


def make_job_trace(n_jobs: int = 200, n_heavy_frac: float = 0.2,
                   arrival_rate: float = 2.0, seed: int = 0) -> List[Job]:
    """Benchmark-job trace: mostly short smoke jobs + a heavy AutoML tail
    (the paper's motivation: AutoML-style tasks hog workers)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    jobs = []
    for i in range(n_jobs):
        t += rng.exponential(1.0 / arrival_rate)
        if rng.random() < n_heavy_frac:
            proc = rng.uniform(20.0, 60.0)       # AutoML-ish sweeps
        else:
            proc = rng.uniform(0.5, 5.0)         # single-config checks
        jobs.append(Job(job_id=f"j{i}", submit_s=t, processing_s=proc))
    return jobs


def evaluate_schedulers(n_workers: int = 4, n_jobs: int = 200,
                        seed: int = 0) -> Dict[str, float]:
    """Reproduce the paper's Fig. 15: RR+FCFS vs QA+FCFS (LB) vs QA+SJF."""
    jobs = make_job_trace(n_jobs=n_jobs, seed=seed)
    out = {}
    for name, (lb, order) in {
        "rr_fcfs": (RR, FCFS),
        "qa_fcfs": (QA, FCFS),
        "rr_sjf": (RR, SJF),
        "qa_sjf": (QA, SJF),
    }.items():
        sched = ClusterScheduler(n_workers, lb=lb, order=order)
        out[name] = average_jct(sched.run(jobs))
    out["speedup_qa_sjf_vs_rr_fcfs"] = out["rr_fcfs"] / out["qa_sjf"]
    return out
