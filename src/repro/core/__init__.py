from repro.core.leader import Leader
from repro.core.perfdb import PerfDB
from repro.core.results import JobResult, ScheduleInfo, StageBreakdown
from repro.core.scheduler import ClusterScheduler, evaluate_schedulers
from repro.core.session import (BenchmarkSession, ConcurrentFollowerExecutor,
                                Executor, Follower, InlineExecutor, JobHandle,
                                execute_job, resolve_policy, run_stages)
from repro.core.spec import (BenchmarkJobSpec, CalibrationSpec, ClusterSpec,
                             DisaggSpec, MemorySpec, ModelRef, PlanSpec,
                             SoftwareSpec, SweepSpec, load_jobs,
                             spec_from_dict)

__all__ = [
    "BenchmarkSession", "ConcurrentFollowerExecutor", "Executor", "Follower",
    "InlineExecutor", "JobHandle", "execute_job", "resolve_policy",
    "run_stages", "JobResult", "ScheduleInfo", "StageBreakdown", "Leader",
    "PerfDB", "ClusterScheduler", "evaluate_schedulers", "BenchmarkJobSpec",
    "CalibrationSpec", "ClusterSpec", "DisaggSpec", "MemorySpec", "ModelRef",
    "PlanSpec", "SoftwareSpec", "SweepSpec", "load_jobs", "spec_from_dict",
]
