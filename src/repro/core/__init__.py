from repro.core.leader import Leader, execute_job
from repro.core.perfdb import PerfDB
from repro.core.scheduler import ClusterScheduler, evaluate_schedulers
from repro.core.spec import BenchmarkJobSpec, ModelRef, SoftwareSpec, SweepSpec

__all__ = ["Leader", "execute_job", "PerfDB", "ClusterScheduler",
           "evaluate_schedulers", "BenchmarkJobSpec", "ModelRef",
           "SoftwareSpec", "SweepSpec"]
