"""Canonical model generator (paper §4.2.2).

Builds parameterised micro-models from the paper's four block families —
FC, residual CNN, LSTM, transformer — across swept hyper-parameters
(layer count, width/neurons, batch size).  Unlike the registered real-world
archs these run *for real* on CPU, so the sensitivity heat maps (Fig. 9)
and generated-model rooflines (Fig. 10b) use measured numbers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

FAMILIES = ("fc", "cnn", "lstm", "transformer")


@dataclasses.dataclass(frozen=True)
class GeneratedSpec:
    family: str
    layers: int = 4
    width: int = 256          # neurons / channels / hidden / d_model
    batch: int = 1
    seq: int = 64             # lstm/transformer sequence, cnn spatial 32²
    num_classes: int = 100

    @property
    def name(self) -> str:
        return f"{self.family}-L{self.layers}-W{self.width}"


def _dense(key, i, o):
    return jax.random.normal(key, (i, o), jnp.float32) / math.sqrt(i)


def example_inputs(spec: GeneratedSpec) -> Tuple:
    """The example input batch for a spec — separate from ``build`` so
    latency sweeps can vary (batch, seq) without re-initializing params
    (which depend only on family/layers/width)."""
    if spec.family == "fc":
        return (jnp.ones((spec.batch, spec.width), jnp.float32),)
    if spec.family == "cnn":
        return (jnp.ones((spec.batch, 32, 32, 3), jnp.float32),)
    if spec.family in ("lstm", "transformer"):
        return (jnp.ones((spec.batch, spec.seq, spec.width), jnp.float32),)
    raise ValueError(spec.family)


def build(spec: GeneratedSpec) -> Tuple[Dict, Callable, Tuple]:
    """Returns (params, apply_fn, example_inputs)."""
    key = jax.random.key(hash(spec.name) % (2 ** 31))
    ks = jax.random.split(key, spec.layers + 2)
    W = spec.width

    if spec.family == "fc":
        params = {"in": _dense(ks[0], W, W),
                  "layers": jnp.stack([_dense(ks[i + 1], W, W)
                                       for i in range(spec.layers)]),
                  "out": _dense(ks[-1], W, spec.num_classes)}

        def apply(p, x):
            h = jnp.tanh(x @ p["in"])
            def body(h, w):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, h, p["layers"])
            return h @ p["out"]
        return params, apply, example_inputs(spec)

    if spec.family == "cnn":
        C = max(W // 16, 8)
        def conv_w(k, ci, co):
            return jax.random.normal(k, (3, 3, ci, co), jnp.float32) \
                / math.sqrt(9 * ci)
        params = {"in": conv_w(ks[0], 3, C),
                  "layers": jnp.stack([conv_w(ks[i + 1], C, C)
                                       for i in range(spec.layers)]),
                  "out": _dense(ks[-1], C, spec.num_classes)}

        def apply(p, x):
            dn = jax.lax.conv_dimension_numbers(x.shape, p["in"].shape,
                                                ("NHWC", "HWIO", "NHWC"))
            h = jax.nn.relu(jax.lax.conv_general_dilated(
                x, p["in"], (1, 1), "SAME", dimension_numbers=dn))
            def body(h, w):
                y = jax.lax.conv_general_dilated(
                    h, w, (1, 1), "SAME", dimension_numbers=dn)
                return jax.nn.relu(y) + h, None      # residual block
            h, _ = jax.lax.scan(body, h, p["layers"])
            return h.mean(axis=(1, 2)) @ p["out"]
        return params, apply, example_inputs(spec)

    if spec.family == "lstm":
        def cell_w(k):
            k1, k2 = jax.random.split(k)
            return {"wx": _dense(k1, W, 4 * W), "wh": _dense(k2, W, 4 * W)}
        params = {"in": _dense(ks[0], W, W),
                  "layers": jax.tree.map(
                      lambda *xs: jnp.stack(xs),
                      *[cell_w(ks[i + 1]) for i in range(spec.layers)]),
                  "out": _dense(ks[-1], W, spec.num_classes)}

        def lstm_layer(w, xs):
            def step(carry, x):
                h, c = carry
                z = x @ w["wx"] + h @ w["wh"]
                i, f, g, o = jnp.split(z, 4, axis=-1)
                c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
                h = jax.nn.sigmoid(o) * jnp.tanh(c)
                return (h, c), h
            B = xs.shape[1]
            h0 = (jnp.zeros((B, W)), jnp.zeros((B, W)))
            _, hs = jax.lax.scan(step, h0, xs)
            return hs

        def apply(p, x):
            hs = jnp.tanh(x @ p["in"]).transpose(1, 0, 2)     # (S, B, W)
            def body(hs, w):
                return lstm_layer(w, hs), None
            hs, _ = jax.lax.scan(body, hs, p["layers"])
            return hs[-1] @ p["out"]
        return params, apply, example_inputs(spec)

    if spec.family == "transformer":
        H = max(W // 64, 1)
        def block_w(k):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            return {"wq": _dense(k1, W, W), "wk": _dense(k2, W, W),
                    "wv": _dense(k3, W, W), "wo": _dense(k4, W, W),
                    "w1": _dense(jax.random.fold_in(k1, 9), W, 4 * W),
                    "w2": _dense(jax.random.fold_in(k2, 9), 4 * W, W)}
        params = {"in": _dense(ks[0], W, W),
                  "layers": jax.tree.map(
                      lambda *xs: jnp.stack(xs),
                      *[block_w(ks[i + 1]) for i in range(spec.layers)]),
                  "out": _dense(ks[-1], W, spec.num_classes)}

        def apply(p, x):
            h = x @ p["in"]
            def body(h, w):
                B, S, _ = h.shape
                q = (h @ w["wq"]).reshape(B, S, H, W // H)
                k = (h @ w["wk"]).reshape(B, S, H, W // H)
                v = (h @ w["wv"]).reshape(B, S, H, W // H)
                logits = jnp.einsum("bshd,bthd->bhst", q, k) / math.sqrt(W // H)
                mask = jnp.tril(jnp.ones((S, S), bool))
                logits = jnp.where(mask, logits, -1e30)
                a = jax.nn.softmax(logits, -1)
                o = jnp.einsum("bhst,bthd->bshd", a, v).reshape(B, S, W)
                h = h + o @ w["wo"]
                h = h + jax.nn.relu(h @ w["w1"]) @ w["w2"]
                return h, None
            h, _ = jax.lax.scan(body, h, p["layers"])
            return h[:, -1] @ p["out"]
        return params, apply, example_inputs(spec)

    raise ValueError(spec.family)


def flops_estimate(spec: GeneratedSpec) -> float:
    """Analytic inference FLOPs per example (for roofline intensity)."""
    W, L, S = spec.width, spec.layers, spec.seq
    if spec.family == "fc":
        return 2 * W * W * (L + 1) + 2 * W * spec.num_classes
    if spec.family == "cnn":
        C = max(W // 16, 8)
        return 2 * 9 * C * C * 32 * 32 * L + 2 * 9 * 3 * C * 32 * 32
    if spec.family == "lstm":
        return S * L * 2 * (W * 4 * W * 2)
    if spec.family == "transformer":
        return S * L * (2 * 4 * W * W + 2 * 8 * W * W) + 4 * S * S * W * L
    raise ValueError(spec.family)


def param_bytes(params) -> float:
    return float(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params)))
