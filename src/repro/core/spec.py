"""Benchmark job specification — the paper's "a YAML file with a few lines".

A ``BenchmarkJobSpec`` fully describes one benchmark task: which model
(a registered arch or a generated canonical model), which hardware tier,
which serving-software tier (batching policy + runtime options), which
workload, and which metrics/SLO to evaluate.  ``SweepSpec`` expands the
cross-product the way the paper's system iterates configurations.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Sequence, Union

from repro.obs.spec import ObsSpec
from repro.serving.cluster import ClusterSpec, DisaggSpec
from repro.serving.memory import MemorySpec
from repro.serving.workload import WorkloadSpec


def _load_config_data(path: Union[str, Path]) -> Dict[str, Any]:
    """Parse a JSON or TOML config file into a plain dict."""
    path = Path(path)
    suffix = path.suffix.lower()
    if suffix == ".toml":
        try:
            import tomllib
        except ImportError:
            try:
                import tomli as tomllib  # type: ignore[no-redef]
            except ImportError as e:  # pragma: no cover - env without tomli
                raise RuntimeError(
                    "TOML configs need Python 3.11+ (tomllib) or the tomli "
                    f"package; rewrite {path} as JSON instead") from e
        with path.open("rb") as f:
            return tomllib.load(f)
    if suffix == ".json":
        return json.loads(path.read_text())
    raise ValueError(f"unsupported config format {suffix!r} for {path} "
                     "(expected .json or .toml)")


@dataclasses.dataclass(frozen=True)
class ModelRef:
    kind: str = "registered"        # registered | generated
    name: str = "gemma2-2b"         # arch id, or generated family
    # generated-model hyper-parameters (paper's canonical generator):
    family: str = "transformer"     # fc | cnn | lstm | transformer
    layers: int = 4
    width: int = 256
    batch_hint: int = 1

    @property
    def label(self) -> str:
        """Stable display/profile key: the arch id, or the generated
        model's canonical ``family-Ln-Wm`` name."""
        if self.kind == "generated":
            return f"{self.family}-L{self.layers}-W{self.width}"
        return self.name


@dataclasses.dataclass(frozen=True)
class SoftwareSpec:
    """Serving-software tier: batching policy + runtime options.

    Attributes:
        policy: batching scheduler — ``none`` | ``tfs`` | ``tris`` |
            ``continuous`` (Orca-style continuous batching).
        max_batch: batch-window cap (tfs/tris) or continuous-batching
            decode slots per replica (requests).
        timeout_s: batch-window wait timeout (seconds).
        preferred: preferred batch sizes, largest first (tris policy).
        max_prefill: continuous batching — prefills admitted per engine
            iteration (requests).
        int8: the paper's INT8-conversion step (legacy boolean; prefer
            ``speed_mode="int8"``).
        use_pallas_kernels: route model execution through the Pallas
            kernels (``repro.kernels.ops``) instead of pure-jnp refs.
        speed_mode: named serving :class:`~repro.serving.latency_model.
            SpeedMode` ("fp16" | "int8" | "speculative") applied to the
            latency oracle; None = vanilla fp16.
    """
    policy: str = "tris"            # none | tfs | tris | continuous
    max_batch: int = 8              # window cap / continuous decode slots
    timeout_s: float = 0.005
    preferred: Sequence[int] = (8, 4, 2, 1)
    max_prefill: int = 8            # continuous: joins per iteration
    int8: bool = False              # the paper's INT8-conversion step
    use_pallas_kernels: bool = True
    speed_mode: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class BenchmarkJobSpec:
    """One fully-specified benchmark task (the paper's YAML job).

    Attributes:
        job_id: unique submission id (string, user-chosen).
        user: submitting user (display/bookkeeping only).
        model: the :class:`ModelRef` under test.
        hardware: hardware-tier key in ``repro.hw.HARDWARE``.
        chips: accelerator chips per replica (weights/KV sharded).
        software: serving :class:`SoftwareSpec` (policy + options).
        workload: request-arrival :class:`WorkloadSpec`.
        cluster: multi-replica :class:`ClusterSpec` (routing, scaling).
        network: client-side network model key (``lan`` | ``wifi`` |
            ``4g`` | ...), see ``repro.serving.latency_model.NETWORKS``.
        scenario: named production scenario filling workload/SLO
            defaults (``repro.scenarios.profiles``); explicit fields win.
        slo_latency_s: end-to-end request latency SLO (seconds), or
            None for no latency SLO.
        slo_ttft_s: time-to-first-token SLO (seconds); enables goodput.
        slo_tpot_s: time-per-output-token SLO (seconds/token).
        metrics: metric groups to evaluate (names, e.g. "latency").
        est_processing_s: scheduler runtime hint (seconds).
        profile: calibrated-profile ref (JSON path or
            ``model@hardware``) replacing the analytic oracle.
        obs: observability opt-in (:class:`~repro.obs.spec.ObsSpec`);
            None = fast path with aggregate metrics only.
    """
    job_id: str
    user: str = "dev"
    model: ModelRef = ModelRef()
    hardware: str = "tpu-v5e"
    chips: int = 8
    software: SoftwareSpec = SoftwareSpec()
    workload: WorkloadSpec = WorkloadSpec()
    cluster: ClusterSpec = ClusterSpec()
    network: str = "lan"
    # named production scenario (repro.scenarios.profiles): one config
    # line — {"scenario": "chat"} — fills the workload's token/session
    # distributions and the job's SLOs with the profile's values;
    # explicitly-set fields always win over the profile
    scenario: Optional[str] = None
    slo_latency_s: Optional[float] = None
    # phase SLOs (the TTFT/TPOT language LLM deployments are judged by):
    # when either is set, results gain goodput_rps + phase_slo_attainment
    # (requests meeting every provided SLO jointly)
    slo_ttft_s: Optional[float] = None
    slo_tpot_s: Optional[float] = None
    metrics: Sequence[str] = ("latency", "throughput", "cost", "utilization")
    est_processing_s: float = 1.0   # scheduler hint (paper: known a priori)
    # calibrated oracle: profile JSON path or "model@hardware" key — when
    # set, serving is clocked by the fitted profile instead of the
    # analytic roofline model (hardware/chips then come from the profile)
    profile: Optional[str] = None
    # observability layer (repro.obs): time-series recorder + span
    # timeline for this job's simulation.  Merged into the cluster spec;
    # an ObsSpec already set there wins.  None (default) = fast path.
    obs: Optional[ObsSpec] = None

    def __post_init__(self):
        # accept plain dicts for the nested specs (declarative construction)
        coercions = (("model", ModelRef), ("software", SoftwareSpec),
                     ("workload", WorkloadSpec), ("cluster", ClusterSpec))
        for field, cls in coercions:
            val = getattr(self, field)
            if isinstance(val, dict):
                d = dict(val)
                if cls is SoftwareSpec and isinstance(d.get("preferred"),
                                                      list):
                    d["preferred"] = tuple(d["preferred"])
                object.__setattr__(self, field, cls(**d))
        if isinstance(self.obs, dict):
            object.__setattr__(self, "obs", ObsSpec.from_dict(self.obs))
        if self.obs is not None and self.cluster.obs is None:
            # job-level opt-in rides into the simulation via the cluster
            # spec (idempotent: a round-tripped spec re-merges to itself)
            object.__setattr__(self, "cluster",
                               dataclasses.replace(self.cluster,
                                                   obs=self.obs))
        if self.scenario:
            # resolve the named profile: fill workload fields left at
            # their defaults, and adopt the profile's SLOs where the job
            # declares none (idempotent, so to_dict → from_dict round
            # trips are stable)
            from repro.scenarios.profiles import get_profile
            prof = get_profile(self.scenario)
            object.__setattr__(self, "workload",
                               prof.apply_to_workload(self.workload))
            for slo_field, default in prof.slos().items():
                if default is not None \
                        and getattr(self, slo_field) is None:
                    object.__setattr__(self, slo_field, default)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BenchmarkJobSpec":
        d = dict(d)
        # nested dicts (model/software/workload/cluster) are coerced to
        # their spec types by __post_init__
        if isinstance(d.get("metrics"), list):
            d["metrics"] = tuple(d["metrics"])
        return cls(**d)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "BenchmarkJobSpec":
        return cls.from_dict(json.loads(text))

    @classmethod
    def from_file(cls, path: Union[str, Path]) -> "BenchmarkJobSpec":
        """One job from a JSON/TOML file (use ``load_jobs`` for sweeps)."""
        data = _load_config_data(path)
        if "base" in data or "jobs" in data:
            raise ValueError(
                f"{path} holds a sweep/job-list config; load it with "
                "repro.core.spec.load_jobs or BenchmarkSession.submit_file")
        return cls.from_dict(data)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Cross-product expansion (the paper's automatic iteration)."""
    base: BenchmarkJobSpec
    axes: Dict[str, List[Any]] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"base": self.base.to_dict(), "axes": dict(self.axes)}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SweepSpec":
        base = d["base"]
        if isinstance(base, dict):
            base = BenchmarkJobSpec.from_dict(base)
        return cls(base=base, axes=dict(d.get("axes", {})))

    @classmethod
    def from_file(cls, path: Union[str, Path]) -> "SweepSpec":
        return cls.from_dict(_load_config_data(path))

    def expand(self) -> Iterator[BenchmarkJobSpec]:
        keys = list(self.axes)
        for i, combo in enumerate(itertools.product(
                *(self.axes[k] for k in keys))):
            d = self.base.to_dict()
            for k, v in zip(keys, combo):
                node = d
                *path, leaf = k.split(".")
                for p in path:
                    node = node[p]
                node[leaf] = v
            d["job_id"] = f"{self.base.job_id}-{i}"
            yield BenchmarkJobSpec.from_dict(d)


# ---- calibration + capacity planning (repro.calibrate) ---------------------
@dataclasses.dataclass(frozen=True)
class CalibrationSpec:
    """A microbenchmark sweep → fitted latency profile (measure → model).

    Generated models (``model.kind == "generated"``) are executed for
    real on CPU per grid point; registered archs are swept through the
    kernel-validated analytic roofline oracle.  The resulting records
    land in PerfDB under ``kind="calibration"`` and the least-squares
    fit is persisted as a named profile when ``profile_dir`` is set.

    Attributes:
        job_id: unique submission id.
        user: submitting user.
        model: the :class:`ModelRef` to calibrate.
        hardware: hardware-tier key in ``repro.hw.HARDWARE``.
        chips: chips per replica the fit is valid for.
        batches: batch sizes swept (requests per step).
        seqs: prefill prompt lengths swept (tokens).
        contexts: decode KV context lengths swept (tokens); empty
            means reuse ``seqs``.
        mode: ``auto`` | ``measured`` (wall-clock CPU) | ``oracle``
            (analytic roofline).
        repeats: measured-mode timing iterations per grid point
            (min-of-N per pass, two passes).
        holdout_fraction: fraction of grid points held out to score
            fit generalization (0 disables).
        profile_dir: directory the fitted profile JSON is saved to
            (None = don't persist).
        kernels: Pallas kernels to microbench alongside the model
            sweep (``repro.calibrate.kernel_bench`` registry names;
            empty = skip).  Their per-kernel fits + derived speed
            modes ride into the profile.
        kernel_target: what the kernel sweep clocks — ``auto``
            (reference on CPU, compiled kernel on TPU) | ``kernel`` |
            ``reference``.
        est_processing_s: scheduler runtime hint (seconds).
    """
    job_id: str
    user: str = "dev"
    model: ModelRef = ModelRef(kind="generated", family="fc",
                               layers=2, width=64)
    hardware: str = "cpu-xeon"
    chips: int = 1
    batches: Sequence[int] = (1, 2, 4, 8)
    seqs: Sequence[int] = (16, 32, 64, 128)
    contexts: Sequence[int] = ()        # decode KV lengths; () → ``seqs``
    mode: str = "auto"                  # auto | measured | oracle
    repeats: int = 10                   # measured-mode timing iterations
                                        # (min-of-N per pass, two passes)
    holdout_fraction: float = 0.25      # grid points held out for validation
    profile_dir: Optional[str] = None   # save the fitted profile JSON here
    kernels: Sequence[str] = ()         # Pallas kernels to microbench too
    kernel_target: str = "auto"         # auto | kernel | reference
    est_processing_s: float = 1.0       # scheduler hint

    kind = "calibration"

    def __post_init__(self):
        if isinstance(self.model, dict):
            object.__setattr__(self, "model", ModelRef(**self.model))
        for field in ("batches", "seqs", "contexts", "kernels"):
            val = getattr(self, field)
            if isinstance(val, list):
                object.__setattr__(self, field, tuple(val))

    def to_dict(self) -> Dict[str, Any]:
        return dict(dataclasses.asdict(self), kind=self.kind)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CalibrationSpec":
        d = dict(d)
        d.pop("kind", None)
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class PlanSpec:
    """An SLO-aware capacity-planning job (model → plan).

    Loads a calibration profile (path or ``model@hardware`` key), drives
    the cluster simulator over a replicas × batching-policy × router
    grid, and reports the cheapest configuration whose SLO attainment
    meets ``slo_target``.

    Attributes:
        job_id: unique submission id.
        profile: calibration-profile ref — JSON path or
            ``model@hardware`` key resolved in ``profile_dir``.
        user: submitting user.
        profile_dir: directory ``model@hardware`` keys resolve in.
        workload: aggregate request-arrival :class:`WorkloadSpec`.
        tenants: multi-tenant split (TenantSpec list/dicts); the plan
            then requires every tenant's own SLOs at ``slo_target``.
        slo_latency_s: end-to-end latency SLO (seconds); None = only
            phase SLOs apply.
        slo_target: required attainment fraction in [0, 1].
        ttft_slo_s: time-to-first-token SLO (seconds).
        tpot_slo_s: time-per-output-token SLO (seconds/token).
        replicas: replica counts searched.
        policies: batching policies searched.
        routers: router kinds searched.
        max_batch: decode-slot cap used when ``max_batches`` is empty
            (requests).
        max_batches: decode-slot grid (requests); empty =
            ``(max_batch,)``.
        max_prefill: prefill admissions per engine iteration.
        prefill_decode_splits: disaggregated (prefill, decode) replica
            splits added to the grid.
        kv_network: interconnect for the disaggregated KV handoff.
        network: client network model key.
        objective: SLO-feasible candidates are ranked by this summary
            metric (e.g. ``cost_per_1k_req``, USD per 1000 requests).
        speed_modes: serving speed modes searched ("fp16" | "int8" |
            "speculative" names, or SpeedMode parameter dicts); empty =
            fp16 only.  Calibrated parameters in the profile's
            ``speed_modes`` section override the named presets.
        memory: per-replica HBM budget
            (:class:`~repro.serving.memory.MemorySpec`); candidates
            whose KV working set cannot fit are rejected up front.
        fleets: heterogeneous fleet compositions added to the grid —
            each entry is a list of ``PoolSpec`` dicts (hardware,
            replicas, pricing, region, ...), simulated under every
            policy/router/slot combination so the plan can recommend a
            device mix or a spot-backed fleet on the objective.
        est_processing_s: scheduler runtime hint (seconds).
    """
    job_id: str
    profile: str                         # profile path or model@hardware key
    user: str = "dev"
    profile_dir: str = "configs/profiles"
    workload: WorkloadSpec = WorkloadSpec()
    # multi-tenant mix: TenantSpec list (or dicts) splitting the
    # workload's aggregate rate — the plan then requires *every*
    # tenant's own SLOs at slo_target (see repro.scenarios.tenants)
    tenants: Sequence[Any] = ()
    slo_latency_s: Optional[float] = 0.25
    slo_target: float = 0.99             # required attainment fraction
    # phase SLOs: attainment becomes joint over every SLO provided (set
    # slo_latency_s to None to plan on TTFT/TPOT alone)
    ttft_slo_s: Optional[float] = None
    tpot_slo_s: Optional[float] = None
    replicas: Sequence[int] = (1, 2, 4)
    policies: Sequence[str] = ("tfs", "continuous")
    routers: Sequence[str] = ("least-loaded",)
    max_batch: int = 16
    max_batches: Sequence[int] = ()      # grid over decode slots; () →
                                         # (max_batch,)
    max_prefill: int = 8
    # disaggregation axis: (prefill, decode) replica splits added to the
    # search grid as split-pool candidates (KV handoff over kv_network)
    prefill_decode_splits: Sequence[Sequence[int]] = ()
    kv_network: str = "infiniband"
    network: str = "lan"
    objective: str = "cost_per_1k_req"   # minimized among SLO-feasible
    # serving speed modes searched alongside the hardware/software grid;
    # names resolve through the profile's calibrated ``speed_modes``
    # section first, then the built-in presets
    speed_modes: Sequence[Any] = ()
    # KV-cache awareness: when set, candidates whose working set exceeds
    # the per-replica HBM budget are rejected up front (with the reason),
    # and feasible candidates are simulated under that budget.  Fitted
    # profiles carry no model config, so set hbm_gb + kv_bytes_per_token.
    memory: Optional[MemorySpec] = None
    # fleet-composition axis: sequences of PoolSpec dicts (heterogeneous
    # hardware / spot / regions) searched beside the flat-replica grid
    fleets: Sequence[Any] = ()
    est_processing_s: float = 1.0        # scheduler hint

    kind = "plan"

    def __post_init__(self):
        if isinstance(self.workload, dict):
            object.__setattr__(self, "workload",
                               WorkloadSpec(**self.workload))
        if isinstance(self.memory, dict):
            object.__setattr__(self, "memory",
                               MemorySpec.from_dict(self.memory))
        if self.tenants:
            from repro.scenarios.tenants import coerce_tenants
            object.__setattr__(self, "tenants",
                               coerce_tenants(self.tenants))
        else:
            object.__setattr__(self, "tenants", ())
        for field in ("replicas", "policies", "routers", "max_batches",
                      "speed_modes"):
            val = getattr(self, field)
            if isinstance(val, list):
                object.__setattr__(self, field, tuple(val))
        if isinstance(self.prefill_decode_splits, list):
            object.__setattr__(
                self, "prefill_decode_splits",
                tuple(tuple(s) for s in self.prefill_decode_splits))
        if self.fleets:
            # keep pools as plain dicts (JSON round-trip); the planner
            # coerces them into PoolSpec when it builds the grid
            object.__setattr__(
                self, "fleets",
                tuple(tuple(dict(p) if isinstance(p, dict) else p
                            for p in f) for f in self.fleets))
        else:
            object.__setattr__(self, "fleets", ())

    def to_dict(self) -> Dict[str, Any]:
        return dict(dataclasses.asdict(self), kind=self.kind)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PlanSpec":
        d = dict(d)
        d.pop("kind", None)
        return cls(**d)


AnyJobSpec = Union[BenchmarkJobSpec, CalibrationSpec, PlanSpec]

_SPEC_KINDS = {"benchmark": BenchmarkJobSpec, "calibration": CalibrationSpec,
               "plan": PlanSpec}


def spec_from_dict(d: Dict[str, Any]) -> AnyJobSpec:
    """Dict → typed spec, dispatching on the optional ``kind`` field
    (``benchmark`` when absent)."""
    kind = d.get("kind", "benchmark")
    cls = _SPEC_KINDS.get(kind)
    if cls is None:
        raise ValueError(f"unknown job kind {kind!r} "
                         f"(expected one of {sorted(_SPEC_KINDS)})")
    d = {k: v for k, v in d.items() if k != "kind"}
    return cls(**d) if cls is not BenchmarkJobSpec \
        else BenchmarkJobSpec.from_dict(d)


def load_jobs(path: Union[str, Path]) -> List[AnyJobSpec]:
    """Expand a config file into concrete job specs.

    Accepted layouts (JSON or TOML):
      * a single job object (optionally ``kind: calibration | plan``),
      * ``{"base": {...}, "axes": {...}}`` — a sweep, expanded here,
      * ``{"jobs": [{...}, ...]}`` — an explicit job list.
    """
    data = _load_config_data(path)
    if "base" in data:
        return list(SweepSpec.from_dict(data).expand())
    if "jobs" in data:
        return [spec_from_dict(j) for j in data["jobs"]]
    return [spec_from_dict(data)]
