"""Benchmark job specification — the paper's "a YAML file with a few lines".

A ``BenchmarkJobSpec`` fully describes one benchmark task: which model
(a registered arch or a generated canonical model), which hardware tier,
which serving-software tier (batching policy + runtime options), which
workload, and which metrics/SLO to evaluate.  ``SweepSpec`` expands the
cross-product the way the paper's system iterates configurations.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Any, Dict, Iterator, List, Optional, Sequence

from repro.serving.workload import WorkloadSpec


@dataclasses.dataclass(frozen=True)
class ModelRef:
    kind: str = "registered"        # registered | generated
    name: str = "gemma2-2b"         # arch id, or generated family
    # generated-model hyper-parameters (paper's canonical generator):
    family: str = "transformer"     # fc | cnn | lstm | transformer
    layers: int = 4
    width: int = 256
    batch_hint: int = 1


@dataclasses.dataclass(frozen=True)
class SoftwareSpec:
    policy: str = "tris"            # none | tfs | tris
    max_batch: int = 8
    timeout_s: float = 0.005
    preferred: Sequence[int] = (8, 4, 2, 1)
    int8: bool = False              # the paper's INT8-conversion step
    use_pallas_kernels: bool = True


@dataclasses.dataclass(frozen=True)
class BenchmarkJobSpec:
    job_id: str
    user: str = "dev"
    model: ModelRef = ModelRef()
    hardware: str = "tpu-v5e"
    chips: int = 8
    software: SoftwareSpec = SoftwareSpec()
    workload: WorkloadSpec = WorkloadSpec()
    network: str = "lan"
    slo_latency_s: Optional[float] = None
    metrics: Sequence[str] = ("latency", "throughput", "cost", "utilization")
    est_processing_s: float = 1.0   # scheduler hint (paper: known a priori)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BenchmarkJobSpec":
        d = dict(d)
        if isinstance(d.get("model"), dict):
            d["model"] = ModelRef(**d["model"])
        if isinstance(d.get("software"), dict):
            sw = dict(d["software"])
            if isinstance(sw.get("preferred"), list):
                sw["preferred"] = tuple(sw["preferred"])
            d["software"] = SoftwareSpec(**sw)
        if isinstance(d.get("workload"), dict):
            d["workload"] = WorkloadSpec(**d["workload"])
        if isinstance(d.get("metrics"), list):
            d["metrics"] = tuple(d["metrics"])
        return cls(**d)

    @classmethod
    def from_json(cls, text: str) -> "BenchmarkJobSpec":
        return cls.from_dict(json.loads(text))


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Cross-product expansion (the paper's automatic iteration)."""
    base: BenchmarkJobSpec
    axes: Dict[str, List[Any]] = dataclasses.field(default_factory=dict)

    def expand(self) -> Iterator[BenchmarkJobSpec]:
        keys = list(self.axes)
        for i, combo in enumerate(itertools.product(
                *(self.axes[k] for k in keys))):
            d = self.base.to_dict()
            for k, v in zip(keys, combo):
                node = d
                *path, leaf = k.split(".")
                for p in path:
                    node = node[p]
                node[leaf] = v
            d["job_id"] = f"{self.base.job_id}-{i}"
            yield BenchmarkJobSpec.from_dict(d)
