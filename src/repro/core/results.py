"""Typed benchmark results (paper §4.2.5 — the PerfDB record schema).

``JobResult`` is the frozen, typed view of one benchmark outcome.  It
serializes to exactly the PerfDB JSONL record layout the repo has always
written (``to_record``) and parses back losslessly (``from_record``), so
the storage schema and every existing analysis/leaderboard consumer are
unchanged — only the in-process representation is now structured.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional

from repro.core.spec import (AnyJobSpec, BenchmarkJobSpec, CalibrationSpec,
                             PlanSpec)


@dataclasses.dataclass(frozen=True)
class StageBreakdown:
    """Mean per-request latency of each pipeline stage (paper Fig. 14).

    ``batch_wait`` is the policy-attributable slice of ``queue`` (waiting
    while capacity was free but the batch had not fired), so it is *not*
    added again by ``total()``.  ``kv_transfer`` is the disaggregated
    prefill→decode KV handoff (0 for colocated serving and for records
    written before the stage existed).
    """
    preprocess: float = 0.0
    transmit: float = 0.0
    queue: float = 0.0
    inference: float = 0.0
    postprocess: float = 0.0
    batch_wait: float = 0.0
    kv_transfer: float = 0.0

    def total(self) -> float:
        return (self.preprocess + self.transmit + self.queue
                + self.kv_transfer + self.inference + self.postprocess)

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, float]) -> "StageBreakdown":
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class ScheduleInfo:
    """Where/when the two-tier scheduler placed the job (paper §4.3.2)."""
    worker: int
    start_s: float
    finish_s: float
    jct_s: float

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, float]) -> "ScheduleInfo":
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class JobResult:
    """One executed benchmark job: spec + metrics + provenance.

    ``metrics`` holds the mode-dependent measurement dict (the record's
    ``result`` field: throughput/percentiles/cost for simulated serving,
    roofline numbers for generated models); treat it as read-only.
    """
    spec: AnyJobSpec
    metrics: Dict[str, Any]
    stages: Optional[StageBreakdown] = None
    cold_start_s: Optional[float] = None
    generated: Optional[Dict[str, Any]] = None
    cluster: Optional[Dict[str, Any]] = None   # replica-tier provenance
    memory: Optional[Dict[str, Any]] = None    # KV-cache accounting (peak/
                                               # mean occupancy, prefix hit
                                               # rate, preemption count)
    timeseries: Optional[Dict[str, Any]] = None  # repro.obs Timeseries
                                               # dict (ObsSpec runs only);
                                               # the HTML report plots it
    schedule: Optional[ScheduleInfo] = None
    benchmark_wall_s: float = 0.0
    ts: Optional[float] = None
    # side-channel records the session also persists to PerfDB (e.g. the
    # per-grid-point kind="calibration" measurements behind a fitted
    # profile); not part of this result's own record
    extra_records: Optional[List[Dict[str, Any]]] = None

    # ---- convenience accessors -------------------------------------------
    @property
    def job_id(self) -> str:
        return self.spec.job_id

    @property
    def mode(self) -> str:
        return str(self.metrics.get("mode", "unknown"))

    def metric(self, key: str, default: float = float("nan")) -> float:
        return self.metrics.get(key, default)

    def with_schedule(self, schedule: ScheduleInfo) -> "JobResult":
        return dataclasses.replace(self, schedule=schedule)

    # ---- PerfDB JSONL schema ---------------------------------------------
    def to_record(self) -> Dict[str, Any]:
        """The flat PerfDB record.

        Benchmark jobs keep the unchanged legacy schema; calibration and
        plan jobs add a top-level ``kind`` plus their own provenance
        columns (``extra_records`` are *not* folded in — the session
        persists those as sibling rows).
        """
        spec = self.spec
        if isinstance(spec, CalibrationSpec):
            rec = {
                "job_id": spec.job_id,
                "user": spec.user,
                "kind": spec.kind,
                "arch": spec.model.label,
                "hardware": spec.hardware,
                "chips": spec.chips,
                "spec": spec.to_dict(),
            }
        elif isinstance(spec, PlanSpec):
            rec = {
                "job_id": spec.job_id,
                "user": spec.user,
                "kind": spec.kind,
                "profile": spec.profile,
                "spec": spec.to_dict(),
            }
        else:
            rec = {
                "job_id": spec.job_id,
                "user": spec.user,
                "arch": spec.model.name,
                "hardware": spec.hardware,
                "chips": spec.chips,
                "policy": spec.software.policy,
                "network": spec.network,
                "spec": spec.to_dict(),
            }
            if spec.scenario:
                # top-level so PerfDB queries can filter by scenario
                # without walking into the spec
                rec["scenario"] = spec.scenario
        if self.generated is not None:
            rec["generated"] = dict(self.generated)
        rec["result"] = dict(self.metrics)
        if self.stages is not None:
            rec["stages"] = self.stages.to_dict()
        if self.cold_start_s is not None:
            rec["cold_start_s"] = self.cold_start_s
        if self.cluster is not None:
            rec["cluster"] = dict(self.cluster)
        if self.memory is not None:
            rec["memory"] = dict(self.memory)
        if self.timeseries is not None:
            rec["timeseries"] = dict(self.timeseries)
        rec["benchmark_wall_s"] = self.benchmark_wall_s
        if self.schedule is not None:
            rec["sched"] = self.schedule.to_dict()
        if self.ts is not None:
            rec["ts"] = self.ts
        return rec

    @classmethod
    def from_record(cls, rec: Mapping[str, Any]) -> "JobResult":
        spec_cls = {"calibration": CalibrationSpec,
                    "plan": PlanSpec}.get(rec.get("kind", "benchmark"),
                                          BenchmarkJobSpec)
        return cls(
            spec=spec_cls.from_dict(rec["spec"]),
            metrics=dict(rec.get("result", {})),
            stages=(StageBreakdown.from_dict(rec["stages"])
                    if "stages" in rec else None),
            cold_start_s=rec.get("cold_start_s"),
            generated=(dict(rec["generated"])
                       if rec.get("generated") is not None else None),
            cluster=(dict(rec["cluster"])
                     if rec.get("cluster") is not None else None),
            memory=(dict(rec["memory"])
                    if rec.get("memory") is not None else None),
            timeseries=(dict(rec["timeseries"])
                        if rec.get("timeseries") is not None else None),
            schedule=(ScheduleInfo.from_dict(rec["sched"])
                      if "sched" in rec else None),
            benchmark_wall_s=rec.get("benchmark_wall_s", 0.0),
            ts=rec.get("ts"),
        )
