"""BenchmarkSession — the declarative job-submission surface (paper §4.1).

The paper's promise is that a developer hands the system "a configuration
file consisting of a few lines of code" and the leader/follower machinery
does the rest.  This module is that front end:

  * jobs are submitted as ``BenchmarkJobSpec`` objects, plain dicts, or
    JSON/TOML config files (single job, job list, or sweep);
  * ``submit`` returns a ``JobHandle`` future resolved when the job runs;
  * execution is pluggable behind the ``Executor`` protocol —
    ``InlineExecutor`` runs the two-tier schedule sequentially in-process,
    ``ConcurrentFollowerExecutor`` fans out one thread per follower with
    real per-worker queues and ``Follower.busy_until`` bookkeeping;
  * every outcome is a typed ``JobResult`` that serializes to the
    unchanged PerfDB JSONL schema.

The four benchmark stages per job are unchanged:
  1 Generate — resolve the model (registered arch or canonical generated
               model) + workload trace,
  2 Serve    — run the serving pipeline (simulator clocked by the roofline
               latency oracle, or real CPU execution for generated models),
  3 Collect  — per-stage latencies, utilization, energy/cost,
  4 Analyze  — aggregate into PerfDB; recommender/leaderboard read it.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Union)

from repro import hw as hw_lib
from repro.configs import get_config
from repro.core import generator as gen_lib
from repro.core.perfdb import PerfDB
from repro.core.results import JobResult, ScheduleInfo, StageBreakdown
from repro.core.scheduler import ClusterScheduler, Job, ScheduledJob
from repro.core.spec import (AnyJobSpec, BenchmarkJobSpec, CalibrationSpec,
                             PlanSpec, SoftwareSpec, SweepSpec, load_jobs,
                             spec_from_dict)
from repro.serving.batching import BatchPolicy, make_policy
from repro.serving.cluster import simulate_cluster
from repro.serving.latency_model import (FittedLatencyModel, LatencyModel,
                                         MeasuredLatency, NETWORKS)

JobLike = Union[AnyJobSpec, Mapping[str, Any], str, Path]


def resolve_policy(sw: SoftwareSpec) -> BatchPolicy:
    """Software tier → batching policy (paper's TFS vs TrIS comparison,
    plus the Orca/vLLM-style continuous batcher)."""
    if sw.policy in ("none", "nobatch"):
        return make_policy("none")
    if sw.policy in ("tfs", "window"):
        return make_policy("tfs", max_batch=sw.max_batch,
                           timeout_s=sw.timeout_s)
    if sw.policy in ("continuous", "orca", "vllm"):
        return make_policy("continuous", max_batch=sw.max_batch,
                           max_prefill=sw.max_prefill)
    return make_policy("tris", preferred=tuple(sw.preferred))


def run_stages(spec: AnyJobSpec) -> JobResult:
    """Stages 1–3 for one job; pure w.r.t. session state (thread-safe).

    Calibration and plan submissions dispatch to their own stage runners
    in :mod:`repro.calibrate` (lazy imports keep the core importable
    without pulling the calibration stack in)."""
    if isinstance(spec, CalibrationSpec):
        from repro.calibrate.microbench import run_calibration_job
        return run_calibration_job(spec)
    if isinstance(spec, PlanSpec):
        from repro.calibrate.planner import run_plan_job
        return run_plan_job(spec)

    t0 = time.time()
    hwm = hw_lib.HARDWARE[spec.hardware]

    if spec.model.kind == "generated":
        gspec = gen_lib.GeneratedSpec(
            family=spec.model.family, layers=spec.model.layers,
            width=spec.model.width, batch=spec.model.batch_hint)
        import jax
        params, apply_fn, inputs = gen_lib.build(gspec)
        jitted = jax.jit(apply_fn)
        measured = MeasuredLatency(jitted).measure(params, *inputs)
        flops = gspec.batch * gen_lib.flops_estimate(gspec)
        bytes_moved = gen_lib.param_bytes(params) + sum(
            float(x.size * x.dtype.itemsize) for x in inputs)
        return JobResult(
            spec=spec,
            generated=dataclasses.asdict(gspec),
            metrics={
                "latency_s": measured,
                "throughput_rps": gspec.batch / measured,
                "flops": flops,
                "bytes": bytes_moved,
                "intensity": flops / max(bytes_moved, 1.0),
                "attained_flops": flops / measured,
                "mode": "measured-cpu",
            },
            benchmark_wall_s=time.time() - t0)

    if spec.profile:
        # calibrated oracle: the fitted profile replaces the analytic
        # roofline model (its hardware/chips define the cost context)
        lat = FittedLatencyModel.from_profile(spec.profile)
    else:
        cfg = get_config(spec.model.name)
        lat = LatencyModel(cfg, hw=hwm, chips=spec.chips,
                           int8=spec.software.int8)
    if spec.software.speed_mode:
        # serving speed mode (int8 / speculative): scale the oracle's
        # roofline terms and effective decode step
        from repro.serving.latency_model import apply_speed_mode
        lat = apply_speed_mode(lat, spec.software.speed_mode)
    policy = resolve_policy(spec.software)
    sim_t0 = time.perf_counter()
    res = simulate_cluster(spec.workload, policy, lat, cluster=spec.cluster,
                           network=NETWORKS[spec.network])
    sim_wall = time.perf_counter() - sim_t0
    metrics = dict(res.summary(),
                   mode="fitted-profile" if spec.profile
                   else "roofline-model")
    if spec.software.speed_mode:
        metrics["speed_mode"] = spec.software.speed_mode
    # simulator provenance on every simulator-backed record: reports can
    # plot the event-loop perf trajectory straight from PerfDB
    metrics["events"] = res.events
    metrics["requests_served"] = res.requests_served or len(res.traces)
    metrics["sim_events_per_sec"] = (res.events / sim_wall
                                     if sim_wall > 0 else 0.0)
    if spec.slo_latency_s is not None:
        metrics["slo_attainment"] = res.slo_attainment(spec.slo_latency_s)
    if spec.slo_ttft_s is not None or spec.slo_tpot_s is not None:
        # joint phase attainment/goodput over every SLO the job declares
        metrics["phase_slo_attainment"] = res.phase_slo_attainment(
            ttft_slo_s=spec.slo_ttft_s, tpot_slo_s=spec.slo_tpot_s,
            e2e_slo_s=spec.slo_latency_s)
        metrics["goodput_rps"] = res.goodput(
            spec.slo_ttft_s, spec.slo_tpot_s, spec.slo_latency_s)
    if spec.workload.tenants:
        # multi-tenant run: per-tenant goodput/attainment against each
        # tenant's own SLOs + fairness/isolation aggregates
        from repro.scenarios.tenants import tenant_report
        metrics["tenants"] = tenant_report(res, spec.workload.tenants)
    cluster_info = {
        "replicas": res.replicas,
        "router": res.router,
        "autoscale": spec.cluster.autoscale,
        "replica_seconds": res.billed_replica_seconds(),
        "per_replica_busy_s": list(res.per_replica_busy_s or []),
    }
    if res.pools is not None:
        cluster_info["pools"] = dict(res.pools)
    if res.fleet is not None:
        # heterogeneous-fleet provenance: per-pool hardware/pricing bill
        # plus the spot/cross-region counters, preserved in the PerfDB
        cluster_info["fleet"] = dict(res.fleet)
    return JobResult(
        spec=spec,
        metrics=metrics,
        stages=StageBreakdown.from_dict(res.stage_means()),
        cold_start_s=lat.cold_start(),
        cluster=cluster_info,
        memory=res.memory,
        timeseries=(res.timeseries.to_dict()
                    if res.timeseries is not None else None),
        benchmark_wall_s=time.time() - t0)


def execute_job(spec: BenchmarkJobSpec) -> Dict[str, Any]:
    """Legacy entry point: stages 1–3, returned as the PerfDB record."""
    return run_stages(spec).to_record()


@dataclasses.dataclass
class Follower:
    """A follower worker (paper Fig. 5): executes its queue in order.

    ``busy_until`` tracks the worker's horizon on the schedule clock — it
    advances monotonically to each job's scheduled finish as the job
    completes, so mid-run reads reflect actual progress.
    """
    worker_id: int
    busy_until: float = 0.0
    executed: int = 0


class JobHandle:
    """Future for one submitted job; resolved when its executor runs it."""

    def __init__(self, spec: AnyJobSpec):
        self.spec = spec
        self._done = threading.Event()
        self._result: Optional[JobResult] = None
        self._exc: Optional[BaseException] = None

    @property
    def job_id(self) -> str:
        return self.spec.job_id

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> JobResult:
        if not self._done.wait(timeout):
            raise TimeoutError(f"job {self.job_id!r} not finished "
                               "(did you call BenchmarkSession.run()?)")
        if self._exc is not None:
            raise self._exc
        assert self._result is not None
        return self._result

    def _resolve(self, result: JobResult) -> None:
        self._result = result
        self._done.set()

    def _fail(self, exc: BaseException) -> None:
        self._exc = exc
        self._done.set()


@dataclasses.dataclass
class PlacedJob:
    """A submission bound to its slot in the two-tier schedule."""
    handle: JobHandle
    sched: ScheduledJob

    @property
    def spec(self) -> AnyJobSpec:
        return self.handle.spec

    def schedule_info(self) -> ScheduleInfo:
        return ScheduleInfo(worker=self.sched.worker,
                            start_s=self.sched.start_s,
                            finish_s=self.sched.finish_s,
                            jct_s=self.sched.jct)


class Executor:
    """Execution strategy for a scheduled batch of benchmark jobs.

    Implementations must honor the two-tier schedule: tier-1 placement
    (``PlacedJob.sched.worker``) is fixed, and each worker runs its own
    jobs in scheduled start order.
    """
    name = "base"

    def execute(self, placed: Sequence[PlacedJob],
                followers: Sequence[Follower],
                on_result: Callable[[JobResult], None]) -> List[JobResult]:
        raise NotImplementedError


def _run_placed(pj: PlacedJob, follower: Follower,
                on_result: Callable[[JobResult], None]) -> JobResult:
    try:
        result = run_stages(pj.spec).with_schedule(pj.schedule_info())
    except BaseException as exc:
        pj.handle._fail(exc)
        raise
    follower.busy_until = max(follower.busy_until, pj.sched.finish_s)
    follower.executed += 1
    on_result(result)
    pj.handle._resolve(result)
    return result


class InlineExecutor(Executor):
    """Sequential in-process execution in global scheduled-start order
    (the behavior of the old ``Leader.run_all``)."""
    name = "inline"

    def execute(self, placed, followers, on_result):
        results = []
        for pj in sorted(placed, key=lambda p: p.sched.start_s):
            results.append(_run_placed(pj, followers[pj.sched.worker],
                                       on_result))
        return results


class ConcurrentFollowerExecutor(Executor):
    """One thread per follower, each draining its own queue in scheduled
    order — the schedule's per-worker timelines actually run concurrently."""
    name = "concurrent"

    def execute(self, placed, followers, on_result):
        queues: Dict[int, List[PlacedJob]] = {f.worker_id: []
                                              for f in followers}
        for pj in placed:
            queues[pj.sched.worker].append(pj)
        for q in queues.values():
            q.sort(key=lambda p: p.sched.start_s)

        results: List[JobResult] = []
        lock = threading.Lock()

        def locked_on_result(res: JobResult) -> None:
            with lock:
                on_result(res)
                results.append(res)

        def drain(follower: Follower) -> None:
            for pj in queues[follower.worker_id]:
                _run_placed(pj, follower, locked_on_result)

        active = [f for f in followers if queues[f.worker_id]]
        if not active:
            return []
        with ThreadPoolExecutor(max_workers=len(active)) as pool:
            futures = [pool.submit(drain, f) for f in active]
            for fut in futures:
                fut.result()
        return results


class BenchmarkSession:
    """Facade: declarative submission → two-tier schedule → executor → PerfDB.

    >>> session = BenchmarkSession(n_workers=4)
    >>> session.submit({"job_id": "j0", "model": {"name": "gemma2-2b"}})
    >>> session.submit_file("configs/jobs/quickstart.json")   # sweep
    >>> results = session.run()                               # [JobResult]
    """

    def __init__(self, n_workers: int = 4, db: Optional[PerfDB] = None,
                 lb: str = "qa", order: str = "sjf",
                 executor: Optional[Executor] = None):
        self.db = db if db is not None else PerfDB()
        self.followers = [Follower(i) for i in range(n_workers)]
        self.scheduler = ClusterScheduler(n_workers, lb=lb, order=order)
        self.executor = executor if executor is not None else InlineExecutor()
        self._pending: List[JobHandle] = []
        self._pending_ids: set = set()
        self._results: List[JobResult] = []

    # ---- submission -------------------------------------------------------
    def _coerce(self, job: JobLike) -> AnyJobSpec:
        if isinstance(job, (BenchmarkJobSpec, CalibrationSpec, PlanSpec)):
            return job
        if isinstance(job, Mapping):
            # dicts dispatch on their optional "kind" field
            # (benchmark | calibration | plan)
            return spec_from_dict(dict(job))
        raise TypeError(f"cannot submit {type(job).__name__}; expected "
                        "BenchmarkJobSpec/CalibrationSpec/PlanSpec, dict, "
                        "or a config-file path")

    def submit(self, job: JobLike) -> JobHandle:
        """Queue one job (spec, dict, or single-job config file)."""
        if isinstance(job, (str, Path)):
            specs = load_jobs(job)
            if len(specs) != 1:
                raise ValueError(
                    f"{job} expands to {len(specs)} jobs; use submit_file")
            job = specs[0]
        spec = self._coerce(job)
        if spec.job_id in self._pending_ids:
            raise ValueError(f"duplicate pending job_id {spec.job_id!r}")
        handle = JobHandle(spec)
        self._pending.append(handle)
        self._pending_ids.add(spec.job_id)
        return handle

    def submit_sweep(self, sweep: Union[SweepSpec, Mapping[str, Any]]
                     ) -> List[JobHandle]:
        """Queue a cross-product sweep (SweepSpec or its dict form)."""
        if isinstance(sweep, Mapping):
            sweep = SweepSpec.from_dict(dict(sweep))
        return [self.submit(spec) for spec in sweep.expand()]

    def submit_file(self, path: Union[str, Path]) -> List[JobHandle]:
        """Queue every job a JSON/TOML config expands to (job/list/sweep)."""
        return [self.submit(spec) for spec in load_jobs(path)]

    # ---- execution --------------------------------------------------------
    def run(self) -> List[JobResult]:
        """Schedule all pending jobs and execute them; returns their results
        in the executor's completion order."""
        pending, self._pending = self._pending, []
        self._pending_ids.clear()
        if not pending:
            return []
        jobs = [Job(job_id=h.spec.job_id, submit_s=float(i),
                    processing_s=h.spec.est_processing_s)
                for i, h in enumerate(pending)]
        by_id = {h.spec.job_id: h for h in pending}
        placed = [PlacedJob(handle=by_id[sj.job.job_id], sched=sj)
                  for sj in self.scheduler.run(jobs)]
        try:
            return self.executor.execute(placed, self.followers, self._record)
        finally:
            # a job that raised aborts its worker's queue; make sure every
            # unexecuted handle fails loudly instead of blocking result()
            for h in pending:
                if not h.done():
                    h._fail(RuntimeError(
                        f"job {h.job_id!r} was not executed "
                        "(another job aborted the run)"))

    def _record(self, result: JobResult) -> None:
        # side-channel rows first (e.g. per-grid-point calibration
        # records), then the job's own record — both write-through
        for rec in result.extra_records or ():
            self.db.append(dict(rec))
        self.db.append(result.to_record())
        self._results.append(result)

    def results(self) -> List[JobResult]:
        """All results produced by this session so far."""
        return list(self._results)

    def report(self, path: str, *, title: str = "Benchmark run report"
               ) -> str:
        """Render the session's results as a standalone HTML report
        (see :mod:`repro.obs.report`); returns the HTML."""
        from repro.obs.report import write_report
        return write_report([r.to_record() for r in self._results], path,
                            title=title)

    @property
    def pending(self) -> int:
        return len(self._pending)

    # ---- context manager: run whatever is still queued on clean exit ------
    def __enter__(self) -> "BenchmarkSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None and self._pending:
            self.run()
