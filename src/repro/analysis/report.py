"""Assemble the EXPERIMENTS.md roofline table from dry-run artifacts."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional


def load_cells(dryrun_dir: str, mesh: str = "single",
               rules: Optional[str] = None) -> List[Dict]:
    out = []
    suffix = f"__{mesh}" + (f"__{rules}" if rules else "")
    for f in sorted(Path(dryrun_dir).glob(f"*{suffix}.json")):
        if rules is None and f.stem.count("__") != 2:
            continue
        out.append(json.loads(f.read_text()))
    return out


def roofline_table(cells: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s (model) | memory s (HLO) |"
           " collective s | dominant | useful | MFU | peak GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    rows = [hdr]
    for c in cells:
        if c.get("skipped"):
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | "
                        f"skip ({'sub-quadratic only' if 'long' in c['shape'] else ''}) | — | — | — |")
            continue
        if not c.get("ok") or "roofline" not in c:
            rows.append(f"| {c['arch']} | {c['shape']} | FAILED |||||||||")
            continue
        r = c["roofline"]
        peak = c["memory"]["peak_bytes"] / 2 ** 30
        rows.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['memory_s_hlo']:.4f} | "
            f"{r['collective_s']:.4f} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['model_flops_util']:.3f} | "
            f"{peak:.2f} |")
    return "\n".join(rows)


def pick_hillclimb_cells(cells: List[Dict]) -> Dict[str, Dict]:
    """Worst roofline fraction, most collective-bound, paper-representative."""
    live = [c for c in cells if c.get("ok") and "roofline" in c]
    worst = min(live, key=lambda c: c["roofline"]["model_flops_util"])
    coll = max(live, key=lambda c: (c["roofline"]["collective_s"]
                                    / max(c["roofline"]["step_time_s"], 1e-12)))
    # most representative of InferBench: a *serving decode* cell of a
    # mainstream dense model (the paper benchmarks online inference)
    reps = [c for c in live if c["shape"] == "decode_32k"
            and c["arch"] in ("yi-9b", "granite-8b", "gemma2-2b")]
    rep = max(reps, key=lambda c: c["roofline"]["step_time_s"])
    return {"worst_mfu": worst, "most_collective": coll,
            "paper_representative": rep}
