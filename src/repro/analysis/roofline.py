"""Three-term roofline analysis from compiled dry-run artifacts.

    compute_term    = per-device HLO FLOPs / peak FLOP/s per chip
    memory_term     = per-device HLO bytes-accessed / HBM bandwidth per chip
    collective_term = per-device collective operand bytes / ICI bandwidth

With GSPMD the compiled module *is* the per-device program, so
``cost_analysis()`` figures are per-device already (verified empirically:
a matmul sharded 4-way reports ≈1/4 of the unsharded FLOPs).  The
"useful" ratio MODEL_FLOPS / (HLO_FLOPs × chips) exposes remat/dispatch
overhead and redundant compute.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro import hw as hw_lib
from repro.analysis import hlo as hlo_lib


@dataclasses.dataclass
class RooflineReport:
    flops_per_device: float
    bytes_per_device: float            # measured (CPU-XLA fusion)
    bytes_model_per_device: float      # analytic TPU-fused model
    collective_bytes_per_device: float
    chips: int
    compute_s: float
    memory_s_hlo: float                # from measured bytes
    memory_s: float                    # from the TPU-fused model
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    step_time_s: float
    model_flops_util: float            # MFU against the roofline step time
    collectives: Dict[str, Dict[str, float]]

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(*, flops_per_device: float, bytes_per_device: float,
            chips: int, model_flops: float,
            bytes_model_per_device: Optional[float] = None,
            hlo_text: Optional[str] = None,
            collectives: Optional[Dict] = None,
            hw: hw_lib.HardwareModel = hw_lib.TPU_V5E) -> RooflineReport:
    colls = (collectives if collectives is not None
             else hlo_lib.parse_collectives(hlo_text or ""))
    coll_bytes = float(sum(v["bytes"] for v in colls.values()))
    compute_s = flops_per_device / hw.peak_flops
    memory_s_hlo = bytes_per_device / hw.hbm_bw
    bytes_model = (bytes_model_per_device if bytes_model_per_device is not None
                   else bytes_per_device)
    memory_s = bytes_model / hw.hbm_bw
    collective_s = coll_bytes / hw.link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())
    total_hlo_flops = flops_per_device * chips
    useful = model_flops / total_hlo_flops if total_hlo_flops else 0.0
    mfu = (model_flops / (chips * hw.peak_flops * step_time)
           if step_time > 0 else 0.0)
    return RooflineReport(
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        bytes_model_per_device=bytes_model,
        collective_bytes_per_device=coll_bytes,
        chips=chips,
        compute_s=compute_s,
        memory_s_hlo=memory_s_hlo,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=useful,
        step_time_s=step_time,
        model_flops_util=mfu,
        collectives=colls,
    )
