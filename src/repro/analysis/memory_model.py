"""Analytic per-device HBM-traffic model (TPU-fused counterpart to the
CPU-XLA "bytes accessed" figure).

CPU XLA materialises f32 attention-logit and CE-logit intermediates that
the TPU path (Pallas flash kernels, fused chunked CE) never writes to HBM,
so the measured bytes overstate the memory term by 5–20×.  This model
counts the traffic a well-fused TPU program actually pays:

  train   ≈ 8·P  (params fwd+bwd reads, grad write, Adam m/v r/w, param write)
          + L·C_act·A      per-layer residual/QKVO streams incl. remat reread
          + CE logits chunk traffic (bf16, fwd+bwd)
  prefill ≈ P + L·C_pre·A + cache write
  decode  ≈ P + cache read+write + batch·d streams

A = B_dev·S·d_model·act_bytes.  C_act = 24 (fwd ~8 streams, bwd ~12,
remat reread ~4), C_pre = 8.  The constants are documented estimates, not
fits; both the measured-HLO and model terms are reported side by side in
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

from typing import Dict

from repro import hw as hw_lib
from repro.configs.shapes import DECODE, PREFILL, TRAIN, ShapeSpec
from repro.models.config import ModelConfig

C_ACT_TRAIN = 24.0
C_ACT_PREFILL = 8.0


def estimate_bytes(kind: str, cfg: ModelConfig, shape: ShapeSpec,
                   mem_info: Dict[str, float]) -> float:
    """Per-device HBM bytes for one step under TPU-grade fusion."""
    P = mem_info["params_bytes"]
    O = mem_info.get("opt_bytes", 0.0)
    C = mem_info.get("cache_bytes", 0.0)
    b_dev = mem_info["batch_dev"]
    act_bytes = 2.0 if "float32" not in cfg.dtype else 4.0
    A = b_dev * shape.seq_len * cfg.d_model * act_bytes
    L = cfg.num_layers + cfg.encoder_layers
    v_shard = mem_info.get("vocab_shard_bytes_per_token", 0.0)

    if kind == TRAIN:
        # params fwd + bwd + grads + m/v read/write + write-back (O≈2P f32)
        weight_traffic = 4.0 * P + 2.0 * O
        act_traffic = L * C_ACT_TRAIN * A
        ce_traffic = 4.0 * b_dev * shape.seq_len * v_shard
        return weight_traffic + act_traffic + ce_traffic
    if kind == PREFILL:
        return P + L * C_ACT_PREFILL * A + 2.0 * C
    if kind == DECODE:
        return P + C + 8.0 * b_dev * cfg.d_model * act_bytes * max(L, 1)
    raise ValueError(kind)


# ---- serving KV-cache capacity (the memory subsystem's budget source) ------
def kv_bytes_per_token(cfg: ModelConfig,
                       bytes_per_elem: float = 2.0) -> float:
    """KV-cache bytes one cached token costs across all attention layers.

    Attention-free blocks (RG-LRU, RWKV6) keep constant-size state, so
    only ``attn_*`` layers contribute; K and V are each
    ``num_kv_heads × head_dim`` elements per layer.
    """
    n_attn = sum(k.startswith("attn") for k in cfg.layer_kinds())
    return n_attn * 2.0 * cfg.num_kv_heads * cfg.head_dim * bytes_per_elem


def serving_hbm_headroom(hw: hw_lib.HardwareModel, chips: int,
                         weight_bytes: float,
                         util_fraction: float = 0.9) -> float:
    """HBM bytes left for KV cache on one replica after resident weights.

    ``util_fraction`` reserves slack for activations, collectives and
    allocator fragmentation, mirroring vLLM's ``gpu_memory_utilization``.
    """
    usable = hw.hbm_bytes * max(chips, 1) * util_fraction
    return max(usable - weight_bytes, 0.0)
