"""Post-SPMD HLO inspection: collective-traffic accounting.

``compiled.cost_analysis()`` has no collective-bytes entry, so we parse the
partitioned HLO text and sum the operand sizes of every communication op.
The module text is the *per-device* program, so the sums are per-device
bytes moved over the interconnect.
"""
from __future__ import annotations

import re
from typing import Dict, Iterable, Tuple

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# shapes like  bf16[256,1024]{1,0}  or  f32[] — capture dtype + dims
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[\w\[\]{},. ]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\s*\(([^)]*)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


_GROUPS_RE = re.compile(r"replica_groups=\[\d+,(\d+)\]")


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-device *effective* interconnect bytes of every collective, by kind.

    Effective-traffic model (ring algorithms, n = group size):
      all-gather          ≈ result bytes        (each device receives n−1 shards)
      all-reduce          ≈ 2 × result bytes    (reduce-scatter + all-gather)
      reduce-scatter      ≈ result bytes × n    (full operand streams through)
      all-to-all          ≈ result bytes        (sends/receives (n−1)/n of it)
      collective-permute  ≈ result bytes
    Result shapes are parsed from the op line (compiled HLO references
    operands by name only).
    """
    out: Dict[str, Dict[str, float]] = {
        k: {"bytes": 0, "count": 0} for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if "-done" in line.split("=")[1][:60]:
            continue  # -done consumes the -start token; avoid double count
        rhs = line.split("=", 1)[1]
        result_part = rhs.split(kind, 1)[0]
        total = 0
        if result_part.strip().startswith("("):   # tuple result: sum elements
            for sm in _SHAPE_RE.finditer(result_part):
                total += _shape_bytes(sm.group(1), sm.group(2))
        else:
            rm = _SHAPE_RE.search(result_part)
            if rm:
                total = _shape_bytes(rm.group(1), rm.group(2))
        if kind == "all-reduce":
            total *= 2
        elif kind == "reduce-scatter":
            gm = _GROUPS_RE.search(line)
            total *= int(gm.group(1)) if gm else 2
        out[kind]["bytes"] += total
        out[kind]["count"] += 1
    return out


def collective_bytes(hlo_text: str) -> int:
    return int(sum(v["bytes"] for v in parse_collectives(hlo_text).values()))


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\b", hlo_text))
