"""Roofline-based batch latency oracle (paper §4.3.1, promoted to the
simulator's clock).

This container has no TPU, so serving latencies are *derived*, not
measured: for a (model, batch, context, phase) we compute the three
roofline terms analytically (same math the dry-run validates against the
compiled HLO) and take their max plus a fixed launch overhead.  The same
interface also has a ``measured`` mode that wall-clocks a real jitted step
on CPU — used for the small canonical models where real execution is
feasible, exactly mirroring the paper's measured-vs-modeled split.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, Optional, Tuple

from repro import hw as hw_lib
from repro.models.config import ModelConfig
from repro.models.registry import model_flops_per_token

LAUNCH_OVERHEAD_S = 50e-6      # dispatch + DMA warmup per device step
COLD_START_DISK_BW = 2e9       # bytes/s from checkpoint storage
COLD_START_CONST_S = 2.0       # runtime + compile cache init


# --- speed modes ------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SpeedMode:
    """A serving *speed mode*: a named bundle of roofline scale factors
    plus an optional speculative-decoding model.

    Quantization is expressed as byte/FLOP scale factors applied to the
    roofline terms of an oracle (``weight_bytes_scale`` and
    ``kv_bytes_scale`` shrink the memory terms and the KV footprint the
    ``KVCacheManager`` charges; ``compute_scale`` models quant/dequant
    overhead on the compute term).  Speculative decoding is expressed as
    a draft/verify cycle: a draft model proposes ``draft_len`` tokens at
    ``draft_cost_frac`` of a target decode step each, the target verifies
    them in one step, and on average ``expected_tokens_per_cycle()``
    tokens are emitted per cycle — so effective per-token decode latency
    is the base latency times ``decode_cost_factor()``.

    Attributes (all scales dimensionless):
        name: mode identifier ("fp16", "int8", "speculative", ...).
        weight_bytes_scale: resident-weight bytes multiplier (int8 = 0.5
            of bf16).
        kv_bytes_scale: per-token KV-cache bytes multiplier; < 1 means
            more sequences fit a fixed HBM budget.
        compute_scale: FLOP-term multiplier (> 1 models quantize /
            dequantize overhead).
        draft_len: speculative draft tokens per cycle (k); 0 disables
            speculation.
        acceptance_rate: probability a ∈ [0, 1] each draft token is
            accepted (position-independent, the standard geometric
            model).
        draft_cost_frac: cost of one draft-model step as a fraction of a
            target decode step.
    """
    name: str = "fp16"
    weight_bytes_scale: float = 1.0
    kv_bytes_scale: float = 1.0
    compute_scale: float = 1.0
    draft_len: int = 0
    acceptance_rate: float = 0.0
    draft_cost_frac: float = 1.0

    def __post_init__(self):
        if not (0.0 < self.weight_bytes_scale
                and 0.0 < self.kv_bytes_scale
                and 0.0 < self.compute_scale):
            raise ValueError(f"speed mode {self.name!r}: scale factors "
                             "must be positive")
        if self.draft_len < 0:
            raise ValueError(f"speed mode {self.name!r}: draft_len must "
                             "be >= 0")
        if not 0.0 <= self.acceptance_rate <= 1.0:
            raise ValueError(f"speed mode {self.name!r}: acceptance_rate "
                             "must be in [0, 1]")
        if self.draft_cost_frac < 0.0:
            raise ValueError(f"speed mode {self.name!r}: draft_cost_frac "
                             "must be >= 0")

    @property
    def is_identity(self) -> bool:
        """True when the mode changes nothing (vanilla fp16 serving)."""
        return (self.weight_bytes_scale == 1.0
                and self.kv_bytes_scale == 1.0
                and self.compute_scale == 1.0
                and self.draft_len == 0)

    def expected_tokens_per_cycle(self) -> float:
        """E[tokens emitted per draft/verify cycle] = (1-a^(k+1))/(1-a).

        With ``draft_len=k`` drafts the cycle emits the accepted prefix
        plus the verifier's one corrected/bonus token: 1 + a + … + a^k.
        Equals ``k+1`` exactly at ``acceptance_rate=1`` and 1 with no
        drafting.
        """
        k, a = self.draft_len, self.acceptance_rate
        if k <= 0:
            return 1.0
        if a >= 1.0:
            return float(k + 1)
        return (1.0 - a ** (k + 1)) / (1.0 - a)

    def decode_cost_factor(self) -> float:
        """Multiplier on base decode latency per *emitted* token.

        One cycle costs ``1 + draft_len·draft_cost_frac`` target-step
        equivalents (the verify step scores all drafts in one pass —
        decode is memory-bound, so verifying k+1 positions reads the
        same weights/KV as one step) and emits
        ``expected_tokens_per_cycle()`` tokens.  With
        ``acceptance_rate=1`` and ``draft_cost_frac=1`` the factor is
        exactly 1.0 — a draft as expensive as the target buys nothing.
        """
        if self.draft_len <= 0:
            return 1.0
        cycle_cost = 1.0 + self.draft_len * self.draft_cost_frac
        return cycle_cost / self.expected_tokens_per_cycle()

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SpeedMode":
        return cls(**d)


#: Named presets the planner and specs resolve by string.  ``int8``
#: halves weight + KV bytes (bf16 → int8) with a small dequant compute
#: penalty, so it wins where serving is KV/memory-bound and loses where
#: it is compute-bound.  ``speculative`` uses a 4-token draft at 30% of
#: target step cost with the conventional ~0.7 acceptance rate.
SPEED_MODES: Dict[str, SpeedMode] = {
    "fp16": SpeedMode("fp16"),
    "int8": SpeedMode("int8", weight_bytes_scale=0.5, kv_bytes_scale=0.5,
                      compute_scale=1.05),
    "speculative": SpeedMode("speculative", draft_len=4,
                             acceptance_rate=0.7, draft_cost_frac=0.3),
}


def resolve_speed_mode(mode, overrides: Optional[dict] = None) -> SpeedMode:
    """Coerce ``mode`` (SpeedMode | name | dict | None) to a SpeedMode.

    ``overrides`` maps mode names to parameter dicts (e.g. calibrated
    values from a profile's ``speed_modes`` section) consulted before
    the built-in :data:`SPEED_MODES` presets.
    """
    if mode is None:
        return SPEED_MODES["fp16"]
    if isinstance(mode, SpeedMode):
        return mode
    if isinstance(mode, dict):
        return SpeedMode.from_dict(mode)
    if isinstance(mode, str):
        if overrides and mode in overrides:
            ov = dict(overrides[mode])
            ov.setdefault("name", mode)
            return SpeedMode.from_dict(ov)
        if mode in SPEED_MODES:
            return SPEED_MODES[mode]
        raise KeyError(f"unknown speed mode {mode!r} "
                       f"(known: {sorted(SPEED_MODES)})")
    raise TypeError(f"cannot resolve speed mode from {type(mode).__name__}")


def apply_speed_mode(oracle: "LatencyOracle", mode) -> "LatencyOracle":
    """Return an oracle serving under ``mode`` (identity modes pass the
    oracle through untouched).

    Oracles that know their roofline decomposition
    (:class:`LatencyModel`, :class:`FittedLatencyModel`) implement
    ``with_speed_mode`` and get exact per-term scaling; anything else is
    wrapped in a conservative :class:`SpeedModeOracle`.
    """
    mode = resolve_speed_mode(mode)
    if mode.is_identity:
        return oracle
    with_mode = getattr(oracle, "with_speed_mode", None)
    if with_mode is not None:
        return with_mode(mode)
    return SpeedModeOracle(oracle, mode)


class LatencyOracle:
    """Shared per-request composition over a prefill/decode split.

    Concrete oracles (the analytic :class:`LatencyModel`, the calibrated
    :class:`FittedLatencyModel`) supply ``prefill_latency(batch, prompt)``
    and ``decode_latency(batch, context)``; everything the simulator
    calls on top of those is defined once here.
    """
    hw: hw_lib.HardwareModel
    chips: int

    def prefill_latency(self, batch: int, prompt: int) -> float:
        raise NotImplementedError

    def decode_latency(self, batch: int, context: int) -> float:
        raise NotImplementedError

    # a steady-state serving simulation revisits a small set of
    # (batch, prompt, context) shapes millions of times; latencies are
    # pure functions of their arguments, so memoize per oracle.  The cap
    # bounds memory on adversarial workloads (every shape distinct) —
    # beyond it results are still computed, just not stored.
    _CACHE_CAP = 1 << 20

    def iteration_latency(self, n_prefill: int, prompt: int,
                          n_decode: int, max_context: int) -> float:
        """One continuous-batching engine iteration (Orca-style): prefill
        the requests joining this boundary, then one decode step for the
        whole running batch."""
        cache = getattr(self, "_iter_cache", None)
        if cache is None:
            cache = self._iter_cache = {}
        key = (n_prefill, prompt, n_decode, max_context)
        t = cache.get(key)
        if t is None:
            t = 0.0
            if n_prefill > 0:
                t += self.prefill_latency(n_prefill, prompt)
            if n_decode > 0:
                t += self.decode_latency(n_decode, max(max_context, 1))
            if len(cache) < self._CACHE_CAP:
                cache[key] = t
        return t

    def request_latency(self, batch: int, prompt: int, out_tokens: int) -> float:
        cache = getattr(self, "_req_cache", None)
        if cache is None:
            cache = self._req_cache = {}
        key = (batch, prompt, out_tokens)
        t = cache.get(key)
        if t is None:
            t = self.prefill_latency(batch, prompt)
            for i in range(out_tokens - 1):
                t += self.decode_latency(batch, prompt + i)
            if len(cache) < self._CACHE_CAP:
                cache[key] = t
        return t


@dataclasses.dataclass
class LatencyModel(LatencyOracle):
    """Analytic roofline oracle for ``cfg`` served on ``chips`` × ``hw``.

    Attributes:
        cfg: architecture being served.
        hw: hardware model (peak FLOPs, HBM bandwidth, $/hr).
        chips: chips per replica (weights + KV sharded across them).
        serve_bytes_per_param: resident bytes per weight (2.0 = bf16).
        int8: legacy shim — sets ``serve_bytes_per_param`` to 1.0
            (weight-only quantization; superseded by ``speed_mode``).
        speed_mode: optional :class:`SpeedMode` scaling the roofline
            terms (weights/KV/compute) and the effective decode step.
    """
    cfg: ModelConfig
    hw: hw_lib.HardwareModel = hw_lib.TPU_V5E
    chips: int = 1
    serve_bytes_per_param: float = 2.0     # bf16 weights
    int8: bool = False
    speed_mode: Optional[SpeedMode] = None

    def __post_init__(self):
        self.flops_per_token = model_flops_per_token(self.cfg) / 3.0  # fwd
        # count every param (incl. all experts) for weight traffic
        from repro.models.registry import build_model, count_params, param_shapes
        self.n_params = count_params(param_shapes(build_model(self.cfg)))
        if self.int8:
            self.serve_bytes_per_param = 1.0
        mode = self.speed_mode
        self._compute_scale = mode.compute_scale if mode else 1.0
        self._kv_scale = mode.kv_bytes_scale if mode else 1.0
        self._weight_scale = mode.weight_bytes_scale if mode else 1.0
        self._decode_factor = mode.decode_cost_factor() if mode else 1.0
        # per-model constants the simulator's hot path would otherwise
        # re-derive on every engine iteration (layer_kinds() builds a
        # fresh tuple per call); values and accumulation order are
        # unchanged, so latencies stay bit-identical
        kinds = self.cfg.layer_kinds()
        self._attn_kinds = tuple(k for k in kinds
                                 if k in ("attn_global", "attn_local"))
        self._n_attn = sum(k.startswith("attn") for k in kinds)
        self._weight_bytes = (self.n_params * self.serve_bytes_per_param
                              * self._weight_scale)

    def with_speed_mode(self, mode: SpeedMode) -> "LatencyModel":
        """This model re-derived under ``mode`` (fresh latency caches)."""
        return dataclasses.replace(self, speed_mode=mode)

    # ---- analytic per-phase latencies -----------------------------------
    def _kv_bytes_per_token(self) -> float:
        # decode_latency calls this once per engine iteration — memoize
        # the (deterministic) model-config derivation instead of paying a
        # module import + recompute on the simulator's hot path
        v = getattr(self, "_kv_bpt", None)
        if v is None:
            from repro.analysis.memory_model import kv_bytes_per_token
            v = self._kv_bpt = kv_bytes_per_token(self.cfg) * self._kv_scale
        return v

    # ---- memory-subsystem hooks (repro.serving.memory) -------------------
    def kv_bytes_per_token(self) -> float:
        """Public alias for the per-token KV footprint (memory accounting)."""
        return self._kv_bytes_per_token()

    def weight_bytes(self) -> float:
        """Resident serving weights on one replica (all chips pooled)."""
        return self._weight_bytes

    def prefill_latency(self, batch: int, prompt: int) -> float:
        cfg = self.cfg
        flops = batch * prompt * self.flops_per_token
        # quadratic attention term (windowed layers capped at the window)
        for kind in self._attn_kinds:
            if kind == "attn_global":
                span = prompt
            else:                       # attn_local
                span = min(cfg.local_window or prompt, prompt)
            flops += 4 * batch * prompt * span * cfg.num_heads * cfg.head_dim / 2
        act_bytes = 8 * batch * prompt * cfg.d_model * 2.0 * cfg.num_layers
        compute_s = flops * self._compute_scale \
            / (self.chips * self.hw.peak_flops)
        memory_s = (self._weight_bytes / self.chips + act_bytes / self.chips) \
            / self.hw.hbm_bw
        return max(compute_s, memory_s) + LAUNCH_OVERHEAD_S

    def decode_latency(self, batch: int, context: int) -> float:
        cfg = self.cfg
        flops = batch * self.flops_per_token
        flops += 4 * batch * min(context, 1 << 30) * cfg.num_heads \
            * cfg.head_dim * self._n_attn
        kv_bytes = batch * context * self._kv_bytes_per_token()
        compute_s = flops * self._compute_scale \
            / (self.chips * self.hw.peak_flops)
        memory_s = (self._weight_bytes + kv_bytes) \
            / (self.chips * self.hw.hbm_bw)
        return (max(compute_s, memory_s) + LAUNCH_OVERHEAD_S) \
            * self._decode_factor

    def cold_start(self) -> float:
        return COLD_START_CONST_S + self._weight_bytes \
            / (self.chips * COLD_START_DISK_BW)

    def to_profile(self, *, batches=(1, 2, 4, 8, 16),
                   seqs=(32, 64, 128, 256), contexts=None,
                   holdout_fraction: float = 0.0):
        """Fit this oracle's analytic grid into a calibration profile
        (``repro.calibrate`` round-trip — see ``CalibrationProfile``)."""
        from repro.calibrate.fit import fit_records
        from repro.calibrate.microbench import oracle_records
        records = oracle_records(self, batches=batches, seqs=seqs,
                                 contexts=contexts)
        return fit_records(
            records, model=self.cfg.name, hardware=self.hw.name,
            chips=self.chips, source="oracle",
            holdout_fraction=holdout_fraction,
            cold_start_s=self.cold_start())


@dataclasses.dataclass
class FittedLatencyModel(LatencyOracle):
    """Parametric latency oracle backed by calibrated coefficients.

    The closed forms are linear in their parameters (what the
    ``repro.calibrate`` least-squares fitter recovers):

        prefill(b, s) = p0 + p1·(b·s) + p2·(b·s²)
        decode(b, c)  = d0 + α·b + β·(b·c)

    ``p1`` is the prefill FLOPs term, ``p2`` the quadratic-attention
    term; ``α`` is the per-sequence decode-step cost and ``β`` the
    per-cached-token (KV read) cost.  Latencies are clamped to a small
    positive floor so a degenerate fit can never stall the simulator.
    """
    prefill_coef: Tuple[float, float, float] = (0.0, 0.0, 0.0)
    decode_coef: Tuple[float, float, float] = (0.0, 0.0, 0.0)
    hw: hw_lib.HardwareModel = hw_lib.TPU_V5E
    chips: int = 1
    cold_start_s: float = COLD_START_CONST_S
    name: str = "fitted"

    _FLOOR_S = 1e-9

    def prefill_latency(self, batch: int, prompt: int) -> float:
        p0, p1, p2 = self.prefill_coef
        toks = batch * prompt
        return max(p0 + p1 * toks + p2 * toks * prompt, self._FLOOR_S)

    def decode_latency(self, batch: int, context: int) -> float:
        d0, alpha, beta = self.decode_coef
        return max(d0 + alpha * batch + beta * batch * context,
                   self._FLOOR_S)

    def cold_start(self) -> float:
        return self.cold_start_s

    def with_speed_mode(self, mode: SpeedMode) -> "FittedLatencyModel":
        """Re-derive the fitted coefficients under ``mode``.

        The mapping follows each coefficient's roofline meaning (see the
        class docstring): decode ``d0`` is the batch-independent weight
        read (× ``weight_bytes_scale``), ``α`` the per-sequence compute
        (× ``compute_scale``), ``β`` the per-cached-token KV read
        (× ``kv_bytes_scale``); the whole decode step is then divided
        among the tokens a speculative cycle emits
        (× ``decode_cost_factor()``).  Prefill is compute-bound at
        calibration batch sizes, so only its token terms scale.
        """
        p0, p1, p2 = self.prefill_coef
        d0, alpha, beta = self.decode_coef
        cs, f = mode.compute_scale, mode.decode_cost_factor()
        return dataclasses.replace(
            self,
            prefill_coef=(p0, p1 * cs, p2 * cs),
            decode_coef=(d0 * mode.weight_bytes_scale * f,
                         alpha * cs * f,
                         beta * mode.kv_bytes_scale * f),
            name=f"{self.name}+{mode.name}")

    @classmethod
    def from_profile(cls, profile) -> "FittedLatencyModel":
        """Build the oracle from a ``CalibrationProfile``, its dict form,
        a profile JSON path, or a ``model@hardware`` key resolved in the
        default profile directory."""
        from repro.calibrate.profile import CalibrationProfile, load_profile
        if isinstance(profile, dict):
            profile = CalibrationProfile.from_dict(profile)
        elif not isinstance(profile, CalibrationProfile):
            profile = load_profile(profile)
        if profile.hardware not in hw_lib.HARDWARE:
            raise ValueError(
                f"profile {profile.key!r} names unknown hardware "
                f"{profile.hardware!r} (known: {sorted(hw_lib.HARDWARE)}) — "
                "costs/energy would be computed for the wrong machine")
        hw = hw_lib.HARDWARE[profile.hardware]
        return cls(prefill_coef=tuple(profile.prefill.coef),
                   decode_coef=tuple(profile.decode.coef),
                   hw=hw, chips=profile.chips,
                   cold_start_s=profile.cold_start_s,
                   name=profile.key)


class SpeedModeOracle(LatencyOracle):
    """Generic :class:`SpeedMode` wrapper for oracles without a native
    ``with_speed_mode``.

    Without a roofline decomposition the byte scales cannot be applied
    per-term, so the wrapper is conservative: prefill scales by
    ``compute_scale`` only, decode by ``max(compute_scale,
    kv_bytes_scale)`` times the speculative ``decode_cost_factor()``.
    KV/weight memory hooks are forwarded scaled when the base oracle
    exposes them.
    """

    def __init__(self, base: LatencyOracle, mode: SpeedMode):
        self.base = base
        self.mode = mode
        # duck-typed bases (tests, ad-hoc oracles) may not carry hardware
        # identity; fall back to the oracle defaults so cost accounting
        # still runs
        self.hw = getattr(base, "hw", None) or hw_lib.HARDWARE["tpu-v5e"]
        self.chips = getattr(base, "chips", 1)
        self._decode_scale = (max(mode.compute_scale, mode.kv_bytes_scale)
                              * mode.decode_cost_factor())

    def prefill_latency(self, batch: int, prompt: int) -> float:
        return self.base.prefill_latency(batch, prompt) \
            * self.mode.compute_scale

    def decode_latency(self, batch: int, context: int) -> float:
        return self.base.decode_latency(batch, context) * self._decode_scale

    # memory hooks exist only when the base oracle has them, so the
    # duck-typed probes in repro.serving.memory behave as if they were
    # looking at the base directly
    def __getattr__(self, name):
        if name in ("base", "mode"):       # guard pre-__init__ recursion
            raise AttributeError(name)
        if name == "kv_bytes_per_token":
            base_fn = self.base.kv_bytes_per_token
            return lambda: base_fn() * self.mode.kv_bytes_scale
        if name == "weight_bytes":
            base_fn = self.base.weight_bytes
            return lambda: base_fn() * self.mode.weight_bytes_scale
        if name == "cold_start":
            return self.base.cold_start
        raise AttributeError(name)


@dataclasses.dataclass
class MeasuredLatency:
    """Wall-clock a real jitted callable (CPU-scale models).

    ``reducer="mean"`` (default) averages one timed loop, matching the
    historical behavior; ``reducer="min"`` times each iteration and
    takes the fastest — the noise-robust estimator the calibration
    microbenchmarks use (scheduler jitter only ever adds time).
    """
    fn: Callable
    warmup: int = 2
    iters: int = 5
    reducer: str = "mean"

    def measure(self, *args) -> float:
        import jax
        for _ in range(self.warmup):
            jax.block_until_ready(self.fn(*args))
        if self.reducer == "min":
            best = math.inf
            for _ in range(self.iters):
                t0 = time.perf_counter()
                jax.block_until_ready(self.fn(*args))
                best = min(best, time.perf_counter() - t0)
            return best
        t0 = time.perf_counter()
        for _ in range(self.iters):
            jax.block_until_ready(self.fn(*args))
        return (time.perf_counter() - t0) / self.iters


# --- network models for the pipeline tier (paper Fig. 14) ------------------
@dataclasses.dataclass(frozen=True)
class NetworkModel:
    name: str
    bandwidth_bps: float
    rtt_s: float
    jitter_s: float = 0.0

    def transmit(self, payload_bytes: int) -> float:
        return self.rtt_s + payload_bytes * 8 / self.bandwidth_bps


NETWORKS: Dict[str, NetworkModel] = {
    "lan": NetworkModel("lan", 10e9, 0.0002),
    "wifi": NetworkModel("wifi", 100e6, 0.004),
    "4g": NetworkModel("4g", 20e6, 0.045),
    # datacenter interconnects for the disaggregated prefill→decode
    # KV-cache handoff (bytes = kv_bytes_per_token × prompt_tokens)
    "infiniband": NetworkModel("infiniband", 400e9, 5e-6),
    "nvlink": NetworkModel("nvlink", 7.2e12, 2e-6),
}


# --- multi-region serving (heterogeneous pools with a region tag) -----------
#: WAN links between serving regions, keyed by unordered region pair.
#: ``simulate_cluster`` charges the link's transmit time to a request
#: whenever the router sends it to a pool outside the cluster's
#: front-door region (the first pool's region).  RTTs follow typical
#: public inter-region latency matrices; per-flow bandwidth is the
#: practical WAN share, not the trunk capacity.
INTER_REGION_NETWORKS: Dict[tuple, NetworkModel] = {
    ("us-central", "us-east"): NetworkModel("us-central<->us-east",
                                            25e9, 0.032),
    ("us-central", "eu-west"): NetworkModel("us-central<->eu-west",
                                            10e9, 0.105),
    ("us-east", "eu-west"): NetworkModel("us-east<->eu-west", 12e9, 0.078),
    ("us-central", "asia-east"): NetworkModel("us-central<->asia-east",
                                              8e9, 0.140),
    ("us-east", "asia-east"): NetworkModel("us-east<->asia-east",
                                           8e9, 0.170),
    ("eu-west", "asia-east"): NetworkModel("eu-west<->asia-east",
                                           6e9, 0.210),
}

#: Fallback link for region pairs not in the table (same order of
#: magnitude as a cross-continent hop).
DEFAULT_INTER_REGION = NetworkModel("inter-region", 10e9, 0.080)


def inter_region_network(a: str, b: str) -> Optional[NetworkModel]:
    """The WAN link between regions ``a`` and ``b``, or None when the
    hop stays inside one region (same name, or either side unset —
    region-less pools are co-located with the front door)."""
    if not a or not b or a == b:
        return None
    return (INTER_REGION_NETWORKS.get((a, b))
            or INTER_REGION_NETWORKS.get((b, a))
            or DEFAULT_INTER_REGION)


def oracle_for_hardware(base: LatencyOracle, hardware: str = "",
                        chips: int = 0) -> LatencyOracle:
    """Re-target a latency oracle at another hardware catalog entry.

    The per-pool plumbing of heterogeneous clusters: a pool that names
    its own ``hardware``/``chips`` gets the *same analytic model* served
    on that chip (fresh roofline terms and latency caches via
    ``dataclasses.replace``).  When the pool matches the base oracle the
    base is returned as-is, sharing its memoized latency caches.

    Fitted oracles embed one machine's measured coefficients, so they
    cannot be re-targeted analytically — pools backed by a
    :class:`FittedLatencyModel` must supply their own per-hardware
    profile (``PoolSpec.profile``) instead.
    """
    base_hw = getattr(base, "hw", None)
    base_chips = getattr(base, "chips", 1)
    hw_name = hardware or (base_hw.name if base_hw is not None else "")
    n_chips = chips or base_chips
    if base_hw is not None and hw_name == base_hw.name \
            and n_chips == base_chips:
        return base
    if hw_name not in hw_lib.HARDWARE:
        raise ValueError(f"unknown hardware {hw_name!r} "
                         f"(known: {sorted(hw_lib.HARDWARE)})")
    if not isinstance(base, LatencyModel):
        raise ValueError(
            f"cannot re-target a {type(base).__name__} oracle at "
            f"{hw_name!r}: fitted/measured oracles embed one machine's "
            "coefficients — give the pool its own calibrated profile "
            "for that hardware")
    return dataclasses.replace(base, hw=hw_lib.HARDWARE[hw_name],
                               chips=n_chips)
