"""Roofline-based batch latency oracle (paper §4.3.1, promoted to the
simulator's clock).

This container has no TPU, so serving latencies are *derived*, not
measured: for a (model, batch, context, phase) we compute the three
roofline terms analytically (same math the dry-run validates against the
compiled HLO) and take their max plus a fixed launch overhead.  The same
interface also has a ``measured`` mode that wall-clocks a real jitted step
on CPU — used for the small canonical models where real execution is
feasible, exactly mirroring the paper's measured-vs-modeled split.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, Optional, Tuple

from repro import hw as hw_lib
from repro.models.config import ModelConfig
from repro.models.registry import model_flops_per_token

LAUNCH_OVERHEAD_S = 50e-6      # dispatch + DMA warmup per device step
COLD_START_DISK_BW = 2e9       # bytes/s from checkpoint storage
COLD_START_CONST_S = 2.0       # runtime + compile cache init


class LatencyOracle:
    """Shared per-request composition over a prefill/decode split.

    Concrete oracles (the analytic :class:`LatencyModel`, the calibrated
    :class:`FittedLatencyModel`) supply ``prefill_latency(batch, prompt)``
    and ``decode_latency(batch, context)``; everything the simulator
    calls on top of those is defined once here.
    """
    hw: hw_lib.HardwareModel
    chips: int

    def prefill_latency(self, batch: int, prompt: int) -> float:
        raise NotImplementedError

    def decode_latency(self, batch: int, context: int) -> float:
        raise NotImplementedError

    # a steady-state serving simulation revisits a small set of
    # (batch, prompt, context) shapes millions of times; latencies are
    # pure functions of their arguments, so memoize per oracle.  The cap
    # bounds memory on adversarial workloads (every shape distinct) —
    # beyond it results are still computed, just not stored.
    _CACHE_CAP = 1 << 20

    def iteration_latency(self, n_prefill: int, prompt: int,
                          n_decode: int, max_context: int) -> float:
        """One continuous-batching engine iteration (Orca-style): prefill
        the requests joining this boundary, then one decode step for the
        whole running batch."""
        cache = getattr(self, "_iter_cache", None)
        if cache is None:
            cache = self._iter_cache = {}
        key = (n_prefill, prompt, n_decode, max_context)
        t = cache.get(key)
        if t is None:
            t = 0.0
            if n_prefill > 0:
                t += self.prefill_latency(n_prefill, prompt)
            if n_decode > 0:
                t += self.decode_latency(n_decode, max(max_context, 1))
            if len(cache) < self._CACHE_CAP:
                cache[key] = t
        return t

    def request_latency(self, batch: int, prompt: int, out_tokens: int) -> float:
        cache = getattr(self, "_req_cache", None)
        if cache is None:
            cache = self._req_cache = {}
        key = (batch, prompt, out_tokens)
        t = cache.get(key)
        if t is None:
            t = self.prefill_latency(batch, prompt)
            for i in range(out_tokens - 1):
                t += self.decode_latency(batch, prompt + i)
            if len(cache) < self._CACHE_CAP:
                cache[key] = t
        return t


@dataclasses.dataclass
class LatencyModel(LatencyOracle):
    cfg: ModelConfig
    hw: hw_lib.HardwareModel = hw_lib.TPU_V5E
    chips: int = 1
    serve_bytes_per_param: float = 2.0     # bf16 weights
    int8: bool = False

    def __post_init__(self):
        self.flops_per_token = model_flops_per_token(self.cfg) / 3.0  # fwd
        # count every param (incl. all experts) for weight traffic
        from repro.models.registry import build_model, count_params, param_shapes
        self.n_params = count_params(param_shapes(build_model(self.cfg)))
        if self.int8:
            self.serve_bytes_per_param = 1.0
        # per-model constants the simulator's hot path would otherwise
        # re-derive on every engine iteration (layer_kinds() builds a
        # fresh tuple per call); values and accumulation order are
        # unchanged, so latencies stay bit-identical
        kinds = self.cfg.layer_kinds()
        self._attn_kinds = tuple(k for k in kinds
                                 if k in ("attn_global", "attn_local"))
        self._n_attn = sum(k.startswith("attn") for k in kinds)
        self._weight_bytes = self.n_params * self.serve_bytes_per_param

    # ---- analytic per-phase latencies -----------------------------------
    def _kv_bytes_per_token(self) -> float:
        # decode_latency calls this once per engine iteration — memoize
        # the (deterministic) model-config derivation instead of paying a
        # module import + recompute on the simulator's hot path
        v = getattr(self, "_kv_bpt", None)
        if v is None:
            from repro.analysis.memory_model import kv_bytes_per_token
            v = self._kv_bpt = kv_bytes_per_token(self.cfg)
        return v

    # ---- memory-subsystem hooks (repro.serving.memory) -------------------
    def kv_bytes_per_token(self) -> float:
        """Public alias for the per-token KV footprint (memory accounting)."""
        return self._kv_bytes_per_token()

    def weight_bytes(self) -> float:
        """Resident serving weights on one replica (all chips pooled)."""
        return self.n_params * self.serve_bytes_per_param

    def prefill_latency(self, batch: int, prompt: int) -> float:
        cfg = self.cfg
        flops = batch * prompt * self.flops_per_token
        # quadratic attention term (windowed layers capped at the window)
        for kind in self._attn_kinds:
            if kind == "attn_global":
                span = prompt
            else:                       # attn_local
                span = min(cfg.local_window or prompt, prompt)
            flops += 4 * batch * prompt * span * cfg.num_heads * cfg.head_dim / 2
        act_bytes = 8 * batch * prompt * cfg.d_model * 2.0 * cfg.num_layers
        compute_s = flops / (self.chips * self.hw.peak_flops)
        memory_s = (self._weight_bytes / self.chips + act_bytes / self.chips) \
            / self.hw.hbm_bw
        return max(compute_s, memory_s) + LAUNCH_OVERHEAD_S

    def decode_latency(self, batch: int, context: int) -> float:
        cfg = self.cfg
        flops = batch * self.flops_per_token
        flops += 4 * batch * min(context, 1 << 30) * cfg.num_heads \
            * cfg.head_dim * self._n_attn
        kv_bytes = batch * context * self._kv_bytes_per_token()
        compute_s = flops / (self.chips * self.hw.peak_flops)
        memory_s = (self._weight_bytes + kv_bytes) \
            / (self.chips * self.hw.hbm_bw)
        return max(compute_s, memory_s) + LAUNCH_OVERHEAD_S

    def cold_start(self) -> float:
        return COLD_START_CONST_S + self._weight_bytes \
            / (self.chips * COLD_START_DISK_BW)

    def to_profile(self, *, batches=(1, 2, 4, 8, 16),
                   seqs=(32, 64, 128, 256), contexts=None,
                   holdout_fraction: float = 0.0):
        """Fit this oracle's analytic grid into a calibration profile
        (``repro.calibrate`` round-trip — see ``CalibrationProfile``)."""
        from repro.calibrate.fit import fit_records
        from repro.calibrate.microbench import oracle_records
        records = oracle_records(self, batches=batches, seqs=seqs,
                                 contexts=contexts)
        return fit_records(
            records, model=self.cfg.name, hardware=self.hw.name,
            chips=self.chips, source="oracle",
            holdout_fraction=holdout_fraction,
            cold_start_s=self.cold_start())


@dataclasses.dataclass
class FittedLatencyModel(LatencyOracle):
    """Parametric latency oracle backed by calibrated coefficients.

    The closed forms are linear in their parameters (what the
    ``repro.calibrate`` least-squares fitter recovers):

        prefill(b, s) = p0 + p1·(b·s) + p2·(b·s²)
        decode(b, c)  = d0 + α·b + β·(b·c)

    ``p1`` is the prefill FLOPs term, ``p2`` the quadratic-attention
    term; ``α`` is the per-sequence decode-step cost and ``β`` the
    per-cached-token (KV read) cost.  Latencies are clamped to a small
    positive floor so a degenerate fit can never stall the simulator.
    """
    prefill_coef: Tuple[float, float, float] = (0.0, 0.0, 0.0)
    decode_coef: Tuple[float, float, float] = (0.0, 0.0, 0.0)
    hw: hw_lib.HardwareModel = hw_lib.TPU_V5E
    chips: int = 1
    cold_start_s: float = COLD_START_CONST_S
    name: str = "fitted"

    _FLOOR_S = 1e-9

    def prefill_latency(self, batch: int, prompt: int) -> float:
        p0, p1, p2 = self.prefill_coef
        toks = batch * prompt
        return max(p0 + p1 * toks + p2 * toks * prompt, self._FLOOR_S)

    def decode_latency(self, batch: int, context: int) -> float:
        d0, alpha, beta = self.decode_coef
        return max(d0 + alpha * batch + beta * batch * context,
                   self._FLOOR_S)

    def cold_start(self) -> float:
        return self.cold_start_s

    @classmethod
    def from_profile(cls, profile) -> "FittedLatencyModel":
        """Build the oracle from a ``CalibrationProfile``, its dict form,
        a profile JSON path, or a ``model@hardware`` key resolved in the
        default profile directory."""
        from repro.calibrate.profile import CalibrationProfile, load_profile
        if isinstance(profile, dict):
            profile = CalibrationProfile.from_dict(profile)
        elif not isinstance(profile, CalibrationProfile):
            profile = load_profile(profile)
        if profile.hardware not in hw_lib.HARDWARE:
            raise ValueError(
                f"profile {profile.key!r} names unknown hardware "
                f"{profile.hardware!r} (known: {sorted(hw_lib.HARDWARE)}) — "
                "costs/energy would be computed for the wrong machine")
        hw = hw_lib.HARDWARE[profile.hardware]
        return cls(prefill_coef=tuple(profile.prefill.coef),
                   decode_coef=tuple(profile.decode.coef),
                   hw=hw, chips=profile.chips,
                   cold_start_s=profile.cold_start_s,
                   name=profile.key)


@dataclasses.dataclass
class MeasuredLatency:
    """Wall-clock a real jitted callable (CPU-scale models).

    ``reducer="mean"`` (default) averages one timed loop, matching the
    historical behavior; ``reducer="min"`` times each iteration and
    takes the fastest — the noise-robust estimator the calibration
    microbenchmarks use (scheduler jitter only ever adds time).
    """
    fn: Callable
    warmup: int = 2
    iters: int = 5
    reducer: str = "mean"

    def measure(self, *args) -> float:
        import jax
        for _ in range(self.warmup):
            jax.block_until_ready(self.fn(*args))
        if self.reducer == "min":
            best = math.inf
            for _ in range(self.iters):
                t0 = time.perf_counter()
                jax.block_until_ready(self.fn(*args))
                best = min(best, time.perf_counter() - t0)
            return best
        t0 = time.perf_counter()
        for _ in range(self.iters):
            jax.block_until_ready(self.fn(*args))
        return (time.perf_counter() - t0) / self.iters


# --- network models for the pipeline tier (paper Fig. 14) ------------------
@dataclasses.dataclass(frozen=True)
class NetworkModel:
    name: str
    bandwidth_bps: float
    rtt_s: float
    jitter_s: float = 0.0

    def transmit(self, payload_bytes: int) -> float:
        return self.rtt_s + payload_bytes * 8 / self.bandwidth_bps


NETWORKS: Dict[str, NetworkModel] = {
    "lan": NetworkModel("lan", 10e9, 0.0002),
    "wifi": NetworkModel("wifi", 100e6, 0.004),
    "4g": NetworkModel("4g", 20e6, 0.045),
    # datacenter interconnects for the disaggregated prefill→decode
    # KV-cache handoff (bytes = kv_bytes_per_token × prompt_tokens)
    "infiniband": NetworkModel("infiniband", 400e9, 5e-6),
    "nvlink": NetworkModel("nvlink", 7.2e12, 2e-6),
}
