"""Inference engine: jitted prefill / decode steps over any model family.

``make_prefill_fn`` builds the cache *inside* the jit (so the dry-run does
not need a cache operand) and returns (cache, last-token logits);
``make_decode_fn`` is the one-token step with the cache donated so XLA
aliases it in place — the KV cache is read-modify-write, never copied.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import build_model


def serving_config(cfg: ModelConfig) -> ModelConfig:
    """Inference variant: bf16 params (or the config's serve dtype)."""
    return dataclasses.replace(cfg, param_dtype=cfg.serve_param_dtype)


def make_prefill_fn(model, max_len: Optional[int] = None) -> Callable:
    cfg = model.cfg

    def prefill_step(params, tokens, lengths, frames=None, patches=None):
        B, S = tokens.shape
        total = S + (patches.shape[1] if patches is not None else 0)
        cache_len = max_len or total
        kwargs: Dict[str, Any] = {}
        if cfg.is_encdec:
            cache = model.init_cache(B, cache_len, enc_len=frames.shape[1])
            kwargs["frames"] = frames
        else:
            cache = model.init_cache(B, cache_len)
            if patches is not None:
                kwargs["prefix_embeds"] = patches
        return model.prefill(params, cache, tokens, lengths, **kwargs)

    return prefill_step


def make_decode_fn(model) -> Callable:
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return decode_step


def greedy_sample(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def make_generate_fn(model, steps: int) -> Callable:
    """prefill + `steps` greedy decode steps, scanned (for smoke/e2e tests)."""
    prefill = make_prefill_fn(model)
    decode = make_decode_fn(model)

    def generate(params, tokens, lengths, **kw):
        cache, logits = prefill(params, tokens, lengths, **kw)
        nxt = greedy_sample(logits)

        def body(carry, _):
            cache, tok = carry
            cache, logits = decode(params, cache, tok)
            nxt = greedy_sample(logits)
            return (cache, nxt), nxt

        (cache, _), toks = jax.lax.scan(body, (cache, nxt), None, length=steps)
        return jnp.concatenate([nxt[:, None], toks.T], axis=1)

    return generate
