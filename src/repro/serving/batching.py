"""Dynamic-batching policies (paper §3.2 "Advanced Features").

The paper's software tier compares TFS and Triton; what actually differs
between them is the batching policy, which we implement as composable
strategies over the same engine:

  NoBatching       — every request served alone (the CPU baseline).
  WindowBatcher    — TFS-style: wait up to ``timeout`` for ``max_batch``;
                     fires on full batch or timeout of the oldest request.
  PreferredBatcher — TrIS-style: fire eagerly as soon as any preferred
                     size is reachable; pad-free, lowest queueing delay.

A policy sees the queue and the clock and decides (batch, fire_time).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.serving.workload import Request


@dataclasses.dataclass
class QueuedRequest:
    request: Request
    enqueue_s: float


class BatchPolicy:
    name = "base"

    def next_batch(self, queue: List[QueuedRequest], now: float,
                   server_free_at: float
                   ) -> Optional[Tuple[List[QueuedRequest], float]]:
        """Return (requests_to_serve, fire_time) or None to wait."""
        raise NotImplementedError

    def earliest_fire(self, queue: List[QueuedRequest]) -> Optional[float]:
        """Next time at which the policy might fire without new arrivals."""
        return None


class NoBatching(BatchPolicy):
    name = "none"

    def next_batch(self, queue, now, server_free_at):
        if not queue:
            return None
        t = max(now, server_free_at, queue[0].enqueue_s)
        return [queue[0]], t


@dataclasses.dataclass
class WindowBatcher(BatchPolicy):
    """TFS-style: fill up to max_batch, or flush on timeout."""
    max_batch: int = 8
    timeout_s: float = 0.005
    name: str = "tfs-window"

    def next_batch(self, queue, now, server_free_at):
        if not queue:
            return None
        t_free = max(now, server_free_at)
        if len(queue) >= self.max_batch:
            batch = queue[:self.max_batch]
            return batch, max(t_free, batch[-1].enqueue_s)
        deadline = queue[0].enqueue_s + self.timeout_s
        if t_free >= deadline:
            return list(queue), t_free
        return None

    def earliest_fire(self, queue):
        if not queue:
            return None
        return queue[0].enqueue_s + self.timeout_s


@dataclasses.dataclass
class PreferredBatcher(BatchPolicy):
    """TrIS-style: serve eagerly at the largest reachable preferred size."""
    preferred: Sequence[int] = (8, 4, 2, 1)
    max_queue_delay_s: float = 0.002
    name: str = "tris-preferred"

    def next_batch(self, queue, now, server_free_at):
        if not queue:
            return None
        t_free = max(now, server_free_at)
        for size in sorted(self.preferred, reverse=True):
            if len(queue) >= size:
                batch = queue[:size]
                return batch, max(t_free, batch[-1].enqueue_s)
        deadline = queue[0].enqueue_s + self.max_queue_delay_s
        if t_free >= deadline:      # don't hold a partial batch forever
            return list(queue[:max(self.preferred)]), t_free
        return None

    def earliest_fire(self, queue):
        if not queue:
            return None
        return queue[0].enqueue_s + self.max_queue_delay_s


def make_policy(name: str, **kw) -> BatchPolicy:
    if name in ("none", "nobatch"):
        return NoBatching()
    if name in ("tfs", "window", "tfs-window"):
        return WindowBatcher(**kw)
    if name in ("tris", "preferred", "tris-preferred"):
        return PreferredBatcher(**kw)
    raise ValueError(name)
