"""Dynamic-batching policies (paper §3.2 "Advanced Features").

The paper's software tier compares TFS and Triton; what actually differs
between them is the batching policy, which we implement as composable
strategies over the same engine:

  NoBatching        — every request served alone (the CPU baseline).
  WindowBatcher     — TFS-style: wait up to ``timeout`` for ``max_batch``;
                      fires on full batch or timeout of the oldest request.
  PreferredBatcher  — TrIS-style: fire eagerly as soon as any preferred
                      size is reachable; pad-free, lowest queueing delay.
  ContinuousBatcher — Orca/vLLM-style token-level policy: decode slots
                      free per iteration and waiting requests join the
                      running batch at every iteration boundary.

A request-level policy sees the queue and the clock and decides
(batch, fire_time).  ``ContinuousBatcher`` is configuration only — the
simulator's iteration-level engine interprets it (requests are admitted
mid-batch, so there is no single "fire" event to decide).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.serving.workload import Request


@dataclasses.dataclass(slots=True)
class QueuedRequest:
    request: Request
    enqueue_s: float
    # ---- preemption state (continuous engine + KV cache manager) ----------
    # a preempted request re-enters the queue carrying its progress: the
    # tokens it must re-prefill (prompt + generated so far, recomputed at
    # latency-model cost) and the decode tokens still owed
    remaining: Optional[int] = None     # None → derive from the request
    recompute_tokens: int = 0           # context to re-prefill on rejoin
    preemptions: int = 0
    # ---- disaggregated serving (prefill→decode migration) -----------------
    # the request was prefilled on the prefill pool and its KV transferred:
    # the decode engine admits it with KV already resident (no prefill
    # compute) unless a later preemption forces a recompute
    migrated: bool = False


class BatchPolicy:
    name = "base"

    def next_batch(self, queue: List[QueuedRequest], now: float,
                   server_free_at: float
                   ) -> Optional[Tuple[List[QueuedRequest], float]]:
        """Return (requests_to_serve, fire_time) or None to wait."""
        raise NotImplementedError

    def earliest_fire(self, queue: List[QueuedRequest]) -> Optional[float]:
        """Next time at which the policy might fire without new arrivals."""
        return None


class NoBatching(BatchPolicy):
    name = "none"

    def next_batch(self, queue, now, server_free_at):
        if not queue:
            return None
        t = max(now, server_free_at, queue[0].enqueue_s)
        return [queue[0]], t


@dataclasses.dataclass
class WindowBatcher(BatchPolicy):
    """TFS-style: fill up to max_batch, or flush on timeout."""
    max_batch: int = 8
    timeout_s: float = 0.005
    name: str = "tfs-window"

    def next_batch(self, queue, now, server_free_at):
        if not queue:
            return None
        t_free = max(now, server_free_at)
        if len(queue) >= self.max_batch:
            batch = queue[:self.max_batch]
            return batch, max(t_free, batch[-1].enqueue_s)
        deadline = queue[0].enqueue_s + self.timeout_s
        if t_free >= deadline:
            return list(queue), t_free
        return None

    def earliest_fire(self, queue):
        if not queue:
            return None
        return queue[0].enqueue_s + self.timeout_s


@dataclasses.dataclass
class PreferredBatcher(BatchPolicy):
    """TrIS-style: serve eagerly at the largest reachable preferred size."""
    preferred: Sequence[int] = (8, 4, 2, 1)
    max_queue_delay_s: float = 0.002
    name: str = "tris-preferred"

    def next_batch(self, queue, now, server_free_at):
        if not queue:
            return None
        t_free = max(now, server_free_at)
        for size in sorted(self.preferred, reverse=True):
            if len(queue) >= size:
                batch = queue[:size]
                return batch, max(t_free, batch[-1].enqueue_s)
        deadline = queue[0].enqueue_s + self.max_queue_delay_s
        if t_free >= deadline:      # don't hold a partial batch forever
            return list(queue[:max(self.preferred)]), t_free
        return None

    def earliest_fire(self, queue):
        if not queue:
            return None
        return queue[0].enqueue_s + self.max_queue_delay_s


@dataclasses.dataclass
class ContinuousBatcher(BatchPolicy):
    """Orca/vLLM-style iteration-level batching configuration.

    ``max_batch`` caps concurrent decode slots; ``max_prefill`` caps how
    many queued requests are prefilled (joined) per iteration boundary.
    The policy holds no queue logic itself — the simulator's continuous
    engine admits requests into free slots every iteration.
    """
    max_batch: int = 16
    max_prefill: int = 8
    name: str = "continuous"

    def __post_init__(self):
        if self.max_batch < 1 or self.max_prefill < 1:
            raise ValueError("ContinuousBatcher needs max_batch >= 1 "
                             "and max_prefill >= 1")

    def next_batch(self, queue, now, server_free_at):
        raise TypeError(
            "ContinuousBatcher is iteration-level; it is interpreted by "
            "the simulator's continuous engine, not via next_batch()")


def make_policy(name: str, **kw) -> BatchPolicy:
    if name in ("none", "nobatch"):
        return NoBatching()
    if name in ("tfs", "window", "tfs-window"):
        return WindowBatcher(**kw)
    if name in ("tris", "preferred", "tris-preferred"):
        return PreferredBatcher(**kw)
    if name in ("continuous", "orca", "vllm"):
        return ContinuousBatcher(**kw)
    raise ValueError(name)
