"""Workload generator (paper §4.2.2).

Produces request arrival traces for the serving benchmarks: Poisson (the
paper's primary mode), uniform, closed-loop, and spike/burst patterns.
Deterministic given a seed.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

POISSON = "poisson"
UNIFORM = "uniform"
BURST = "burst"
CLOSED = "closed"


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    arrival_s: float
    prompt_tokens: int
    output_tokens: int
    payload_bytes: int


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    kind: str = POISSON
    rate: float = 30.0                  # requests/s (poisson & uniform)
    duration_s: float = 60.0
    prompt_tokens: int = 128
    output_tokens: int = 1              # classification-style: 1 step
    payload_bytes: int = 150 * 1024     # ~one image
    burst_factor: float = 10.0          # rate multiplier inside a burst
    burst_fraction: float = 0.1         # fraction of time bursting
    concurrency: int = 8                # closed-loop clients
    seed: int = 0


def generate(spec: WorkloadSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    times: List[float] = []
    if spec.kind == POISSON:
        t = 0.0
        while t < spec.duration_s:
            t += rng.exponential(1.0 / spec.rate)
            if t < spec.duration_s:
                times.append(t)
    elif spec.kind == UNIFORM:
        n = int(spec.rate * spec.duration_s)
        times = list(np.linspace(0, spec.duration_s, n, endpoint=False))
    elif spec.kind == BURST:
        t = 0.0
        period = spec.duration_s / 10.0
        while t < spec.duration_s:
            in_burst = (t % period) < spec.burst_fraction * period
            rate = spec.rate * (spec.burst_factor if in_burst else 1.0)
            t += rng.exponential(1.0 / rate)
            if t < spec.duration_s:
                times.append(t)
    elif spec.kind == CLOSED:
        # one seed request per client at t=0; simulator.simulate reissues
        # each client's next request on completion until duration_s
        times = [0.0] * spec.concurrency
    else:
        raise ValueError(spec.kind)
    return [
        Request(req_id=i, arrival_s=float(t),
                prompt_tokens=spec.prompt_tokens,
                output_tokens=spec.output_tokens,
                payload_bytes=spec.payload_bytes)
        for i, t in enumerate(times)
    ]
