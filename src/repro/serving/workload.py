"""Workload generator (paper §4.2.2).

Produces request arrival traces for the serving benchmarks: Poisson (the
paper's primary mode), uniform, closed-loop, spike/burst patterns, stepped
``ramp`` rate sweeps (for saturation-knee finding), and ``trace`` replay
from recorded JSONL files (schema documented in ``configs/traces/``).
Deterministic given a seed.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import List, Optional

import numpy as np

POISSON = "poisson"
UNIFORM = "uniform"
BURST = "burst"
CLOSED = "closed"
RAMP = "ramp"
TRACE = "trace"

KINDS = (POISSON, UNIFORM, BURST, CLOSED, RAMP, TRACE)

# ``output_tokens_max=None`` ⇒ generate-until-stopped.  Requests carry this
# sentinel; the continuous engine bounds each decode by the model's
# ``max_seq_len`` (minus the prompt) so slot/KV accounting stays finite.
UNBOUNDED_OUTPUT_TOKENS = 1 << 15

# JSONL trace-replay columns; only ``arrival_s`` is required per line, the
# rest default to the WorkloadSpec values (see configs/traces/README.md).
TRACE_FIELDS = ("arrival_s", "prompt_tokens", "output_tokens",
                "payload_bytes", "session_id", "prefix_tokens")


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    arrival_s: float
    prompt_tokens: int
    output_tokens: int
    payload_bytes: int
    session_id: int = 0             # client/session for affinity routing
    prefix_tokens: int = 0          # leading prompt tokens shared by every
                                    # request of this session (system prompt
                                    # / chat history — prefix-cache reusable)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    kind: str = POISSON
    rate: float = 30.0                  # requests/s (poisson & uniform)
    duration_s: float = 60.0
    prompt_tokens: int = 128
    prompt_tokens_max: int = 0          # > prompt_tokens ⇒ per-request
                                        # uniform sample in [min, max] —
                                        # mixed short/long-prefill loads
                                        # (disaggregation's home turf)
    prefix_tokens: int = 0              # leading prompt tokens identical
                                        # within a session (shared-prefix
                                        # chat; enables prefix-cache reuse)
    output_tokens: int = 1              # classification-style: 1 step
    output_tokens_max: Optional[int] = 0    # > output_tokens ⇒ per-request
                                        # uniform sample in [min, max];
                                        # None ⇒ unbounded generation — the
                                        # serving engine bounds it by the
                                        # model's max_seq_len when memory
                                        # accounting is on
    payload_bytes: int = 150 * 1024     # ~one image
    burst_factor: float = 10.0          # rate multiplier inside a burst
    burst_fraction: float = 0.1         # fraction of time bursting
    concurrency: int = 8                # closed-loop clients
    session_count: int = 4              # distinct sessions (affinity routing)
    ramp_min_rate: float = 10.0         # ramp: first step's rate
    ramp_max_rate: float = 200.0        # ramp: last step's rate
    ramp_steps: int = 5                 # ramp: number of equal-length steps
    trace_path: Optional[str] = None    # trace: JSONL file to replay
    seed: int = 0


def ramp_step_rates(spec: WorkloadSpec) -> List[float]:
    """The per-step arrival rates of a ``ramp`` workload (low → high)."""
    denom = max(spec.ramp_steps - 1, 1)
    return [spec.ramp_min_rate
            + (spec.ramp_max_rate - spec.ramp_min_rate) * k / denom
            for k in range(spec.ramp_steps)]


def _load_trace(spec: WorkloadSpec) -> List[Request]:
    if not spec.trace_path:
        raise ValueError("kind='trace' needs WorkloadSpec.trace_path")
    rows = []
    for line in Path(spec.trace_path).read_text().splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        rows.append(json.loads(line))
    rows.sort(key=lambda d: float(d["arrival_s"]))
    return [
        Request(req_id=i, arrival_s=float(d["arrival_s"]),
                prompt_tokens=int(d.get("prompt_tokens", spec.prompt_tokens)),
                output_tokens=int(d.get("output_tokens", spec.output_tokens)),
                payload_bytes=int(d.get("payload_bytes", spec.payload_bytes)),
                session_id=int(d.get("session_id", 0)),
                prefix_tokens=int(d.get("prefix_tokens",
                                        spec.prefix_tokens)))
        for i, d in enumerate(rows)
    ]


def generate(spec: WorkloadSpec) -> List[Request]:
    if spec.kind == TRACE:
        return _load_trace(spec)
    rng = np.random.default_rng(spec.seed)
    times: List[float] = []
    if spec.kind == POISSON:
        t = 0.0
        while t < spec.duration_s:
            t += rng.exponential(1.0 / spec.rate)
            if t < spec.duration_s:
                times.append(t)
    elif spec.kind == UNIFORM:
        n = int(spec.rate * spec.duration_s)
        times = list(np.linspace(0, spec.duration_s, n, endpoint=False))
    elif spec.kind == BURST:
        t = 0.0
        period = spec.duration_s / 10.0
        while t < spec.duration_s:
            in_burst = (t % period) < spec.burst_fraction * period
            rate = spec.rate * (spec.burst_factor if in_burst else 1.0)
            t += rng.exponential(1.0 / rate)
            if t < spec.duration_s:
                times.append(t)
    elif spec.kind == RAMP:
        step_len = spec.duration_s / spec.ramp_steps
        for k, rate in enumerate(ramp_step_rates(spec)):
            t, end = k * step_len, (k + 1) * step_len
            while True:
                t += rng.exponential(1.0 / max(rate, 1e-9))
                if t >= end:
                    break
                times.append(t)
    elif spec.kind == CLOSED:
        # one seed request per client at t=0; the simulator reissues each
        # client's next request on completion until duration_s
        times = [0.0] * spec.concurrency
    else:
        raise ValueError(spec.kind)

    n = len(times)
    if spec.kind == CLOSED:
        # each closed-loop client is its own session (sticky routing keeps
        # a client's loop on one replica)
        sessions = np.arange(n)
    elif spec.session_count > 1:
        sessions = rng.integers(0, spec.session_count, size=n)
    else:
        sessions = np.zeros(n, dtype=int)
    if spec.output_tokens_max is None:
        # unbounded generation: the engine clamps by the model's max
        # sequence length (see UNBOUNDED_OUTPUT_TOKENS)
        outs = np.full(n, UNBOUNDED_OUTPUT_TOKENS, dtype=int)
    elif spec.output_tokens_max > spec.output_tokens:
        outs = rng.integers(spec.output_tokens, spec.output_tokens_max + 1,
                            size=n)
    else:
        outs = np.full(n, spec.output_tokens, dtype=int)
    # mixed prompt lengths only sample the rng when enabled, so legacy
    # workloads keep byte-identical request streams for a given seed
    if spec.prompt_tokens_max > spec.prompt_tokens:
        prompts = rng.integers(spec.prompt_tokens,
                               spec.prompt_tokens_max + 1, size=n)
    else:
        prompts = np.full(n, spec.prompt_tokens, dtype=int)
    prefix0 = max(spec.prefix_tokens, 0)
    return [
        Request(req_id=i, arrival_s=float(t),
                prompt_tokens=int(prompts[i]),
                output_tokens=int(outs[i]),
                payload_bytes=spec.payload_bytes,
                session_id=int(sessions[i]),
                prefix_tokens=min(prefix0, int(prompts[i])))
        for i, t in enumerate(times)
    ]
