"""Multi-replica cluster simulation: routers, reactive autoscaling, and
the shared discrete-event loop over ``ReplicaEngine`` timelines.

This is the capacity-planning layer the paper's benchmark questions need
at scale: N model replicas behind a pluggable router (round-robin,
least-loaded/JSQ, session-affinity) with an optional reactive autoscaler
that adds replicas under backlog and retires idle ones.  Every replica
runs the same batching policy (request-level or continuous) against the
same roofline latency oracle; the event loop owns arrivals, routing,
closed-loop reissue and the shared clock.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro.serving.batching import (BatchPolicy, ContinuousBatcher,
                                    QueuedRequest)
from repro.serving.latency_model import LatencyModel, NetworkModel, NETWORKS
from repro.serving.memory import (KVBudgetError, KVCacheManager, MemorySpec,
                                  ResolvedMemory, resolve_memory)
from repro.serving.simulator import (EPS, PRE_PROCESS_S, ReplicaEngine,
                                     RequestTrace, SimResult)
from repro.serving.workload import CLOSED, TRACE, Request, WorkloadSpec, \
    generate


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Replica-tier configuration (plumbed through BenchmarkJobSpec)."""
    replicas: int = 1
    router: str = "round-robin"     # round-robin | least-loaded | affinity
    autoscale: bool = False
    min_replicas: int = 1
    max_replicas: int = 8
    scale_interval_s: float = 0.5   # reactive-controller evaluation period
    scale_up_load: float = 4.0      # mean in-flight/replica to add one
    scale_down_load: float = 0.5    # mean in-flight/replica to retire one
    spawn_delay_s: float = 0.5      # cold-start before a new replica serves
    memory: Optional[MemorySpec] = None   # per-replica KV-cache accounting
                                    # (None → memory unmodeled, legacy)

    def __post_init__(self):
        if self.replicas < 1 or self.min_replicas < 1:
            raise ValueError("ClusterSpec needs replicas >= 1 and "
                             "min_replicas >= 1 (the cluster cannot scale "
                             "up from zero: backlog is only observed on "
                             "live replicas)")
        if self.max_replicas < self.min_replicas:
            raise ValueError("ClusterSpec.max_replicas must be >= "
                             "min_replicas")
        if isinstance(self.memory, dict):
            object.__setattr__(self, "memory",
                               MemorySpec.from_dict(self.memory))

    @classmethod
    def from_dict(cls, d) -> "ClusterSpec":
        return cls(**dict(d))


# ---- routers ---------------------------------------------------------------
class Router:
    """Picks a live replica index for each arriving request."""
    name = "base"

    def route(self, request: Request, engines: List[ReplicaEngine],
              now: float) -> int:
        raise NotImplementedError


class RoundRobinRouter(Router):
    name = "round-robin"

    def __init__(self):
        self._i = 0

    def route(self, request, engines, now):
        idx = self._i % len(engines)
        self._i += 1
        return idx


class LeastLoadedRouter(Router):
    """Join-the-shortest-queue over in-flight work (queued + running)."""
    name = "least-loaded"

    def route(self, request, engines, now):
        return min(range(len(engines)),
                   key=lambda i: (engines[i].load(now), i))


class SessionAffinityRouter(Router):
    """Sticky sessions: a session always lands on the same replica (while
    the live replica set is stable)."""
    name = "affinity"

    def route(self, request, engines, now):
        return request.session_id % len(engines)


def make_router(name: str) -> Router:
    if name in ("round-robin", "rr"):
        return RoundRobinRouter()
    if name in ("least-loaded", "jsq", "least_loaded"):
        return LeastLoadedRouter()
    if name in ("affinity", "session", "session-affinity"):
        return SessionAffinityRouter()
    raise ValueError(f"unknown router {name!r}")


# ---- reactive autoscaler ---------------------------------------------------
class Autoscaler:
    """Threshold controller: scale up when mean *queued* (waiting, not
    yet served) requests per replica exceed ``scale_up_load`` — in-flight
    decode slots are healthy capacity use, not backlog — and retire an
    idle replica when mean in-flight work drops below
    ``scale_down_load``.  New replicas pay ``spawn_delay_s`` cold start."""

    def __init__(self, spec: ClusterSpec, policy: BatchPolicy,
                 latency: LatencyModel, make_engine=None):
        self.spec = spec
        self.policy = policy
        self.latency = latency
        # factory so spawned replicas get their own KV-cache manager
        self.make_engine = make_engine or (
            lambda i, spawn_s: ReplicaEngine(i, policy, latency,
                                             spawn_s=spawn_s))

    def step(self, engines: List[ReplicaEngine], now: float) -> None:
        live = [e for e in engines if not e.retired]
        n = len(live)
        queued = sum(len(e.queue) for e in live) / max(n, 1)
        inflight = sum(e.load(now) for e in live) / max(n, 1)
        if queued > self.spec.scale_up_load and n < self.spec.max_replicas:
            engines.append(self.make_engine(
                len(engines), now + self.spec.spawn_delay_s))
        elif inflight < self.spec.scale_down_load \
                and n > self.spec.min_replicas:
            for e in reversed(live):
                if e.idle(now):
                    e.retired = True
                    break


# ---- memory grounding ------------------------------------------------------
def _resolve_cluster_memory(cluster: ClusterSpec, policy: BatchPolicy,
                            latency, requests: List[Request]
                            ) -> Optional[ResolvedMemory]:
    """Ground the cluster's MemorySpec and validate that the per-replica
    block budget can hold the largest single request — below that there
    is no victim to preempt and the sequence could never run."""
    if cluster.memory is None:
        return None
    resolved = resolve_memory(cluster.memory, latency)
    continuous = isinstance(policy, ContinuousBatcher)
    worst = 0
    for r in requests:
        out = r.output_tokens
        if continuous:
            out = max(1, min(out, resolved.max_model_len - r.prompt_tokens))
        worst = max(worst, r.prompt_tokens + out)
    bt = cluster.memory.block_tokens
    need = -(-worst // bt)
    if need > resolved.total_blocks:
        raise KVBudgetError(
            f"KV budget of {resolved.total_blocks} blocks "
            f"({resolved.budget_bytes / 1024**3:.2f} GiB at "
            f"{bt} tok/block) cannot hold one {worst}-token sequence "
            f"({need} blocks); raise hbm_gb/num_blocks or shrink the "
            "workload's prompt/output lengths")
    return resolved


# ---- cluster event loop ----------------------------------------------------
def simulate_cluster(workload: WorkloadSpec, policy: BatchPolicy,
                     latency: LatencyModel, *,
                     cluster: ClusterSpec = ClusterSpec(),
                     network: NetworkModel = NETWORKS["lan"]) -> SimResult:
    """Drive a cluster of replicas over a workload; returns a SimResult
    whose utilization/energy/cost account for the peak replica count.

    ``duration_s`` is ``max(workload window, last completion)`` — a sparse
    open-loop workload no longer reports inflated throughput, and overload
    (completions past the window) stretches the denominator instead of
    shrinking it.  Trace replay has no declared window, so its duration is
    the makespan.
    """
    requests = generate(workload)
    closed_loop = workload.kind == CLOSED
    traces: Dict[int, RequestTrace] = {}
    arrivals: List[Tuple[float, int, Request]] = []   # (server_arrival, id, r)

    def admit(r: Request) -> None:
        tr = RequestTrace(request=r, t_preprocess=PRE_PROCESS_S,
                          t_transmit=network.transmit(r.payload_bytes))
        traces[r.req_id] = tr
        heapq.heappush(arrivals,
                       (r.arrival_s + tr.t_preprocess + tr.t_transmit,
                        r.req_id, r))

    for r in requests:
        admit(r)
    next_id = len(requests)

    resolved = _resolve_cluster_memory(cluster, policy, latency, requests)
    # decode is bounded by the model's context limit even when memory is
    # unmodeled — otherwise output_tokens_max=None workloads run their
    # 32k-token sentinel far past max_seq_len
    max_len = resolved.max_model_len if resolved is not None \
        else getattr(getattr(latency, "cfg", None), "max_seq_len", 0)

    def make_engine(i: int, spawn_s: float = 0.0) -> ReplicaEngine:
        kv = KVCacheManager(cluster.memory, resolved) \
            if resolved is not None else None
        return ReplicaEngine(i, policy, latency, spawn_s=spawn_s,
                             kv=kv, max_model_len=max_len)

    engines = [make_engine(i) for i in range(max(cluster.replicas, 1))]
    router = make_router(cluster.router)
    scaler = Autoscaler(cluster, policy, latency, make_engine) \
        if cluster.autoscale else None
    next_scale = cluster.scale_interval_s
    peak = len(engines)

    now = 0.0
    while True:
        candidates = []
        if arrivals:
            candidates.append(arrivals[0][0])
        for e in engines:
            t = e.next_action_s(now)
            if t is not None:
                candidates.append(t)
        if not candidates:
            break
        if scaler is not None:      # only re-evaluate while work remains
            candidates.append(next_scale)
        now = max(now, min(candidates))

        while arrivals and arrivals[0][0] <= now + EPS:
            t_arr, _, r = heapq.heappop(arrivals)
            live = [e for e in engines if not e.retired]
            # prefer replicas already past cold start; a still-spawning
            # replica only takes traffic if no warm replica exists
            ready = [e for e in live if e.spawn_s <= now + EPS] or live
            ready[router.route(r, ready, now)].enqueue(
                QueuedRequest(request=r, enqueue_s=t_arr))

        if scaler is not None and now + EPS >= next_scale:
            scaler.step(engines, now)
            peak = max(peak, sum(1 for e in engines if not e.retired))
            while next_scale <= now + EPS:
                next_scale += cluster.scale_interval_s

        for e in engines:
            for done_s, r in e.act(now, traces):
                if closed_loop and done_s < workload.duration_s:
                    # the client observes the response and issues its next
                    # request, keeping its loop at concurrency 1
                    admit(dataclasses.replace(r, req_id=next_id,
                                              arrival_s=done_s))
                    next_id += 1

    done = [t for t in traces.values() if t.done_s > 0]
    last_done = max((t.done_s for t in done), default=0.0)
    window = 0.0 if workload.kind == TRACE else workload.duration_s
    duration = max(window, last_done)
    memory = None
    if resolved is not None:
        per = [e.kv.stats(duration) for e in engines]
        hits = sum(p["prefix_hit_tokens"] for p in per)
        served_tokens = sum(e.kv.hit_tokens + e.kv.miss_tokens
                            for e in engines)
        memory = {
            "block_tokens": cluster.memory.block_tokens,
            "total_blocks_per_replica": resolved.total_blocks,
            "budget_bytes_per_replica": resolved.budget_bytes,
            "kv_bytes_per_token": resolved.kv_bytes_per_token,
            "max_model_len": resolved.max_model_len,
            "peak_blocks": max(p["peak_blocks"] for p in per),
            "peak_occupancy": max(p["peak_occupancy"] for p in per),
            "mean_occupancy": (sum(p["mean_occupancy"] for p in per)
                               / len(per)),
            "prefix_hit_tokens": hits,
            "prefix_hit_rate": hits / served_tokens if served_tokens
            else 0.0,
            "preemptions": sum(p["preemptions"] for p in per),
            "evictions": sum(p["evictions"] for p in per),
            "per_replica": per,
        }
    return SimResult(
        traces=done,
        busy_s=sum(e.busy_s for e in engines),
        duration_s=duration,
        hw=latency.hw,
        chips=latency.chips,
        replicas=peak,
        router=cluster.router,
        per_replica_busy_s=[e.busy_s for e in engines],
        memory=memory)
