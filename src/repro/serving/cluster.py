"""Multi-replica cluster simulation: routers, reactive autoscaling, and
the shared discrete-event loop over ``ReplicaEngine`` timelines.

This is the capacity-planning layer the paper's benchmark questions need
at scale: N model replicas behind a pluggable router (round-robin,
least-loaded/JSQ, session-affinity, cost-weighted, fastest-TTFT) with an
optional reactive autoscaler that adds replicas under backlog and
retires idle ones.  A cluster is either a flat pool of identical
replicas (``ClusterSpec.replicas``), a prefill/decode split
(``disaggregation``), or a heterogeneous fleet of typed ``PoolSpec``s —
each pool with its own hardware, latency oracle, memory budget, pricing
class (reserved vs. spot, with a seeded reclamation process) and
optional region.  The event loop owns arrivals, routing, closed-loop
reissue, spot kills, inter-region forwarding and the shared clock.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Tuple

from repro import hw as hw_lib
from repro.obs.recorder import MetricsRecorder
from repro.obs.spec import ObsSpec
from repro.serving.batching import (BatchPolicy, ContinuousBatcher,
                                    QueuedRequest)
from repro.serving.latency_model import (FittedLatencyModel, LatencyModel,
                                         NetworkModel, NETWORKS,
                                         inter_region_network,
                                         oracle_for_hardware)
from repro.serving.memory import (KVBudgetError, KVCacheManager, MemorySpec,
                                  ResolvedMemory, oracle_kv_bytes_per_token,
                                  resolve_memory,
                                  validate_budget_for_requests)
from repro.serving.simulator import (EPS, PRE_PROCESS_S, ReplicaEngine,
                                     RequestTrace, SimResult,
                                     clamped_output_tokens)
from repro.serving.workload import CLOSED, TRACE, Request, WorkloadSpec, \
    generate


@dataclasses.dataclass(frozen=True)
class DisaggSpec:
    """Disaggregated prefill/decode serving (DistServe/Splitwise-style).

    Requests land on a *prefill pool* that runs chunked prefill only and
    emits the first token; the KV cache then migrates to a *decode pool*
    over ``kv_network`` (bytes = ``kv_bytes_per_token × prompt_tokens``)
    and the request joins a decode engine's continuous batch with its KV
    already resident.  Each pool has its own replica count, router, and
    batching knobs, so prefill bursts can no longer stall decode
    iterations (TPOT) and long prompts stop queueing behind decode
    (TTFT).
    """
    prefill_replicas: int = 1
    decode_replicas: int = 1
    prefill_router: str = "least-loaded"
    decode_router: str = "least-loaded"
    prefill_chunk_tokens: int = 512  # chunked-prefill granularity
                                     # (0 → whole-prompt prefill)
    prefill_max_batch: int = 4       # concurrent prefills per engine
    decode_max_batch: int = 0        # decode slots; 0 → the job policy's
                                     # max_batch
    kv_network: str = "infiniband"   # NetworkModel clocking the handoff
    kv_bytes_per_token: float = 0.0  # 0 → derive from the memory spec /
                                     # model config (0 if underivable:
                                     # the handoff costs one RTT)

    def __post_init__(self):
        if self.prefill_replicas < 1 or self.decode_replicas < 1:
            raise ValueError("DisaggSpec needs at least one replica in "
                             "each pool")
        if self.prefill_max_batch < 1:
            raise ValueError("DisaggSpec.prefill_max_batch must be >= 1")
        if self.prefill_chunk_tokens < 0:
            raise ValueError("DisaggSpec.prefill_chunk_tokens must be "
                             ">= 0 (0 = whole-prompt prefill)")
        if self.kv_network not in NETWORKS:
            raise ValueError(f"unknown kv_network {self.kv_network!r} "
                             f"(known: {sorted(NETWORKS)})")

    @property
    def total_replicas(self) -> int:
        return self.prefill_replicas + self.decode_replicas

    @classmethod
    def from_dict(cls, d) -> "DisaggSpec":
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """One homogeneous slice of a heterogeneous fleet.

    A fleet is a list of pools; each pool contributes ``replicas``
    engines that share one hardware target, latency oracle, memory
    budget and billing class.  The flat ``ClusterSpec(replicas=N)``
    cluster is the degenerate one-pool case (and keeps its own code
    path, byte-identical to the pre-fleet simulator).

    Fields:

    - ``name``: label for routing/observability ("" → ``pool{index}``).
    - ``hardware``: ``hw.HARDWARE`` catalog key; "" inherits the job's
      base oracle hardware.  The pool's oracle is the same analytic
      roofline model re-targeted at this chip (``oracle_for_hardware``),
      unless ``profile`` supplies calibrated coefficients.
    - ``replicas``: initial engine count (>= 1).
    - ``chips``: chips per replica (0 → the base oracle's count).
    - ``pricing``: ``"reserved"`` (on-demand rates) or ``"spot"``
      (discounted rates + eligibility for the reclamation process).
    - ``region``: placement label; requests routed across regions pay
      the ``inter_region_network`` RTT, and session affinity prefers a
      session's home region ("" → co-located with the front door).
    - ``preempt_mtbf_s``: mean seconds between spot reclamations per
      replica slot (exponential inter-kill times, seeded by
      ``ClusterSpec.preempt_seed``).  0 disables kills.  Only the
      pool's *initial* replica slots are tracked; each kill immediately
      provisions a cold replacement into the same slot.
    - ``min_replicas`` / ``max_replicas``: per-pool autoscaler bounds
      (0 → pinned at ``replicas``; any pool with ``min != max`` turns
      on the per-pool reactive controller).
    - ``memory``: pool-specific ``MemorySpec`` overriding
      ``ClusterSpec.memory`` (each pool's budget is resolved against
      its *own* oracle/HBM).
    - ``profile``: ``CalibrationProfile`` (dict/path/key) for a fitted
      per-pool latency oracle instead of the analytic roofline.
    """
    name: str = ""
    hardware: str = ""
    replicas: int = 1
    chips: int = 0
    pricing: str = "reserved"
    region: str = ""
    preempt_mtbf_s: float = 0.0
    min_replicas: int = 0
    max_replicas: int = 0
    memory: Optional[MemorySpec] = None
    profile: Optional[dict] = None

    def __post_init__(self):
        if self.replicas < 1:
            raise ValueError("PoolSpec.replicas must be >= 1")
        if self.chips < 0:
            raise ValueError("PoolSpec.chips must be >= 0 (0 inherits "
                             "the base oracle's chip count)")
        if self.pricing not in hw_lib.PRICING_CLASSES:
            raise ValueError(f"unknown pricing class {self.pricing!r} "
                             f"(expected one of {hw_lib.PRICING_CLASSES})")
        if self.hardware and self.hardware not in hw_lib.HARDWARE:
            raise ValueError(f"unknown hardware {self.hardware!r} "
                             f"(known: {sorted(hw_lib.HARDWARE)})")
        if self.preempt_mtbf_s < 0:
            raise ValueError("PoolSpec.preempt_mtbf_s must be >= 0")
        if self.preempt_mtbf_s > 0 and self.pricing != "spot":
            raise ValueError("preempt_mtbf_s models spot reclamation; "
                             "set pricing='spot' (reserved capacity is "
                             "never reclaimed)")
        if self.min_replicas < 0 or self.max_replicas < 0:
            raise ValueError("PoolSpec autoscale bounds must be >= 0 "
                             "(0 pins the pool at its replica count)")
        lo, hi = self.bounds()
        if not lo <= self.replicas <= hi:
            raise ValueError(
                f"PoolSpec.replicas={self.replicas} outside autoscale "
                f"bounds [{lo}, {hi}]")
        if isinstance(self.memory, dict):
            object.__setattr__(self, "memory",
                               MemorySpec.from_dict(self.memory))

    def bounds(self) -> Tuple[int, int]:
        """Effective (min, max) replica bounds (0 → pinned)."""
        return (self.min_replicas or self.replicas,
                self.max_replicas or self.replicas)

    @classmethod
    def from_dict(cls, d) -> "PoolSpec":
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Replica-tier configuration (plumbed through BenchmarkJobSpec)."""
    replicas: int = 1
    router: str = "round-robin"     # round-robin | least-loaded | affinity
    autoscale: bool = False
    min_replicas: int = 1
    max_replicas: int = 8
    scale_interval_s: float = 0.5   # reactive-controller evaluation period
    scale_up_load: float = 4.0      # mean in-flight/replica to add one
    scale_down_load: float = 0.5    # mean in-flight/replica to retire one
    spawn_delay_s: float = 0.5      # cold-start before a new replica serves
    memory: Optional[MemorySpec] = None   # per-replica KV-cache accounting
                                    # (None → memory unmodeled, legacy)
    disaggregation: Optional[DisaggSpec] = None   # split prefill/decode
                                    # pools (None → colocated, legacy)
    obs: Optional[ObsSpec] = None   # observability layer (time-series +
                                    # timeline); None → fast path, zero
                                    # recording overhead
    pools: Optional[Tuple[PoolSpec, ...]] = None  # heterogeneous fleet
                                    # (None → flat identical replicas;
                                    # when set, ``replicas`` is ignored)
    preempt_seed: int = 0           # seeds the spot-reclamation schedule

    def __post_init__(self):
        if self.replicas < 1 or self.min_replicas < 1:
            raise ValueError("ClusterSpec needs replicas >= 1 and "
                             "min_replicas >= 1 (the cluster cannot scale "
                             "up from zero: backlog is only observed on "
                             "live replicas)")
        if self.max_replicas < self.min_replicas:
            raise ValueError("ClusterSpec.max_replicas must be >= "
                             "min_replicas")
        if isinstance(self.memory, dict):
            object.__setattr__(self, "memory",
                               MemorySpec.from_dict(self.memory))
        if isinstance(self.disaggregation, dict):
            object.__setattr__(self, "disaggregation",
                               DisaggSpec.from_dict(self.disaggregation))
        if isinstance(self.obs, dict):
            object.__setattr__(self, "obs", ObsSpec.from_dict(self.obs))
        if self.disaggregation is not None and self.autoscale:
            raise ValueError("disaggregated pools are fixed-size: "
                             "autoscale=True is not supported with "
                             "ClusterSpec.disaggregation")
        if self.pools is not None:
            coerced = tuple(
                PoolSpec.from_dict(p) if isinstance(p, dict) else p
                for p in self.pools)
            if not coerced:
                raise ValueError("ClusterSpec.pools must name at least "
                                 "one pool when set (None means a flat "
                                 "cluster)")
            object.__setattr__(self, "pools", coerced)
            if self.disaggregation is not None:
                raise ValueError("pools and disaggregation are mutually "
                                 "exclusive cluster layouts")
            if self.autoscale:
                raise ValueError("fleet pools carry their own min/max_"
                                 "replicas bounds; leave ClusterSpec."
                                 "autoscale off")

    @classmethod
    def from_dict(cls, d) -> "ClusterSpec":
        return cls(**dict(d))


# ---- routers ---------------------------------------------------------------
class Router:
    """Picks a live replica index for each arriving request."""
    name = "base"

    def route(self, request: Request, engines: List[ReplicaEngine],
              now: float) -> int:
        raise NotImplementedError


class RoundRobinRouter(Router):
    """Rotation over stable ``replica_id``s, skip-based.

    Each arrival goes to the lowest live ``replica_id`` greater than the
    previously chosen one (wrapping to the lowest).  The old
    implementation applied a global counter mod the *filtered* ready
    list, so an autoscaler add/retire — or a replica merely finishing
    its cold start — shifted every subsequent assignment and skewed the
    distribution (the same churn bug the affinity router had).  Skipping
    over missing ids keeps the rotation anchored to replica identity:
    membership changes only affect the replicas that actually changed.
    """
    name = "round-robin"

    def __init__(self):
        self._last_id = -1

    def route(self, request, engines, now):
        nxt = wrap = None
        for i, e in enumerate(engines):
            rid = e.replica_id
            if rid > self._last_id and (
                    nxt is None or rid < engines[nxt].replica_id):
                nxt = i
            if wrap is None or rid < engines[wrap].replica_id:
                wrap = i
        idx = nxt if nxt is not None else wrap
        self._last_id = engines[idx].replica_id
        return idx


class LeastLoadedRouter(Router):
    """Join-the-shortest-queue over in-flight work (queued + running)."""
    name = "least-loaded"

    def route(self, request, engines, now):
        # explicit scan (first minimum wins, same tie-break as the old
        # min-with-key) — this runs once per arrival over every live
        # replica, so the continuous-engine load signal (queued +
        # running, exactly ``ReplicaEngine.load``) is inlined rather
        # than paying a method call per engine
        best = 0
        e = engines[0]
        best_load = len(e.queue) + len(e.active) if e.continuous \
            else e.load(now)
        for i in range(1, len(engines)):
            e = engines[i]
            load = len(e.queue) + len(e.active) if e.continuous \
                else e.load(now)
            if load < best_load:
                best, best_load = i, load
        return best


class CostWeightedRouter(Router):
    """Marginal-cost routing for heterogeneous fleets.

    Picks the replica minimizing ``cost_rate × (load + 1)`` — the
    $/hour the next request's marginal share of the replica would cost
    — so work packs onto cheap pools until their backlog makes an
    expensive replica's idle capacity worth paying for.  Ties (and the
    flat-cluster case where every ``cost_rate`` is equal or zero) fall
    back to least-loaded, then lowest ``replica_id``.
    """
    name = "cost-weighted"

    def route(self, request, engines, now):
        best = 0
        e = engines[0]
        best_key = (e.cost_rate * (e.load(now) + 1), e.load(now),
                    e.replica_id)
        for i in range(1, len(engines)):
            e = engines[i]
            load = e.load(now)
            key = (e.cost_rate * (load + 1), load, e.replica_id)
            if key < best_key:
                best, best_key = i, key
        return best


class FastestTTFTRouter(Router):
    """Latency-aware routing for heterogeneous fleets.

    Picks the replica minimizing ``ttft_hint × (load + 1)`` — the
    pool's nominal first-token latency scaled by the queue the request
    would join — so fast hardware absorbs traffic until its backlog
    erases its speed advantage.  Ties (including flat clusters, where
    every hint is equal or zero) fall back to least-loaded, then lowest
    ``replica_id``.
    """
    name = "fastest-ttft"

    def route(self, request, engines, now):
        best = 0
        e = engines[0]
        best_key = (e.ttft_hint * (e.load(now) + 1), e.load(now),
                    e.replica_id)
        for i in range(1, len(engines)):
            e = engines[i]
            load = e.load(now)
            key = (e.ttft_hint * (load + 1), load, e.replica_id)
            if key < best_key:
                best, best_key = i, key
        return best


_MASK64 = (1 << 64) - 1


def _rendezvous_weight(session_id: int, replica_id: int) -> int:
    """Deterministic splitmix64-style mix of (session, replica) — the
    highest-random-weight (rendezvous) hash.  Seed-independent, so runs
    are reproducible across processes."""
    x = (session_id * 0x9E3779B97F4A7C15
         + replica_id * 0xBF58476D1CE4E5B9 + 0x94D049BB133111EB) & _MASK64
    x ^= x >> 30
    x = (x * 0xBF58476D1CE4E5B9) & _MASK64
    x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


def _kill_gap(seed: int, slot: int, draw: int, mtbf_s: float) -> float:
    """Exponential spot-reclamation gap, deterministic in
    (seed, slot, draw) — inverse-CDF over a splitmix64 uniform."""
    x = _rendezvous_weight(seed * 1000003 + slot + 1, draw)
    u = (x + 0.5) / float(_MASK64 + 1)      # uniform in (0, 1)
    return -mtbf_s * math.log(u)


class SessionAffinityRouter(Router):
    """Sticky sessions bound to stable ``replica_id``s.

    A session stays on its assigned replica for as long as that replica
    is live; only sessions whose replica was retired are remapped
    (rendezvous hashing over the currently-live set picks the new home).
    The old implementation hashed ``session_id % len(engines)`` over the
    *filtered* ready list, so every autoscaler add/retire — or a replica
    merely cold-starting — remapped every session, destroying stickiness
    and the prefix-cache hit rate.

    Multi-region fleets add one preference: a remapped session stays in
    its recorded home *region* when any replica there is available, so
    a spot kill inside the region doesn't send the session (and its
    prefix-cache locality) across a WAN hop.  Region-less clusters see
    identical behavior (every region label is "").
    """
    name = "affinity"

    def __init__(self):
        self._home: Dict[int, int] = {}     # session_id → replica_id
        self._region: Dict[int, str] = {}   # session_id → home region

    def route(self, request, engines, now):
        sid = request.session_id
        home = self._home.get(sid)
        if home is not None:
            for i, e in enumerate(engines):
                if e.replica_id == home:
                    return i
        cands = range(len(engines))
        region = self._region.get(sid)
        if region:
            # getattr: routers are duck-typed over engine stand-ins
            local = [i for i in cands
                     if getattr(engines[i], "region", "") == region]
            if local:
                cands = local
        idx = max(cands,
                  key=lambda i: _rendezvous_weight(sid,
                                                   engines[i].replica_id))
        self._home[sid] = engines[idx].replica_id
        home_region = getattr(engines[idx], "region", "")
        if home_region:
            self._region[sid] = home_region
        return idx


def make_router(name: str) -> Router:
    if name in ("round-robin", "rr"):
        return RoundRobinRouter()
    if name in ("least-loaded", "jsq", "least_loaded"):
        return LeastLoadedRouter()
    if name in ("affinity", "session", "session-affinity"):
        return SessionAffinityRouter()
    if name in ("cost-weighted", "cost_weighted", "cost"):
        return CostWeightedRouter()
    if name in ("fastest-ttft", "fastest_ttft", "ttft"):
        return FastestTTFTRouter()
    raise ValueError(f"unknown router {name!r}")


# ---- reactive autoscaler ---------------------------------------------------
class Autoscaler:
    """Threshold controller: scale up when mean *queued* (waiting, not
    yet served) requests per replica exceed ``scale_up_load`` — in-flight
    decode slots are healthy capacity use, not backlog — and retire an
    idle replica when mean in-flight work drops below
    ``scale_down_load``.  New replicas pay ``spawn_delay_s`` cold start."""

    def __init__(self, spec: ClusterSpec, policy: BatchPolicy,
                 latency: LatencyModel, make_engine=None):
        self.spec = spec
        self.policy = policy
        self.latency = latency
        # factory so spawned replicas get their own KV-cache manager
        self.make_engine = make_engine or (
            lambda i, spawn_s=0.0, created_s=0.0: ReplicaEngine(
                i, policy, latency, spawn_s=spawn_s, created_s=created_s))

    def step(self, engines: List[ReplicaEngine], now: float) -> None:
        live = [e for e in engines if not e.retired]
        n = len(live)
        queued = sum(len(e.queue) for e in live) / max(n, 1)
        inflight = sum(e.load(now) for e in live) / max(n, 1)
        if queued > self.spec.scale_up_load and n < self.spec.max_replicas:
            engines.append(self.make_engine(
                len(engines), now + self.spec.spawn_delay_s, now))
        elif inflight < self.spec.scale_down_load \
                and n > self.spec.min_replicas:
            for e in reversed(live):
                if e.idle(now):
                    e.retired = True
                    e.retired_s = now   # billing: the replica-second
                    # integral stops here, not at the end of the run
                    break


# ---- per-pool reactive autoscaler ------------------------------------------
class FleetAutoscaler:
    """Per-pool threshold controller for heterogeneous fleets.

    Each pool scales independently between its own ``PoolSpec`` bounds
    using the cluster-wide thresholds — so a spot overflow pool grows
    under backlog while the reserved baseline stays pinned.  Shares the
    flat :class:`Autoscaler`'s signals: mean *queued* per replica to
    add, mean in-flight per replica to retire an idle one.
    """

    def __init__(self, spec: ClusterSpec, pools, bounds, make_engine,
                 pool_of: List[int]):
        self.spec = spec
        self.pools = pools
        self.bounds = bounds            # [(lo, hi)] aligned with pools
        self.make_engine = make_engine  # (pool_idx, rid, spawn_s, created_s)
        self.pool_of = pool_of          # replica_id → pool index (shared
        # with the event loop; appends here keep it aligned with engines)

    def step(self, engines: List[ReplicaEngine], now: float) -> None:
        live: List[List[ReplicaEngine]] = [[] for _ in self.pools]
        for e in engines:
            if not e.retired:
                live[self.pool_of[e.replica_id]].append(e)
        for pi, (lo, hi) in enumerate(self.bounds):
            members = live[pi]
            n = len(members)
            queued = sum(len(e.queue) for e in members) / max(n, 1)
            inflight = sum(e.load(now) for e in members) / max(n, 1)
            if queued > self.spec.scale_up_load and n < hi:
                rid = len(engines)
                engines.append(self.make_engine(
                    pi, rid, now + self.spec.spawn_delay_s, now))
                self.pool_of.append(pi)
            elif inflight < self.spec.scale_down_load and n > lo:
                for e in reversed(members):
                    if e.idle(now):
                        e.retired = True
                        e.retired_s = now
                        break


# ---- memory grounding ------------------------------------------------------
def _resolve_cluster_memory(cluster: ClusterSpec, policy: BatchPolicy,
                            latency, requests: List[Request]
                            ) -> Optional[ResolvedMemory]:
    """Ground the cluster's MemorySpec and validate that the per-replica
    block budget can hold the largest single request — below that there
    is no victim to preempt and the sequence could never run."""
    if cluster.memory is None:
        return None
    resolved = resolve_memory(cluster.memory, latency)
    validate_budget_for_requests(cluster.memory, resolved, requests,
                                 isinstance(policy, ContinuousBatcher))
    return resolved


# ---- cluster event loop ----------------------------------------------------
def simulate_cluster(workload: WorkloadSpec, policy: BatchPolicy,
                     latency: LatencyModel, *,
                     cluster: ClusterSpec = ClusterSpec(),
                     network: NetworkModel = NETWORKS["lan"],
                     trace_sample: float = 1.0) -> SimResult:
    """Drive a cluster of replicas over a workload; returns a SimResult
    whose utilization accounts for the peak replica count and whose
    energy/cost bill the integrated live replica-seconds.

    ``duration_s`` is ``max(workload window, last completion)`` — a sparse
    open-loop workload no longer reports inflated throughput, and overload
    (completions past the window) stretches the denominator instead of
    shrinking it.  Trace replay has no declared window, so its duration is
    the makespan.

    With ``cluster.disaggregation`` set, arrivals land on the prefill
    pool, completions there (= first token) trigger a KV handoff over the
    disaggregation's ``kv_network``, and the decode pool finishes the
    generation with the migrated KV already resident.

    With ``cluster.pools`` set, the fleet is heterogeneous: each
    ``PoolSpec`` contributes replicas on its own hardware/oracle/memory
    budget, billed at its pricing class.  Spot pools are subject to a
    seeded reclamation process (kills requeue in-flight work through
    the recompute machinery and provision a cold replacement); requests
    routed to a pool outside the front door's region (the first pool's)
    pay the ``inter_region_network`` transfer before enqueueing, and
    ``SimResult.fleet`` carries the per-pool bill plus
    ``spot_preemptions`` / ``cross_region_fraction``.

    ``trace_sample`` < 1 keeps full per-request trace recording (stage
    accounting, per-iteration batch sizes) for only that deterministic
    fraction of requests and drops the rest from ``SimResult.traces``.
    Counting aggregates — throughput, duration, utilization, cost, the
    memory/pool dicts and ``requests_served`` — remain exact over *all*
    requests; percentile metrics are computed over the sample.  Use it
    for aggregate-only sweeps at production scale.
    """
    disagg = cluster.disaggregation
    if disagg is not None and not isinstance(policy, ContinuousBatcher):
        raise ValueError(
            "disaggregated serving needs the continuous batcher "
            f"(got {policy.name!r}): request-level policies have no "
            "decode loop to migrate into")
    fleet = cluster.pools
    pool_names: List[str] = []
    if fleet is not None:
        if any(p.preempt_mtbf_s > 0 for p in fleet) \
                and not isinstance(policy, ContinuousBatcher):
            raise ValueError(
                "spot preemption requeues in-flight decode work through "
                "the continuous engine's recompute machinery (got "
                f"{policy.name!r}); use a continuous policy or set "
                "preempt_mtbf_s=0")
        pool_names = [p.name or f"pool{i}" for i, p in enumerate(fleet)]
        if len(set(pool_names)) != len(pool_names):
            raise ValueError(f"duplicate pool names in fleet: "
                             f"{pool_names}")
    if not 0.0 < trace_sample <= 1.0:
        raise ValueError(f"trace_sample must be in (0, 1], got "
                         f"{trace_sample}")
    sampling = trace_sample < 1.0
    # deterministic per-request coin flip (splitmix64 of req_id): the
    # same requests are sampled across runs and processes
    sample_cut = int(trace_sample * float(_MASK64 + 1))
    requests = generate(workload)
    closed_loop = workload.kind == CLOSED
    traces: Dict[int, RequestTrace] = {}
    arrivals: List[Tuple[float, int, Request]] = []   # (server_arrival, id, r)

    def admit(r: Request) -> None:
        tr = RequestTrace(request=r, t_preprocess=PRE_PROCESS_S,
                          t_transmit=network.transmit(r.payload_bytes))
        if sampling:
            tr.detail = _rendezvous_weight(r.req_id, 0x7ACE) < sample_cut
        traces[r.req_id] = tr
        heapq.heappush(arrivals,
                       (r.arrival_s + tr.t_preprocess + tr.t_transmit,
                        r.req_id, r))

    for r in requests:
        admit(r)
    next_id = len(requests)

    pool_oracles: List = []
    pool_mem: List[Tuple[Optional[MemorySpec], Optional[ResolvedMemory]]] \
        = []
    if fleet is not None:
        resolved = None
        continuous = isinstance(policy, ContinuousBatcher)
        lens = []
        for p in fleet:
            if p.profile is not None:
                oracle_p = FittedLatencyModel.from_profile(p.profile)
            else:
                oracle_p = oracle_for_hardware(latency, p.hardware,
                                               p.chips)
            pool_oracles.append(oracle_p)
            mspec = p.memory if p.memory is not None else cluster.memory
            res_p = None
            if mspec is not None:
                # each pool's budget grounds against its *own* oracle
                # (HBM, KV bytes/token), and every pool must hold the
                # workload's worst request — any request can route there
                res_p = resolve_memory(mspec, oracle_p)
                validate_budget_for_requests(mspec, res_p, requests,
                                             continuous)
                lens.append(res_p.max_model_len)
            else:
                ml = getattr(getattr(oracle_p, "cfg", None),
                             "max_seq_len", 0)
                if ml:
                    lens.append(ml)
            pool_mem.append((mspec, res_p))
        # spot requeue can move a sequence between pools mid-flight, so
        # decode is clamped by the tightest pool's context limit
        max_len = min(lens) if lens else 0
    else:
        resolved = _resolve_cluster_memory(cluster, policy, latency,
                                           requests)
        # decode is bounded by the model's context limit even when
        # memory is unmodeled — otherwise output_tokens_max=None
        # workloads run their 32k-token sentinel far past max_seq_len
        max_len = resolved.max_model_len if resolved is not None \
            else getattr(getattr(latency, "cfg", None), "max_seq_len", 0)
    if max_len:
        over = next((r for r in requests if r.prompt_tokens >= max_len),
                    None)
        if over is not None:
            # clamped_output_tokens would otherwise floor the budget at 1
            # and decode a token past the context limit
            raise ValueError(
                f"request {over.req_id}: prompt of {over.prompt_tokens} "
                f"tokens is at/over the model context limit "
                f"(max_model_len={max_len}) — no output token fits; "
                "shrink the workload's prompts or raise the context "
                "limit")

    def _kv():
        return KVCacheManager(cluster.memory, resolved) \
            if resolved is not None else None

    # observability (opt-in): counters/gauges + engine activity spans.
    # rec is None on the default path — every hook below is behind a
    # single None-check, keeping the fast path's event rate intact.
    rec: Optional[MetricsRecorder] = None
    if cluster.obs is not None and cluster.obs.enabled:
        window0 = 0.0 if workload.kind == TRACE else workload.duration_s
        rec = MetricsRecorder(cluster.obs,
                              cluster.obs.resolve_interval(window0))
    rec_ticks = rec if rec is not None and cluster.obs.timeseries else None
    # local mirror of rec_ticks.next_tick so the event loop pays one
    # float compare per pass, not an attribute walk (inf when sampling
    # is off)
    obs_next_tick = (rec_ticks.next_tick if rec_ticks is not None
                     else float("inf"))

    def make_engine(i: int, spawn_s: float = 0.0,
                    created_s: float = 0.0) -> ReplicaEngine:
        if rec is not None:
            rec.register_engine(i, "serve")
        return ReplicaEngine(i, policy, latency, spawn_s=spawn_s,
                             kv=_kv(), max_model_len=max_len,
                             created_s=created_s, obs=rec)

    pool_of: List[int] = []         # replica_id → pool index
    pool_rates: List[float] = []    # $/chip-hour at the pool's pricing
    pool_chips: List[int] = []
    if fleet is not None:
        for pi, p in enumerate(fleet):
            oracle_p = pool_oracles[pi]
            pool_rates.append(hw_lib.cloud_rate_usd_per_hour(
                oracle_p.hw.name, pricing=p.pricing))
            pool_chips.append(getattr(oracle_p, "chips", 1) or 1)

    def make_fleet_engine(pi: int, rid: int, spawn_s: float = 0.0,
                          created_s: float = 0.0) -> ReplicaEngine:
        p = fleet[pi]
        oracle_p = pool_oracles[pi]
        mspec, res_p = pool_mem[pi]
        if rec is not None:
            rec.register_engine(rid, pool_names[pi])
        e = ReplicaEngine(
            rid, policy, oracle_p, spawn_s=spawn_s,
            kv=KVCacheManager(mspec, res_p) if res_p is not None
            else None,
            max_model_len=max_len, created_s=created_s, obs=rec)
        e.pool_name = pool_names[pi]
        e.region = p.region
        e.cost_rate = pool_rates[pi] * pool_chips[pi]
        # nominal single-stream first-token time on this hardware — the
        # fastest-ttft router's capability signal (memoized per oracle)
        e.ttft_hint = oracle_p.prefill_latency(1, 256) \
            + oracle_p.decode_latency(1, 257)
        return e

    migrations: List[Tuple[float, int, Request]] = []  # (kv_ready, id, r)
    prefill_engines: List[ReplicaEngine] = []
    decode_engines: List[ReplicaEngine] = []
    decode_router = kv_net = None
    kv_bpt = 0.0
    if disagg is not None:
        prefill_policy = ContinuousBatcher(
            max_batch=disagg.prefill_max_batch,
            max_prefill=disagg.prefill_max_batch)
        decode_policy = policy if disagg.decode_max_batch <= 0 else \
            dataclasses.replace(policy, max_batch=disagg.decode_max_batch)
        prefill_engines = [
            ReplicaEngine(i, prefill_policy, latency, kv=_kv(),
                          max_model_len=max_len, role="prefill",
                          chunk_tokens=disagg.prefill_chunk_tokens,
                          obs=rec)
            for i in range(disagg.prefill_replicas)]
        decode_engines = [
            ReplicaEngine(disagg.prefill_replicas + i, decode_policy,
                          latency, kv=_kv(), max_model_len=max_len,
                          role="decode", obs=rec)
            for i in range(disagg.decode_replicas)]
        if rec is not None:
            for e in prefill_engines:
                rec.register_engine(e.replica_id, "prefill")
            for e in decode_engines:
                rec.register_engine(e.replica_id, "decode")
        engines = prefill_engines + decode_engines
        router = make_router(disagg.prefill_router)
        decode_router = make_router(disagg.decode_router)
        kv_net = NETWORKS[disagg.kv_network]
        kv_bpt = disagg.kv_bytes_per_token
        if kv_bpt <= 0 and resolved is not None:
            kv_bpt = resolved.kv_bytes_per_token
        if kv_bpt <= 0:
            kv_bpt = oracle_kv_bytes_per_token(latency)
    elif fleet is not None:
        engines = []
        for pi, p in enumerate(fleet):
            for _ in range(p.replicas):
                engines.append(make_fleet_engine(pi, len(engines)))
                pool_of.append(pi)
        router = make_router(cluster.router)
    else:
        engines = [make_engine(i) for i in range(max(cluster.replicas, 1))]
        router = make_router(cluster.router)
    if fleet is not None:
        fbounds = [p.bounds() for p in fleet]
        scaler = FleetAutoscaler(cluster, fleet, fbounds,
                                 make_fleet_engine, pool_of) \
            if any(lo != hi for lo, hi in fbounds) else None
    else:
        scaler = Autoscaler(cluster, policy, latency, make_engine) \
            if cluster.autoscale else None
    next_scale = cluster.scale_interval_s
    peak = len(engines)

    # spot reclamation: one slot per initial spot replica, exponential
    # inter-kill gaps from a counter-keyed splitmix stream — the same
    # preempt_seed reproduces the same kill schedule in any process
    kills: List[Tuple[float, int]] = []
    slot_engine: List[int] = []     # slot → current replica_id
    slot_pool: List[int] = []
    slot_draws: List[int] = []
    n_kills = 0
    # inter-region forwarding: a WAN-routed request reaches its target
    # engine only after the transfer (seq breaks heap ties)
    forwards: List[Tuple[float, int, int, QueuedRequest]] = []
    fwd_seq = 0
    cross_arrivals = routed_arrivals = 0
    home_region = fleet[0].region if fleet is not None else ""
    if fleet is not None:
        for rid, pi in enumerate(pool_of):
            p = fleet[pi]
            if p.pricing == "spot" and p.preempt_mtbf_s > 0:
                slot = len(slot_engine)
                slot_engine.append(rid)
                slot_pool.append(pi)
                slot_draws.append(1)
                heapq.heappush(kills, (_kill_gap(
                    cluster.preempt_seed, slot, 0, p.preempt_mtbf_s),
                    slot))

    # ---- indexed event scheduler -----------------------------------------
    # Per-engine next-event times live in a lazy-deletion heap instead of
    # being rescanned across all replicas on every pass: entries are
    # (t, engine_idx, version) and an entry is live iff its version
    # matches the engine's current one (``evers``) — every reschedule
    # bumps the version, staling out old entries in O(1).  Only engines
    # whose entry is due at ``now`` act; an engine's next-event time can
    # only change when its own state changes (an enqueue or its own act),
    # so everything else is provably a no-op and is skipped.  Engine list
    # position == replica_id (the autoscaler appends with len(engines)),
    # which lets routed targets be rescheduled by id.
    eheap: List[Tuple[float, int, int]] = []
    evers: List[int] = [0] * len(engines)

    def schedule(i: int, t_now: float) -> None:
        evers[i] += 1
        t = engines[i].next_action_s(t_now)
        if t is not None:
            heapq.heappush(eheap, (t, i, evers[i]))

    route_pool = prefill_engines if disagg is not None else engines

    def live_engines() -> List[ReplicaEngine]:
        return [e for e in route_pool if not e.retired]

    for i in range(len(engines)):
        schedule(i, 0.0)
    # the live routing set only changes on autoscaler steps — maintain it
    # across passes instead of refiltering per arrival
    live = live_engines()
    events = 0
    now = 0.0
    inf = float("inf")
    while True:
        while eheap and eheap[0][2] != evers[eheap[0][1]]:
            heapq.heappop(eheap)            # stale (rescheduled) entries
        t_next = arrivals[0][0] if arrivals else inf
        if migrations and migrations[0][0] < t_next:
            t_next = migrations[0][0]
        if forwards and forwards[0][0] < t_next:
            t_next = forwards[0][0]
        if eheap and eheap[0][0] < t_next:
            t_next = eheap[0][0]
        if t_next == inf:
            break
        if scaler is not None and next_scale < t_next:
            t_next = next_scale     # only re-evaluate while work remains
        if kills and kills[0][0] < t_next:
            t_next = kills[0][0]    # reclamations fire only while work
            # remains — an idle fleet past the last completion has
            # nothing observable to lose
        if obs_next_tick < t_next - EPS:
            # state is constant between events: every tick in the open
            # interval (now, t_next) samples it exactly
            rec_ticks.sample_ticks(t_next, engines)
            obs_next_tick = rec_ticks.next_tick
        if t_next > now:
            now = t_next

        # spot reclamations run before arrivals so this pass's routing
        # already sees the post-kill fleet
        if kills and kills[0][0] <= now + EPS:
            touched_k = set()
            while kills and kills[0][0] <= now + EPS:
                _, slot = heapq.heappop(kills)
                pi = slot_pool[slot]
                p = fleet[pi]
                victim = engines[slot_engine[slot]]
                if not victim.retired:
                    events += 1
                    n_kills += 1
                    work = victim.spot_kill(now, traces)
                    evers[victim.replica_id] += 1   # stale its entries
                    # a cold replacement takes over the slot
                    rid2 = len(engines)
                    engines.append(make_fleet_engine(
                        pi, rid2, now + cluster.spawn_delay_s, now))
                    pool_of.append(pi)
                    evers.append(0)
                    slot_engine[slot] = rid2
                    touched_k.add(rid2)
                    live = live_engines()
                    warm = [e for e in live
                            if e.spawn_s <= now + EPS] or live
                    for q in work:
                        e2 = warm[router.route(q.request, warm, now)]
                        xnet = inter_region_network(victim.region,
                                                    e2.region)
                        if xnet is not None:
                            xfer = xnet.transmit(q.request.payload_bytes)
                            traces[q.request.req_id].t_transmit += xfer
                            q.enqueue_s = max(q.enqueue_s, now + xfer)
                            fwd_seq += 1
                            heapq.heappush(forwards,
                                           (now + xfer, fwd_seq,
                                            e2.replica_id, q))
                        else:
                            e2.enqueue(q)
                            touched_k.add(e2.replica_id)
                # the slot's next reclamation clocks from when its
                # replacement comes up, whether or not this kill landed
                k = slot_draws[slot]
                slot_draws[slot] += 1
                heapq.heappush(kills, (
                    now + cluster.spawn_delay_s + _kill_gap(
                        cluster.preempt_seed, slot, k,
                        p.preempt_mtbf_s),
                    slot))
            for i in touched_k:
                schedule(i, now)

        if arrivals and arrivals[0][0] <= now + EPS:
            # prefer replicas already past cold start; a still-spawning
            # replica only takes traffic if no warm replica exists
            # (retired/spawn states are fixed within a pass, so the ready
            # set is computed once per drain)
            ready = [e for e in live if e.spawn_s <= now + EPS] or live
            touched = set()
            while arrivals and arrivals[0][0] <= now + EPS:
                t_arr, _, r = heapq.heappop(arrivals)
                events += 1
                if rec is not None:
                    rec.count_arrival(r.tenant)
                e = ready[router.route(r, ready, now)]
                if fleet is not None:
                    routed_arrivals += 1
                    xnet = inter_region_network(home_region, e.region)
                    if xnet is not None:
                        # WAN hop: the request reaches its target pool
                        # after the inter-region transfer
                        cross_arrivals += 1
                        xfer = xnet.transmit(r.payload_bytes)
                        traces[r.req_id].t_transmit += xfer
                        fwd_seq += 1
                        heapq.heappush(
                            forwards,
                            (t_arr + xfer, fwd_seq, e.replica_id,
                             QueuedRequest(request=r,
                                           enqueue_s=t_arr + xfer)))
                        continue
                e.enqueue(QueuedRequest(request=r, enqueue_s=t_arr))
                touched.add(e.replica_id)
            for i in touched:
                schedule(i, now)

        # cross-region deliveries whose transfer finished join their
        # target; a target reclaimed mid-flight gets rerouted locally
        while forwards and forwards[0][0] <= now + EPS:
            _, _, rid, q = heapq.heappop(forwards)
            events += 1
            e = engines[rid]
            if e.retired:
                cands = [x for x in live
                         if x.spawn_s <= now + EPS] or live
                e = cands[router.route(q.request, cands, now)]
            e.enqueue(q)
            schedule(e.replica_id, now)

        # KV handoffs whose transfer finished join the decode pool with
        # their cache already resident (first token was already emitted)
        while migrations and migrations[0][0] <= now + EPS:
            t_ready, _, r = heapq.heappop(migrations)
            events += 1
            out = clamped_output_tokens(r, max_len)
            e = decode_engines[decode_router.route(r, decode_engines, now)]
            e.enqueue(QueuedRequest(request=r, enqueue_s=t_ready,
                                    remaining=out - 1, migrated=True))
            schedule(e.replica_id, now)

        if scaler is not None and now + EPS >= next_scale:
            n_before = len(engines)
            scaler.step(engines, now)
            peak = max(peak, sum(1 for e in engines if not e.retired))
            while next_scale <= now + EPS:
                next_scale += cluster.scale_interval_s
            for i in range(n_before, len(engines)):
                evers.append(0)
                schedule(i, now)    # spawned replica enters the heap
            live = live_engines()   # membership changed (add/retire)

        due = []
        while eheap and eheap[0][0] <= now + EPS:
            t, i, ver = heapq.heappop(eheap)
            if ver == evers[i]:
                due.append(i)
        due.sort()                  # act in replica order (determinism)
        for i in due:
            e = engines[i]
            events += 1
            for done_s, r in e.act(now, traces):
                if e.role == "prefill" \
                        and clamped_output_tokens(r, max_len) > 1:
                    # first token out — clock the KV handoff and hand the
                    # request to the decode pool (single-token requests
                    # are complete after prefill and never migrate)
                    tr = traces[r.req_id]
                    transfer = kv_net.transmit(kv_bpt * r.prompt_tokens)
                    tr.t_kv_transfer = transfer
                    tr.done_s = 0.0     # decode owns final completion
                    heapq.heappush(migrations,
                                   (done_s + transfer, r.req_id, r))
                    continue
                if rec is not None:
                    rec.count_completion(r.tenant)
                if closed_loop and done_s < workload.duration_s:
                    # the client observes the response and issues its next
                    # request, keeping its loop at concurrency 1
                    admit(dataclasses.replace(r, req_id=next_id,
                                              arrival_s=done_s))
                    next_id += 1
            schedule(i, now)

    done = [t for t in traces.values() if t.done_s > 0]
    served = len(done)
    last_done = max((t.done_s for t in done), default=0.0)
    if sampling:
        done = [t for t in done if t.detail]
    window = 0.0 if workload.kind == TRACE else workload.duration_s
    duration = max(window, last_done)
    # live replica-seconds (spawn→retire spans): what energy/cost bill —
    # an autoscaled cluster no longer pays its peak count for the full run
    replica_seconds = sum(
        max((e.retired_s if e.retired_s is not None else duration)
            - e.created_s, 0.0)
        for e in engines)
    pools = None
    if disagg is not None:
        transfers = [t.t_kv_transfer for t in done if t.t_kv_transfer > 0]
        pools = {
            "prefill_replicas": disagg.prefill_replicas,
            "decode_replicas": disagg.decode_replicas,
            "prefill_busy_s": sum(e.busy_s for e in prefill_engines),
            "decode_busy_s": sum(e.busy_s for e in decode_engines),
            "kv_network": disagg.kv_network,
            "kv_bytes_per_token": kv_bpt,
            "migrated_requests": len(transfers),
            "mean_kv_transfer_s": (sum(transfers) / len(transfers)
                                   if transfers else 0.0),
        }
    fleet_info = None
    if fleet is not None:
        pools_out = []
        for pi, p in enumerate(fleet):
            members = [e for e in engines if pool_of[e.replica_id] == pi]
            rs = sum(
                max((e.retired_s if e.retired_s is not None else duration)
                    - e.created_s, 0.0)
                for e in members)
            hw_name = pool_oracles[pi].hw.name
            d = {
                "name": pool_names[pi],
                "hardware": hw_name,
                "region": p.region,
                "pricing": p.pricing,
                "chips": pool_chips[pi],
                "replicas": len(members),
                "replica_seconds": rs,
                "busy_s": sum(e.busy_s for e in members),
                # integrated replica-seconds billed at the pool's class
                # (spot capacity pays spot rates — that's the bargain
                # the reclamation process prices in)
                "cost_usd": hw_lib.cloud_cost_usd(
                    hw_name, rs, pricing=p.pricing) * pool_chips[pi],
            }
            if pool_mem[pi][1] is not None:
                stats = [e.kv.stats(duration) for e in members]
                d["kv_preemptions"] = sum(s["preemptions"]
                                          for s in stats)
                d["peak_occupancy"] = max(s["peak_occupancy"]
                                          for s in stats)
            pools_out.append(d)
        fleet_info = {
            "pools": pools_out,
            "spot_preemptions": n_kills,
            "spot_killed_requests": sum(
                1 for t in traces.values() if t.spot_evictions > 0),
            "cross_region_fraction": cross_arrivals / routed_arrivals
            if routed_arrivals else 0.0,
            "routed_requests": routed_arrivals,
        }
    memory = None
    if resolved is not None:
        per = [e.kv.stats(duration) for e in engines]
        hits = sum(p["prefix_hit_tokens"] for p in per)
        served_tokens = sum(e.kv.hit_tokens + e.kv.miss_tokens
                            for e in engines)
        memory = {
            "block_tokens": cluster.memory.block_tokens,
            "total_blocks_per_replica": resolved.total_blocks,
            "budget_bytes_per_replica": resolved.budget_bytes,
            "kv_bytes_per_token": resolved.kv_bytes_per_token,
            "max_model_len": resolved.max_model_len,
            "peak_blocks": max(p["peak_blocks"] for p in per),
            "peak_occupancy": max(p["peak_occupancy"] for p in per),
            "mean_occupancy": (sum(p["mean_occupancy"] for p in per)
                               / len(per)),
            "prefix_hit_tokens": hits,
            "prefix_hit_rate": hits / served_tokens if served_tokens
            else 0.0,
            "preemptions": sum(p["preemptions"] for p in per),
            "evictions": sum(p["evictions"] for p in per),
            "per_replica": per,
        }
    timeseries = engine_spans = None
    if rec is not None:
        if rec_ticks is not None:
            rec.finish(duration, engines)
            timeseries = rec.build()
        if cluster.obs.timeline:
            engine_spans = rec.spans
    return SimResult(
        traces=done,
        busy_s=sum(e.busy_s for e in engines),
        duration_s=duration,
        hw=latency.hw,
        chips=latency.chips,
        replicas=peak,
        router="disaggregated" if disagg is not None else cluster.router,
        per_replica_busy_s=[e.busy_s for e in engines],
        memory=memory,
        replica_seconds=replica_seconds,
        pools=pools,
        fleet=fleet_info,
        requests_served=served,
        events=events,
        timeseries=timeseries,
        engine_spans=engine_spans)
