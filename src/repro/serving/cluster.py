"""Multi-replica cluster simulation: routers, reactive autoscaling, and
the shared discrete-event loop over ``ReplicaEngine`` timelines.

This is the capacity-planning layer the paper's benchmark questions need
at scale: N model replicas behind a pluggable router (round-robin,
least-loaded/JSQ, session-affinity) with an optional reactive autoscaler
that adds replicas under backlog and retires idle ones.  Every replica
runs the same batching policy (request-level or continuous) against the
same roofline latency oracle; the event loop owns arrivals, routing,
closed-loop reissue and the shared clock.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro.obs.recorder import MetricsRecorder
from repro.obs.spec import ObsSpec
from repro.serving.batching import (BatchPolicy, ContinuousBatcher,
                                    QueuedRequest)
from repro.serving.latency_model import LatencyModel, NetworkModel, NETWORKS
from repro.serving.memory import (KVBudgetError, KVCacheManager, MemorySpec,
                                  ResolvedMemory, oracle_kv_bytes_per_token,
                                  resolve_memory)
from repro.serving.simulator import (EPS, PRE_PROCESS_S, ReplicaEngine,
                                     RequestTrace, SimResult,
                                     clamped_output_tokens)
from repro.serving.workload import CLOSED, TRACE, Request, WorkloadSpec, \
    generate


@dataclasses.dataclass(frozen=True)
class DisaggSpec:
    """Disaggregated prefill/decode serving (DistServe/Splitwise-style).

    Requests land on a *prefill pool* that runs chunked prefill only and
    emits the first token; the KV cache then migrates to a *decode pool*
    over ``kv_network`` (bytes = ``kv_bytes_per_token × prompt_tokens``)
    and the request joins a decode engine's continuous batch with its KV
    already resident.  Each pool has its own replica count, router, and
    batching knobs, so prefill bursts can no longer stall decode
    iterations (TPOT) and long prompts stop queueing behind decode
    (TTFT).
    """
    prefill_replicas: int = 1
    decode_replicas: int = 1
    prefill_router: str = "least-loaded"
    decode_router: str = "least-loaded"
    prefill_chunk_tokens: int = 512  # chunked-prefill granularity
                                     # (0 → whole-prompt prefill)
    prefill_max_batch: int = 4       # concurrent prefills per engine
    decode_max_batch: int = 0        # decode slots; 0 → the job policy's
                                     # max_batch
    kv_network: str = "infiniband"   # NetworkModel clocking the handoff
    kv_bytes_per_token: float = 0.0  # 0 → derive from the memory spec /
                                     # model config (0 if underivable:
                                     # the handoff costs one RTT)

    def __post_init__(self):
        if self.prefill_replicas < 1 or self.decode_replicas < 1:
            raise ValueError("DisaggSpec needs at least one replica in "
                             "each pool")
        if self.prefill_max_batch < 1:
            raise ValueError("DisaggSpec.prefill_max_batch must be >= 1")
        if self.prefill_chunk_tokens < 0:
            raise ValueError("DisaggSpec.prefill_chunk_tokens must be "
                             ">= 0 (0 = whole-prompt prefill)")
        if self.kv_network not in NETWORKS:
            raise ValueError(f"unknown kv_network {self.kv_network!r} "
                             f"(known: {sorted(NETWORKS)})")

    @property
    def total_replicas(self) -> int:
        return self.prefill_replicas + self.decode_replicas

    @classmethod
    def from_dict(cls, d) -> "DisaggSpec":
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Replica-tier configuration (plumbed through BenchmarkJobSpec)."""
    replicas: int = 1
    router: str = "round-robin"     # round-robin | least-loaded | affinity
    autoscale: bool = False
    min_replicas: int = 1
    max_replicas: int = 8
    scale_interval_s: float = 0.5   # reactive-controller evaluation period
    scale_up_load: float = 4.0      # mean in-flight/replica to add one
    scale_down_load: float = 0.5    # mean in-flight/replica to retire one
    spawn_delay_s: float = 0.5      # cold-start before a new replica serves
    memory: Optional[MemorySpec] = None   # per-replica KV-cache accounting
                                    # (None → memory unmodeled, legacy)
    disaggregation: Optional[DisaggSpec] = None   # split prefill/decode
                                    # pools (None → colocated, legacy)
    obs: Optional[ObsSpec] = None   # observability layer (time-series +
                                    # timeline); None → fast path, zero
                                    # recording overhead

    def __post_init__(self):
        if self.replicas < 1 or self.min_replicas < 1:
            raise ValueError("ClusterSpec needs replicas >= 1 and "
                             "min_replicas >= 1 (the cluster cannot scale "
                             "up from zero: backlog is only observed on "
                             "live replicas)")
        if self.max_replicas < self.min_replicas:
            raise ValueError("ClusterSpec.max_replicas must be >= "
                             "min_replicas")
        if isinstance(self.memory, dict):
            object.__setattr__(self, "memory",
                               MemorySpec.from_dict(self.memory))
        if isinstance(self.disaggregation, dict):
            object.__setattr__(self, "disaggregation",
                               DisaggSpec.from_dict(self.disaggregation))
        if isinstance(self.obs, dict):
            object.__setattr__(self, "obs", ObsSpec.from_dict(self.obs))
        if self.disaggregation is not None and self.autoscale:
            raise ValueError("disaggregated pools are fixed-size: "
                             "autoscale=True is not supported with "
                             "ClusterSpec.disaggregation")

    @classmethod
    def from_dict(cls, d) -> "ClusterSpec":
        return cls(**dict(d))


# ---- routers ---------------------------------------------------------------
class Router:
    """Picks a live replica index for each arriving request."""
    name = "base"

    def route(self, request: Request, engines: List[ReplicaEngine],
              now: float) -> int:
        raise NotImplementedError


class RoundRobinRouter(Router):
    """Rotation over stable ``replica_id``s, skip-based.

    Each arrival goes to the lowest live ``replica_id`` greater than the
    previously chosen one (wrapping to the lowest).  The old
    implementation applied a global counter mod the *filtered* ready
    list, so an autoscaler add/retire — or a replica merely finishing
    its cold start — shifted every subsequent assignment and skewed the
    distribution (the same churn bug the affinity router had).  Skipping
    over missing ids keeps the rotation anchored to replica identity:
    membership changes only affect the replicas that actually changed.
    """
    name = "round-robin"

    def __init__(self):
        self._last_id = -1

    def route(self, request, engines, now):
        nxt = wrap = None
        for i, e in enumerate(engines):
            rid = e.replica_id
            if rid > self._last_id and (
                    nxt is None or rid < engines[nxt].replica_id):
                nxt = i
            if wrap is None or rid < engines[wrap].replica_id:
                wrap = i
        idx = nxt if nxt is not None else wrap
        self._last_id = engines[idx].replica_id
        return idx


class LeastLoadedRouter(Router):
    """Join-the-shortest-queue over in-flight work (queued + running)."""
    name = "least-loaded"

    def route(self, request, engines, now):
        # explicit scan (first minimum wins, same tie-break as the old
        # min-with-key) — this runs once per arrival over every live
        # replica, so the continuous-engine load signal (queued +
        # running, exactly ``ReplicaEngine.load``) is inlined rather
        # than paying a method call per engine
        best = 0
        e = engines[0]
        best_load = len(e.queue) + len(e.active) if e.continuous \
            else e.load(now)
        for i in range(1, len(engines)):
            e = engines[i]
            load = len(e.queue) + len(e.active) if e.continuous \
                else e.load(now)
            if load < best_load:
                best, best_load = i, load
        return best


_MASK64 = (1 << 64) - 1


def _rendezvous_weight(session_id: int, replica_id: int) -> int:
    """Deterministic splitmix64-style mix of (session, replica) — the
    highest-random-weight (rendezvous) hash.  Seed-independent, so runs
    are reproducible across processes."""
    x = (session_id * 0x9E3779B97F4A7C15
         + replica_id * 0xBF58476D1CE4E5B9 + 0x94D049BB133111EB) & _MASK64
    x ^= x >> 30
    x = (x * 0xBF58476D1CE4E5B9) & _MASK64
    x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


class SessionAffinityRouter(Router):
    """Sticky sessions bound to stable ``replica_id``s.

    A session stays on its assigned replica for as long as that replica
    is live; only sessions whose replica was retired are remapped
    (rendezvous hashing over the currently-live set picks the new home).
    The old implementation hashed ``session_id % len(engines)`` over the
    *filtered* ready list, so every autoscaler add/retire — or a replica
    merely cold-starting — remapped every session, destroying stickiness
    and the prefix-cache hit rate.
    """
    name = "affinity"

    def __init__(self):
        self._home: Dict[int, int] = {}     # session_id → replica_id

    def route(self, request, engines, now):
        sid = request.session_id
        home = self._home.get(sid)
        if home is not None:
            for i, e in enumerate(engines):
                if e.replica_id == home:
                    return i
        idx = max(range(len(engines)),
                  key=lambda i: _rendezvous_weight(sid,
                                                   engines[i].replica_id))
        self._home[sid] = engines[idx].replica_id
        return idx


def make_router(name: str) -> Router:
    if name in ("round-robin", "rr"):
        return RoundRobinRouter()
    if name in ("least-loaded", "jsq", "least_loaded"):
        return LeastLoadedRouter()
    if name in ("affinity", "session", "session-affinity"):
        return SessionAffinityRouter()
    raise ValueError(f"unknown router {name!r}")


# ---- reactive autoscaler ---------------------------------------------------
class Autoscaler:
    """Threshold controller: scale up when mean *queued* (waiting, not
    yet served) requests per replica exceed ``scale_up_load`` — in-flight
    decode slots are healthy capacity use, not backlog — and retire an
    idle replica when mean in-flight work drops below
    ``scale_down_load``.  New replicas pay ``spawn_delay_s`` cold start."""

    def __init__(self, spec: ClusterSpec, policy: BatchPolicy,
                 latency: LatencyModel, make_engine=None):
        self.spec = spec
        self.policy = policy
        self.latency = latency
        # factory so spawned replicas get their own KV-cache manager
        self.make_engine = make_engine or (
            lambda i, spawn_s=0.0, created_s=0.0: ReplicaEngine(
                i, policy, latency, spawn_s=spawn_s, created_s=created_s))

    def step(self, engines: List[ReplicaEngine], now: float) -> None:
        live = [e for e in engines if not e.retired]
        n = len(live)
        queued = sum(len(e.queue) for e in live) / max(n, 1)
        inflight = sum(e.load(now) for e in live) / max(n, 1)
        if queued > self.spec.scale_up_load and n < self.spec.max_replicas:
            engines.append(self.make_engine(
                len(engines), now + self.spec.spawn_delay_s, now))
        elif inflight < self.spec.scale_down_load \
                and n > self.spec.min_replicas:
            for e in reversed(live):
                if e.idle(now):
                    e.retired = True
                    e.retired_s = now   # billing: the replica-second
                    # integral stops here, not at the end of the run
                    break


# ---- memory grounding ------------------------------------------------------
def _resolve_cluster_memory(cluster: ClusterSpec, policy: BatchPolicy,
                            latency, requests: List[Request]
                            ) -> Optional[ResolvedMemory]:
    """Ground the cluster's MemorySpec and validate that the per-replica
    block budget can hold the largest single request — below that there
    is no victim to preempt and the sequence could never run."""
    if cluster.memory is None:
        return None
    resolved = resolve_memory(cluster.memory, latency)
    continuous = isinstance(policy, ContinuousBatcher)
    worst = 0
    for r in requests:
        out = r.output_tokens
        if continuous:
            if r.prompt_tokens >= resolved.max_model_len:
                # previously clamped to a 1-token sentinel, silently
                # validating a sequence the engine would then decode
                # past the context limit
                raise KVBudgetError(
                    f"request {r.req_id}: prompt of {r.prompt_tokens} "
                    f"tokens leaves no room to decode within "
                    f"max_model_len={resolved.max_model_len}; raise "
                    "MemorySpec.max_model_len or shrink the workload's "
                    "prompts")
            out = max(1, min(out, resolved.max_model_len - r.prompt_tokens))
        worst = max(worst, r.prompt_tokens + out)
    bt = cluster.memory.block_tokens
    need = -(-worst // bt)
    if need > resolved.total_blocks:
        raise KVBudgetError(
            f"KV budget of {resolved.total_blocks} blocks "
            f"({resolved.budget_bytes / 1024**3:.2f} GiB at "
            f"{bt} tok/block) cannot hold one {worst}-token sequence "
            f"({need} blocks); raise hbm_gb/num_blocks or shrink the "
            "workload's prompt/output lengths")
    return resolved


# ---- cluster event loop ----------------------------------------------------
def simulate_cluster(workload: WorkloadSpec, policy: BatchPolicy,
                     latency: LatencyModel, *,
                     cluster: ClusterSpec = ClusterSpec(),
                     network: NetworkModel = NETWORKS["lan"],
                     trace_sample: float = 1.0) -> SimResult:
    """Drive a cluster of replicas over a workload; returns a SimResult
    whose utilization accounts for the peak replica count and whose
    energy/cost bill the integrated live replica-seconds.

    ``duration_s`` is ``max(workload window, last completion)`` — a sparse
    open-loop workload no longer reports inflated throughput, and overload
    (completions past the window) stretches the denominator instead of
    shrinking it.  Trace replay has no declared window, so its duration is
    the makespan.

    With ``cluster.disaggregation`` set, arrivals land on the prefill
    pool, completions there (= first token) trigger a KV handoff over the
    disaggregation's ``kv_network``, and the decode pool finishes the
    generation with the migrated KV already resident.

    ``trace_sample`` < 1 keeps full per-request trace recording (stage
    accounting, per-iteration batch sizes) for only that deterministic
    fraction of requests and drops the rest from ``SimResult.traces``.
    Counting aggregates — throughput, duration, utilization, cost, the
    memory/pool dicts and ``requests_served`` — remain exact over *all*
    requests; percentile metrics are computed over the sample.  Use it
    for aggregate-only sweeps at production scale.
    """
    disagg = cluster.disaggregation
    if disagg is not None and not isinstance(policy, ContinuousBatcher):
        raise ValueError(
            "disaggregated serving needs the continuous batcher "
            f"(got {policy.name!r}): request-level policies have no "
            "decode loop to migrate into")
    if not 0.0 < trace_sample <= 1.0:
        raise ValueError(f"trace_sample must be in (0, 1], got "
                         f"{trace_sample}")
    sampling = trace_sample < 1.0
    # deterministic per-request coin flip (splitmix64 of req_id): the
    # same requests are sampled across runs and processes
    sample_cut = int(trace_sample * float(_MASK64 + 1))
    requests = generate(workload)
    closed_loop = workload.kind == CLOSED
    traces: Dict[int, RequestTrace] = {}
    arrivals: List[Tuple[float, int, Request]] = []   # (server_arrival, id, r)

    def admit(r: Request) -> None:
        tr = RequestTrace(request=r, t_preprocess=PRE_PROCESS_S,
                          t_transmit=network.transmit(r.payload_bytes))
        if sampling:
            tr.detail = _rendezvous_weight(r.req_id, 0x7ACE) < sample_cut
        traces[r.req_id] = tr
        heapq.heappush(arrivals,
                       (r.arrival_s + tr.t_preprocess + tr.t_transmit,
                        r.req_id, r))

    for r in requests:
        admit(r)
    next_id = len(requests)

    resolved = _resolve_cluster_memory(cluster, policy, latency, requests)
    # decode is bounded by the model's context limit even when memory is
    # unmodeled — otherwise output_tokens_max=None workloads run their
    # 32k-token sentinel far past max_seq_len
    max_len = resolved.max_model_len if resolved is not None \
        else getattr(getattr(latency, "cfg", None), "max_seq_len", 0)
    if max_len:
        over = next((r for r in requests if r.prompt_tokens >= max_len),
                    None)
        if over is not None:
            # clamped_output_tokens would otherwise floor the budget at 1
            # and decode a token past the context limit
            raise ValueError(
                f"request {over.req_id}: prompt of {over.prompt_tokens} "
                f"tokens is at/over the model context limit "
                f"(max_model_len={max_len}) — no output token fits; "
                "shrink the workload's prompts or raise the context "
                "limit")

    def _kv():
        return KVCacheManager(cluster.memory, resolved) \
            if resolved is not None else None

    # observability (opt-in): counters/gauges + engine activity spans.
    # rec is None on the default path — every hook below is behind a
    # single None-check, keeping the fast path's event rate intact.
    rec: Optional[MetricsRecorder] = None
    if cluster.obs is not None and cluster.obs.enabled:
        window0 = 0.0 if workload.kind == TRACE else workload.duration_s
        rec = MetricsRecorder(cluster.obs,
                              cluster.obs.resolve_interval(window0))
    rec_ticks = rec if rec is not None and cluster.obs.timeseries else None
    # local mirror of rec_ticks.next_tick so the event loop pays one
    # float compare per pass, not an attribute walk (inf when sampling
    # is off)
    obs_next_tick = (rec_ticks.next_tick if rec_ticks is not None
                     else float("inf"))

    def make_engine(i: int, spawn_s: float = 0.0,
                    created_s: float = 0.0) -> ReplicaEngine:
        if rec is not None:
            rec.register_engine(i, "serve")
        return ReplicaEngine(i, policy, latency, spawn_s=spawn_s,
                             kv=_kv(), max_model_len=max_len,
                             created_s=created_s, obs=rec)

    migrations: List[Tuple[float, int, Request]] = []  # (kv_ready, id, r)
    prefill_engines: List[ReplicaEngine] = []
    decode_engines: List[ReplicaEngine] = []
    decode_router = kv_net = None
    kv_bpt = 0.0
    if disagg is not None:
        prefill_policy = ContinuousBatcher(
            max_batch=disagg.prefill_max_batch,
            max_prefill=disagg.prefill_max_batch)
        decode_policy = policy if disagg.decode_max_batch <= 0 else \
            dataclasses.replace(policy, max_batch=disagg.decode_max_batch)
        prefill_engines = [
            ReplicaEngine(i, prefill_policy, latency, kv=_kv(),
                          max_model_len=max_len, role="prefill",
                          chunk_tokens=disagg.prefill_chunk_tokens,
                          obs=rec)
            for i in range(disagg.prefill_replicas)]
        decode_engines = [
            ReplicaEngine(disagg.prefill_replicas + i, decode_policy,
                          latency, kv=_kv(), max_model_len=max_len,
                          role="decode", obs=rec)
            for i in range(disagg.decode_replicas)]
        if rec is not None:
            for e in prefill_engines:
                rec.register_engine(e.replica_id, "prefill")
            for e in decode_engines:
                rec.register_engine(e.replica_id, "decode")
        engines = prefill_engines + decode_engines
        router = make_router(disagg.prefill_router)
        decode_router = make_router(disagg.decode_router)
        kv_net = NETWORKS[disagg.kv_network]
        kv_bpt = disagg.kv_bytes_per_token
        if kv_bpt <= 0 and resolved is not None:
            kv_bpt = resolved.kv_bytes_per_token
        if kv_bpt <= 0:
            kv_bpt = oracle_kv_bytes_per_token(latency)
    else:
        engines = [make_engine(i) for i in range(max(cluster.replicas, 1))]
        router = make_router(cluster.router)
    scaler = Autoscaler(cluster, policy, latency, make_engine) \
        if cluster.autoscale else None
    next_scale = cluster.scale_interval_s
    peak = len(engines)

    # ---- indexed event scheduler -----------------------------------------
    # Per-engine next-event times live in a lazy-deletion heap instead of
    # being rescanned across all replicas on every pass: entries are
    # (t, engine_idx, version) and an entry is live iff its version
    # matches the engine's current one (``evers``) — every reschedule
    # bumps the version, staling out old entries in O(1).  Only engines
    # whose entry is due at ``now`` act; an engine's next-event time can
    # only change when its own state changes (an enqueue or its own act),
    # so everything else is provably a no-op and is skipped.  Engine list
    # position == replica_id (the autoscaler appends with len(engines)),
    # which lets routed targets be rescheduled by id.
    eheap: List[Tuple[float, int, int]] = []
    evers: List[int] = [0] * len(engines)

    def schedule(i: int, t_now: float) -> None:
        evers[i] += 1
        t = engines[i].next_action_s(t_now)
        if t is not None:
            heapq.heappush(eheap, (t, i, evers[i]))

    route_pool = prefill_engines if disagg is not None else engines

    def live_engines() -> List[ReplicaEngine]:
        return [e for e in route_pool if not e.retired]

    for i in range(len(engines)):
        schedule(i, 0.0)
    # the live routing set only changes on autoscaler steps — maintain it
    # across passes instead of refiltering per arrival
    live = live_engines()
    events = 0
    now = 0.0
    inf = float("inf")
    while True:
        while eheap and eheap[0][2] != evers[eheap[0][1]]:
            heapq.heappop(eheap)            # stale (rescheduled) entries
        t_next = arrivals[0][0] if arrivals else inf
        if migrations and migrations[0][0] < t_next:
            t_next = migrations[0][0]
        if eheap and eheap[0][0] < t_next:
            t_next = eheap[0][0]
        if t_next == inf:
            break
        if scaler is not None and next_scale < t_next:
            t_next = next_scale     # only re-evaluate while work remains
        if obs_next_tick < t_next - EPS:
            # state is constant between events: every tick in the open
            # interval (now, t_next) samples it exactly
            rec_ticks.sample_ticks(t_next, engines)
            obs_next_tick = rec_ticks.next_tick
        if t_next > now:
            now = t_next

        if arrivals and arrivals[0][0] <= now + EPS:
            # prefer replicas already past cold start; a still-spawning
            # replica only takes traffic if no warm replica exists
            # (retired/spawn states are fixed within a pass, so the ready
            # set is computed once per drain)
            ready = [e for e in live if e.spawn_s <= now + EPS] or live
            touched = set()
            while arrivals and arrivals[0][0] <= now + EPS:
                t_arr, _, r = heapq.heappop(arrivals)
                events += 1
                if rec is not None:
                    rec.count_arrival(r.tenant)
                e = ready[router.route(r, ready, now)]
                e.enqueue(QueuedRequest(request=r, enqueue_s=t_arr))
                touched.add(e.replica_id)
            for i in touched:
                schedule(i, now)

        # KV handoffs whose transfer finished join the decode pool with
        # their cache already resident (first token was already emitted)
        while migrations and migrations[0][0] <= now + EPS:
            t_ready, _, r = heapq.heappop(migrations)
            events += 1
            out = clamped_output_tokens(r, max_len)
            e = decode_engines[decode_router.route(r, decode_engines, now)]
            e.enqueue(QueuedRequest(request=r, enqueue_s=t_ready,
                                    remaining=out - 1, migrated=True))
            schedule(e.replica_id, now)

        if scaler is not None and now + EPS >= next_scale:
            n_before = len(engines)
            scaler.step(engines, now)
            peak = max(peak, sum(1 for e in engines if not e.retired))
            while next_scale <= now + EPS:
                next_scale += cluster.scale_interval_s
            for i in range(n_before, len(engines)):
                evers.append(0)
                schedule(i, now)    # spawned replica enters the heap
            live = live_engines()   # membership changed (add/retire)

        due = []
        while eheap and eheap[0][0] <= now + EPS:
            t, i, ver = heapq.heappop(eheap)
            if ver == evers[i]:
                due.append(i)
        due.sort()                  # act in replica order (determinism)
        for i in due:
            e = engines[i]
            events += 1
            for done_s, r in e.act(now, traces):
                if e.role == "prefill" \
                        and clamped_output_tokens(r, max_len) > 1:
                    # first token out — clock the KV handoff and hand the
                    # request to the decode pool (single-token requests
                    # are complete after prefill and never migrate)
                    tr = traces[r.req_id]
                    transfer = kv_net.transmit(kv_bpt * r.prompt_tokens)
                    tr.t_kv_transfer = transfer
                    tr.done_s = 0.0     # decode owns final completion
                    heapq.heappush(migrations,
                                   (done_s + transfer, r.req_id, r))
                    continue
                if rec is not None:
                    rec.count_completion(r.tenant)
                if closed_loop and done_s < workload.duration_s:
                    # the client observes the response and issues its next
                    # request, keeping its loop at concurrency 1
                    admit(dataclasses.replace(r, req_id=next_id,
                                              arrival_s=done_s))
                    next_id += 1
            schedule(i, now)

    done = [t for t in traces.values() if t.done_s > 0]
    served = len(done)
    last_done = max((t.done_s for t in done), default=0.0)
    if sampling:
        done = [t for t in done if t.detail]
    window = 0.0 if workload.kind == TRACE else workload.duration_s
    duration = max(window, last_done)
    # live replica-seconds (spawn→retire spans): what energy/cost bill —
    # an autoscaled cluster no longer pays its peak count for the full run
    replica_seconds = sum(
        max((e.retired_s if e.retired_s is not None else duration)
            - e.created_s, 0.0)
        for e in engines)
    pools = None
    if disagg is not None:
        transfers = [t.t_kv_transfer for t in done if t.t_kv_transfer > 0]
        pools = {
            "prefill_replicas": disagg.prefill_replicas,
            "decode_replicas": disagg.decode_replicas,
            "prefill_busy_s": sum(e.busy_s for e in prefill_engines),
            "decode_busy_s": sum(e.busy_s for e in decode_engines),
            "kv_network": disagg.kv_network,
            "kv_bytes_per_token": kv_bpt,
            "migrated_requests": len(transfers),
            "mean_kv_transfer_s": (sum(transfers) / len(transfers)
                                   if transfers else 0.0),
        }
    memory = None
    if resolved is not None:
        per = [e.kv.stats(duration) for e in engines]
        hits = sum(p["prefix_hit_tokens"] for p in per)
        served_tokens = sum(e.kv.hit_tokens + e.kv.miss_tokens
                            for e in engines)
        memory = {
            "block_tokens": cluster.memory.block_tokens,
            "total_blocks_per_replica": resolved.total_blocks,
            "budget_bytes_per_replica": resolved.budget_bytes,
            "kv_bytes_per_token": resolved.kv_bytes_per_token,
            "max_model_len": resolved.max_model_len,
            "peak_blocks": max(p["peak_blocks"] for p in per),
            "peak_occupancy": max(p["peak_occupancy"] for p in per),
            "mean_occupancy": (sum(p["mean_occupancy"] for p in per)
                               / len(per)),
            "prefix_hit_tokens": hits,
            "prefix_hit_rate": hits / served_tokens if served_tokens
            else 0.0,
            "preemptions": sum(p["preemptions"] for p in per),
            "evictions": sum(p["evictions"] for p in per),
            "per_replica": per,
        }
    timeseries = engine_spans = None
    if rec is not None:
        if rec_ticks is not None:
            rec.finish(duration, engines)
            timeseries = rec.build()
        if cluster.obs.timeline:
            engine_spans = rec.spans
    return SimResult(
        traces=done,
        busy_s=sum(e.busy_s for e in engines),
        duration_s=duration,
        hw=latency.hw,
        chips=latency.chips,
        replicas=peak,
        router="disaggregated" if disagg is not None else cluster.router,
        per_replica_busy_s=[e.busy_s for e in engines],
        memory=memory,
        replica_seconds=replica_seconds,
        pools=pools,
        requests_served=served,
        events=events,
        timeseries=timeseries,
        engine_spans=engine_spans)
