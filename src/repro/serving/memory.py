"""Paged KV-cache accounting, prefix caching, and preemption bookkeeping.

Real LLM serving is capacity-capped by KV-cache memory, not compute: a
replica holds only as many concurrent sequences as its HBM holds KV
blocks (the PagedAttention argument).  This module gives the cluster
simulator that constraint:

  ``MemorySpec``     — serving-memory configuration (block size, HBM
                       budget, prefix cache on/off, preemption victim
                       policy), plumbed through ``ClusterSpec``.
  ``KVCacheManager`` — per-replica block-granular allocator with a
                       ref-counted per-session prefix cache (LRU eviction
                       of unreferenced prefix blocks) plus occupancy /
                       hit-rate / preemption accounting.
  ``resolve_memory`` — derive the block budget from the hardware catalog
                       (``repro.hw``) and the model KV footprint
                       (``repro.analysis.memory_model``) for any latency
                       oracle.

The continuous engine consumes the manager at every iteration boundary:
block allocation on join (prefix-cache hits shrink the prefill), one
block extension per decoded token crossing a block boundary, and
recompute-style preemption (victim freed and requeued; its re-prefill is
clocked by the latency model) when extension fails.  Request-level
engines bound each batch's transient working set against the same
budget.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

GiB = 1024 ** 3
DEFAULT_MAX_MODEL_LEN = 8192

VICTIM_POLICIES = ("youngest", "largest")


class KVBudgetError(ValueError):
    """A grounded KV budget cannot serve the given workload (e.g. it
    cannot hold even one sequence).  Distinct from plain ValueError so
    callers sweeping configurations (the planner) can reject the
    candidate without masking genuine configuration mistakes."""


@dataclasses.dataclass(frozen=True)
class MemorySpec:
    """Serving-memory configuration (``ClusterSpec.memory``).

    ``hbm_gb``/``kv_bytes_per_token``/``max_model_len`` default to 0 =
    "derive from the latency oracle": HBM capacity × chips from the
    hardware catalog minus resident weights, the per-token KV footprint
    from the model config, and the model's ``max_seq_len``.  Fitted
    profiles carry no model config, so profile-driven jobs must set
    ``hbm_gb`` and ``kv_bytes_per_token`` explicitly.  ``num_blocks``
    bypasses byte math entirely (tests / what-if analyses).
    """
    block_tokens: int = 16          # KV tokens per page
    hbm_gb: float = 0.0             # KV budget per replica; 0 → derive
    kv_bytes_per_token: float = 0.0  # 0 → derive from the model config
    util_fraction: float = 0.9      # usable fraction of HBM (frag. slack)
    prefix_caching: bool = True
    preemption: str = "youngest"    # victim selection: youngest | largest
    max_model_len: int = 0          # context cap; 0 → model max_seq_len
    num_blocks: int = 0             # explicit block count (overrides bytes)

    def __post_init__(self):
        if self.block_tokens < 1:
            raise ValueError("MemorySpec.block_tokens must be >= 1")
        if self.preemption not in VICTIM_POLICIES:
            raise ValueError(f"unknown preemption policy "
                             f"{self.preemption!r} "
                             f"(expected one of {VICTIM_POLICIES})")
        if not 0.0 < self.util_fraction <= 1.0:
            raise ValueError("MemorySpec.util_fraction must be in (0, 1]")

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "MemorySpec":
        return cls(**dict(d))


def scaled_memory_spec(spec: Optional[MemorySpec],
                       mode) -> Optional[MemorySpec]:
    """A :class:`MemorySpec` adjusted for serving under a
    :class:`~repro.serving.latency_model.SpeedMode`.

    Only an *explicitly set* ``kv_bytes_per_token`` needs rescaling
    (quantized KV entries are smaller, so the same HBM budget holds
    more tokens); oracle-derived footprints flow through the oracle's
    own speed-mode-scaled ``kv_bytes_per_token``/``weight_bytes`` hooks.
    An explicit ``num_blocks`` is a byte-free what-if knob and is left
    untouched.
    """
    if spec is None or mode is None:
        return spec
    scale = getattr(mode, "kv_bytes_scale", 1.0)
    if scale == 1.0 or spec.kv_bytes_per_token <= 0:
        return spec
    return dataclasses.replace(
        spec, kv_bytes_per_token=spec.kv_bytes_per_token * scale)


def oracle_kv_bytes_per_token(oracle) -> float:
    """Per-token KV footprint of a latency oracle, or 0.0 when the oracle
    carries no model config (fitted calibration profiles).  Shared by the
    KV budget resolution here and the disaggregated prefill→decode
    transfer sizing in ``repro.serving.cluster``."""
    fn = getattr(oracle, "kv_bytes_per_token", None)
    if fn is None:
        return 0.0
    return float(fn())


@dataclasses.dataclass(frozen=True)
class ResolvedMemory:
    """A MemorySpec grounded against one oracle: concrete block budget."""
    total_blocks: int
    kv_bytes_per_token: float
    max_model_len: int
    budget_bytes: float


def resolve_memory(spec: MemorySpec, oracle) -> ResolvedMemory:
    """Ground a MemorySpec against a latency oracle's model + hardware."""
    cfg = getattr(oracle, "cfg", None)
    kv_b = spec.kv_bytes_per_token
    if kv_b <= 0:
        kv_b = oracle_kv_bytes_per_token(oracle)
        if kv_b <= 0:
            if spec.num_blocks > 0:
                kv_b = 0.0  # block count given directly; bytes cosmetic
            else:
                raise ValueError(
                    "MemorySpec.kv_bytes_per_token must be set explicitly "
                    "for latency oracles without a model config (e.g. "
                    "fitted calibration profiles)")
    max_len = spec.max_model_len or getattr(cfg, "max_seq_len", 0) \
        or DEFAULT_MAX_MODEL_LEN
    if spec.num_blocks > 0:
        total = spec.num_blocks
        budget = float(total * spec.block_tokens * kv_b)
    else:
        if spec.hbm_gb > 0:
            budget = spec.hbm_gb * GiB
        else:
            weight_fn = getattr(oracle, "weight_bytes", None)
            if weight_fn is None:
                raise ValueError(
                    "MemorySpec.hbm_gb must be set explicitly for latency "
                    "oracles without a parameter count (e.g. fitted "
                    "calibration profiles)")
            from repro.analysis.memory_model import serving_hbm_headroom
            budget = serving_hbm_headroom(oracle.hw, oracle.chips,
                                          weight_fn(), spec.util_fraction)
        total = int(budget // (spec.block_tokens * kv_b))
    if total < 1:
        raise ValueError(
            f"KV budget of {budget / GiB:.2f} GiB holds zero "
            f"{spec.block_tokens}-token blocks at "
            f"{kv_b:.0f} B/token — the model's weights alone exhaust HBM")
    return ResolvedMemory(total_blocks=total, kv_bytes_per_token=kv_b,
                          max_model_len=max_len, budget_bytes=budget)


def validate_budget_for_requests(spec: MemorySpec, resolved: ResolvedMemory,
                                 requests, continuous: bool) -> None:
    """Reject a grounded budget that cannot hold the workload's largest
    single request — below that there is no victim to preempt and the
    sequence could never run.  Shared by the flat cluster path and every
    pool of a heterogeneous fleet (any request may route to any pool, so
    each pool's budget must clear the same bar)."""
    worst = 0
    for r in requests:
        out = r.output_tokens
        if continuous:
            if r.prompt_tokens >= resolved.max_model_len:
                # previously clamped to a 1-token sentinel, silently
                # validating a sequence the engine would then decode
                # past the context limit
                raise KVBudgetError(
                    f"request {r.req_id}: prompt of {r.prompt_tokens} "
                    f"tokens leaves no room to decode within "
                    f"max_model_len={resolved.max_model_len}; raise "
                    "MemorySpec.max_model_len or shrink the workload's "
                    "prompts")
            out = max(1, min(out, resolved.max_model_len - r.prompt_tokens))
        worst = max(worst, r.prompt_tokens + out)
    bt = spec.block_tokens
    need = -(-worst // bt)
    if need > resolved.total_blocks:
        raise KVBudgetError(
            f"KV budget of {resolved.total_blocks} blocks "
            f"({resolved.budget_bytes / 1024**3:.2f} GiB at "
            f"{bt} tok/block) cannot hold one {worst}-token sequence "
            f"({need} blocks); raise hbm_gb/num_blocks or shrink the "
            "workload's prompt/output lengths")


@dataclasses.dataclass
class _Alloc:
    """Blocks one live request references."""
    private_blocks: int
    shared_blocks: int              # blocks referenced inside a prefix entry
    session: Optional[int]
    tokens: int


@dataclasses.dataclass
class _PrefixEntry:
    """Cached prefix blocks of one session (radix-path equivalent: with
    whole-session sharing the trie degenerates to one path per session)."""
    blocks: int
    refs: int
    last_used: float


class KVCacheManager:
    """Block-granular KV allocator for one replica.

    All bookkeeping is in block *counts* (the simulator never materializes
    tensors); the invariant maintained is ``resident_blocks <=
    total_blocks`` at all times, where resident = privately allocated +
    prefix-cached blocks.
    """

    def __init__(self, spec: MemorySpec, resolved: ResolvedMemory):
        self.spec = spec
        self.block_tokens = spec.block_tokens
        self.total_blocks = resolved.total_blocks
        self.kv_bytes_per_token = resolved.kv_bytes_per_token
        self.max_model_len = resolved.max_model_len
        self.budget_bytes = resolved.budget_bytes
        self.free_blocks = resolved.total_blocks
        # bumped on every free(): engines compare versions to detect
        # "KV blocks were released since my admission got blocked", which
        # re-attributes the wait from batching policy to memory pressure
        self.version = 0
        self._allocs: Dict[int, _Alloc] = {}
        self._cache: Dict[int, _PrefixEntry] = {}
        # ---- accounting ----
        self.peak_blocks = 0
        self.hit_tokens = 0
        self.miss_tokens = 0
        self.preemptions = 0
        self.evictions = 0
        self._occ_integral = 0.0        # ∫ resident_blocks dt
        self._last_t = 0.0

    # ---- gauges -----------------------------------------------------------
    @property
    def resident_blocks(self) -> int:
        """Blocks occupying HBM right now (allocated + prefix-cached)."""
        return self.total_blocks - self.free_blocks

    def referenced_blocks(self) -> int:
        """Blocks referenced by live requests (excludes idle cache)."""
        private = sum(a.private_blocks for a in self._allocs.values())
        shared = sum(e.blocks for e in self._cache.values() if e.refs > 0)
        return private + shared

    def blocks_for(self, tokens: int) -> int:
        return -(-max(int(tokens), 0) // self.block_tokens)

    # ---- time accounting --------------------------------------------------
    def touch(self, now: float) -> None:
        """Advance the occupancy integral to ``now``."""
        if now > self._last_t:
            self._occ_integral += self.resident_blocks * (now - self._last_t)
            self._last_t = now

    def _bump_peak(self) -> None:
        self.peak_blocks = max(self.peak_blocks, self.resident_blocks)

    # ---- allocation -------------------------------------------------------
    def _reclaim(self, need: int) -> bool:
        """Evict idle (refs == 0) prefix entries, LRU-first, until ``need``
        free blocks exist.  Returns whether the reclaim succeeded."""
        if need <= self.free_blocks:
            return True
        idle = sorted(((e.last_used, sid) for sid, e in self._cache.items()
                       if e.refs == 0))
        for _, sid in idle:
            entry = self._cache.pop(sid)
            self.free_blocks += entry.blocks
            self.evictions += 1
            if need <= self.free_blocks:
                return True
        return need <= self.free_blocks

    def allocate(self, req_id: int, context_tokens: int, now: float, *,
                 session_id: Optional[int] = None,
                 prefix_tokens: int = 0) -> Optional[int]:
        """Allocate blocks covering ``context_tokens`` for a joining
        request.  Returns the number of prefix-cache-hit tokens (0 when
        cold), or None when the budget cannot hold the request — the
        caller leaves it queued.
        """
        if req_id in self._allocs:
            raise ValueError(f"request {req_id} already holds KV blocks")
        self.touch(now)
        total_needed = self.blocks_for(context_tokens)
        shared_target = hit_blocks = 0
        entry = None
        if self.spec.prefix_caching and session_id is not None \
                and prefix_tokens > 0:
            # only whole blocks are shareable (page-aligned prefix)
            shared_target = min(prefix_tokens, context_tokens) \
                // self.block_tokens
            entry = self._cache.get(session_id)
            if entry is not None:
                hit_blocks = min(entry.blocks, shared_target)
        need = (shared_target - hit_blocks) \
            + (total_needed - shared_target)
        # pin the session's own entry: the LRU reclaim must not evict the
        # blocks this allocation is about to hit (refs is 0 until commit)
        if entry is not None:
            entry.refs += 1
        ok = self._reclaim(need)
        if entry is not None:
            entry.refs -= 1
        if not ok:
            if entry is not None and entry.refs == 0:
                # the pin itself may be what starves us: sacrifice the
                # session's idle prefix and retry cold — with an empty
                # replica this always succeeds (budget holds any single
                # request by construction), so the engine cannot stall on
                # a head-of-line request whose own cache blocks the way
                self._cache.pop(session_id)
                self.free_blocks += entry.blocks
                self.evictions += 1
                return self.allocate(req_id, context_tokens, now,
                                     session_id=session_id,
                                     prefix_tokens=prefix_tokens)
            return None
        self.free_blocks -= need
        if shared_target > 0:
            if entry is None:
                entry = _PrefixEntry(blocks=0, refs=0, last_used=now)
                self._cache[session_id] = entry
            entry.blocks = max(entry.blocks, shared_target)
            entry.refs += 1
            entry.last_used = now
        cached_tokens = hit_blocks * self.block_tokens
        self.hit_tokens += cached_tokens
        self.miss_tokens += max(context_tokens - cached_tokens, 0)
        self._allocs[req_id] = _Alloc(
            private_blocks=total_needed - shared_target,
            shared_blocks=shared_target,
            session=session_id if shared_target > 0 else None,
            tokens=context_tokens)
        self._bump_peak()
        return cached_tokens

    def extend(self, req_id: int, context_tokens: int, now: float) -> bool:
        """Grow a live request's KV to ``context_tokens``.  Returns False
        when no block can be allocated (caller preempts a victim)."""
        a = self._allocs[req_id]
        need = self.blocks_for(context_tokens) \
            - (a.private_blocks + a.shared_blocks)
        if need <= 0:
            a.tokens = context_tokens
            return True
        self.touch(now)
        if not self._reclaim(need):
            return False
        self.free_blocks -= need
        a.private_blocks += need
        a.tokens = context_tokens
        self._bump_peak()
        return True

    def free(self, req_id: int, now: float, *,
             preempted: bool = False) -> None:
        """Release a request's private blocks; its prefix blocks stay
        cached (refs-decremented) for future session hits."""
        self.touch(now)
        self.version += 1
        a = self._allocs.pop(req_id)
        self.free_blocks += a.private_blocks
        if a.session is not None:
            entry = self._cache[a.session]
            entry.refs -= 1
            entry.last_used = now
        if preempted:
            self.preemptions += 1

    # ---- request-level (whole-batch) engines ------------------------------
    def charge_span(self, blocks: int, start: float, end: float) -> None:
        """Account a transient whole-batch working set held over
        [start, end] (request-level policies allocate and free at batch
        granularity, so no per-token paging is simulated)."""
        self._occ_integral += blocks * max(end - start, 0.0)
        self.peak_blocks = max(self.peak_blocks,
                               self.resident_blocks + blocks)

    # ---- reporting --------------------------------------------------------
    def stats(self, duration_s: float) -> Dict[str, Any]:
        self.touch(duration_s)
        denom = self.total_blocks * duration_s
        served = self.hit_tokens + self.miss_tokens
        return {
            "total_blocks": self.total_blocks,
            "block_tokens": self.block_tokens,
            "budget_bytes": self.budget_bytes,
            "peak_blocks": self.peak_blocks,
            "peak_occupancy": self.peak_blocks / self.total_blocks,
            "mean_occupancy": self._occ_integral / denom if denom else 0.0,
            "prefix_hit_tokens": self.hit_tokens,
            "prefix_hit_rate": self.hit_tokens / served if served else 0.0,
            "preemptions": self.preemptions,
            "evictions": self.evictions,
            "resident_blocks_end": self.resident_blocks,
            "referenced_blocks_end": self.referenced_blocks(),
        }
