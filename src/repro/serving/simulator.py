"""Discrete-event simulator of the full inference pipeline (paper Fig. 4):

  client → pre-process → transmission → route → queue/batch → inference → post.

The unit of execution is a ``ReplicaEngine`` — one server timeline that
interprets either a request-level batching policy (NoBatching / Window /
Preferred: whole batches occupy the server) or a ``ContinuousBatcher``
(Orca/vLLM-style: decode slots free per iteration, waiting requests join
the running batch at iteration boundaries, clocked by the LatencyModel's
prefill/decode split).  ``simulate`` runs one replica; a ``Cluster`` of
replicas behind a router/autoscaler lives in ``repro.serving.cluster``
and drives the same engines from a shared indexed event loop.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import hw as hw_lib
from repro.serving.batching import (BatchPolicy, ContinuousBatcher,
                                    QueuedRequest)
from repro.serving.latency_model import (LatencyModel, NetworkModel,
                                         NETWORKS)
from repro.serving.memory import KVCacheManager
from repro.serving.workload import Request, WorkloadSpec

PRE_PROCESS_S = 0.0015     # resize + tensorize, per request
POST_PROCESS_S = 0.0004    # label lookup / detokenize, per request
EPS = 1e-12


@dataclasses.dataclass(slots=True)
class RequestTrace:
    request: Request
    t_preprocess: float = 0.0
    t_transmit: float = 0.0
    t_queue: float = 0.0       # enqueue → service start (total wait)
    t_batch_wait: float = 0.0  # the policy-attributable slice of t_queue:
                               # time waited while capacity (slots *and*
                               # KV memory) was free but the batch had not
                               # fired / the iteration boundary had not
                               # been reached.  Waits caused by a full KV
                               # cache are memory pressure, not policy,
                               # and are excluded
    t_inference: float = 0.0
    t_postprocess: float = 0.0
    t_kv_transfer: float = 0.0      # disaggregated serving: prefill→decode
                                    # KV-cache handoff over the interconnect
    batch_size: int = 1
    replica: int = 0
    done_s: float = 0.0
    first_token_s: float = 0.0      # absolute sim time of the first token
                                    # (end of prefill); 0 = none emitted
    tokens_out: int = 0             # tokens actually generated (post-clamp)
    preemptions: int = 0            # KV-pressure evict/recompute cycles
    spot_evictions: int = 0         # times a spot reclamation killed this
                                    # request's replica mid-decode (subset
                                    # of preemptions; recompute on rejoin)
    cached_prompt_tokens: int = 0   # prompt tokens served from prefix cache
    detail: bool = True             # False → unsampled (trace_sample < 1):
                                    # engines skip per-iteration stage
                                    # bookkeeping and the trace is dropped
                                    # from the result's per-request view

    @property
    def e2e(self) -> float:
        # t_batch_wait is a sub-component of t_queue, not an extra stage
        return (self.t_preprocess + self.t_transmit + self.t_queue
                + self.t_kv_transfer + self.t_inference + self.t_postprocess)

    # ---- phase latencies (the TTFT/TPOT language of LLM SLOs) ------------
    @property
    def t_first_token(self) -> float:
        """TTFT: request arrival → first generated token (0 if none)."""
        if self.first_token_s <= 0.0:
            return 0.0
        return self.first_token_s - self.request.arrival_s

    @property
    def tpot(self) -> float:
        """Mean time per output token after the first (0 when ≤ 1 token).
        Preemption stalls and KV-transfer gaps between tokens count — the
        client experiences them as inter-token latency."""
        if self.tokens_out <= 1 or self.first_token_s <= 0.0:
            return 0.0
        last = self.done_s - self.t_postprocess
        return max(last - self.first_token_s, 0.0) / (self.tokens_out - 1)


@dataclasses.dataclass
class SimResult:
    traces: List[RequestTrace]
    busy_s: float
    duration_s: float
    hw: hw_lib.HardwareModel
    chips: int
    replicas: int = 1                   # peak live replica count
    router: str = "single"
    per_replica_busy_s: Optional[List[float]] = None
    memory: Optional[Dict[str, object]] = None   # KV-cache accounting
                                        # (None when memory is unmodeled)
    replica_seconds: float = 0.0        # ∫ live replicas dt over the run
                                        # (0 → bill replicas × duration)
    pools: Optional[Dict[str, object]] = None    # disaggregated prefill/
                                        # decode pool provenance (None when
                                        # colocated)
    fleet: Optional[Dict[str, object]] = None    # heterogeneous-pool
                                        # provenance (ClusterSpec.pools):
                                        # per-pool hardware/pricing/region
                                        # splits, spot preemptions, cross-
                                        # region routing (None for flat
                                        # identical-replica clusters)
    requests_served: int = 0            # completions including unsampled
                                        # traces (0 → len(traces): full
                                        # recording, the default)
    events: int = 0                     # event-loop work units processed
                                        # (engine acts + arrival/migration
                                        # pops) — bench_simulator.py's
                                        # sim-events/sec numerator
    timeseries: Optional[object] = None  # repro.obs Timeseries (only when
                                        # the run carried an ObsSpec with
                                        # timeseries=True)
    engine_spans: Optional[List] = None  # repro.obs EngineSpan activity
                                        # (ObsSpec.timeline runs; feeds
                                        # the Chrome-trace export)
    # percentile/mean metrics re-materialized these arrays on every call
    # (summary() alone did so ~10×); memoize per result.  init=False so
    # dataclasses.replace()-based slicing (tenant_result) starts cold.
    _cache: Dict[str, np.ndarray] = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False)

    # ---- aggregate metrics (the paper's metric collector) ----------------
    def _served(self) -> int:
        return self.requests_served or len(self.traces)

    def _sample_scale(self) -> float:
        """Served-to-recorded ratio: scales counts derived from the
        sampled traces back to the full population (1.0 when every
        trace was recorded)."""
        if self.requests_served and self.traces \
                and self.requests_served != len(self.traces):
            return self.requests_served / len(self.traces)
        return 1.0

    def latencies(self) -> np.ndarray:
        a = self._cache.get("latencies")
        if a is None:
            a = np.array([t.e2e for t in self.traces])
            self._cache["latencies"] = a
        return a

    def percentile(self, p: float) -> float:
        lat = self.latencies()
        return float(np.percentile(lat, p)) if len(lat) else 0.0

    def throughput(self) -> float:
        return self._served() / self.duration_s if self.duration_s else 0.0

    def utilization(self) -> float:
        denom = self.duration_s * max(self.replicas, 1)
        return self.busy_s / denom if denom else 0.0

    def slo_attainment(self, slo_latency_s: float) -> float:
        """Fraction of served requests whose e2e latency met the SLO."""
        from repro.core.analysis import slo_attainment
        return slo_attainment(self.latencies(), slo_latency_s)

    # ---- phase metrics (TTFT / TPOT / goodput) ---------------------------
    def ttfts(self) -> np.ndarray:
        """Time-to-first-token of every request that emitted one."""
        a = self._cache.get("ttfts")
        if a is None:
            a = np.array([t.t_first_token for t in self.traces
                          if t.first_token_s > 0.0])
            self._cache["ttfts"] = a
        return a

    def tpots(self) -> np.ndarray:
        """Per-token decode time of every request with ≥ 2 tokens
        (single-token requests have no defined inter-token latency)."""
        a = self._cache.get("tpots")
        if a is None:
            a = np.array([t.tpot for t in self.traces if t.tokens_out > 1])
            self._cache["tpots"] = a
        return a

    def ttft(self, p: float = 50.0) -> float:
        """TTFT percentile (median by default)."""
        v = self.ttfts()
        return float(np.percentile(v, p)) if len(v) else 0.0

    def tpot(self, p: float = 50.0) -> float:
        """TPOT percentile (median by default)."""
        v = self.tpots()
        return float(np.percentile(v, p)) if len(v) else 0.0

    def _meets_phase_slos(self, t: RequestTrace,
                          ttft_slo_s: Optional[float],
                          tpot_slo_s: Optional[float],
                          e2e_slo_s: Optional[float]) -> bool:
        if ttft_slo_s is not None and t.t_first_token > ttft_slo_s:
            return False
        # single-token requests trivially meet any TPOT SLO (no decode)
        if tpot_slo_s is not None and t.tokens_out > 1 \
                and t.tpot > tpot_slo_s:
            return False
        if e2e_slo_s is not None and t.e2e > e2e_slo_s:
            return False
        return True

    def goodput(self, ttft_slo_s: Optional[float] = None,
                tpot_slo_s: Optional[float] = None,
                e2e_slo_s: Optional[float] = None) -> float:
        """Requests/s meeting *every* provided SLO (TTFT and TPOT and,
        optionally, e2e) — the rate real LLM deployments are judged by.
        Under trace sampling the recorded traces' attainment rate is
        extrapolated to the full served count."""
        if not self.duration_s:
            return 0.0
        n = sum(self._meets_phase_slos(t, ttft_slo_s, tpot_slo_s, e2e_slo_s)
                for t in self.traces)
        return n * self._sample_scale() / self.duration_s

    def phase_slo_attainment(self, ttft_slo_s: Optional[float] = None,
                             tpot_slo_s: Optional[float] = None,
                             e2e_slo_s: Optional[float] = None) -> float:
        """Fraction of served requests meeting every provided SLO."""
        if not self.traces:
            return 0.0
        n = sum(self._meets_phase_slos(t, ttft_slo_s, tpot_slo_s, e2e_slo_s)
                for t in self.traces)
        return n / len(self.traces)

    def preemption_goodput_loss(self, ttft_slo_s: Optional[float] = None,
                                tpot_slo_s: Optional[float] = None,
                                e2e_slo_s: Optional[float] = None) -> float:
        """Goodput (req/s) lost to spot preemption under the given SLOs.

        Counterfactuals are unobservable, so the loss is estimated as the
        *excess* SLO-miss rate among preemption-affected requests (those
        whose replica was spot-killed mid-decode at least once) over the
        unaffected baseline, scaled by the affected arrival rate.  0.0
        when no request was spot-killed — including every reserved-only
        or flat cluster.
        """
        if not self.duration_s or not self.traces:
            return 0.0
        affected = [t for t in self.traces if t.spot_evictions > 0]
        if not affected:
            return 0.0
        clean = [t for t in self.traces if t.spot_evictions == 0]

        def miss_rate(ts):
            if not ts:
                return 0.0
            n = sum(not self._meets_phase_slos(t, ttft_slo_s, tpot_slo_s,
                                               e2e_slo_s) for t in ts)
            return n / len(ts)

        excess = max(miss_rate(affected) - miss_rate(clean), 0.0)
        return excess * len(affected) * self._sample_scale() \
            / self.duration_s

    def cdf(self, points: int = 50):
        lat = np.sort(self.latencies())
        if not len(lat):
            return [], []
        qs = np.linspace(0, 1, points)
        return list(np.quantile(lat, qs)), list(qs)

    # ---- per-tenant slicing (multi-tenant workloads) ---------------------
    def tenants(self) -> List[str]:
        """Tenant names present in the served traces (multi-tenant
        workloads tag every request; [] for single-tenant runs)."""
        return sorted({t.request.tenant for t in self.traces
                       if t.request.tenant})

    def tenant_result(self, name: str) -> "SimResult":
        """This result restricted to one tenant's requests.

        Latency/TTFT/TPOT/goodput metrics of the slice are exact;
        cluster-wide provenance (busy_s, memory, pools) stays aggregate,
        and cost/energy still bill the whole cluster — tenants share the
        fleet, so per-tenant dollars need an attribution policy, not a
        slice.  Use :func:`repro.scenarios.tenants.tenant_report` for
        the fairness/isolation view across all tenants.
        """
        sub = [t for t in self.traces if t.request.tenant == name]
        # the slice serves exactly its recorded traces (sampling scale
        # does not survive slicing: per-tenant served counts are unknown)
        return dataclasses.replace(self, traces=sub, requests_served=0)

    def billed_replica_seconds(self) -> float:
        """Replica-seconds energy/cost are billed over: the integrated
        live-replica span when the event loop measured it, else the static
        ``replicas × duration`` (identical for fixed-size clusters).  An
        autoscaled cluster is no longer charged its *peak* replica count
        for the whole run."""
        if self.replica_seconds > 0.0:
            return self.replica_seconds
        return self.duration_s * max(self.replicas, 1)

    def energy_joules(self) -> float:
        if self.fleet is not None:
            # heterogeneous pools: each pool's chips draw their own TDP
            # over that pool's live span at that pool's utilization
            total = 0.0
            for p in self.fleet["pools"]:
                rs = p["replica_seconds"]
                util = min(p["busy_s"] / rs, 1.0) if rs else 0.0
                total += hw_lib.energy_joules(
                    hw_lib.HARDWARE[p["hardware"]], rs, util) * p["chips"]
            return total
        rs = self.billed_replica_seconds()
        util = min(self.busy_s / rs, 1.0) if rs else 0.0
        return hw_lib.energy_joules(self.hw, rs, util) * self.chips

    def co2_kg(self) -> float:
        return hw_lib.co2_kg(self.energy_joules())

    def cost_usd(self) -> float:
        if self.fleet is not None:
            # per-pool bill: each pool's integrated replica-seconds at
            # its own hardware's rate and pricing class (spot pools are
            # billed spot rates only up to each replica's kill time)
            return sum(p["cost_usd"] for p in self.fleet["pools"])
        return hw_lib.cloud_cost_usd(self.hw.name,
                                     self.billed_replica_seconds()) \
            * self.chips

    def cost_per_1k_requests(self) -> float:
        n = self._served()
        return self.cost_usd() / n * 1000 if n else 0.0

    def stage_means(self) -> Dict[str, float]:
        if not self.traces:
            return {}
        return {
            "preprocess": float(np.mean([t.t_preprocess for t in self.traces])),
            "transmit": float(np.mean([t.t_transmit for t in self.traces])),
            "queue": float(np.mean([t.t_queue for t in self.traces])),
            "batch_wait": float(np.mean([t.t_batch_wait
                                         for t in self.traces])),
            "kv_transfer": float(np.mean([t.t_kv_transfer
                                          for t in self.traces])),
            "inference": float(np.mean([t.t_inference for t in self.traces])),
            "postprocess": float(np.mean([t.t_postprocess
                                          for t in self.traces])),
        }

    def summary(self) -> Dict[str, float]:
        s = {
            "requests": self._served(),
            "throughput_rps": self.throughput(),
            "p50_s": self.percentile(50),
            "p95_s": self.percentile(95),
            "p99_s": self.percentile(99),
            "mean_s": float(np.mean(self.latencies())) if self.traces else 0.0,
            "ttft_p50_s": self.ttft(50),
            "ttft_p99_s": self.ttft(99),
            "tpot_p50_s": self.tpot(50),
            "tpot_p99_s": self.tpot(99),
            "utilization": self.utilization(),
            "replicas": self.replicas,
            "replica_seconds": self.billed_replica_seconds(),
            "energy_j": self.energy_joules(),
            "co2_kg": self.co2_kg(),
            "cost_usd": self.cost_usd(),
            "cost_per_1k_req": self.cost_per_1k_requests(),
        }
        if self.pools is not None:
            s["prefill_replicas"] = self.pools["prefill_replicas"]
            s["decode_replicas"] = self.pools["decode_replicas"]
            s["mean_kv_transfer_s"] = self.pools["mean_kv_transfer_s"]
        if self.fleet is not None:
            s["spot_preemptions"] = self.fleet["spot_preemptions"]
            s["spot_killed_requests"] = self.fleet["spot_killed_requests"]
            s["cross_region_fraction"] = self.fleet["cross_region_fraction"]
        if self.memory is not None:
            s["prefix_hit_rate"] = self.memory["prefix_hit_rate"]
            s["preemptions"] = self.memory["preemptions"]
            s["kv_peak_occupancy"] = self.memory["peak_occupancy"]
            s["kv_mean_occupancy"] = self.memory["mean_occupancy"]
        return s


@dataclasses.dataclass(slots=True)
class _ActiveRequest:
    """A request occupying a decode slot of a continuous engine."""
    qreq: QueuedRequest
    remaining: int          # tokens still to produce (prefill yields one)
    context: int            # KV length so far
    join_s: float
    prefill_left: int = 0   # prompt tokens still to chunk-prefill (0 when
                            # the prompt was prefilled whole at join)
    chunk: int = 0          # tokens being prefilled this iteration
    trace: Optional[RequestTrace] = None    # resolved once at join so the
                            # per-token hot loop never hits the trace dict


def clamped_output_tokens(request: Request, max_model_len: int) -> int:
    """Decode tokens owed, bounded by the model's context limit so
    slot/KV accounting is always finite (``output_tokens_max=None``
    workloads carry an unbounded-generation sentinel).  Prompts at or
    over ``max_model_len`` are rejected at simulation entry
    (``simulate_cluster``), so the ≥1 floor never masks a context
    overrun — it only guards zero-output workloads."""
    out = request.output_tokens
    if max_model_len:
        out = min(out, max_model_len - request.prompt_tokens)
    return max(out, 1)


class ReplicaEngine:
    """One server timeline, steppable from an external event loop.

    The loop calls ``next_action_s`` to learn when this replica next wants
    the clock, advances global time, then calls ``act(now, traces)`` which
    performs every action due at ``now`` and returns ``(done_s, request)``
    completions (``done_s`` may lie in the future — inference started at
    ``now`` finishes later; completions only feed closed-loop reissue).
    ``next_action_s`` only changes when the engine's own state changes
    (an enqueue or its own act), which is what lets the cluster loop index
    engines in a lazy-deletion heap instead of rescanning all of them.
    """

    def __init__(self, replica_id: int, policy: BatchPolicy,
                 latency: LatencyModel, spawn_s: float = 0.0,
                 kv: Optional[KVCacheManager] = None,
                 max_model_len: int = 0, role: str = "both",
                 chunk_tokens: int = 0, created_s: float = 0.0,
                 obs=None):
        self.replica_id = replica_id
        self.obs = obs      # MetricsRecorder hooks (None → zero overhead)
        # span hook bound only when the timeline actually records, so the
        # per-iteration site pays one attribute check otherwise
        self.obs_span = (obs.engine_span if obs is not None
                         and getattr(obs, "record_spans", False) else None)
        self.policy = policy
        self.latency = latency
        self.continuous = isinstance(policy, ContinuousBatcher)
        self.spawn_s = spawn_s
        self.kv = kv                        # None → memory unmodeled
        self.max_model_len = max_model_len  # 0 → unbounded decode
        # disaggregated serving: a "prefill" engine runs chunked prefill
        # only and completes each request at its first token (the cluster
        # loop migrates it to the decode pool); "decode"/"both" engines
        # run the full continuous loop
        self.role = role
        self.chunk_tokens = chunk_tokens    # 0 → whole-prompt prefill
        self.created_s = created_s          # provisioning time (billing)
        self.retired_s: Optional[float] = None
        # fleet routing metadata — defaults describe the flat cluster;
        # simulate_cluster overwrites these for heterogeneous pools
        self.pool_name = "serve"
        self.region = ""
        self.cost_rate = 0.0    # $/replica-hour (rate × chips), router hint
        self.ttft_hint = 0.0    # nominal first-token latency, router hint
        # continuous admission pops head / preempts back to head: deque.
        # Request-level policies slice the queue (queue[:n]), so they
        # keep a list.
        self.queue = deque() if self.continuous else []
        self.server_free_at = spawn_s
        self.busy_s = 0.0
        self.served = 0
        self.retired = False
        # continuous-engine state
        self.active: List[_ActiveRequest] = []
        self.iter_end: Optional[float] = None
        self._slot_free_s = spawn_s     # last time capacity opened (a
        # decode slot freed, or KV blocks freed after blocking admission)
        self._kv_blocked_ver: Optional[int] = None  # KV version observed
        # when admission last failed allocation (None = not blocked)
        # memoized policy decision; every queue/clock mutation the engine
        # can see changes (now, len(queue), server_free_at)
        self._decision_key = None
        self._decision = None
        # bind the dispatch once — act() is called for every engine event
        self.act = self._act_continuous if self.continuous \
            else self._act_batched

    # ---- routing signals --------------------------------------------------
    def load(self, now: float) -> int:
        """In-flight work (queued + running) — the least-loaded signal."""
        n = len(self.queue) + len(self.active)
        if not self.continuous and self.server_free_at > now + EPS:
            n += 1          # a batch currently occupies the server
        return n

    def idle(self, now: float) -> bool:
        return (not self.queue and not self.active and self.iter_end is None
                and self.server_free_at <= now + EPS)

    # ---- event-loop interface --------------------------------------------
    def enqueue(self, qreq: QueuedRequest) -> None:
        self.queue.append(qreq)

    def next_action_s(self, now: float) -> Optional[float]:
        """Earliest time this replica can change state (None = nothing)."""
        if self.continuous:
            if self.iter_end is not None:
                return self.iter_end
            if self.queue or self.active:
                return max(now, self.spawn_s)
            return None
        if not self.queue:
            return None
        decision = self._decide(now)
        if decision is not None:
            return max(now, decision[1])
        fire = self.policy.earliest_fire(self.queue)
        if fire is not None:
            return max(fire, self.server_free_at)
        return None

    def _decide(self, now: float):
        key = (now, len(self.queue), self.server_free_at)
        if key != self._decision_key:
            self._decision = self.policy.next_batch(self.queue, now,
                                                    self.server_free_at)
            self._decision_key = key
        return self._decision

    def act(self, now: float,
            traces: Dict[int, RequestTrace]) -> List[Tuple[float, Request]]:
        if self.continuous:
            return self._act_continuous(now, traces)
        return self._act_batched(now, traces)

    # ---- request-level policies ------------------------------------------
    def _batch_fitting_memory(self, batch):
        """Longest batch prefix whose whole-batch KV working set fits the
        replica budget (request-level policies hold every sequence's full
        context for the batch's duration)."""
        kept, blocks = [], 0
        for q in batch:
            b = self.kv.blocks_for(q.request.prompt_tokens
                                   + q.request.output_tokens)
            if kept and blocks + b > self.kv.total_blocks:
                break
            kept.append(q)
            blocks += b
        return kept, blocks

    def _act_batched(self, now, traces):
        completions: List[Tuple[float, Request]] = []
        while self.queue:
            decision = self._decide(now)
            if decision is None:
                break
            batch, fire_t = decision
            if fire_t > now + EPS:
                break
            kv_blocks = 0
            if self.kv is not None:
                batch, kv_blocks = self._batch_fitting_memory(batch)
            ids = {q.request.req_id for q in batch}
            self.queue = [q for q in self.queue
                          if q.request.req_id not in ids]
            bsz = len(batch)
            prompt = max(q.request.prompt_tokens for q in batch)
            out_toks = max(q.request.output_tokens for q in batch)
            infer_s = self.latency.request_latency(bsz, prompt, out_toks)
            prev_free = self.server_free_at
            start = max(now, prev_free)
            self.server_free_at = start + infer_s
            self.busy_s += infer_s
            self.served += bsz
            if self.obs_span is not None:
                self.obs_span(self.replica_id, start,
                              self.server_free_at, "batch", bsz)
            if self.kv is not None:
                self.kv.charge_span(kv_blocks, start, self.server_free_at)
            # the batch emits its first tokens once the (padded) prefill
            # completes; decode steps follow until the batch's max length
            first_token = start + self.latency.prefill_latency(bsz, prompt)
            for q in batch:
                tr = traces[q.request.req_id]
                tr.replica = self.replica_id
                if tr.detail:
                    tr.t_queue = start - q.enqueue_s
                    tr.t_batch_wait = max(
                        0.0, start - max(q.enqueue_s, prev_free))
                    tr.t_inference = infer_s
                    tr.t_postprocess = POST_PROCESS_S
                    tr.batch_size = bsz
                tr.first_token_s = min(first_token, self.server_free_at)
                tr.tokens_out = clamped_output_tokens(q.request,
                                                      self.max_model_len)
                tr.done_s = self.server_free_at + POST_PROCESS_S
                completions.append((tr.done_s, q.request))
        return completions

    # ---- continuous (token-level) engine ---------------------------------
    def _clamped_output(self, request: Request) -> int:
        return clamped_output_tokens(request, self.max_model_len)

    def _preempt(self, victim: _ActiveRequest, now: float, traces) -> None:
        """Evict a running request under KV pressure (recompute policy):
        free its blocks and requeue it carrying its progress — on rejoin
        it re-prefills prompt + generated-so-far at latency-model cost."""
        q = victim.qreq
        self.kv.free(q.request.req_id, now, preempted=True)
        if self.obs is not None:
            self.obs.count_preemption()
        q.remaining = victim.remaining
        q.recompute_tokens = victim.context
        q.preemptions += 1
        tr = victim.trace
        tr.preemptions += 1
        # close this service segment so stage accounting stays truthful:
        # time served so far is inference, the wait from here to the
        # rejoin accrues to t_queue (segments accumulate via +=)
        if tr.detail:
            tr.t_inference += now - victim.join_s
        q.enqueue_s = now
        self.queue.appendleft(q)

    def spot_kill(self, now: float, traces) -> List[QueuedRequest]:
        """Spot reclamation: the provider takes the replica back *now*.

        Every in-flight sequence loses its KV and rejoins the fleet via
        the cluster router carrying its progress (recompute on rejoin,
        same machinery as memory-pressure preemption); queued requests
        are handed back untouched.  Returns the work to re-route.  The
        engine is retired and bills only up to ``now`` — the partially
        run iteration never completes, so its unspent tail is refunded
        from ``busy_s``.
        """
        victims: List[QueuedRequest] = []
        if self.iter_end is not None and self.iter_end > now:
            self.busy_s -= self.iter_end - now
        self.iter_end = None
        for a in self.active:
            q = a.qreq
            if self.kv is not None:
                self.kv.free(q.request.req_id, now, preempted=True)
            if self.obs is not None:
                self.obs.count_preemption()
            q.remaining = a.remaining
            q.recompute_tokens = a.context
            q.preemptions += 1
            tr = a.trace
            tr.preemptions += 1
            tr.spot_evictions += 1
            if tr.detail:
                tr.t_inference += now - a.join_s
            q.enqueue_s = now
            victims.append(q)
        self.active = []
        # queued work was never started: keep its original enqueue_s so
        # queue-time accounting spans the whole wait
        victims.extend(self.queue)
        self.queue.clear()
        self.server_free_at = now
        self.retired = True
        self.retired_s = now
        return victims

    def _grow_or_preempt(self, still: List[_ActiveRequest], now: float,
                         traces) -> List[_ActiveRequest]:
        """Extend every surviving sequence's KV by its new token; when a
        block allocation fails, preempt victims (youngest-join or
        largest-context first) until the extension fits."""
        survivors: List[_ActiveRequest] = []
        pending = sorted(still, key=lambda a: (a.join_s,
                                               a.qreq.request.req_id))
        while pending:
            a = pending.pop(0)              # oldest first: highest priority
            while not self.kv.extend(a.qreq.request.req_id, a.context, now):
                candidates = pending + survivors
                if not candidates:
                    raise RuntimeError(
                        "KV budget cannot hold a single sequence — "
                        "simulate_cluster validates against this; was the "
                        "manager constructed directly with too few blocks?")
                if self.kv.spec.preemption == "largest":
                    victim = max(candidates,
                                 key=lambda v: (v.context,
                                                v.qreq.request.req_id))
                else:                       # youngest join first
                    victim = max(candidates,
                                 key=lambda v: (v.join_s,
                                                v.qreq.request.req_id))
                self._preempt(victim, now, traces)
                (pending if victim in pending else survivors).remove(victim)
            survivors.append(a)
        return survivors

    def _act_continuous(self, now, traces):
        completions: List[Tuple[float, Request]] = []
        cap = self.policy.max_batch
        if self.iter_end is not None and self.iter_end <= now + EPS:
            end = self.iter_end
            self.iter_end = None
            was_full = len(self.active) >= cap
            still: List[_ActiveRequest] = []
            for a in self.active:
                if a.chunk > 0:
                    # chunked prefill advanced; no token until the final
                    # chunk's iteration (which falls through below)
                    a.prefill_left -= a.chunk
                    a.chunk = 0
                    if a.prefill_left > 0:
                        still.append(a)
                        continue
                a.remaining -= 1
                a.context += 1
                tr = a.trace
                if tr.detail:
                    tr.tokens_out += 1
                    if tr.first_token_s <= 0.0:
                        tr.first_token_s = end
                if a.remaining <= 0:
                    if tr.detail:
                        tr.t_inference += end - a.join_s
                    if self.role == "prefill" and clamped_output_tokens(
                            a.qreq.request, self.max_model_len) > 1:
                        # hand-off point (the cluster loop migrates this
                        # request): the decode pool owns the final done/
                        # postprocess accounting.  Single-token requests
                        # finish here and pay postprocess like everyone
                        tr.done_s = end
                    else:
                        tr.t_postprocess = POST_PROCESS_S
                        tr.done_s = end + POST_PROCESS_S
                    completions.append((tr.done_s, a.qreq.request))
                    self.served += 1
                    if self.kv is not None:
                        self.kv.free(a.qreq.request.req_id, now)
                else:
                    still.append(a)
            if self.kv is not None and still:
                still = self._grow_or_preempt(still, now, traces)
            if was_full and len(still) < cap:
                self._slot_free_s = end
            if self._kv_blocked_ver is not None \
                    and self.kv.version != self._kv_blocked_ver:
                # admission was blocked on a failed KV allocation and
                # blocks have since been freed: capacity (re)opened *now*,
                # so the wait up to this point was memory pressure, not
                # batching policy — advance the marker before admission
                # below computes t_batch_wait
                self._slot_free_s = max(self._slot_free_s, end)
                self._kv_blocked_ver = None
            self.active = still
        if self.iter_end is None and (self.queue or self.active):
            start = max(now, self.spawn_s)
            joined: List[_ActiveRequest] = []
            decode_joins: List[_ActiveRequest] = []
            prefill_lens: List[int] = []
            # max_prefill caps prefill admissions per boundary; migrated
            # (KV-resident) joins need no prefill compute, so they only
            # count against the decode-slot cap
            while (self.queue and len(self.active) + len(joined) < cap
                   and len(joined) - len(decode_joins)
                   < self.policy.max_prefill):
                q = self.queue[0]
                # a preempted request re-prefills its full saved context
                context0 = q.recompute_tokens or q.request.prompt_tokens
                if self.role == "prefill":
                    remaining = 1   # prefill emits exactly the first token
                elif q.remaining is not None:
                    remaining = q.remaining
                else:
                    remaining = self._clamped_output(q.request)
                cached = 0
                if self.kv is not None:
                    # migrated KV arrives as private blocks — keep it out
                    # of the prefix cache (its prefix was already shared
                    # on the prefill pool)
                    got = self.kv.allocate(
                        q.request.req_id, context0, now,
                        session_id=None if q.migrated
                        else q.request.session_id,
                        prefix_tokens=0 if q.migrated
                        else q.request.prefix_tokens)
                    if got is None:
                        # no KV headroom: stays queued.  Remember the
                        # cache's version so the next free() is seen as
                        # the moment capacity reopened (t_batch_wait must
                        # not charge this wait to the batching policy)
                        self._kv_blocked_ver = self.kv.version
                        break
                    cached = got
                self.queue.popleft()
                tr = traces[q.request.req_id]
                tr.replica = self.replica_id
                if tr.detail:
                    # += so a preempted request's rejoin adds its re-queue
                    # segment instead of overwriting the first one
                    tr.t_queue += start - q.enqueue_s
                    tr.t_batch_wait += max(
                        0.0, start - max(q.enqueue_s, self._slot_free_s))
                    tr.cached_prompt_tokens = max(tr.cached_prompt_tokens,
                                                  cached)
                a = _ActiveRequest(qreq=q, remaining=remaining,
                                   context=context0, join_s=start,
                                   trace=tr)
                if q.migrated and not q.recompute_tokens:
                    # KV already resident (transferred): no prefill
                    # compute; it takes a decode step this very iteration
                    decode_joins.append(a)
                else:
                    # prefix-cache hits skip those tokens' prefill compute
                    need = max(context0 - cached, 1)
                    if self.chunk_tokens and need > self.chunk_tokens:
                        a.prefill_left = need
                        a.chunk = min(self.chunk_tokens, need)
                        prefill_lens.append(a.chunk)
                    else:
                        prefill_lens.append(need)
                joined.append(a)
            # in-flight chunked prefills schedule their next chunk
            # (prefill_left can only be nonzero on chunking engines)
            if self.chunk_tokens:
                for a in self.active:
                    if a.prefill_left > 0:
                        a.chunk = min(self.chunk_tokens, a.prefill_left)
                        prefill_lens.append(a.chunk)
            if joined or self.active:
                if self.chunk_tokens:
                    decoders = [a for a in self.active
                                if a.prefill_left <= 0] + decode_joins
                else:
                    decoders = self.active + decode_joins
                n_decode = len(decoders)
                max_ctx = 0
                for a in decoders:
                    if a.context > max_ctx:
                        max_ctx = a.context
                n_prefill = len(prefill_lens)
                max_prompt = max(prefill_lens, default=0)
                t_iter = self.latency.iteration_latency(
                    n_prefill, max_prompt, n_decode, max_ctx)
                self.active.extend(joined)
                bsz = len(self.active)
                for a in self.active:
                    tr = a.trace
                    if tr.detail and bsz > tr.batch_size:
                        tr.batch_size = bsz
                self.iter_end = start + t_iter
                self.server_free_at = self.iter_end
                self.busy_s += t_iter
                if self.obs_span is not None:
                    self.obs_span(self.replica_id, start, self.iter_end,
                                  "iteration", bsz, n_prefill)
        return completions


def simulate(workload: WorkloadSpec, policy: BatchPolicy,
             latency: LatencyModel, *, network: NetworkModel = NETWORKS["lan"],
             server_side_processing: bool = True,
             memory=None, trace_sample: float = 1.0,
             obs=None) -> SimResult:
    """Run the single-replica pipeline simulation.

    This is the one-server special case of
    :func:`repro.serving.cluster.simulate_cluster`; closed-loop workloads
    (``kind="closed"``) reissue each client's next request on completion
    until ``duration_s``.  ``memory`` (a ``MemorySpec`` or its dict form)
    enables KV-cache accounting on the single replica.  ``trace_sample``
    < 1 records full per-request traces for only that fraction of
    requests (aggregates like throughput stay exact; see
    ``simulate_cluster``).  ``obs`` (an ``ObsSpec``) opts into the
    observability layer — time-series + timeline on the single replica.
    """
    from repro.serving.cluster import ClusterSpec, simulate_cluster
    return simulate_cluster(workload, policy, latency,
                            cluster=ClusterSpec(replicas=1, memory=memory,
                                                obs=obs),
                            network=network, trace_sample=trace_sample)
