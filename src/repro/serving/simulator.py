"""Discrete-event simulator of the full inference pipeline (paper Fig. 4):

  client → pre-process → transmission → queue/batch → inference → post.

Drives a batching policy + latency oracle over a workload trace, recording
per-request stage latencies — the substrate for the tail-latency (Fig. 11),
dynamic-batching (Fig. 12), utilization (Fig. 13) and pipeline-
decomposition (Fig. 14) reproductions.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import hw as hw_lib
from repro.serving.batching import BatchPolicy, QueuedRequest
from repro.serving.latency_model import (LatencyModel, NetworkModel,
                                         NETWORKS)
from repro.serving.workload import CLOSED, Request, WorkloadSpec, generate

PRE_PROCESS_S = 0.0015     # resize + tensorize, per request
POST_PROCESS_S = 0.0004    # label lookup / detokenize, per request


@dataclasses.dataclass
class RequestTrace:
    request: Request
    t_preprocess: float = 0.0
    t_transmit: float = 0.0
    t_queue: float = 0.0
    t_batch_wait: float = 0.0
    t_inference: float = 0.0
    t_postprocess: float = 0.0
    batch_size: int = 1
    done_s: float = 0.0

    @property
    def e2e(self) -> float:
        return (self.t_preprocess + self.t_transmit + self.t_queue
                + self.t_inference + self.t_postprocess)


@dataclasses.dataclass
class SimResult:
    traces: List[RequestTrace]
    busy_s: float
    duration_s: float
    hw: hw_lib.HardwareModel
    chips: int

    # ---- aggregate metrics (the paper's metric collector) ----------------
    def latencies(self) -> np.ndarray:
        return np.array([t.e2e for t in self.traces])

    def percentile(self, p: float) -> float:
        lat = self.latencies()
        return float(np.percentile(lat, p)) if len(lat) else 0.0

    def throughput(self) -> float:
        return len(self.traces) / self.duration_s if self.duration_s else 0.0

    def utilization(self) -> float:
        return self.busy_s / self.duration_s if self.duration_s else 0.0

    def cdf(self, points: int = 50):
        lat = np.sort(self.latencies())
        if not len(lat):
            return [], []
        qs = np.linspace(0, 1, points)
        return list(np.quantile(lat, qs)), list(qs)

    def energy_joules(self) -> float:
        return hw_lib.energy_joules(self.hw, self.duration_s,
                                    self.utilization()) * self.chips

    def co2_kg(self) -> float:
        return hw_lib.co2_kg(self.energy_joules())

    def cost_usd(self) -> float:
        return hw_lib.cloud_cost_usd(self.hw.name, self.duration_s) * self.chips

    def cost_per_1k_requests(self) -> float:
        n = len(self.traces)
        return self.cost_usd() / n * 1000 if n else 0.0

    def stage_means(self) -> Dict[str, float]:
        if not self.traces:
            return {}
        return {
            "preprocess": float(np.mean([t.t_preprocess for t in self.traces])),
            "transmit": float(np.mean([t.t_transmit for t in self.traces])),
            "queue": float(np.mean([t.t_queue for t in self.traces])),
            "inference": float(np.mean([t.t_inference for t in self.traces])),
            "postprocess": float(np.mean([t.t_postprocess for t in self.traces])),
        }

    def summary(self) -> Dict[str, float]:
        return {
            "requests": len(self.traces),
            "throughput_rps": self.throughput(),
            "p50_s": self.percentile(50),
            "p95_s": self.percentile(95),
            "p99_s": self.percentile(99),
            "mean_s": float(np.mean(self.latencies())) if self.traces else 0.0,
            "utilization": self.utilization(),
            "energy_j": self.energy_joules(),
            "co2_kg": self.co2_kg(),
            "cost_usd": self.cost_usd(),
            "cost_per_1k_req": self.cost_per_1k_requests(),
        }


def simulate(workload: WorkloadSpec, policy: BatchPolicy,
             latency: LatencyModel, *, network: NetworkModel = NETWORKS["lan"],
             server_side_processing: bool = True) -> SimResult:
    """Run the pipeline simulation; returns per-request traces + utilization.

    Closed-loop workloads (``kind="closed"``) start from one seed request
    per client; each completion immediately reissues that client's next
    request until ``duration_s``, keeping ``concurrency`` requests in
    flight throughout.
    """
    requests = generate(workload)
    closed_loop = workload.kind == CLOSED
    # arrival at the server = client arrival + preprocess + transmission
    queue: List[QueuedRequest] = []
    traces: Dict[int, RequestTrace] = {}
    arrivals: List[Tuple[float, int, Request]] = []   # (server_arrival, id, r)

    def admit(r: Request) -> None:
        tr = RequestTrace(request=r, t_preprocess=PRE_PROCESS_S,
                          t_transmit=network.transmit(r.payload_bytes))
        traces[r.req_id] = tr
        heapq.heappush(arrivals,
                       (r.arrival_s + tr.t_preprocess + tr.t_transmit,
                        r.req_id, r))

    for r in requests:
        admit(r)
    next_id = len(requests)

    now = 0.0
    busy = 0.0
    server_free_at = 0.0
    while arrivals or queue:
        # admit every arrival up to `now`
        while arrivals and arrivals[0][0] <= now + 1e-12:
            t_arr, _, r = heapq.heappop(arrivals)
            queue.append(QueuedRequest(request=r, enqueue_s=t_arr))
        decision = policy.next_batch(queue, now, server_free_at)
        if decision is None:
            # advance time to the next event (arrival or policy timeout)
            candidates = []
            if arrivals:
                candidates.append(arrivals[0][0])
            fire = policy.earliest_fire(queue)
            if fire is not None:
                candidates.append(max(fire, server_free_at))
            if not candidates:
                break
            now = max(now, min(candidates))
            continue
        batch, fire_t = decision
        if fire_t > now + 1e-12:
            now = fire_t
            continue  # re-admit arrivals before firing
        # serve the batch
        ids = {q.request.req_id for q in batch}
        queue = [q for q in queue if q.request.req_id not in ids]
        bsz = len(batch)
        prompt = max(q.request.prompt_tokens for q in batch)
        out_toks = max(q.request.output_tokens for q in batch)
        infer_s = latency.request_latency(bsz, prompt, out_toks)
        start = max(now, server_free_at)
        server_free_at = start + infer_s
        busy += infer_s
        for q in batch:
            tr = traces[q.request.req_id]
            tr.t_queue = start - q.enqueue_s
            tr.t_inference = infer_s
            tr.t_postprocess = POST_PROCESS_S
            tr.batch_size = bsz
            tr.done_s = server_free_at + POST_PROCESS_S
            if closed_loop and tr.done_s < workload.duration_s:
                # the client observes the response and issues its next
                # request, keeping its loop at concurrency 1
                admit(dataclasses.replace(q.request, req_id=next_id,
                                          arrival_s=tr.done_s))
                next_id += 1
        now = max(now, start)

    done = [t for t in traces.values() if t.done_s > 0]
    duration = max((t.done_s for t in done), default=0.0)
    return SimResult(traces=done, busy_s=busy, duration_s=duration,
                     hw=latency.hw, chips=latency.chips)
