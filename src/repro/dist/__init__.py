from repro.dist import sharding

__all__ = ["sharding"]
