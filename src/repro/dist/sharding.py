"""Logical-axis sharding rules → concrete ``PartitionSpec``s.

Model code names tensor dimensions with *logical* axes ("embed", "ffn",
"batch", ...); a rule set maps each logical axis to the mesh axes it may
shard over.  ``partition_spec`` resolves the mapping against a concrete
(or abstract) mesh with two safety nets:

  * divisibility fallback — a mesh axis that does not evenly divide the
    dimension is dropped (replicate rather than pad),
  * duplicate-axis avoidance — a mesh axis is consumed by the first
    dimension that claims it; later dimensions fall back to replication.

Rule sets are plain dicts so callers can derive variants with ``dict(...)``;
boolean entries ("moe_seq", "moe_ep_local") act as mode flags read by the
MoE dispatch code, not as tensor axes.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

Rules = Dict[str, Sequence[str]]


def abstract_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]):
    """AbstractMesh across jax versions (positional API changed in 0.5)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def _rules(**overrides) -> Rules:
    base: Dict[str, Any] = {
        "batch": ["pod", "data"],
        "seq": [],
        "kv": ["model"],
        "embed": ["data"],
        "act_embed": [],
        "vocab": ["model"],
        "ffn": ["model"],
        "heads": ["model"],
        "kv_heads": ["model"],
        "head_dim": [],
        "embed_out": ["model"],
        "rnn": ["model"],
        "rnn_in": [],
        "expert": ["model"],
    }
    base.update(overrides)
    return base


# FSDP-style training: params sharded over data, contracted dims over model.
TRAIN_RULES: Rules = _rules()

# Serving default: same layout, single-pod batch.
SERVE_RULES: Rules = _rules(batch=["data"])

# Pure tensor-parallel serving: weights replicated over data, TP over model,
# kv cache sharded by heads rather than sequence.
SERVE_TP_RULES: Rules = _rules(batch=["data"], embed=[], kv=[])

# MoE variants (consumed by launch.dryrun / moe.moe_apply mode selection):
# experts sharded over model (GSPMD dispatch).
MOE_EP_RULES: Rules = _rules(expert=["model"], ffn=["model"])
# experts over model with local (shard_map) dispatch — one psum per layer.
MOE_EP_LOCAL_RULES: Rules = _rules(expert=["model"], moe_ep_local=True)
# whole MoE block local per batch shard; expert weights replicated.
MOE_LOCAL_RULES: Rules = _rules(ffn=[], expert=[], heads=[], kv_heads=[],
                                embed=[], embed_out=[], vocab=[], kv=[],
                                rnn=[])
# local MoE + sequence-partitioned dispatch over the model axis.
MOE_SP_RULES: Rules = dict(MOE_LOCAL_RULES, moe_seq=True)
# sequence-partitioned MoE dispatch + tensor-parallel dense layers.
MOE_SP_TP_RULES: Rules = dict(MOE_LOCAL_RULES, moe_seq=True,
                              heads=["model"], kv_heads=["model"],
                              embed_out=["model"], vocab=["model"],
                              rnn=["model"])


def partition_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
                   rules: Rules, mesh) -> P:
    """Resolve logical ``axes`` for ``shape`` into a PartitionSpec."""
    if len(shape) != len(axes):
        raise ValueError(f"rank mismatch: shape {tuple(shape)} "
                         f"vs logical axes {tuple(axes)}")
    sizes = dict(mesh.shape)
    used: set = set()
    entries: List[Any] = []
    for dim, ax in zip(shape, axes):
        chosen: List[str] = []
        factor = 1
        wanted = rules.get(ax, ()) if ax else ()
        for a in wanted:
            if a not in sizes or a in used or a in chosen:
                continue
            if dim % (factor * sizes[a]) == 0:
                chosen.append(a)
                factor *= sizes[a]
        used.update(chosen)
        entries.append(None if not chosen else
                       chosen[0] if len(chosen) == 1 else tuple(chosen))
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def _is_axes_leaf(x: Any) -> bool:
    return x is None or (isinstance(x, tuple) and
                         all(e is None or isinstance(e, str) for e in x))


def tree_partition_specs(shape_tree, axes_tree, rules: Rules, mesh):
    """Map a pytree of ShapeDtypeStructs + logical axes to PartitionSpecs."""
    flat_axes, treedef = jax.tree.flatten(axes_tree, is_leaf=_is_axes_leaf)
    flat_shapes = treedef.flatten_up_to(shape_tree)
    specs = [partition_spec(tuple(s.shape),
                            a if a is not None else (None,) * len(s.shape),
                            rules, mesh)
             for s, a in zip(flat_shapes, flat_axes)]
    return jax.tree.unflatten(treedef, specs)


def tree_shardings(shape_tree, axes_tree, rules: Rules, mesh):
    specs = tree_partition_specs(shape_tree, axes_tree, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _shard_factor(spec: P, sizes: Dict[str, int]) -> int:
    f = 1
    for entry in spec:
        if entry is None:
            continue
        for a in (entry,) if isinstance(entry, str) else entry:
            f *= sizes[a]
    return f


def bytes_per_device(shape_tree, spec_tree, mesh) -> int:
    """Total per-device bytes for a sharded pytree of ShapeDtypeStructs."""
    sizes = dict(mesh.shape)
    flat_shapes, treedef = jax.tree.flatten(shape_tree)
    flat_specs = treedef.flatten_up_to(spec_tree)
    total = 0
    for s, spec in zip(flat_shapes, flat_specs):
        nbytes = int(np.prod(s.shape, dtype=np.int64)) * s.dtype.itemsize
        total += nbytes // _shard_factor(spec, sizes)
    return total


# ---- activation sharding context -------------------------------------------
# One-element cell so jitted closures observe updates; (mesh, rules) or None.
_ACT_CTX: List[Optional[Tuple[Any, Rules]]] = [None]


def set_activation_sharding(mesh, rules: Optional[Rules]) -> None:
    _ACT_CTX[0] = None if mesh is None else (mesh, rules)


def constrain_act(x, *axes: Optional[str]):
    """Apply a with_sharding_constraint when a context is active; else no-op."""
    ctx = _ACT_CTX[0]
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = partition_spec(tuple(x.shape), axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_attn_q(q):
    return constrain_act(q, "batch", "seq", "heads", "head_dim")
