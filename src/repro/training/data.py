"""Deterministic synthetic data pipeline.

Every (step, host) pair maps to a unique, reproducible batch shard via
counter-based hashing (threefry through jax.random with a folded key), so:
  · restarts resume mid-stream with no state files,
  · elastic re-sharding (different host count) re-partitions the same
    global stream,
  · no host ever reads another host's shard (no coordination traffic).
A background prefetch thread keeps ``depth`` batches ready.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int = 32
    seq_len: int = 256
    seed: int = 1234
    n_hosts: int = 1
    host_id: int = 0


def _synthetic_tokens(key, b: int, seq: int, vocab: int) -> jnp.ndarray:
    """Learnable stream: affine bigram x_{t+1} = (a·x_t + c) mod V, with 10%
    uniform noise — a model that learns the bigram drives loss well below
    log V, so training curves are meaningful."""
    k1, k2, k3 = jax.random.split(key, 3)
    a, c = 31, 17
    x0 = jax.random.randint(k1, (b,), 0, vocab, dtype=jnp.int32)

    def step_fn(x, knoise):
        nxt = (a * x + c) % vocab
        noise = jax.random.randint(knoise, x.shape, 0, vocab, dtype=jnp.int32)
        flip = jax.random.uniform(jax.random.fold_in(knoise, 1), x.shape) < 0.1
        nxt = jnp.where(flip, noise, nxt)
        return nxt, nxt

    keys = jax.random.split(k2, seq)
    _, rest = jax.lax.scan(step_fn, x0, keys)
    return jnp.concatenate([x0[:, None], rest.T], axis=1)  # (b, seq+1)


def host_batch(cfg: DataConfig, model_cfg: ModelConfig,
               step: int) -> Dict[str, np.ndarray]:
    """This host's shard of the global batch for `step` (pure function)."""
    assert cfg.global_batch % cfg.n_hosts == 0
    b = cfg.global_batch // cfg.n_hosts
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.key(cfg.seed), step), cfg.host_id)
    toks = _synthetic_tokens(key, b, cfg.seq_len, model_cfg.vocab_size)
    batch = {
        "tokens": np.asarray(toks[:, :-1]),
        "labels": np.asarray(toks[:, 1:]),
        "loss_mask": np.ones((b, cfg.seq_len), np.float32),
    }
    if model_cfg.is_encdec:
        fkey = jax.random.fold_in(key, 7)
        batch["frames"] = np.asarray(jax.random.normal(
            fkey, (b, cfg.seq_len, model_cfg.d_model), jnp.float32))
    if model_cfg.frontend == "vision_patches":
        pkey = jax.random.fold_in(key, 8)
        n = model_cfg.num_frontend_tokens
        batch["patches"] = np.asarray(jax.random.normal(
            pkey, (b, n, model_cfg.d_model), jnp.float32))
    return batch


class PrefetchingLoader:
    """Iterator with a background thread keeping `depth` batches ready."""

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig,
                 start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            batch = host_batch(self.cfg, self.model_cfg, s)
            while not self._stop.is_set():
                try:
                    self._q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
