"""Vocab-chunked cross-entropy.

For 256k-vocab models the (B, S, V) logits tensor alone would be tens of
GB per device; chunking the head projection over the sequence keeps the
live logits at (B, chunk, V) and lets remat discard them between chunks.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def _softcap(x, cap):
    if cap and cap > 0:
        return cap * jnp.tanh(x / cap)
    return x


def chunked_cross_entropy(hidden: jnp.ndarray, embed: jnp.ndarray,
                          labels: jnp.ndarray, mask: jnp.ndarray,
                          *, logit_softcap: float = 0.0,
                          chunk: int = 512, unroll: bool = False) -> jnp.ndarray:
    """hidden: (B, S, D); embed: (V, D) tied head; labels/mask: (B, S).

    ``unroll`` is the cost-accounting mode (see ModelConfig.cost_unroll).
    """
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    if S % chunk:                      # fall back to one chunk if ragged
        chunk = S
    n = S // chunk
    h = hidden.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    y = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    m = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        hs, ys, ms = xs
        logits = jnp.einsum("bsd,vd->bsv", hs.astype(jnp.float32),
                            embed.astype(jnp.float32))
        logits = _softcap(logits, logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ys[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * ms
        return (carry[0] + nll.sum(), carry[1] + ms.sum()), None

    body = jax.checkpoint(body)
    init = (jnp.float32(0.0), jnp.float32(0.0))
    if unroll:
        carry = init
        for i in range(n):
            carry, _ = body(carry, (h[i], y[i], m[i]))
        total, count = carry
    else:
        (total, count), _ = jax.lax.scan(body, init, (h, y, m))
    return total / jnp.maximum(count, 1.0)
