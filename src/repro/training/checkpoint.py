"""Sharded, atomic, async-capable checkpointing with elastic restore.

Layout (one directory per step):
    step_000100.tmp/ → fsync → rename → step_000100/
        manifest.json            treedef, shapes, dtypes, mesh, step
        shard_<host>_<i>.npz     this host's addressable shards

Restore rebuilds arrays via ``jax.make_array_from_callback`` against the
*target* sharding — which may live on a different mesh than the one that
wrote the checkpoint (elastic resharding: N-way DP → M-way DP), since every
callback reads exactly the slice it needs from the full saved arrays.

On this single-host container every shard lands in one npz; the pathways
(per-host shard files, atomic rename, async writer thread) are the
production mechanisms.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _key_str(i: int) -> str:
    return f"leaf_{i:05d}"


def save(ckpt_dir: str, step: int, tree: Any, *,
         host_id: int = 0) -> Path:
    """Synchronous sharded save with atomic rename."""
    leaves, treedef = _flatten(tree)
    final = Path(ckpt_dir) / f"step_{step:08d}"
    tmp = Path(str(final) + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    arrays: Dict[str, np.ndarray] = {}
    for i, leaf in enumerate(leaves):
        # device_get assembles this host's addressable view; on multi-host
        # each host saves only its addressable shards.
        arrays[_key_str(i)] = np.asarray(jax.device_get(leaf))
    np.savez(tmp / f"shard_{host_id:04d}_0.npz", **arrays)

    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "shapes": [list(np.shape(a)) for a in arrays.values()],
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot on the main thread (device_get), write in the background —
    the training loop overlaps the next step with checkpoint I/O."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, snapshot), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    root = Path(ckpt_dir)
    if not root.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in root.glob("step_*")
             if not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, *, step: Optional[int] = None,
            target: Optional[Any] = None,
            shardings: Optional[Any] = None) -> Tuple[int, Any]:
    """Restore a checkpoint, optionally resharding onto ``shardings``.

    ``target`` (a pytree of arrays/ShapeDtypeStructs) supplies the treedef;
    without it the saved treedef is used.  With ``shardings`` each leaf is
    materialised shard-by-shard on the (possibly different) target mesh.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    final = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((final / "manifest.json").read_text())
    data: Dict[str, np.ndarray] = {}
    for shard_file in sorted(final.glob("shard_*.npz")):
        with np.load(shard_file) as z:
            for k in z.files:
                data[k] = z[k]
    leaves = [data[_key_str(i)] for i in range(manifest["n_leaves"])]

    if target is not None:
        treedef = jax.tree_util.tree_structure(target)
    else:
        treedef = jax.tree_util.tree_structure(
            jax.tree_util.tree_unflatten(
                jax.tree_util.TreeDef.deserialize_using_proto(
                    bytes.fromhex(manifest["treedef"])),
                [0] * manifest["n_leaves"]))
        treedef = jax.tree_util.TreeDef.deserialize_using_proto(
            bytes.fromhex(manifest["treedef"]))

    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "addressable_devices"))
        out = []
        for arr, sh in zip(leaves, sh_leaves):
            out.append(jax.make_array_from_callback(
                arr.shape, sh, lambda idx, a=arr: a[idx]))
        leaves = out
    return step, jax.tree_util.tree_unflatten(treedef, leaves)


def cleanup(ckpt_dir: str, keep: int = 3) -> None:
    """Retain only the newest ``keep`` checkpoints (GC for long runs)."""
    root = Path(ckpt_dir)
    if not root.exists():
        return
    steps = sorted(p for p in root.glob("step_*") if not p.name.endswith(".tmp"))
    for p in steps[:-keep]:
        shutil.rmtree(p)
