"""Fault tolerance for long training runs.

``TrainingRunner`` wraps the step loop with:
  · periodic async checkpoints + retention GC,
  · crash/restart recovery (resume from latest, elastic resharding),
  · a straggler monitor — per-host step-time EWMA; hosts slower than
    ``threshold ×`` the fleet median are flagged, and the runner's policy
    hook decides (log / shrink mesh / re-dispatch) exactly like the
    leader's queue-aware dispatch does for benchmark jobs,
  · failure injection for tests (deterministic, per-step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.training import checkpoint as ckpt_lib


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA per-host step times; flags hosts above threshold × median."""
    n_hosts: int
    alpha: float = 0.2
    threshold: float = 1.5

    def __post_init__(self):
        self.ewma = np.zeros(self.n_hosts)

    def record(self, host_times: List[float]) -> List[int]:
        t = np.asarray(host_times, dtype=float)
        self.ewma = np.where(self.ewma == 0, t,
                             (1 - self.alpha) * self.ewma + self.alpha * t)
        med = float(np.median(self.ewma))
        if med <= 0:
            return []
        return [i for i, v in enumerate(self.ewma)
                if v > self.threshold * med]


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    max_steps: int = 200
    n_hosts: int = 1
    fail_at_step: Optional[int] = None     # failure injection (once)
    async_ckpt: bool = True


class TrainingRunner:
    """Checkpoint/restart training driver.

    step_fn(state, step) -> (state, metrics); state is any pytree.
    """

    def __init__(self, cfg: RunnerConfig, step_fn: Callable,
                 init_state_fn: Callable[[], Any],
                 shardings: Optional[Any] = None,
                 on_straggler: Optional[Callable[[List[int]], None]] = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.init_state_fn = init_state_fn
        self.shardings = shardings
        self.monitor = StragglerMonitor(cfg.n_hosts)
        self.on_straggler = on_straggler or (lambda hosts: None)
        self.ckpt = ckpt_lib.AsyncCheckpointer(cfg.ckpt_dir)
        self._failed_once = False
        self.metrics_log: List[Dict] = []

    # ---- recovery ---------------------------------------------------------
    def _load_or_init(self):
        last = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if last is None:
            return 0, self.init_state_fn()
        step, state = ckpt_lib.restore(
            self.cfg.ckpt_dir, step=last,
            target=self.init_state_fn() if self.shardings is None else None,
            shardings=self.shardings)
        return step, state

    # ---- main loop ----------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        start_step, state = self._load_or_init()
        restarts = 0
        step = start_step
        while step < self.cfg.max_steps:
            try:
                if (self.cfg.fail_at_step is not None
                        and step == self.cfg.fail_at_step
                        and not self._failed_once):
                    self._failed_once = True
                    raise SimulatedFailure(f"injected failure at step {step}")
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, step)
                dt = time.perf_counter() - t0
                stragglers = self.monitor.record(
                    [dt] * self.cfg.n_hosts)  # single-host: uniform
                if stragglers:
                    self.on_straggler(stragglers)
                step += 1
                self.metrics_log.append(dict(metrics, step=step, dt=dt))
                if step % self.cfg.ckpt_every == 0:
                    if self.cfg.async_ckpt:
                        self.ckpt.save(step, state)
                    else:
                        ckpt_lib.save(self.cfg.ckpt_dir, step, state)
                    ckpt_lib.cleanup(self.cfg.ckpt_dir, keep=self.cfg.keep)
            except SimulatedFailure:
                # crash/restart path: reload the latest durable checkpoint
                self.ckpt.wait()
                restarts += 1
                step, state = self._load_or_init()
        self.ckpt.wait()
        ckpt_lib.save(self.cfg.ckpt_dir, step, state)
        return {"final_step": step, "restarts": restarts,
                "metrics": self.metrics_log}
