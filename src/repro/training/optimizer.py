"""AdamW with warmup+cosine schedule and global-norm clipping (pure JAX).

Optimizer state carries the same pytree structure as the params, so the
sharding specs derived from the model's logical axes apply verbatim
(ZeRO-style: m/v are sharded at least as finely as the weights).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * step / max(cfg.warmup_steps, 1)
    decay_steps = max(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.learning_rate * cos)


def init_opt_state(params: Any) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_axes(param_axes: Any) -> Dict:
    """Logical axes for the optimizer state (same layout as params)."""
    return {"m": param_axes, "v": param_axes, "step": ()}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: OptimizerConfig, grads: Any, opt_state: Dict,
                 params: Any) -> Tuple[Any, Dict, Dict]:
    """One AdamW step → (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
