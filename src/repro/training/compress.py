"""Gradient compression for cross-pod data parallelism.

int8 symmetric quantization with per-leaf scales and an error-feedback
buffer (residual accumulation), the standard trick for pushing gradient
all-reduce bytes down ~4× on slow inter-pod links.  ``compressed_psum``
does the actual int8 wire-format reduce inside ``shard_map``; the
quantize/dequantize pair + error feedback is usable standalone inside any
train step (hillclimb option for the collective-bound train cells).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads_with_feedback(grads: Any, error: Optional[Any]
                                 ) -> Tuple[Any, Any]:
    """Quantize→dequantize each leaf, carrying the residual into the next
    step (error feedback keeps the compression unbiased over time)."""
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = quantize(g)
        deq = dequantize(q, s)
        return deq, g - deq

    out = jax.tree.map(one, grads, error)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_err


def compressed_psum(g: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-on-the-wire gradient all-reduce (use under shard_map).

    Quantize locally, all-reduce the int8 payload widened to int32 (sum of
    ≤ world int8 values fits), then dequantize with the max scale.
    """
    q, scale = quantize(g)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    max_scale = jax.lax.pmax(scale, axis_name)
    return total.astype(jnp.float32) * max_scale
